"""Config system for the SCBF reproduction framework.

Everything is a frozen dataclass so configs are hashable, comparable and
usable as jit static arguments.  Architectures register themselves into
``repro.configs.ARCHS`` (see ``repro/configs/__init__.py``); input shapes
and meshes are defined here because they are shared across architectures.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Mapping, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Architecture
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArchConfig:
    """A single architecture, as assigned from the public pool.

    ``family`` is one of dense | moe | ssm | hybrid | audio | vlm | mlp.
    Fields default to "off" so dense configs stay short.
    """

    name: str
    family: str
    source: str                      # citation (arXiv / model card)

    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # --- attention flavour ---
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0       # chatglm3: 0.5 (2d RoPE on half the dims)
    sliding_window: int = 0          # 0 = full attention
    attention_every: int = 1         # jamba: 8 -> 1 attention layer per 8
    cross_attn_every: int = 0        # llama-3.2-vision: 5

    # --- MLA (DeepSeek-V2) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1               # apply MoE every k-th layer
    first_dense_layers: int = 0      # deepseek: first layer is dense
    moe_capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # --- encoder / decoder ---
    encoder_layers: int = 0          # whisper: 24
    encoder_seq: int = 1500          # whisper frame count after conv stub

    # --- modality frontend stubs ---
    frontend: str = "none"           # none | audio | vision
    num_patch_tokens: int = 1024     # vision stub patch count

    # --- plain-MLP family (the paper's own model) ---
    mlp_features: Tuple[int, ...] = ()   # e.g. (2917, 256, 64, 1)

    # --- misc ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    activation: str = "silu"         # silu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def num_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_decode_natively(self) -> bool:
        """Sub-quadratic decode without the sliding-window variant."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        if self.family == "mlp":
            n = 0
            for fin, fout in zip(self.mlp_features[:-1], self.mlp_features[1:]):
                n += fin * fout + fout
            return n
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                 # unembed
        for layer in range(L):
            n += self._layer_params(layer)
        if self.encoder_layers:
            for layer in range(self.encoder_layers):
                n += self._enc_layer_params()
        n += d                                        # final norm
        return n

    def _attn_params(self) -> int:
        d, H, KV, hd = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim
        if self.use_mla:
            r, rd = self.kv_lora_rank, self.qk_rope_dim
            n = d * H * (hd + rd)                    # q proj (nope+rope)
            n += d * (r + rd)                        # kv down (+ shared k_rope)
            n += r * H * (hd + hd)                   # kv up (k_nope + v)
            n += H * hd * d                          # out
            return n
        n = d * H * hd + 2 * d * KV * hd + H * hd * d
        if self.qkv_bias:
            n += H * hd + 2 * KV * hd
        return n

    def _mlp_params(self, d_ff: int) -> int:
        return 3 * self.d_model * d_ff               # gated (wi, wg, wo)

    def _is_moe_layer(self, layer: int) -> bool:
        if not self.num_experts:
            return False
        if layer < self.first_dense_layers:
            return False
        return (layer % self.moe_every) == (self.moe_every - 1) \
            if self.moe_every > 1 else True

    def _is_attn_layer(self, layer: int) -> bool:
        if self.family == "ssm":
            return False
        if self.attention_every > 1:
            return (layer % self.attention_every) == (self.attention_every - 1)
        return True

    def _layer_params(self, layer: int) -> int:
        d = self.d_model
        n = 2 * d                                    # two norms
        if self._is_attn_layer(layer):
            n += self._attn_params()
        elif self.family in ("ssm", "hybrid"):
            di, s = self.d_inner, self.ssm_state
            nh = di // self.ssm_head_dim
            n += d * (2 * di + 2 * s + nh)           # in_proj (x,z,B,C,dt)
            n += self.ssm_conv_width * (di + 2 * s)  # conv
            n += nh * 2                              # A_log, D
            n += di * d                              # out_proj
        if self.cross_attn_every and (layer % self.cross_attn_every
                                      == self.cross_attn_every - 1):
            n += self._attn_params() + d
        if self._is_moe_layer(layer):
            n += self.num_experts * self._mlp_params(self.d_ff)
            n += self.num_shared_experts * self._mlp_params(self.d_ff)
            n += d * self.num_experts                # router
        else:
            n += self._mlp_params(self.d_ff)
        return n

    def _enc_layer_params(self) -> int:
        return 2 * self.d_model + self._attn_params() + self._mlp_params(self.d_ff)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.num_experts:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        n = self.param_count()
        for layer in range(L):
            if self._is_moe_layer(layer):
                inactive = self.num_experts - self.experts_per_token
                n -= inactive * self._mlp_params(self.d_ff)
        return n


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


INPUT_SHAPES: Mapping[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


# ---------------------------------------------------------------------------
# SCBF / training config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ScbfConfig:
    """The paper's hyper-parameters (§2.1, Algorithm 1)."""

    upload_rate: float = 0.10        # alpha — fraction of channels uploaded
    selection: str = "positive"      # positive | negative (paper §2.1)
    num_clients: int = 5             # paper §2.2
    # pruning (SCBFwP)
    prune: bool = False
    prune_rate: float = 0.10         # theta — fraction pruned per loop
    prune_total: float = 0.47        # theta_total
    # how a pruned neuron is removed (repro.core.pruning):
    #   reshape  host-side slicing between loops — physically smaller
    #            models immediately, but every step recompiles every
    #            jitted program and the fused round loop cannot run
    #   mask     static-shape keep-masks — geometry stays run-constant
    #            (no recompiles, fused-path compatible, scbf only);
    #            with prune_compact the model is sliced down ONCE when
    #            the cumulative budget is exhausted
    prune_impl: str = "reshape"      # reshape | mask
    # mask mode: compact physically (one extra compile) the moment
    # pruning completes, so flops/bytes shrink for the rest of the run
    prune_compact: bool = True
    # scale-out knobs (beyond paper)
    factored: bool = True            # factored channel scores for big models
    compressed_exchange: bool = False  # top-k gather exchange across pods
    score_norm: bool = False         # per-layer score normalisation
    # differential privacy on the upload path (paper §4 future work):
    # Gaussian mechanism on the masked delta before wire encoding.
    dp_noise_multiplier: float = 0.0  # 0 = off; sigma = nm * dp_clip_norm
    dp_clip_norm: float = 1.0        # L2 clip bound S on the masked delta
    dp_delta: float = 1e-5           # delta of the reported (eps, delta)
    dp_accountant: str = "rdp"       # rdp (Gaussian RDP curve) | classic
    # subsampled-Gaussian privacy amplification (sync sampling only):
    # compose the Mironov et al. 2019 subsampled-RDP curve over rounds
    # with q = per-round inclusion probability.  Refused under fedbuff
    # (participation there is not an i.i.d. per-round sample) and under
    # the classic accountant (amplification is an RDP analysis).
    dp_amplification: bool = False


@dataclass(frozen=True)
class ClockConfig:
    """Simulated wall-clock model (repro.fed.clock.SimClock).

    Per-client compute/network latency distributions plus a diurnal
    availability trace, all a pure function of (seed, round, attempt):
    client k's median compute time is ``compute_med_s`` scaled by a
    lognormal per-client speed trait (``hetero_sigma``), with per-round
    lognormal jitter (``compute_sigma``); network time composes the
    same way.  When enabled, the sync scheduler replaces its coin-flip
    straggler model with deadline-based cohort cuts: the round deadline
    is the ``deadline_quantile`` of the cohort's latencies and misses
    either drop or spill into the FedBuff buffer with clock-derived
    staleness (``deadline_action``).
    """

    enabled: bool = False
    compute_med_s: float = 10.0      # median local-training seconds
    compute_sigma: float = 0.25      # per-round lognormal jitter (compute)
    hetero_sigma: float = 0.6        # per-client speed spread (lognormal)
    net_med_s: float = 2.0           # median upload/network seconds
    net_sigma: float = 0.5           # per-round lognormal jitter (network)
    deadline_quantile: float = 0.9   # server waits for this cohort quantile
    deadline_action: str = "drop"    # drop | spill (into the FedBuff buffer)
    # diurnal churn: availability oscillates over the simulated day with
    # a per-client phase (timezone); amplitude 0 = always-on clients
    availability_mean: float = 1.0
    diurnal_amplitude: float = 0.0
    day_s: float = 86400.0
    round_gap_s: float = 0.0         # fixed server overhead between rounds


@dataclass(frozen=True)
class FaultConfig:
    """Seeded fault injection (repro.fed.faults.FaultInjector).

    Every rate is per sampled participant per round; outcomes are a
    pure function of (seed, round, attempt, client) so any fault trace
    replays deterministically from its seed.  ``bitflip``/``nan``/
    ``poison`` are mutually exclusive per client (their rates must sum
    to <= 1).  Transient network failures retry with exponential
    backoff (``net_backoff_s * 2^i``) up to ``net_retries`` times
    before the upload is lost.
    """

    enabled: bool = False
    seed: int = 0                    # fault-trace seed (independent of run)
    crash_rate: float = 0.0          # P(client crashes mid-round, no upload)
    net_fail_rate: float = 0.0       # P(one send attempt fails)
    net_retries: int = 3             # client retries before giving up
    net_backoff_s: float = 1.0       # backoff base (doubles per retry)
    duplicate_rate: float = 0.0      # P(payload is replayed to the server)
    bitflip_rate: float = 0.0        # P(one wire bit flips post-seal)
    nan_rate: float = 0.0            # P(client update is NaN/Inf)
    poison_rate: float = 0.0         # P(client ships a norm-inflated update)
    poison_scale: float = 16.0       # poisoned norm = scale * norm bound


@dataclass(frozen=True)
class FedConfig:
    """Cross-device federation scenario knobs (repro.fed).

    The seed orchestrator hard-wired 5 always-on clients in a Python
    loop; these knobs describe the cross-device regimes the federation
    engine simulates: cohort sampling, dropout/stragglers, buffered
    async (FedBuff-style), non-IID hospital silos, and (clock/faults)
    chaos-hardened operation under a simulated wall-clock fault model.
    """

    engine: str = "batched"          # batched (vmapped cohort) | sequential
    # --- fused round execution (fed/engine fused chunks) ---
    # fuse_rounds = S > 1 runs S consecutive sync rounds as ONE jitted
    # lax.scan — train → delta → select → DP → on-device aggregation —
    # with no host round-trip inside the chunk.  Reshape-mode pruning
    # and fedbuff fall back to the per-round path (reshape changes
    # shapes mid-run; fedbuff needs per-round server feedback) while
    # mask-mode pruning (ScbfConfig.prune_impl="mask") runs fused;
    # evaluation coarsens to chunk boundaries (docs/FED_ENGINE.md
    # §Fused round loop / §Pruning on the fused path).
    fuse_rounds: int = 1             # 1 = today's per-round behaviour
    # --- bucketed participant padding (amortise recompiles under
    #     varying per-round P — fed/cohort.bucket_size) ---
    bucket: str = "pow2"             # pow2 (O(log K) compiles) | exact
    # --- pod-axis cohort sharding (fed/engine.BatchedEngine) ---
    pods: int = 1                    # devices on the "pod" mesh axis; 1 = off
    # --- per-round client sampling (sync mode) ---
    sample_fraction: float = 1.0     # fraction of clients invited per round
    dropout_rate: float = 0.0        # P(sampled client never reports back)
    straggler_rate: float = 0.0      # P(client is slow this round)
    drop_stragglers: bool = True     # sync: stragglers miss the deadline
    # --- round scheduling mode ---
    mode: str = "sync"               # sync | fedbuff (buffered async)
    buffer_size: int = 10            # fedbuff: server applies every B uploads
    concurrency: int = 20            # fedbuff: max clients training at once
    staleness_exponent: float = 0.5  # fedbuff weight = (1+tau)^-gamma
    server_lr: float = 1.0           # fedbuff server step on the buffer mean
    # --- data partition across clients ---
    partition: str = "iid"           # iid (equal shards) | dirichlet
    dirichlet_alpha: float = 0.5     # label-skew concentration (lower=worse)
    # --- chaos hardening (repro.fed.clock / repro.fed.faults) ---
    clock: ClockConfig = field(default_factory=ClockConfig)
    faults: FaultConfig = field(default_factory=FaultConfig)
    # server-side admission control (repro.fed.strategy): structural
    # validation, checksum verification and nonfinite rejection are
    # always on; the norm gate turns on with max_update_norm > 0
    max_update_norm: float = 0.0     # L2 bound on an admitted update; 0=off
    norm_action: str = "reject"      # reject | clip (scale into the bound)
    # round-level quorum: fewer than this many participants expected to
    # survive validation triggers a bounded re-plan of the round with
    # backoff instead of stepping on garbage (0 = no quorum)
    min_valid_participants: int = 0
    round_retries: int = 2           # re-plans per round on a quorum miss
    retry_backoff_s: float = 30.0    # simulated wait before each re-plan


@dataclass(frozen=True)
class ObsConfig:
    """Flight-recorder knobs (repro.obs, docs/OBSERVABILITY.md).

    ``device_metrics`` forces on-device per-round telemetry (loss /
    selected channels / wire bytes accumulated inside the engine
    programs) even without an active recorder; with a recorder active
    (``obs.trace.recording``) collection turns on automatically.
    ``annotate`` wraps fused chunk dispatches in
    ``jax.profiler.TraceAnnotation`` while recording, so device
    profiles line up with the host event log.
    """

    device_metrics: bool = False
    annotate: bool = True


@dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "sgd"           # sgd | adam | adamw
    learning_rate: float = 1e-3
    lr_schedule: str = "constant"    # constant | cosine (per global loop)
    weight_decay: float = 0.0
    momentum: float = 0.0
    global_loops: int = 30
    # evaluate AUCROC/AUCPR every N loops (plus always the final loop);
    # non-evaluated loops carry the last-known metrics with
    # LoopRecord.evaluated = False.  Fused execution additionally
    # restricts evaluation to chunk boundaries.
    eval_every: int = 1
    local_epochs: int = 1
    local_batch_size: int = 256
    seed: int = 0
    remat: bool = True
    # debug runs: finite/validity assertions on params and round
    # metrics at chunk boundaries (the SL006-class dynamic net).
    # Host-side checks on already-offloaded values, so the traced
    # program is byte-identical with the flag on or off.
    debug_checks: bool = False
    scbf: ScbfConfig = field(default_factory=ScbfConfig)
    fed: FedConfig = field(default_factory=FedConfig)
    obs: ObsConfig = field(default_factory=ObsConfig)


# ---------------------------------------------------------------------------
# Mesh
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self) -> Tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


# TPU v5e hardware constants for the roofline analysis.
@dataclass(frozen=True)
class HardwareConfig:
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link
    hbm_bytes: float = 16e9          # HBM capacity per chip


HARDWARE = HardwareConfig()


def replace(cfg, **kw):
    """dataclasses.replace that works through our frozen configs."""
    return dataclasses.replace(cfg, **kw)
