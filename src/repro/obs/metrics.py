"""On-device telemetry for the federated engines — ``MetricsCarry``.

The fused round loop (repro.fed.engine) runs whole chunks of rounds as
one jitted ``lax.scan`` with zero host crossings inside; the price was
that per-round observables (train loss, selected channels, upload
bytes) were invisible without breaking fusion.  This module computes
them **inside the trace**: each cohort slot contributes a small typed
pytree, slots reduce to per-round sums, the scan stacks rounds along
the leading axis, and ONE ``jax.device_get`` at the chunk boundary
(``offload``) brings the whole chunk's telemetry to the host — the same
transfer discipline as the payload emission, proven clean under
``jax.transfer_guard`` in tests/test_obs.py.

Byte accounting mirrors ``repro.comm.wire`` exactly: per leaf the three
codec costs (coo / bitmap / dense, in ``wire.CODECS`` order) are
evaluated on the nonzero count of the masked delta and the cheapest
wins, with ``argmin``'s first-minimum tie-break matching ``min()`` over
the same tuple order — so the device numbers equal the encoded payload
bytes bit-for-bit (cross-checked against ``Payload.nbytes`` in tests).
Mask-mode SCBFwP emission compacts payloads to the effective geometry;
``effective_leaf_sizes`` reproduces those sizes host-side so the device
math prices the compacted encoding (nonzero counts are unaffected:
pruned coordinates are exactly zero by construction).

Everything here is f32/i32 scalar work per leaf — a few hundred flops
next to a round's training matmuls — which is what keeps the measured
telemetry overhead on the fused path under the docs/OBSERVABILITY.md
budget.
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import wire
from repro.obs import trace

_ITEMSIZE = 4                      # masked deltas travel as f32


class MetricsCarry(NamedTuple):
    """Per-round SCBF telemetry, accumulated on device.

    All fields are *sums over valid slots* (padding contributes exact
    zeros), so an (S,)-stacked carry offloads as raw per-round totals
    and the host derives means (``offload`` divides loss by
    participants).  ``selected`` is ``(L,)`` — selected channels per
    layer; ``codec_bytes`` is ``(3,)`` in ``wire.CODECS`` order.
    """

    loss_sum: jnp.ndarray          # f32 scalar — Σ valid-slot train loss
    participants: jnp.ndarray      # i32 scalar — valid slots this round
    selected: jnp.ndarray          # (L,) i32 — Σ selected channels/layer
    sparse_bytes: jnp.ndarray      # i32 — Σ cheapest-codec wire bytes
    codec_bytes: jnp.ndarray       # (3,) i32 — bytes by winning codec


class FedAvgMetrics(NamedTuple):
    """FedAvg's slimmer carry: dense uploads have no codec/selection."""

    loss_sum: jnp.ndarray          # f32 scalar
    participants: jnp.ndarray      # i32 scalar


def leaf_codec_costs(nnz, sizes):
    """(3, n_leaves) codec cost matrix, rows in ``wire.CODECS`` order.

    The formulas are ``wire.coo_bytes`` / ``bitmap_bytes`` /
    ``dense_bytes`` transcribed to i32 array math; any edit there must
    land here too (pinned by the bytes cross-check in tests/test_obs).
    """
    coo = nnz * (wire.INDEX_BYTES + _ITEMSIZE)
    bitmap = (sizes + 7) // 8 + nnz * _ITEMSIZE
    dense = sizes * _ITEMSIZE
    return jnp.stack([coo, bitmap, dense])


def slot_metrics(loss, masked, masks, v, eff_sizes=None) -> MetricsCarry:
    """One cohort slot's telemetry, traced inside the engine pass.

    ``masked``/``masks`` arrive already validity-zeroed by the engine
    (padding slots have all-zero deltas and all-false masks), so the
    byte and channel counts need no extra gating — an invalid slot's
    nnz is 0, coo wins at 0 bytes, and every sum field contributes
    nothing.  Only ``loss`` (computed before the zeroing) is gated by
    ``v`` here.  ``eff_sizes`` is the (n_leaves,) effective-geometry
    size vector (mask-mode SCBFwP; ``None`` prices full leaf sizes,
    folded in as trace-time constants).
    """
    leaves = jax.tree_util.tree_leaves(tuple(masked))
    nnz = jnp.stack([jnp.count_nonzero(lf).astype(jnp.int32)
                     for lf in leaves])
    if eff_sizes is None:
        sizes = jnp.asarray([int(np.prod(lf.shape)) for lf in leaves],
                            jnp.int32)
    else:
        sizes = eff_sizes.astype(jnp.int32)
    costs = leaf_codec_costs(nnz, sizes)
    cheapest = jnp.min(costs, axis=0)
    # first minimum == wire.cheapest_bytes' min() over CODECS order
    winner = jnp.argmin(costs, axis=0)
    per_codec = jnp.stack([
        jnp.sum(jnp.where(winner == c, cheapest, 0))
        for c in range(len(wire.CODECS))])
    sel = []
    for layer in masks:
        b = layer.get("b")
        if b is not None:
            sel.append(jnp.sum(b.astype(jnp.int32)))
        else:
            # bias-free layer: a channel is selected iff any of its
            # edges is (the mask column is all-true or all-false only
            # for the input layer, so reduce with any, not all)
            sel.append(jnp.sum(jnp.any(layer["w"], axis=0)
                               .astype(jnp.int32)))
    return MetricsCarry(
        loss_sum=jnp.where(v, loss, 0.0).astype(jnp.float32),
        participants=v.astype(jnp.int32),
        selected=jnp.stack(sel),
        sparse_bytes=jnp.sum(cheapest),
        codec_bytes=per_codec)


def reduce_slots(slot_stacked):
    """Sum a (B,)-stacked slot carry down to one per-round carry."""
    return jax.tree_util.tree_map(lambda a: jnp.sum(a, axis=0),
                                  slot_stacked)


def effective_leaf_sizes(params: Sequence[dict],
                         keep: Optional[Sequence[np.ndarray]] = None
                         ) -> np.ndarray:
    """Host (n_leaves,) int32 — leaf sizes after emission compaction.

    Mirrors ``fed.engine._compact_layers`` geometry: hidden layer l
    keeps ``len(keep[l])`` neurons, so layer l's weight is
    (kept_{l-1}, kept_l) and its bias (kept_l,), with the input and
    output dimensions never compacted.  ``keep=None`` returns the full
    sizes.  Leaf order is jax's dict flatten order (sorted keys: "b"
    before "w" per layer), matching ``tree_leaves`` of the masked
    delta; ``None`` entries (bias-free layers) produce no leaf.
    """
    last = len(params) - 1
    sizes: List[int] = []
    for l, layer in enumerate(params):
        rows = int(np.shape(layer["w"])[0]) if l == 0 or keep is None \
            else len(keep[l - 1])
        cols = int(np.shape(layer["w"])[1]) if l == last or keep is None \
            else len(keep[l])
        for k in sorted(layer.keys()):
            if layer[k] is None:
                continue
            if k == "w":
                sizes.append(rows * cols)
            elif k == "b":
                sizes.append(cols)
            else:
                sizes.append(int(np.prod(np.shape(layer[k]))))
    return np.asarray(sizes, np.int32)


def offload(carry, rounds: Optional[int] = None
            ) -> Union[Dict[str, Any], List[Dict[str, Any]]]:
    """THE device→host transfer for a chunk's telemetry.

    One ``jax.device_get`` of the whole stacked carry — called at chunk
    boundaries only, never inside the fused scan (the transfer-guard
    tests pin this).  ``rounds=None`` converts a single round's carry;
    an integer trims an (S,)-stacked carry to its real (non-padding)
    rounds.  Returns plain-python dicts ready for the event log:
    ``train_loss`` is the per-participant mean, ``codec_bytes`` keys by
    ``wire.CODECS`` name.
    """
    host = jax.device_get(carry)
    trace.count("host_offloads")
    fields = host._asdict() if hasattr(host, "_asdict") else dict(host)

    def row(r: Optional[int]) -> Dict[str, Any]:
        def pick(name):
            a = np.asarray(fields[name])
            return a if r is None else a[r]

        p = int(pick("participants"))
        out: Dict[str, Any] = {
            "participants": p,
            "train_loss": float(pick("loss_sum")) / max(p, 1),
        }
        if "selected" in fields:
            out["selected"] = [int(s) for s in np.atleast_1d(
                pick("selected"))]
        if "sparse_bytes" in fields:
            out["sparse_bytes"] = int(pick("sparse_bytes"))
        if "codec_bytes" in fields:
            out["codec_bytes"] = {
                c: int(b) for c, b in zip(wire.CODECS,
                                          np.atleast_1d(pick("codec_bytes")))}
        return out

    if rounds is None:
        return row(None)
    return [row(r) for r in range(rounds)]
