"""Flight recorder for the federated engine (docs/OBSERVABILITY.md).

Three layers:

* ``repro.obs.trace``   — host spans + the JSONL run event log
  (``with span("encode"): ...``, ``recording(path)``, Perfetto export);
* ``repro.obs.metrics`` — ``MetricsCarry``, the on-device per-round
  telemetry pytree threaded through the fused scan and offloaded only
  at chunk boundaries;
* ``repro.obs.report``  — ``python -m repro.obs.report events.jsonl``,
  per-round tables and the machine-readable summary the benches and
  the CI perf gate consume.
"""
from repro.obs.trace import (EMITTER, EVENT_SCHEMA, Recorder, count, event,
                             get_recorder, recording, span, to_chrome_trace)

__all__ = ["EMITTER", "EVENT_SCHEMA", "Recorder", "count", "event",
           "get_recorder", "recording", "span", "to_chrome_trace"]
