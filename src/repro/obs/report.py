"""Run-report pipeline — render an events.jsonl into tables + summaries.

The flight recorder (repro.obs.trace) writes one JSONL event stream per
run.  This module is its consumer:

    PYTHONPATH=src python -m repro.obs.report events.jsonl
    PYTHONPATH=src python -m repro.obs.report events.jsonl \
        --json-out report.json --trace-out trace.json

* the per-round table (loss, participants, bytes, ε trajectory, prune
  timeline) prints to stdout;
* ``--json-out`` writes ``summarize()``'s machine-readable summary —
  the same structure ``benchmarks/bench_fed_engine.py --json-out``
  embeds and ``benchmarks/check_fed_regression.py`` gates on, so the
  CI perf gate reads exactly the telemetry users see;
* ``--trace-out`` writes the Chrome/Perfetto trace-event export
  (load at ui.perfetto.dev or chrome://tracing).

``read_events`` refuses streams whose leading ``meta`` event carries a
different schema version than this reader understands — a versioned
contract, not a KeyError (docs/OBSERVABILITY.md §Event schema).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional

from repro.obs.trace import EMITTER, EVENT_SCHEMA, to_chrome_trace


def read_events(path: str) -> List[Dict[str, Any]]:
    """Load an events.jsonl, validating the schema handshake."""
    events = []
    with open(path) as fh:
        for ln, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{ln}: not JSONL ({e})") from e
    if not events or events[0].get("ev") != "meta":
        raise ValueError(
            f"{path}: not a repro.obs event log — the first line must be "
            "the 'meta' event (was the file produced by obs.trace?)")
    schema = events[0].get("schema")
    if schema != EVENT_SCHEMA:
        raise ValueError(
            f"{path}: event schema {schema!r} != supported {EVENT_SCHEMA} "
            f"(emitter {events[0].get('emitter')!r}, reader {EMITTER}); "
            "re-record with a matching repro.obs version instead of "
            "guessing at field meanings")
    return events


def _span_summary(events: List[Dict[str, Any]]) -> Dict[str, Dict]:
    spans: Dict[str, Dict] = {}
    for e in events:
        if e.get("ev") != "span":
            continue
        s = spans.setdefault(e.get("name", "?"),
                             {"count": 0, "total_s": 0.0, "max_s": 0.0})
        d = float(e.get("dur", 0.0))
        s["count"] += 1
        s["total_s"] = round(s["total_s"] + d, 6)
        s["max_s"] = round(max(s["max_s"], d), 6)
    return spans


def _chaos_summary(events: List[Dict[str, Any]]) -> Optional[Dict]:
    """Fault-model aggregates, or None on a fault-free stream.

    ``fault_injected`` / ``payload_rejected`` / ``round_retried`` /
    ``quorum_miss`` are the chaos event kinds (docs/FED_ENGINE.md
    §Fault model & resilience); additive on EVENT_SCHEMA 1, so
    fault-free logs summarize exactly as before.
    """
    faults: Dict[str, int] = {}
    rejects: Dict[str, int] = {}
    retries = quorum_misses = 0
    for e in events:
        ev = e.get("ev")
        if ev == "fault_injected":
            k = e.get("fault", "?")
            faults[k] = faults.get(k, 0) + 1
        elif ev == "payload_rejected":
            r = e.get("reason", "?")
            rejects[r] = rejects.get(r, 0) + 1
        elif ev == "round_retried":
            retries += 1
        elif ev == "quorum_miss":
            quorum_misses += 1
    if not (faults or rejects or retries or quorum_misses):
        return None
    return {"faults_injected": faults, "payloads_rejected": rejects,
            "rounds_retried": retries, "quorum_misses": quorum_misses}


def summarize(events: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Machine-readable run summary (the benches/CI-gate contract).

    Totals come from the ``round`` events; span aggregates from the
    ``span`` events; compile watchdogs from ``run_end``.  Works on
    engine-only streams too (no ``round`` events → zero totals, spans
    still aggregated) — the bench's telemetry section uses that.
    """
    meta = events[0] if events and events[0].get("ev") == "meta" else {}
    rounds = [e for e in events if e.get("ev") == "round"]
    prunes = [e for e in events if e.get("ev") == "prune"]
    run_end = next((e for e in reversed(events)
                    if e.get("ev") == "run_end"), {})

    total_sparse = sum(int(e.get("sparse_bytes", 0)) for e in rounds)
    total_dense = sum(int(e.get("dense_bytes", 0)) for e in rounds)
    codec: Dict[str, int] = {}
    losses = []
    eps = None
    for e in rounds:
        for c, b in (e.get("codec_bytes") or {}).items():
            codec[c] = codec.get(c, 0) + int(b)
        if e.get("train_loss") is not None and e.get("participants"):
            losses.append(float(e["train_loss"]))
        if e.get("epsilon") is not None:
            eps = float(e["epsilon"])
    wall = sum(float(e.get("wall", 0.0)) for e in rounds)
    return {
        "schema": meta.get("schema", EVENT_SCHEMA),
        "emitter": meta.get("emitter", EMITTER),
        "rounds": len(rounds),
        "total_sparse_bytes": total_sparse,
        "total_dense_bytes": total_dense,
        "codec_bytes": codec,
        "mean_train_loss": (sum(losses) / len(losses)) if losses else None,
        "final_train_loss": losses[-1] if losses else None,
        "final_epsilon": eps,
        "round_wall_s": round(wall, 6),
        "rounds_per_s": round(len(rounds) / wall, 3) if wall > 0 else None,
        "wall_is_amortized": any(e.get("wall_is_amortized")
                                 for e in rounds),
        "prune_steps": len(prunes),
        "hidden_final": rounds[-1].get("hidden") if rounds else None,
        "compiles": {k: run_end[k] for k in ("scbf_compiles",
                                             "fused_compiles")
                     if k in run_end},
        "host_offloads": run_end.get("host_offloads"),
        "spans": _span_summary(events),
        "chaos": _chaos_summary(events),
    }


def per_round_table(events: List[Dict[str, Any]]) -> str:
    """The human-facing per-round table."""
    rounds = [e for e in events if e.get("ev") == "round"]
    if not rounds:
        return "(no round events)"
    hdr = (f"{'loop':>4} {'P':>4} {'loss':>9} {'sel_bytes':>10} "
           f"{'codec':>7} {'eps':>8} {'keep':>5} {'stale':>6} "
           f"{'wall_s':>8}")
    lines = [hdr, "-" * len(hdr)]
    for e in rounds:
        loss = e.get("train_loss")
        cb = e.get("codec_bytes") or {}
        dominant = max(cb, key=cb.get) if any(cb.values()) else "-"
        epsv = e.get("epsilon")
        wall = float(e.get("wall", 0.0))
        lines.append(
            f"{e.get('loop', -1):>4} {e.get('participants', 0):>4} "
            + (f"{loss:>9.4f}" if loss is not None else f"{'-':>9}")
            + f" {e.get('sparse_bytes', 0):>10} {dominant:>7} "
            + (f"{epsv:>8.3f}" if epsv is not None else f"{'-':>8}")
            + f" {e.get('keep_density', 1.0):>5.2f} "
            f"{e.get('staleness_mean', 0.0):>6.2f} "
            + f"{wall:>7.3f}{'~' if e.get('wall_is_amortized') else ' '}")
    lines.append("(wall '~' = chunk-amortized: chunk wall / rounds, "
                 "not a per-round measurement)")
    for e in events:
        if e.get("ev") == "prune":
            lines.append(f"prune @ loop {e.get('loop')}: "
                         f"hidden -> {e.get('hidden')}")
        elif e.get("ev") == "compact":
            lines.append(f"compact @ loop {e.get('loop')}: "
                         f"hidden {e.get('hidden')} now physical")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Render a repro.obs events.jsonl into a per-round "
                    "table, a machine-readable summary, and a "
                    "Chrome/Perfetto trace export.")
    ap.add_argument("events", help="events.jsonl written by obs.trace")
    ap.add_argument("--json-out", default=None,
                    help="write summarize() as JSON")
    ap.add_argument("--trace-out", default=None,
                    help="write the Chrome trace-event export (load at "
                         "ui.perfetto.dev)")
    ap.add_argument("--no-table", action="store_true",
                    help="skip the stdout per-round table")
    args = ap.parse_args(argv)

    try:
        events = read_events(args.events)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 1

    if not args.no_table:
        print(per_round_table(events))
    summary = summarize(events)
    if args.json_out:
        with open(args.json_out, "w") as fh:
            json.dump(summary, fh, indent=1)
        print(f"wrote {args.json_out}")
    if args.trace_out:
        with open(args.trace_out, "w") as fh:
            json.dump(to_chrome_trace(events), fh)
        print(f"wrote {args.trace_out} (open at ui.perfetto.dev)")
    if not args.no_table:
        sp = summary["spans"]
        if sp:
            print("spans: " + "; ".join(
                f"{k}×{v['count']} {v['total_s']:.3f}s"
                for k, v in sorted(sp.items())))
        ch = summary["chaos"]
        if ch:
            fi = "; ".join(f"{k}×{v}" for k, v in
                           sorted(ch["faults_injected"].items()))
            rj = "; ".join(f"{k}×{v}" for k, v in
                           sorted(ch["payloads_rejected"].items()))
            print(f"chaos: injected [{fi or '-'}] rejected [{rj or '-'}] "
                  f"retries={ch['rounds_retried']} "
                  f"quorum_misses={ch['quorum_misses']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
