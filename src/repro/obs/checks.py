"""Runtime finite/validity checks for debug runs (``debug_checks``).

The static gate (shapelint, ``docs/STATIC_ANALYSIS.md`` §Shape lint)
holds the padding/mask discipline at review time; this module is its
*dynamic* counterpart for the escapes static analysis cannot see —
an SL006-class nonfinite (inf/nan from an all-masked round, an
unguarded denominator through an opaque call) or a validity-mask
bug that corrupts the aggregated parameters.

Design: the checks run **host-side at chunk boundaries**, on values
the training loop has already offloaded (parameters after a chunk of
fused rounds, the per-round metric records).  Nothing is inserted
into the traced program — with ``TrainConfig.debug_checks`` on or
off, the jitted computation is byte-identical, which is what makes
the parity contract trivial to test and keeps the checks off the
hot path (one extra ``device_get`` per chunk, not per round).

``verify_round`` raises :class:`DebugCheckError` with the offending
leaf path, the breakdown (nan/inf count), and the boundary label, so
a poisoned run fails at the *first* corrupted chunk instead of
surfacing as a quietly wrong AUC at the end.
"""
from __future__ import annotations

from typing import Any, Iterable, Optional

import jax
import numpy as np


class DebugCheckError(AssertionError):
    """A finite/validity assertion failed at a chunk boundary."""


def _leaf_label(path) -> str:
    out = []
    for p in path:
        key = getattr(p, "key", None)
        if key is None:
            key = getattr(p, "idx", None)
        if key is None:
            key = getattr(p, "name", None)
        out.append(str(key) if key is not None else str(p))
    return "/".join(out) or "<root>"


def _check_leaf(label: str, leaf: Any, where: str) -> None:
    arr = np.asarray(jax.device_get(leaf))
    if not np.issubdtype(arr.dtype, np.floating):
        return
    finite = np.isfinite(arr)
    if finite.all():
        return
    bad = arr[~finite]
    n_nan = int(np.count_nonzero(np.isnan(bad)))
    n_inf = bad.size - n_nan
    raise DebugCheckError(
        f"debug_checks: non-finite values at {where}: leaf '{label}' "
        f"has {n_nan} nan / {n_inf} inf of {arr.size} elements "
        f"(dtype {arr.dtype}) — an SL006-class escape; check masked "
        "denominators and guards on the aggregation path")


def check_finite(tree: Any, *, where: str) -> None:
    """Assert every floating leaf of ``tree`` is finite."""
    if tree is None:
        return
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        _check_leaf(_leaf_label(path), leaf, where)


def check_participants(count: Any, p_count: Optional[int], *,
                       where: str) -> None:
    """Assert the masked participant tally matches the live count.

    ``Σvalid`` disagreeing with ``p_count`` means a validity mask was
    widened or narrowed somewhere between padding and aggregation —
    the exact bug class SL001/SL002 guard statically.
    """
    if count is None or p_count is None:
        return
    got = int(np.asarray(jax.device_get(count)))
    if got != int(p_count):
        raise DebugCheckError(
            f"debug_checks: participant accounting skew at {where}: "
            f"Σvalid = {got} but the cohort has {p_count} live "
            "slot(s) — a validity mask was corrupted between padding "
            "and aggregation")


def verify_round(params: Any, metrics: Any = None, *,
                 where: str,
                 p_count: Optional[int] = None,
                 participants: Any = None) -> None:
    """One chunk-boundary verification: params + metrics finite, and
    (when both are known) the participant tally consistent."""
    check_finite(params, where=f"{where} [params]")
    if metrics is not None:
        check_finite(metrics, where=f"{where} [metrics]")
    check_participants(participants, p_count, where=where)


def verify_records(records: Iterable[Any], *, where: str) -> None:
    """Check the floating fields of host-side loop records."""
    for i, rec in enumerate(records):
        for name in ("loss", "auc_roc", "auc_pr", "train_loss"):
            v = getattr(rec, name, None)
            if v is None:
                continue
            f = float(v)
            if f != f or f in (float("inf"), float("-inf")):
                raise DebugCheckError(
                    f"debug_checks: non-finite record field "
                    f"'{name}'={f} at {where} (record {i})")
