"""Host-side flight recorder — spans, events, and the run event log.

The engine's hot path is a single jitted ``lax.scan`` over whole chunks
of federated rounds (repro.fed.engine): deliberately opaque to Python.
Everything the host *does* observe — round boundaries, chunk dispatch,
wire emission, evaluation, pruning — goes through this module so one
run produces one machine-readable event stream instead of scattered
``time.perf_counter`` pairs and prints.

Three pieces:

``Recorder``
    An append-only event log.  Every event is one JSON-able dict with
    an ``ev`` kind and a monotonic ``ts`` (seconds since the recorder
    started).  ``write()`` dumps the whole log as JSONL — the
    ``events.jsonl`` format ``repro.obs.report`` renders (schema:
    docs/OBSERVABILITY.md, golden-tested in tests/test_obs.py).

``recording(...)`` / ``get_recorder()``
    The ambient-recorder contract: instrumentation calls ``event()`` /
    ``span()`` unconditionally, and they no-op (cheaply — one global
    read) when no recorder is active.  The driver, the engines and the
    benchmarks never need a recorder argument threaded through them.

``span(name)``
    A timed region.  ``elapsed`` is always measured (two
    ``perf_counter`` calls) so callers can use the span as their one
    wall-clock source whether or not a recorder is active — this is
    what replaced the hand-rolled timing blocks in ``core/scbf.py``.
    With ``annotate=True`` and an active recorder the region is also
    wrapped in ``jax.profiler.TraceAnnotation`` so device profiles
    (``jax.profiler.trace``) show the same names as the event log.

Everything here is host-only code: no jax arrays are touched, so the
module is trivially TL002/TL006-clean (docs/STATIC_ANALYSIS.md).
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Any, Dict, Iterable, List, Optional

# Version of the events.jsonl format, written into every log's leading
# ``meta`` event and checked by repro.obs.report.  Bump on any
# backwards-incompatible change to event kinds or required fields.
EVENT_SCHEMA = 1

EMITTER = f"repro.obs/{EVENT_SCHEMA}"


class Span:
    """One timed region.  ``elapsed`` is valid after the block exits."""

    __slots__ = ("name", "attrs", "t0", "elapsed")

    def __init__(self, name: str, attrs: Dict[str, Any]):
        self.name = name
        self.attrs = attrs
        self.t0 = time.perf_counter()
        self.elapsed = 0.0

    def stop(self) -> float:
        self.elapsed = time.perf_counter() - self.t0
        return self.elapsed


class Recorder:
    """Append-only run event log with span/counter bookkeeping.

    ``path`` (optional) is where ``write()`` — and ``recording()`` on
    exit — dumps the JSONL stream.  Counters accumulate watchdog-style
    totals (events, spans, host offloads, compile deltas) that the
    driver folds into ``RunResult.telemetry`` at run end.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.events: List[Dict[str, Any]] = []
        self.counters: Dict[str, int] = {"events": 0, "spans": 0,
                                         "host_offloads": 0}
        self._t0 = time.perf_counter()
        self.events.append({"ev": "meta", "ts": 0.0,
                            "schema": EVENT_SCHEMA, "emitter": EMITTER})

    # ------------------------------------------------------------------
    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def event(self, kind: str, **fields) -> Dict[str, Any]:
        e = {"ev": kind, "ts": round(self._now(), 6), **fields}
        self.events.append(e)
        self.counters["events"] += 1
        return e

    def count(self, name: str, n: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        sp = Span(name, attrs)
        self.counters["spans"] += 1
        try:
            yield sp
        finally:
            sp.stop()
            self.event("span", name=name, dur=round(sp.elapsed, 6), **attrs)

    # ------------------------------------------------------------------
    def write(self, path: Optional[str] = None) -> str:
        """Dump the log as JSONL; returns the path written."""
        out = path or self.path
        if not out:
            raise ValueError("no output path: pass one to write() or to "
                             "the Recorder/recording() constructor")
        with open(out, "w") as fh:
            for e in self.events:
                fh.write(json.dumps(e) + "\n")
        return out


class _NullSpan(Span):
    """Span without an attached recorder — timing only."""


# The ambient recorder stack.  Plain module state, not a contextvar: the
# federated driver is single-threaded host code, and nesting (a bench
# recording around a run_federated recording) is LIFO by construction.
_STACK: List[Recorder] = []


def get_recorder() -> Optional[Recorder]:
    """The active recorder, or None when not recording."""
    return _STACK[-1] if _STACK else None


@contextlib.contextmanager
def recording(path: Optional[str] = None,
              recorder: Optional[Recorder] = None):
    """Activate a recorder for the block; write JSONL on exit if it has
    a path.  Yields the recorder."""
    rec = recorder if recorder is not None else Recorder(path)
    if path is not None and rec.path is None:
        rec.path = path
    _STACK.append(rec)
    try:
        yield rec
    finally:
        _STACK.pop()
        if rec.path:
            rec.write()


def event(kind: str, **fields) -> None:
    """Record an event on the active recorder; no-op when not recording."""
    rec = get_recorder()
    if rec is not None:
        rec.event(kind, **fields)


def count(name: str, n: int = 1) -> None:
    """Bump a watchdog counter on the active recorder (no-op inactive)."""
    rec = get_recorder()
    if rec is not None:
        rec.count(name, n)


@contextlib.contextmanager
def span(name: str, annotate: bool = False, **attrs):
    """Timed region: always measures, records when a recorder is active.

    ``annotate=True`` additionally wraps the region in
    ``jax.profiler.TraceAnnotation`` (recorder active only, so the
    default un-recorded path stays free of any jax call) — the fused
    chunk dispatches carry this so device profiles line up with the
    event log.
    """
    rec = get_recorder()
    if rec is None:
        sp = _NullSpan(name, attrs)
        try:
            yield sp
        finally:
            sp.stop()
        return
    if annotate:
        import jax.profiler
        with jax.profiler.TraceAnnotation(name):
            with rec.span(name, **attrs) as sp:
                yield sp
    else:
        with rec.span(name, **attrs) as sp:
            yield sp


# ---------------------------------------------------------------------------
# Chrome / Perfetto trace-event export
# ---------------------------------------------------------------------------

def to_chrome_trace(events: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Render an event stream as a Chrome trace-event JSON object.

    Loadable in Perfetto (ui.perfetto.dev) or chrome://tracing: spans
    become complete ('X') slices on one host track, everything else an
    instant ('i') event, timestamps in microseconds.  ``span`` events
    carry their end time in ``ts`` (they are emitted when the region
    closes), so the slice start is ``ts - dur``.
    """
    trace_events: List[Dict[str, Any]] = []
    for e in events:
        kind = e.get("ev")
        if kind == "meta":
            continue
        ts_us = float(e.get("ts", 0.0)) * 1e6
        args = {k: v for k, v in e.items() if k not in ("ev", "ts", "dur",
                                                        "name")}
        if kind == "span":
            dur_us = float(e.get("dur", 0.0)) * 1e6
            trace_events.append({
                "name": e.get("name", "span"), "ph": "X", "cat": "host",
                "ts": ts_us - dur_us, "dur": dur_us,
                "pid": 0, "tid": 0, "args": args})
        else:
            trace_events.append({
                "name": kind, "ph": "i", "s": "t", "cat": "event",
                "ts": ts_us, "pid": 0, "tid": 0, "args": args})
    return {"traceEvents": trace_events,
            "displayTimeUnit": "ms",
            "otherData": {"emitter": EMITTER}}
