"""Pytree checkpointing to a single .npz (host-side, flat key paths).

Good enough for the federated experiments and examples; keys are
'/'-joined tree paths, dtypes/shapes round-trip exactly.
"""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or "bfloat16" in str(arr.dtype):
            arr = arr.astype(np.float32)      # bf16 -> f32 (lossless)
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    flat = _flatten(tree)
    flat["__step__"] = np.asarray(step)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp.npz"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)


def load_checkpoint(path: str, like: Any):
    """Restore into the structure of ``like``; returns (tree, step)."""
    data = np.load(path)
    step = int(data["__step__"]) if "__step__" in data else 0
    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    import jax.numpy as jnp
    for path, leaf in paths_and_leaves:
        key = "/".join(_path_str(p) for p in path)
        arr = data[key]
        if hasattr(leaf, "dtype"):
            leaves.append(jnp.asarray(arr).astype(leaf.dtype))
        else:
            leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step
