"""Pluggable server aggregation — ``ServerState``-carrying strategies.

The seed hard-wired two update rules inside the orchestrator loop
(sum-of-masked-deltas for SCBF, plain mean for FedAvg).  Strategies
make the server side a value: ``aggregate(state, contribution)`` maps
one round's client uploads to a new ``ServerState``, so schedulers and
engines compose with any aggregation rule.

``scbf_sum``   W ← W + Σ_k ΔW̃_k — the paper's Algorithm 1, applied via
               ``comm.wire.apply_payloads`` (no K dense deltas).
``fedavg``     W ← Σ_k (n_k/n) W_k — example-weighted McMahan mean
               (equal shards reduce to the seed's plain mean).
``fedbuff``    buffered async: decoded deltas are weighted by
               (1+τ)^−γ (τ = staleness, γ = ``staleness_exponent``) and
               accumulated; once ``buffer_size`` uploads are buffered
               the server steps by ``server_lr`` × the buffer mean and
               bumps its version.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import wire
from repro.config import FedConfig, ScbfConfig
from repro.core import server
from repro.obs import trace as obstrace


# ---------------------------------------------------------------------------
# Pure on-device reducers — the fused execution path's server step.
#
# The stateful strategies below decode wire payloads on the host; a
# fused chunk (repro.fed.engine) keeps whole rounds on device, so its
# scan body needs the same aggregation rules as pure stacked-array
# reducers with NO wire decode on the hot path.  Wire encoding still
# happens — off the critical path, from the chunk's returned stacked
# deltas — so repro.comm.wire stays the single source of truth for
# upload-byte accounting.
# ---------------------------------------------------------------------------

def scbf_sum_step(params, stacked_deltas, neuron_masks=None):
    """W ← W + Σ_b ΔW̃_b over the slot axis of a ``(B, ...)`` stack.

    Accumulates the deltas *delta-first in slot order* via a
    ``lax.scan`` (not a tree reduction), then adds the total to the
    parameters once — exactly the accumulation ``wire.apply_payloads``
    performs (zero-init scatter in client order, one add into W), which
    is what keeps the fused and per-round trajectories bit-identical.
    Invalid slots arrive already zeroed by the engine's validity mask,
    and ``x + 0.0`` is a bitwise no-op, so padding (including
    fully-empty rounds) passes the carry through untouched.

    ``neuron_masks`` (mask-mode SCBFwP): per-hidden-layer keep-masks.
    Client deltas at pruned coordinates are exactly zero by
    construction (zero gradients through the mask, channel selection
    excludes pruned edges), and zeroing the accumulated total there
    turns that invariant into a structural guarantee: the server's
    pruned coordinates stay bit-frozen no matter what a client ships.
    """
    zero = jax.tree_util.tree_map(
        lambda ref: jnp.zeros(ref.shape, jnp.float32), params)

    def add_slot(acc, delta):
        return jax.tree_util.tree_map(
            lambda a, d: a + d.astype(jnp.float32), acc, delta), None

    total, _ = jax.lax.scan(add_slot, zero, stacked_deltas)
    if neuron_masks is not None:
        total = _mask_total(total, neuron_masks)
    return jax.tree_util.tree_map(
        lambda p, t: (p.astype(jnp.float32) + t).astype(p.dtype),
        params, total)


def _mask_total(total, neuron_masks):
    """Zero a summed delta pytree at pruned coordinates.

    Layer l's weight columns and bias mask by keep_l (its output
    neurons) and its weight rows by keep_{l-1} (its input neurons);
    the output layer masks rows only.  Kept coordinates multiply by
    1.0 — a bitwise no-op — so the fused trajectory stays exactly the
    per-round one.
    """
    out = []
    n = len(total)
    for l, layer in enumerate(total):
        row = neuron_masks[l - 1][:, None] if l > 0 else 1.0
        col = neuron_masks[l][None, :] if l < n - 1 else 1.0
        new = {"w": layer["w"] * row * col}
        if "b" in layer:
            new["b"] = layer["b"] * (neuron_masks[l] if l < n - 1 else 1.0)
        out.append(new)
    return tuple(out)


def fedavg_step(params, stacked_params, weights):
    """W ← Σ_b w_b W_b over the slot axis (McMahan example weighting).

    ``weights`` is the ``(B,)`` normalised weight vector with exact
    zeros on invalid slots; accumulation runs in slot order to mirror
    ``core.server.fedavg_update``.  A round with no valid slot (all
    weights zero) returns ``params`` unchanged, matching the per-round
    strategy's skip of empty contributions.
    """
    zero = jax.tree_util.tree_map(
        lambda ref: jnp.zeros(ref.shape, jnp.float32), params)

    def add_slot(acc, wp):
        w, p = wp
        return jax.tree_util.tree_map(
            lambda a, x: a + x.astype(jnp.float32) * w, acc, p), None

    acc, _ = jax.lax.scan(add_slot, zero, (weights, stacked_params))
    any_valid = jnp.sum(weights) > 0
    return jax.tree_util.tree_map(
        lambda a, ref: jnp.where(any_valid, a,
                                 ref.astype(jnp.float32)).astype(ref.dtype),
        acc, params)


@dataclass
class ServerState:
    params: Any                      # current global model
    version: int = 0                 # bumps on every applied update
    buffer_sum: Any = None           # fedbuff: Σ weighted decoded deltas
    buffer_count: int = 0            # fedbuff: uploads buffered so far
    # (client, round) nonces of every payload already accepted past the
    # dedup gate — a replayed upload hits its nonce and is rejected
    seen_nonces: Set[Tuple[int, int]] = field(default_factory=set)


@dataclass
class RoundContribution:
    """Everything one round's participants handed to the server."""

    num_examples: np.ndarray                   # (P,) shard sizes
    staleness: np.ndarray                      # (P,) server-version lag
    payloads: Optional[List[wire.Payload]] = None   # sparse scbf uploads
    client_params: Optional[List[Any]] = None  # per-client full weights
    # client ids aligned to the lists above (telemetry on rejection)
    clients: Optional[np.ndarray] = None
    # mask-mode SCBFwP ships effective-geometry payloads whose checksums
    # seal the bytes actually on the wire; the server stores full
    # geometry, so admission runs on the wire artifacts FIRST and this
    # callback remaps the admitted survivors to full geometry
    # (repro.core.pruning.expand_payloads) just before application
    expand: Optional[Callable[[List[wire.Payload]],
                              List[wire.Payload]]] = None


# ---------------------------------------------------------------------------
# Server-side admission control
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AdmissionPolicy:
    """What the server refuses to fold into the model.

    Structural validation and checksum verification are always part of
    the gate; ``max_update_norm`` bounds an admitted update's L2 norm
    (0 = unbounded) with ``norm_action`` deciding whether an oversized
    update is rejected outright or scaled down into the bound
    ("clip").  Rejected updates are excluded from the aggregation
    denominator entirely — a poisoned cohort shrinks, it does not
    dilute.
    """

    max_update_norm: float = 0.0
    norm_action: str = "reject"

    def __post_init__(self):
        if self.norm_action not in ("reject", "clip"):
            raise ValueError(f"unknown norm_action "
                             f"{self.norm_action!r}; reject|clip")
        if self.max_update_norm < 0:
            raise ValueError(f"max_update_norm must be >= 0, got "
                             f"{self.max_update_norm}")


def _payload_finite(p: wire.Payload) -> bool:
    return all(bool(np.isfinite(np.asarray(lp.values)).all())
               for lp in p.layers)


def _payload_norm(p: wire.Payload) -> float:
    return float(np.sqrt(sum(
        float(np.sum(np.square(np.asarray(lp.values), dtype=np.float64)))
        for lp in p.layers)))


def _scale_payload(p: wire.Payload, s: float) -> wire.Payload:
    layers = tuple(dataclasses.replace(
        lp, values=(np.asarray(lp.values) * s).astype(lp.values.dtype))
        for lp in p.layers)
    return dataclasses.replace(p, layers=layers)


def _reject(p: wire.Payload, i: int, contrib: RoundContribution,
            reason: str) -> None:
    meta = p.meta
    client = meta.client_id if meta is not None else (
        int(contrib.clients[i]) if contrib.clients is not None
        and i < len(contrib.clients) else None)
    obstrace.event("payload_rejected", reason=reason, client=client,
                   round=meta.round_index if meta is not None else None)
    obstrace.count("payloads_rejected")
    obstrace.count(f"rejected_{reason}")


def admit_payloads(state: ServerState, contrib: RoundContribution,
                   policy: AdmissionPolicy
                   ) -> Tuple[List[wire.Payload], List[int]]:
    """The server's admission gate, in rejection-precedence order:
    structural validation ("malformed") → checksum ("checksum") →
    (client, round) nonce dedup ("duplicate") → nonfinite values
    ("nonfinite") → L2 norm bound ("norm", rejected or clipped into
    the bound).  Returns the admitted payloads (clipped where
    applicable) and their indices into ``contrib.payloads``; every
    rejection emits a ``payload_rejected`` event and bumps counters.
    """
    kept: List[wire.Payload] = []
    kept_idx: List[int] = []
    for i, p in enumerate(contrib.payloads):
        try:
            wire.validate_payload(p)
        except wire.PayloadError:
            _reject(p, i, contrib, "malformed")
            continue
        if not wire.verify_checksum(p):
            _reject(p, i, contrib, "checksum")
            continue
        if p.meta is not None:
            nonce = p.meta.nonce
            if nonce in state.seen_nonces:
                _reject(p, i, contrib, "duplicate")
                continue
            # recorded once the payload passes dedup (even if a later
            # gate rejects it): a replay of a rejected upload is still
            # a replay
            state.seen_nonces.add(nonce)
        if not _payload_finite(p):
            _reject(p, i, contrib, "nonfinite")
            continue
        if policy.max_update_norm > 0:
            norm = _payload_norm(p)
            if norm > policy.max_update_norm:
                if policy.norm_action == "reject":
                    _reject(p, i, contrib, "norm")
                    continue
                p = _scale_payload(p, policy.max_update_norm / norm)
                obstrace.count("payloads_clipped")
        kept.append(p)
        kept_idx.append(i)
    return kept, kept_idx


class ScbfSum:
    """The paper's server rule: sum the sparse masked deltas in place.

    With an ``AdmissionPolicy`` attached, payloads pass the admission
    gate first and only the survivors are applied; a round with no
    admitted payload leaves the state (and version) untouched.  Without
    a policy the fault-free hot path is exactly the pre-admission code.
    """

    name = "scbf_sum"

    def __init__(self, policy: Optional[AdmissionPolicy] = None):
        self.policy = policy

    def init(self, params) -> ServerState:
        return ServerState(params=params)

    def aggregate(self, state: ServerState,
                  contrib: RoundContribution) -> ServerState:
        if not contrib.payloads:
            return state
        payloads = contrib.payloads
        if self.policy is not None:
            payloads, _ = admit_payloads(state, contrib, self.policy)
            if not payloads:
                return state
        if contrib.expand is not None:
            payloads = contrib.expand(payloads)
        params = wire.apply_payloads(state.params, payloads)
        return dataclasses.replace(state, params=params,
                                   version=state.version + 1)


class FedAvg:
    """Example-weighted weight averaging over the reporting cohort.

    Wraps ``core.server.fedavg_update``, which accumulates one running
    pytree — the K client models are never stacked server-side.
    """

    name = "fedavg"

    def __init__(self, policy: Optional[AdmissionPolicy] = None):
        self.policy = policy

    def init(self, params) -> ServerState:
        return ServerState(params=params)

    def aggregate(self, state: ServerState,
                  contrib: RoundContribution) -> ServerState:
        if not contrib.client_params:
            return state
        client_params = contrib.client_params
        n = contrib.num_examples.astype(np.float64)
        if self.policy is not None:
            # dense uploads have no wire payload to checksum; the value
            # gate still applies — a nonfinite client model must never
            # enter the mean (and would poison every parameter at once)
            keep = []
            for i, cp in enumerate(client_params):
                finite = all(
                    bool(np.isfinite(np.asarray(leaf[k])).all())
                    for leaf in cp for k in leaf)
                if finite:
                    keep.append(i)
                else:
                    client = int(contrib.clients[i]) \
                        if contrib.clients is not None else None
                    obstrace.event("payload_rejected", reason="nonfinite",
                                   client=client, round=None)
                    obstrace.count("payloads_rejected")
                    obstrace.count("rejected_nonfinite")
            if not keep:
                return state
            client_params = [client_params[i] for i in keep]
            n = n[keep]
        params = server.fedavg_update(client_params,
                                      weights=n / n.sum())
        return dataclasses.replace(state, params=params,
                                   version=state.version + 1)


class FedBuff:
    """Staleness-weighted buffered-async aggregation."""

    name = "fedbuff"

    def __init__(self, buffer_size: int = 10,
                 staleness_exponent: float = 0.5, server_lr: float = 1.0,
                 policy: Optional[AdmissionPolicy] = None):
        if buffer_size < 1:
            raise ValueError("buffer_size must be >= 1")
        self.buffer_size = buffer_size
        self.staleness_exponent = staleness_exponent
        self.server_lr = server_lr
        self.policy = policy

    def init(self, params) -> ServerState:
        return ServerState(params=params)

    def staleness_weight(self, staleness) -> float:
        """(1+τ)^−γ — a version-0-fresh upload weighs 1, stale ones less."""
        return float((1.0 + float(staleness)) ** -self.staleness_exponent)

    def aggregate(self, state: ServerState,
                  contrib: RoundContribution) -> ServerState:
        """Fold uploads one at a time, stepping the server *each* time
        the buffer reaches ``buffer_size`` (FedBuff's per-upload
        trigger) — a big round can flush more than once, and trailing
        uploads buffer against the advanced version.  (Their staleness
        was measured at plan time, so within-round trailing uploads are
        under-counted by at most the flushes that round.)
        """
        if not contrib.payloads:
            return state
        if self.policy is not None:
            payloads, kept = admit_payloads(state, contrib, self.policy)
            staleness = np.asarray(contrib.staleness)[kept] \
                if kept else np.zeros(0, np.int64)
        else:
            payloads, staleness = contrib.payloads, contrib.staleness
            # nonfinite guard, always on: the flush divides the buffer
            # by its count, so one NaN delta would poison ServerState
            # forever — reject it before it enters the buffer
            bad = [i for i, p in enumerate(payloads)
                   if not _payload_finite(p)]
            if bad:
                for i in bad:
                    _reject(payloads[i], i, contrib, "nonfinite")
                ok = [i for i in range(len(payloads)) if i not in set(bad)]
                payloads = [payloads[i] for i in ok]
                staleness = np.asarray(staleness)[ok]
        if contrib.expand is not None and payloads:
            payloads = contrib.expand(payloads)
        params, version = state.params, state.version
        buf, count = state.buffer_sum, state.buffer_count
        for payload, tau in zip(payloads, staleness):
            delta = wire.decode(payload)
            wgt = self.staleness_weight(tau)
            scaled = jax.tree_util.tree_map(
                lambda d: d.astype(jnp.float32) * wgt, delta)
            buf = scaled if buf is None else jax.tree_util.tree_map(
                jnp.add, buf, scaled)
            count += 1
            if count >= self.buffer_size:
                step = self.server_lr / count
                params = jax.tree_util.tree_map(
                    lambda p, b: (p.astype(jnp.float32)
                                  + step * b).astype(p.dtype),
                    params, buf)
                version += 1
                obstrace.event("fedbuff_flush", version=version,
                               buffered=count)
                buf, count = None, 0
        return dataclasses.replace(state, params=params, version=version,
                                   buffer_sum=buf, buffer_count=count)


def make_strategy(method: str, scbf_cfg: ScbfConfig, fed_cfg: FedConfig,
                  policy: Optional[AdmissionPolicy] = None):
    """Strategy for (method, mode): fedbuff wraps the sparse scbf path.

    Sync scheduling with ``clock.deadline_action='spill'`` also routes
    through FedBuff: deadline misses keep training and land in later
    rounds with clock-derived staleness, which is exactly the buffered
    staleness-weighted aggregation problem.
    """
    spill = (fed_cfg.mode == "sync" and fed_cfg.clock.enabled
             and fed_cfg.clock.deadline_action == "spill")
    if fed_cfg.mode == "fedbuff" or spill:
        if method != "scbf":
            # FedBuff.aggregate reads only contrib.payloads; fedavg
            # rounds produce client_params, so the server would
            # silently never update
            raise ValueError(
                ("deadline spilling buffers" if spill
                 else "fedbuff buffers")
                + f" sparse scbf payloads; method={method!r} "
                "produces full client weights the FedBuff strategy would "
                "silently ignore")
        return FedBuff(buffer_size=fed_cfg.buffer_size,
                       staleness_exponent=fed_cfg.staleness_exponent,
                       server_lr=fed_cfg.server_lr, policy=policy)
    if method == "scbf":
        return ScbfSum(policy=policy)
    if method == "fedavg":
        return FedAvg(policy=policy)
    raise ValueError(f"no strategy for method {method!r}")
