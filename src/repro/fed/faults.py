"""Seeded fault injection + the resilient round planner.

Everything that can go wrong between a client being sampled and its
update being admitted, as a pure function of ``(seed, round, attempt,
client)``:

* **crash** — the client dies mid-round; no upload.
* **transient network failure** — a send attempt fails; the client
  retries with exponential backoff (``net_backoff_s · 2^i``) up to
  ``net_retries`` times, then the upload is lost.  Retry delay adds to
  the client's latency, so under the wall-clock model a retried upload
  can still miss the round deadline.
* **duplicate** — the sealed payload is replayed; the server's
  (client, round) nonce dedup rejects the copy.
* **bitflip** — one wire bit flips *after* sealing; the CRC-32
  checksum fails server-side.
* **nan / poison** — the client itself produces a NaN/Inf or
  norm-inflated update *before* sealing (checksum valid!); the
  server's nonfinite / norm admission gates reject it.

``Resilience`` is the planner both driver paths share
(repro.core.scbf): plan → fault outcomes → deadline recheck →
round-level quorum with bounded retry-and-backoff.  Because every
outcome is decided here, host-side, at plan time, the fused (S, B)
path folds faults into its per-slot admit masks with zero extra
compiles — and with everything disabled the planner is a strict
pass-through of ``scheduler.plan``, preserving bit-parity with the
fault-free trace.

Fault decisions and payload corruption are host-side numpy only (no
jax) — tracelint/privlint stay clean by construction.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.comm import wire
from repro.config import FaultConfig, FedConfig
from repro.fed.scheduler import RoundPlan
from repro.obs import trace as obstrace

# hashed-RNG stream tags (see repro.fed.clock: call-order-free draws)
_TAG_FAULTS = 0xFA17
_TAG_CORRUPT = 0xC0FF

# corruption codes, mutually exclusive per client per round
CORRUPT_NONE = 0
CORRUPT_BITFLIP = 1                  # post-seal wire corruption
CORRUPT_NAN = 2                      # client-side nonfinite update
CORRUPT_POISON = 3                   # client-side norm-inflated update
_CORRUPT_KIND = {CORRUPT_BITFLIP: "bitflip", CORRUPT_NAN: "nan",
                 CORRUPT_POISON: "poison"}


@dataclass
class RoundFaults:
    """One (round, attempt)'s fault outcomes, aligned to the sampled
    participants (pre-removal)."""

    participants: np.ndarray         # client ids the outcomes align to
    crashed: np.ndarray              # (P,) bool — died mid-round
    net_lost: np.ndarray             # (P,) bool — every send attempt failed
    net_tries: np.ndarray            # (P,) int — send attempts used (>=1)
    net_delay_s: np.ndarray          # (P,) float — backoff added to latency
    duplicated: np.ndarray           # (P,) bool — payload replayed
    corrupt: np.ndarray              # (P,) int8 CORRUPT_* code

    @property
    def lost(self) -> np.ndarray:
        """(P,) bool — upload never reaches the server."""
        return self.crashed | self.net_lost

    def events(self) -> List[dict]:
        """One dict per injected fault, for ``fault_injected`` events."""
        out = []
        for i, k in enumerate(np.asarray(self.participants)):
            k = int(k)
            if self.crashed[i]:
                out.append({"client": k, "kind": "crash"})
            elif self.net_lost[i]:
                out.append({"client": k, "kind": "net_drop",
                            "tries": int(self.net_tries[i])})
            elif self.net_tries[i] > 1:
                out.append({"client": k, "kind": "net_retry",
                            "tries": int(self.net_tries[i]),
                            "delay_s": round(float(self.net_delay_s[i]), 6)})
            if self.duplicated[i] and not self.lost[i]:
                out.append({"client": k, "kind": "duplicate"})
            code = int(self.corrupt[i])
            if code != CORRUPT_NONE and not self.lost[i]:
                out.append({"client": k, "kind": _CORRUPT_KIND[code]})
        return out


class FaultInjector:
    """Draws per-round fault outcomes from a hashed, seeded RNG."""

    def __init__(self, num_clients: int, cfg: FaultConfig):
        for name in ("crash_rate", "net_fail_rate", "duplicate_rate",
                     "bitflip_rate", "nan_rate", "poison_rate"):
            v = getattr(cfg, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if cfg.bitflip_rate + cfg.nan_rate + cfg.poison_rate > 1.0 + 1e-12:
            raise ValueError(
                "bitflip_rate + nan_rate + poison_rate must be <= 1 "
                "(corruption kinds are mutually exclusive per client)")
        if cfg.net_retries < 0:
            raise ValueError(f"net_retries must be >= 0, got "
                             f"{cfg.net_retries}")
        if cfg.poison_scale <= 1.0:
            raise ValueError(
                f"poison_scale must be > 1 so a poisoned update always "
                f"exceeds the norm bound, got {cfg.poison_scale}")
        self.num_clients = int(num_clients)
        self.cfg = cfg

    def round_faults(self, round_index: int, participants: np.ndarray,
                     attempt: int = 0) -> RoundFaults:
        """Fault outcomes for one (round, attempt) — pure in (seed,
        config, round, attempt, participants); other rounds' draws share
        no state with this one."""
        cfg = self.cfg
        part = np.asarray(participants)
        P = int(part.size)
        r = np.random.default_rng(
            [cfg.seed, _TAG_FAULTS, int(round_index), int(attempt)])
        # one (K,) draw per fault axis, indexed by client id: outcomes
        # depend on WHO was sampled, not on cohort size or order
        crash_u = r.random(self.num_clients)
        net_u = r.random((self.num_clients, cfg.net_retries + 1))
        dup_u = r.random(self.num_clients)
        cor_u = r.random(self.num_clients)

        crashed = crash_u[part] < cfg.crash_rate
        fails = net_u[part] < cfg.net_fail_rate
        net_lost = fails.all(axis=1) if P else np.zeros(0, bool)
        # attempts used = index of first success + 1 (all = retries+1)
        first_ok = np.where(net_lost, fails.shape[1] - 1,
                            np.argmin(fails, axis=1)) if P \
            else np.zeros(0, np.int64)
        net_tries = first_ok + 1
        # exponential backoff before each retry: sum_{i<k} base * 2^i
        net_delay = cfg.net_backoff_s * (2.0 ** first_ok - 1.0) \
            if P else np.zeros(0)
        duplicated = dup_u[part] < cfg.duplicate_rate
        u = cor_u[part]
        corrupt = np.zeros(P, np.int8)
        b, n = cfg.bitflip_rate, cfg.nan_rate
        corrupt[u < b] = CORRUPT_BITFLIP
        corrupt[(u >= b) & (u < b + n)] = CORRUPT_NAN
        corrupt[(u >= b + n) & (u < b + n + cfg.poison_rate)] = \
            CORRUPT_POISON
        return RoundFaults(participants=part, crashed=crashed,
                           net_lost=net_lost, net_tries=net_tries,
                           net_delay_s=net_delay, duplicated=duplicated,
                           corrupt=corrupt)


# ---------------------------------------------------------------------------
# Payload corruption — host-side mutation of the wire artifacts
# ---------------------------------------------------------------------------

def _slot_rng(seed: int, round_index: int, attempt: int, slot: int
              ) -> np.random.Generator:
    return np.random.default_rng(
        [seed, _TAG_CORRUPT, int(round_index), int(attempt), int(slot)])


def _force_malformed(payload: wire.Payload) -> wire.Payload:
    """Fallback corruption for a payload with no value bytes to touch:
    bump a declared nnz so structural validation rejects it."""
    lp = payload.layers[0]
    layers = (dataclasses.replace(lp, nnz=lp.size + 1),) \
        + payload.layers[1:]
    return dataclasses.replace(payload, layers=layers)


def _replace_values(payload: wire.Payload, new_values: List[np.ndarray]
                    ) -> wire.Payload:
    layers = tuple(dataclasses.replace(lp, values=v)
                   for lp, v in zip(payload.layers, new_values))
    return dataclasses.replace(payload, layers=layers)


def corrupt_client_payload(payload: wire.Payload, code: int,
                           rng: np.random.Generator, norm_bound: float,
                           poison_scale: float) -> wire.Payload:
    """Apply a *client-side* fault (pre-seal: checksum will be valid).

    nan: one transmitted value becomes NaN — the server's nonfinite
    gate must catch it.  poison: values are rescaled so the update's
    L2 norm is ``poison_scale`` times the norm bound (or the raw scale
    when no bound is configured) — guaranteed to exceed an active
    reject-mode norm gate, which is what lets the fused path decide
    the admit mask at plan time.
    """
    values = [np.asarray(lp.values) for lp in payload.layers]
    total = sum(v.size for v in values)
    if total == 0:
        return _force_malformed(payload)
    if code == CORRUPT_NAN:
        pos = int(rng.integers(total))
        out = []
        for v in values:
            if 0 <= pos < v.size:
                v = v.copy()
                v[pos] = np.nan
            pos -= v.size
            out.append(v)
        return _replace_values(payload, out)
    if code == CORRUPT_POISON:
        target = poison_scale * (norm_bound if norm_bound > 0 else 1.0)
        cur = float(np.sqrt(sum(
            float(np.sum(np.square(v, dtype=np.float64))) for v in values)))
        if cur > 0:
            s = target / cur
            return _replace_values(
                payload, [(v * s).astype(v.dtype) for v in values])
        c = target / np.sqrt(total)
        return _replace_values(
            payload, [np.full_like(v, c) for v in values])
    raise ValueError(f"not a client-side corruption code: {code}")


def corrupt_wire_payload(payload: wire.Payload,
                         rng: np.random.Generator) -> wire.Payload:
    """Flip one random bit of the sealed payload's buffers (values,
    indices or bitmap) — the CRC-32 checksum catches any single-bit
    flip, so the server must reject this payload."""
    bufs = []                        # (layer_i, field, nbytes)
    for i, lp in enumerate(payload.layers):
        if lp.values is not None and np.asarray(lp.values).nbytes:
            bufs.append((i, "values", np.asarray(lp.values).nbytes))
        if lp.idx is not None and np.asarray(lp.idx).nbytes:
            bufs.append((i, "idx", np.asarray(lp.idx).nbytes))
        if lp.bitmap is not None and np.asarray(lp.bitmap).nbytes:
            bufs.append((i, "bitmap", np.asarray(lp.bitmap).nbytes))
    total = sum(b for _, _, b in bufs)
    if total == 0:
        return _force_malformed(payload)
    pos = int(rng.integers(total))
    bit = int(rng.integers(8))
    for i, fld, nbytes in bufs:
        if pos < nbytes:
            lp = payload.layers[i]
            buf = np.asarray(getattr(lp, fld)).copy()
            raw = buf.view(np.uint8).reshape(-1)
            raw[pos] ^= np.uint8(1 << bit)
            layers = payload.layers[:i] \
                + (dataclasses.replace(lp, **{fld: buf}),) \
                + payload.layers[i + 1:]
            return dataclasses.replace(payload, layers=layers)
        pos -= nbytes
    raise AssertionError("unreachable: position within total bytes")


def apply_payload_faults(payloads: Sequence[wire.Payload],
                         participants: np.ndarray,
                         corrupt: np.ndarray, duplicated: np.ndarray,
                         round_index: int, attempt: int, cfg: FaultConfig,
                         norm_bound: float
                         ) -> Tuple[List[wire.Payload], List[int]]:
    """The full client→wire fault pipeline for one round's uploads.

    Per slot: client-side corruption (nan/poison) BEFORE sealing, then
    seal with the (client, round) nonce + checksum, then wire-level
    corruption (bitflip) AFTER sealing, then replay (duplicates append
    the same sealed bytes again).  Returns the wire payload list and
    ``dup_src`` — for each appended duplicate, the slot it replays
    (so the caller can extend per-payload metadata arrays to match).
    """
    out: List[wire.Payload] = []
    dup_src: List[int] = []
    for i, p in enumerate(payloads):
        code = int(corrupt[i]) if i < len(corrupt) else CORRUPT_NONE
        rng = _slot_rng(cfg.seed, round_index, attempt, i)
        if code in (CORRUPT_NAN, CORRUPT_POISON):
            p = corrupt_client_payload(p, code, rng, norm_bound,
                                       cfg.poison_scale)
        p = wire.seal(p, int(participants[i]), round_index)
        if code == CORRUPT_BITFLIP:
            p = corrupt_wire_payload(p, rng)
        out.append(p)
    for i in range(len(payloads)):
        if i < len(duplicated) and duplicated[i]:
            out.append(out[i])
            dup_src.append(i)
    return out, dup_src


# ---------------------------------------------------------------------------
# The resilient round planner — shared by both driver paths
# ---------------------------------------------------------------------------

@dataclass
class AdmittedRound:
    """One round's plan after faults, deadline recheck and quorum.

    ``plan.participants`` are the clients whose uploads ARRIVE (crash /
    net-loss / deadline casualties already removed); ``corrupt`` /
    ``duplicated`` / ``will_reject`` align to them.  ``will_reject`` is
    the plan-time admission prediction the fused path turns into its
    per-slot admit mask — sound because every payload-level fault is
    constructed to fail its server-side gate (see
    ``corrupt_client_payload``).
    """

    plan: RoundPlan
    corrupt: np.ndarray              # (P,) int8 CORRUPT_* per arriver
    duplicated: np.ndarray           # (P,) bool per arriver
    will_reject: np.ndarray          # (P,) bool — planned admission outcome
    quorum_ok: bool = True
    attempts: int = 1                # plan attempts consumed (1 = no retry)
    # arrivers of aborted quorum attempts: they trained and uploaded
    # before the server discarded the round, so their DP releases are
    # real spend the driver must still count
    aborted_arrivers: List[np.ndarray] = field(default_factory=list)

    @property
    def expected_valid(self) -> int:
        return int(np.count_nonzero(~self.will_reject))

    def admit_mask(self) -> np.ndarray:
        """(P,) bool — slots the server will fold into the model."""
        if not self.quorum_ok:
            return np.zeros(self.plan.participants.size, dtype=bool)
        return ~self.will_reject


def _restrict_plan(plan: RoundPlan, keep: np.ndarray,
                   to_dropped: bool) -> RoundPlan:
    """Remove participants where ``~keep``; casualties are folded into
    the plan's dropped (crash/net loss) or stragglers (deadline miss)
    telemetry."""
    removed = plan.participants[~keep]
    kw = dict(participants=plan.participants[keep],
              staleness=plan.staleness[keep])
    if plan.latency_s is not None:
        kw["latency_s"] = plan.latency_s[keep]
    if to_dropped:
        kw["dropped"] = np.sort(np.concatenate([plan.dropped, removed]))
    else:
        kw["stragglers"] = np.sort(np.concatenate([plan.stragglers,
                                                   removed]))
    return dataclasses.replace(plan, **kw)


class Resilience:
    """plan → faults → deadline recheck → quorum retry, in one place.

    With the clock, injector and quorum all off this is a strict
    pass-through of ``scheduler.plan(loop, version)`` — the fault-free
    trace is bit-identical by construction.  Both the per-round loop
    and the fused pre-planner call ``plan_round`` in the same sequence,
    so the two paths see identical participation, faults and clock
    state however rounds are chunked.
    """

    def __init__(self, scheduler, clock, injector: Optional[FaultInjector],
                 fed: FedConfig):
        self.scheduler = scheduler
        self.clock = clock
        self.injector = injector
        self.fed = fed
        self.norm_rejects = (fed.max_update_norm > 0
                             and fed.norm_action == "reject")

    @property
    def active(self) -> bool:
        return (self.injector is not None or self.clock is not None
                or self.fed.min_valid_participants > 0)

    def _will_reject(self, corrupt: np.ndarray) -> np.ndarray:
        wr = (corrupt == CORRUPT_BITFLIP) | (corrupt == CORRUPT_NAN)
        if self.norm_rejects:
            wr |= corrupt == CORRUPT_POISON
        return wr

    def _attempt(self, loop: int, version: int, attempt: int
                 ) -> Tuple[RoundPlan, np.ndarray, np.ndarray]:
        plan = self.scheduler.plan(loop, version, attempt=attempt)
        P = plan.participants.size
        if self.injector is None:
            return plan, np.zeros(P, np.int8), np.zeros(P, bool)
        rf = self.injector.round_faults(loop, plan.participants, attempt)
        for ev in rf.events():
            fault = ev.pop("kind")
            obstrace.event("fault_injected", loop=loop, attempt=attempt,
                           fault=fault, **ev)
        keep = ~rf.lost
        corrupt, dup, delay = rf.corrupt[keep], rf.duplicated[keep], \
            rf.net_delay_s[keep]
        plan = _restrict_plan(plan, keep, to_dropped=True)
        if plan.deadline_s is not None and plan.latency_s is not None \
                and self.fed.clock.deadline_action == "drop":
            # network-retry backoff delays the upload past the cohort
            # deadline: those clients become deadline casualties too
            # (spill mode instead carries the delay into staleness
            # bookkeeping at the scheduler level and is not re-cut here)
            on_time = (plan.latency_s + delay) <= plan.deadline_s
            if not on_time.all():
                corrupt, dup = corrupt[on_time], dup[on_time]
                plan = _restrict_plan(plan, on_time, to_dropped=False)
        return plan, corrupt, dup

    def plan_round(self, loop: int, version: int) -> AdmittedRound:
        quorum = int(self.fed.min_valid_participants)
        max_attempts = (int(self.fed.round_retries) + 1) if quorum > 0 \
            else 1
        aborted: List[np.ndarray] = []
        for attempt in range(max_attempts):
            plan, corrupt, dup = self._attempt(loop, version, attempt)
            wr = self._will_reject(corrupt)
            valid = int(np.count_nonzero(~wr))
            if quorum <= 0 or valid >= quorum:
                return AdmittedRound(plan=plan, corrupt=corrupt,
                                     duplicated=dup, will_reject=wr,
                                     quorum_ok=True, attempts=attempt + 1,
                                     aborted_arrivers=aborted)
            if attempt < max_attempts - 1:
                obstrace.event("round_retried", loop=loop, attempt=attempt,
                               expected_valid=valid, needed=quorum,
                               backoff_s=float(self.fed.retry_backoff_s))
                obstrace.count("rounds_retried")
                # the aborted cohort trained and uploaded before the
                # server gave up on the attempt — privacy spend is real
                aborted.append(np.asarray(plan.participants).copy())
                if self.clock is not None:
                    self.clock.advance(self.fed.retry_backoff_s)
        obstrace.event("quorum_miss", loop=loop, attempts=max_attempts,
                       expected_valid=valid, needed=quorum)
        obstrace.count("quorum_misses")
        return AdmittedRound(plan=plan, corrupt=corrupt, duplicated=dup,
                             will_reject=wr, quorum_ok=False,
                             attempts=max_attempts,
                             aborted_arrivers=aborted)


# ---------------------------------------------------------------------------
# CLI spec parsing (launch/train.py --fault-trace)
# ---------------------------------------------------------------------------

_TRACE_KEYS = {
    "seed": ("seed", int),
    "crash": ("crash_rate", float),
    "net_fail": ("net_fail_rate", float),
    "retries": ("net_retries", int),
    "backoff": ("net_backoff_s", float),
    "duplicate": ("duplicate_rate", float),
    "bitflip": ("bitflip_rate", float),
    "nan": ("nan_rate", float),
    "poison": ("poison_rate", float),
    "poison_scale": ("poison_scale", float),
}


def parse_fault_trace(spec: str) -> FaultConfig:
    """Parse ``"crash=0.1,bitflip=0.05,seed=7"`` into a ``FaultConfig``.

    Keys: seed, crash, net_fail, retries, backoff, duplicate, bitflip,
    nan, poison, poison_scale.  The returned config has ``enabled=True``
    — passing a trace spec IS opting into injection.
    """
    kw = {"enabled": True}
    for part in filter(None, (s.strip() for s in spec.split(","))):
        if "=" not in part:
            raise ValueError(f"--fault-trace entry {part!r} is not "
                             f"key=value")
        k, v = part.split("=", 1)
        k = k.strip()
        if k not in _TRACE_KEYS:
            raise ValueError(f"unknown --fault-trace key {k!r}; one of "
                             f"{sorted(_TRACE_KEYS)}")
        name, cast = _TRACE_KEYS[k]
        kw[name] = cast(v)
    return FaultConfig(**kw)
