"""Cross-device federation engine: vmapped client cohorts, round
scheduling, and pluggable aggregation (docs/FED_ENGINE.md)."""
from repro.fed.cohort import PaddedCohort, pad_clients
from repro.fed.engine import (BatchedEngine, SequentialEngine, make_engine,
                              stack_pytrees)
from repro.fed.scheduler import (FedBuffScheduler, RoundPlan, SyncScheduler,
                                 make_scheduler)
from repro.fed.strategy import (FedAvg, FedBuff, RoundContribution, ScbfSum,
                                ServerState, make_strategy)
