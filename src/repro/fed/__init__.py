"""Cross-device federation engine: vmapped client cohorts with
bucketed-P padding and pod-axis device sharding, round scheduling, and
pluggable aggregation (docs/FED_ENGINE.md)."""
from repro.fed.cohort import (PaddedCohort, bucket_size, pad_clients)
from repro.fed.engine import (BatchedEngine, SequentialEngine, make_engine,
                              reset_scbf_compile_count, scbf_compile_count,
                              stack_pytrees)
from repro.fed.scheduler import (FedBuffScheduler, RoundPlan, SyncScheduler,
                                 make_scheduler)
from repro.fed.strategy import (FedAvg, FedBuff, RoundContribution, ScbfSum,
                                ServerState, make_strategy)
