"""Round scheduling — who trains, who reports, and how stale they are.

Two modes, matching the cross-device regimes surveyed in the healthcare
FL literature (PAPERS.md):

``sync``     classic FedAvg-style rounds: sample ``sample_fraction`` of
             the K clients, lose some to dropout, and (optionally) drop
             stragglers that miss the round deadline.  Every reported
             update has staleness 0.

``fedbuff``  buffered asynchronous rounds (FedBuff-style): up to
             ``concurrency`` clients train concurrently, each pinned to
             the server version it started from.  Each tick some finish
             (stragglers finish more slowly), report an update with
             staleness τ = current_version − start_version, and idle
             clients are restarted.  The server applies the buffer once
             ``buffer_size`` uploads accumulate (repro.fed.strategy).

Both schedulers draw from one seeded ``numpy`` Generator, so a fixed
seed reproduces the exact participation trace — dropout, stragglers,
staleness and all (tests/test_fed_engine.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.config import FedConfig

# ``plan_horizon`` (both schedulers) is the fused-execution planning
# API: the driver asks for the next H rounds up front so a whole chunk
# of rounds can run as one device program (repro.fed.engine).  Sync
# plans never depend on the server state, so any horizon is just H
# consecutive ``plan`` calls off the same RNG — byte-identical to
# planning round by round.  FedBuff plans DO depend on the server
# version advancing between rounds, so its horizon is capped at 1.

# Per-tick completion probabilities for the fedbuff simulation: a fast
# client usually reports within ~1 tick; a straggler takes ~4, which is
# what makes staleness > 0 actually occur.
FAST_COMPLETION_PROB = 0.8
STRAGGLER_COMPLETION_PROB = 0.25


@dataclass
class RoundPlan:
    """One round's participation trace (host-side, all numpy)."""

    round_index: int
    participants: np.ndarray      # client ids whose updates arrive
    staleness: np.ndarray         # (P,) server-version lag per participant
    sampled: np.ndarray           # invited (sync) / newly started (fedbuff)
    dropped: np.ndarray           # lost to dropout this round
    stragglers: np.ndarray        # flagged slow this round
    # wall-clock fields, set only under the simulated clock
    # (repro.fed.clock): the round deadline, each participant's upload
    # latency (0.0 for spilled arrivals — they were already in flight),
    # which sampled clients spilled past the deadline, and the quorum
    # attempt this plan belongs to (repro.fed.faults.Resilience)
    deadline_s: Optional[float] = None
    latency_s: Optional[np.ndarray] = None
    spilled: Optional[np.ndarray] = None
    attempt: int = 0

    @property
    def num_participants(self) -> int:
        return int(self.participants.size)

    def telemetry(self) -> dict:
        """Scheduler fields of the flight recorder's ``round`` event
        (repro.obs, docs/OBSERVABILITY.md): cohort composition plus the
        FedBuff staleness profile (zeros under sync scheduling).  The
        deadline/latency fields appear only under the simulated clock,
        so the fault-free event schema is unchanged."""
        out = {
            "sampled": int(self.sampled.size),
            "dropped": int(self.dropped.size),
            "stragglers": int(self.stragglers.size),
            "staleness_mean": float(np.mean(self.staleness))
            if self.staleness.size else 0.0,
            "staleness_max": int(np.max(self.staleness))
            if self.staleness.size else 0,
        }
        if self.deadline_s is not None:
            out["deadline_s"] = round(float(self.deadline_s), 6)
            out["attempt"] = int(self.attempt)
            if self.latency_s is not None and self.latency_s.size:
                out["latency_mean_s"] = round(
                    float(np.mean(self.latency_s)), 6)
                out["latency_max_s"] = round(
                    float(np.max(self.latency_s)), 6)
            if self.spilled is not None:
                out["spilled"] = int(self.spilled.size)
        return out


class SyncScheduler:
    """Per-round client sampling with dropout and deadline stragglers.

    With a ``SimClock`` attached (repro.fed.clock) the coin-flip
    dropout/straggler model is replaced by **deadline-based cohort
    cuts**: sampling is restricted to clients the availability trace
    says are awake, each sampled client draws a latency, the round
    deadline is the cohort's ``deadline_quantile`` latency, and misses
    either drop (flagged as stragglers) or **spill** — keep training
    past the deadline and report in the round their upload lands in,
    with real clock-derived staleness (the FedBuff buffer absorbs them;
    repro.fed.strategy).
    """

    def __init__(self, num_clients: int, cfg: FedConfig, seed: int = 0,
                 clock=None):
        if clock is not None and (cfg.dropout_rate > 0
                                  or cfg.straggler_rate > 0):
            raise ValueError(
                "the simulated clock REPLACES the coin-flip failure "
                "model: deadline cuts are the straggler model and "
                "crash/net faults (FaultConfig) are the dropout model — "
                "set dropout_rate/straggler_rate to 0 under "
                "ClockConfig.enabled")
        self.num_clients = num_clients
        self.cfg = cfg
        self.rng = np.random.default_rng(seed)
        self.clock = clock
        # spilled uploads still in flight: client -> (start_round,
        # absolute finish time in simulated seconds)
        self.pending: Dict[int, Tuple[int, float]] = {}

    def plan(self, round_index: int, server_version: int = 0,
             attempt: int = 0) -> RoundPlan:
        if self.clock is not None:
            return self._plan_clocked(round_index, attempt)
        cfg, rng = self.cfg, self.rng
        m = self.max_participants
        sampled = np.sort(rng.choice(self.num_clients, size=m,
                                     replace=False))
        drop = rng.random(m) < cfg.dropout_rate
        strag = rng.random(m) < cfg.straggler_rate
        lost = drop | (strag if cfg.drop_stragglers
                       else np.zeros(m, dtype=bool))
        participants = sampled[~lost]
        return RoundPlan(
            round_index=round_index,
            participants=participants,
            staleness=np.zeros(participants.size, dtype=np.int64),
            sampled=sampled,
            dropped=sampled[drop],
            stragglers=sampled[strag],
            attempt=attempt)

    def _plan_clocked(self, round_index: int, attempt: int) -> RoundPlan:
        """Deadline-based cohort cut off the simulated wall-clock."""
        clock, rng = self.clock, self.rng
        ccfg = clock.cfg
        spill = ccfg.deadline_action == "spill"
        avail = clock.available(round_index, attempt)
        busy = np.zeros(self.num_clients, dtype=bool)
        if self.pending:
            busy[list(self.pending)] = True
        candidates = np.flatnonzero(avail & ~busy)
        m = min(self.max_participants, candidates.size)
        sampled = np.sort(rng.choice(candidates, size=m, replace=False)) \
            if m else np.array([], dtype=np.int64)
        lat_all = clock.latencies(round_index, attempt)
        lat = lat_all[sampled]
        deadline = clock.deadline(lat)
        miss = lat > deadline
        on_time, missed = sampled[~miss], sampled[miss]
        parts = [on_time]
        stale = [np.zeros(on_time.size, dtype=np.int64)]
        lats = [lat[~miss]]
        if spill:
            for k in missed:
                self.pending[int(k)] = (round_index,
                                        clock.now + float(lat_all[k]))
            round_end = clock.now + deadline
            arrived = sorted(k for k, (_, t) in self.pending.items()
                             if t <= round_end)
            if arrived:
                r0 = np.array([self.pending.pop(k)[0] for k in arrived],
                              dtype=np.int64)
                arrived = np.array(arrived, dtype=np.int64)
                parts.append(arrived)
                stale.append(round_index - r0)
                lats.append(np.zeros(arrived.size))
        participants = np.concatenate(parts)
        staleness = np.concatenate(stale)
        latency_s = np.concatenate(lats)
        order = np.argsort(participants, kind="stable")
        clock.advance(deadline + ccfg.round_gap_s)
        return RoundPlan(
            round_index=round_index,
            participants=participants[order],
            staleness=staleness[order],
            sampled=sampled,
            dropped=np.array([], dtype=np.int64),
            stragglers=missed,
            deadline_s=deadline,
            latency_s=latency_s[order],
            spilled=missed if spill else None,
            attempt=attempt)

    @property
    def max_participants(self) -> int:
        """Per-round cohort size m = round(sample_fraction · K), the
        single source of that formula: ``plan`` samples exactly m
        (dropout only removes), fused execution sizes its static (S, B)
        plan to it, and the driver's amplification q is m/K.
        """
        m = max(1, int(round(self.cfg.sample_fraction * self.num_clients)))
        return min(m, self.num_clients)

    def plan_horizon(self, start_round: int, horizon: int,
                     server_version: int = 0) -> List[RoundPlan]:
        """Plan the next ``horizon`` rounds in one call.

        Draws from the same RNG as per-round ``plan`` calls, so a fused
        driver and a per-round driver with the same seed see the exact
        same participation trace.
        """
        if horizon < 1:
            raise ValueError(f"horizon must be >= 1, got {horizon}")
        return [self.plan(start_round + i, server_version)
                for i in range(horizon)]

    def referenced_versions(self) -> Set[int]:
        return set()                       # sync trains on the current version

    def referenced_rounds(self) -> Set[int]:
        """Start rounds some spilled upload is still training from — the
        driver keeps those param snapshots alive until the upload lands
        (empty without the clock, or under deadline_action='drop')."""
        return {r0 for r0, _ in self.pending.values()}


class FedBuffScheduler:
    """Buffered-async participation: concurrent clients, stale reports."""

    def __init__(self, num_clients: int, cfg: FedConfig, seed: int = 0):
        self.num_clients = num_clients
        self.cfg = cfg
        self.rng = np.random.default_rng(seed)
        # client id -> (start_version, is_straggler)
        self.in_flight: Dict[int, Tuple[int, bool]] = {}

    def plan(self, round_index: int, server_version: int = 0,
             attempt: int = 0) -> RoundPlan:
        cfg, rng = self.cfg, self.rng
        # refill: start idle clients at the current server version
        idle = sorted(set(range(self.num_clients)) - set(self.in_flight))
        space = max(0, cfg.concurrency - len(self.in_flight))
        n_start = min(space, len(idle))
        started = np.sort(rng.choice(idle, size=n_start, replace=False)) \
            if n_start else np.array([], dtype=np.int64)
        for k in started:
            self.in_flight[int(k)] = (server_version,
                                      bool(rng.random() < cfg.straggler_rate))
        # drain: aborts, then completions
        done, dropped, stragglers = [], [], []
        for k, (v0, slow) in list(self.in_flight.items()):
            if slow:
                stragglers.append(k)
            if rng.random() < cfg.dropout_rate:
                dropped.append(k)
                del self.in_flight[k]
                continue
            p_done = STRAGGLER_COMPLETION_PROB if slow \
                else FAST_COMPLETION_PROB
            if rng.random() < p_done:
                done.append((k, server_version - v0))
                del self.in_flight[k]
        done.sort()
        participants = np.array([k for k, _ in done], dtype=np.int64)
        staleness = np.array([t for _, t in done], dtype=np.int64)
        return RoundPlan(
            round_index=round_index,
            participants=participants,
            staleness=staleness,
            sampled=started,
            dropped=np.array(sorted(dropped), dtype=np.int64),
            stragglers=np.array(sorted(stragglers), dtype=np.int64))

    def plan_horizon(self, start_round: int, horizon: int,
                     server_version: int = 0) -> List[RoundPlan]:
        """FedBuff plans one round at a time: each plan's staleness and
        refill depend on the server version the *previous* round's
        aggregation produced, so a multi-round horizon would silently
        fabricate staleness.  Refused rather than approximated."""
        if horizon != 1:
            raise ValueError(
                "fedbuff scheduling needs per-round server-version "
                f"feedback; plan_horizon supports horizon=1 only, got "
                f"{horizon} (fused execution must fall back to the "
                "per-round path)")
        return [self.plan(start_round, server_version)]

    def referenced_versions(self) -> Set[int]:
        """Server versions some in-flight client is still training from
        (the driver keeps those param snapshots alive)."""
        return {v0 for v0, _ in self.in_flight.values()}


def make_scheduler(cfg: FedConfig, num_clients: int, seed: int = 0,
                   clock=None):
    if cfg.mode == "sync":
        return SyncScheduler(num_clients, cfg, seed, clock=clock)
    if cfg.mode == "fedbuff":
        if clock is not None:
            raise ValueError(
                "the simulated clock drives deadline-based sync rounds; "
                "fedbuff already models asynchrony with its own "
                "completion process — enable at most one")
        return FedBuffScheduler(num_clients, cfg, seed)
    raise ValueError(f"unknown federation mode {cfg.mode!r}; sync|fedbuff")
