"""Simulated wall-clock — per-client latency and diurnal availability.

The scheduler's original failure model was a coin flip per sampled
client; real cross-device federations (hospitals included) fail along a
*time* axis: heterogeneous compute, variable networks, devices that are
simply asleep at 3am local time.  ``SimClock`` models that axis as a
pure function of ``(seed, round_index, attempt)``:

* **Static traits** — each client draws a lognormal *speed* factor and
  a diurnal *phase* (its timezone) once at construction.
* **Per-round latency** — compute and network times are lognormal
  around the configured medians, scaled by the client's speed trait,
  redrawn per (round, attempt) from a hashed RNG — NOT a sequential
  stream, so the trace is identical however many times other rounds
  were planned (fused pre-planning vs per-round planning consume zero
  shared state).
* **Diurnal availability** — the probability a client answers the
  sampler oscillates over the simulated day with its phase;
  ``advance`` moves the simulated ``now`` forward as rounds (and
  quorum-retry backoffs) consume time, so churn follows the clock.

The sync scheduler turns these into **deadline-based cohort cuts**
(repro.fed.scheduler): the round deadline is the
``deadline_quantile`` of the cohort's latencies and misses either drop
or spill into the FedBuff buffer with clock-derived staleness.

Everything here is host-side numpy — no jax, no device state — so the
fault model can never perturb traced programs (tracelint/privlint stay
clean by construction).
"""
from __future__ import annotations

import numpy as np

from repro.config import ClockConfig

# hashed-RNG stream tags: np.random.default_rng seeds on the full int
# sequence, so (seed, TAG, round, attempt) gives every draw site an
# independent, call-order-free stream
_TAG_TRAITS = 0xC10C
_TAG_LATENCY = 0x1A7E
_TAG_AVAIL = 0xA1A1


class SimClock:
    """Deterministic per-client latency / availability simulator."""

    def __init__(self, num_clients: int, cfg: ClockConfig, seed: int = 0):
        if not 0.0 < cfg.deadline_quantile <= 1.0:
            raise ValueError(f"deadline_quantile must be in (0, 1], got "
                             f"{cfg.deadline_quantile}")
        if cfg.deadline_action not in ("drop", "spill"):
            raise ValueError(f"unknown deadline_action "
                             f"{cfg.deadline_action!r}; drop|spill")
        if cfg.compute_med_s < 0 or cfg.net_med_s < 0:
            raise ValueError("latency medians must be >= 0")
        if not 0.0 <= cfg.diurnal_amplitude <= 1.0:
            raise ValueError(f"diurnal_amplitude must be in [0, 1], got "
                             f"{cfg.diurnal_amplitude}")
        self.num_clients = int(num_clients)
        self.cfg = cfg
        self.seed = int(seed)
        self.now = 0.0                       # simulated seconds since start
        traits = np.random.default_rng([self.seed, _TAG_TRAITS])
        # lognormal speed: >1 = slower than the median client, fixed
        # for the whole run (compute heterogeneity is a device trait)
        self.speed = np.exp(cfg.hetero_sigma
                            * traits.standard_normal(self.num_clients))
        # diurnal phase in [0, 1): the client's timezone offset
        self.phase = traits.uniform(0.0, 1.0, self.num_clients)

    def _rng(self, tag: int, round_index: int, attempt: int
             ) -> np.random.Generator:
        return np.random.default_rng(
            [self.seed, tag, int(round_index), int(attempt)])

    # ------------------------------------------------------------------
    def latencies(self, round_index: int, attempt: int = 0) -> np.ndarray:
        """(K,) seconds from round start to upload-complete, per client.

        compute ~ LogNormal(median · speed_k, compute_sigma) plus
        network ~ LogNormal(median, net_sigma): a pure function of
        (seed, round, attempt) — re-planning a round (quorum retry)
        redraws, replaying the run does not.
        """
        cfg = self.cfg
        r = self._rng(_TAG_LATENCY, round_index, attempt)
        comp = cfg.compute_med_s * self.speed * np.exp(
            cfg.compute_sigma * r.standard_normal(self.num_clients))
        net = cfg.net_med_s * np.exp(
            cfg.net_sigma * r.standard_normal(self.num_clients))
        return comp + net

    def available(self, round_index: int, attempt: int = 0) -> np.ndarray:
        """(K,) bool — who answers the sampler at simulated ``now``.

        P(available)_k = mean − amplitude · sin(2π(now/day + phase_k)),
        clipped to [0, 1]: every client sweeps through a daily low
        (offline at night) at its own phase.
        """
        cfg = self.cfg
        frac = (self.now / cfg.day_s) if cfg.day_s > 0 else 0.0
        p = cfg.availability_mean - cfg.diurnal_amplitude * np.sin(
            2.0 * np.pi * (frac + self.phase))
        p = np.clip(p, 0.0, 1.0)
        r = self._rng(_TAG_AVAIL, round_index, attempt)
        return r.random(self.num_clients) < p

    def deadline(self, cohort_latencies: np.ndarray) -> float:
        """The round deadline: the configured quantile of the cohort's
        latencies — 'the server waits for the fastest q fraction'."""
        if cohort_latencies.size == 0:
            return 0.0
        return float(np.quantile(cohort_latencies,
                                 self.cfg.deadline_quantile))

    def advance(self, seconds: float) -> None:
        """Move simulated time forward (round duration, retry backoff)."""
        self.now += max(0.0, float(seconds))
