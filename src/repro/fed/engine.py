"""Cohort execution engines — K local trainings as one XLA program.

The seed orchestrator ran its clients in a sequential Python loop: K
jit dispatches for training, then K eager channel-selection passes,
every global loop.  At cross-device scale (hundreds to thousands of
sampled clients per round) the Python dispatch overhead dominates the
actual math.  ``BatchedEngine`` stacks the sampled clients' shards into
a padded ``(P, n_max, d)`` cohort (repro.fed.cohort) and runs

    local-train  →  delta  →  channel-select  →  (optional DP noise)

for every participant inside a single ``jax.vmap``-ed jit
(``_scbf_pass``), reusing the exact ``lax.scan`` epoch bodies from
``repro.core.client``.  Only the wire encoding (host numpy, it models
bytes crossing the network) remains per-client.

``SequentialEngine`` keeps the seed's per-client loop as the reference
implementation: at full participation with equal shards the two produce
the same trajectories (see tests/test_fed_engine.py), and the gap
between them is what benchmarks/bench_fed_engine.py measures.

Both engines are pure round executors: the driver (repro.core.scbf)
owns PRNG-key derivation, scheduling and aggregation, so an engine swap
can never change the random stream.
"""
from __future__ import annotations

from functools import partial
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import wire
from repro.config import ScbfConfig
from repro.core import privacy
from repro.core import selection as sel
from repro.core.client import (client_delta, local_train, local_train_impl,
                               masked_local_train_impl)
from repro.fed.cohort import PaddedCohort, pad_clients


def stack_pytrees(trees: Sequence):
    """Stack a list of identically-shaped pytrees along a new axis 0."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _reveal_masks(masked, masks):
    """Boolean reveal masks shaped exactly like the masked delta.

    ``select_gradients`` reports a mask entry per layer key (``None``
    for bias-free layers); the DP mechanism needs one boolean leaf per
    *transmitted* leaf so noise lands on every revealed coordinate,
    including revealed entries whose gradient is exactly zero.
    """
    return tuple({k: layer_masks[k] for k in layer_delta}
                 for layer_delta, layer_masks in zip(masked, masks))


@partial(jax.jit, static_argnames=("batch_size", "epochs", "masked_loss",
                                   "stacked_params", "upload_rate",
                                   "selection_mode", "score_norm",
                                   "dp_noise", "dp_clip"))
def _scbf_pass(params, xs, ys, ws, lr, ckeys, skeys, dp_keys, *,
               batch_size: int, epochs: int, masked_loss: bool,
               stacked_params: bool, upload_rate: float,
               selection_mode: str, score_norm: bool,
               dp_noise: float, dp_clip: float):
    """Train + delta + channel-select (+ DP) for P clients in one vmap.

    ``params`` is either one shared pytree (sync rounds) or a P-stacked
    pytree (fedbuff: each participant trains from its own stale
    version).  Returns (masked_deltas, masks), both P-stacked.
    """
    p_ax = 0 if stacked_params else None

    def one(p, x, y, w, ck, sk, dk):
        if masked_loss:
            new_p = masked_local_train_impl(p, x, y, w, lr, ck,
                                            batch_size=batch_size,
                                            epochs=epochs)
        else:
            new_p = local_train_impl(p, x, y, lr, ck,
                                     batch_size=batch_size, epochs=epochs)
        g = client_delta(p, new_p)
        masked, masks, _ = sel.select_gradients(
            g, upload_rate, selection_mode, key=sk, score_norm=score_norm)
        if dp_noise > 0.0:
            masked = privacy.gaussian_mechanism(
                tuple(masked), dk, dp_noise, dp_clip,
                masks=_reveal_masks(masked, masks))
        return tuple(masked), tuple(masks)

    return jax.vmap(one, in_axes=(p_ax, 0, 0, 0, 0, 0, 0))(
        params, xs, ys, ws, ckeys, skeys, dp_keys)


@partial(jax.jit, static_argnames=("batch_size", "epochs", "masked_loss"))
def _fedavg_pass(params, xs, ys, ws, lr, ckeys, *,
                 batch_size: int, epochs: int, masked_loss: bool):
    """Full-weight local training for P clients in one vmap."""
    def one(p, x, y, w, ck):
        if masked_loss:
            return masked_local_train_impl(p, x, y, w, lr, ck,
                                           batch_size=batch_size,
                                           epochs=epochs)
        return local_train_impl(p, x, y, lr, ck,
                                batch_size=batch_size, epochs=epochs)

    return jax.vmap(one, in_axes=(None, 0, 0, 0, 0))(params, xs, ys, ws,
                                                      ckeys)


def _emit_payloads(masked_stacked, masks_stacked, num: int
                   ) -> Tuple[List[wire.Payload], List[sel.UploadStats]]:
    """One device→host transfer, then per-client wire encoding."""
    masked_host = jax.device_get(masked_stacked)
    masks_host = jax.device_get(masks_stacked)
    payloads, stats = [], []
    for i in range(num):
        mg = tuple({kk: vv[i] for kk, vv in layer.items()}
                   for layer in masked_host)
        payloads.append(wire.encode(mg))
        mk = [{kk: (None if vv is None else vv[i])
               for kk, vv in layer.items()} for layer in masks_host]
        stats.append(sel.UploadStats.from_masks(mk))
    return payloads, stats


class BatchedEngine:
    """Vmapped padded-cohort execution: one XLA program per round."""

    name = "batched"

    def __init__(self, clients: Sequence[Tuple[np.ndarray, np.ndarray]],
                 batch_size: int, epochs: int):
        self.cohort: PaddedCohort = pad_clients(clients)
        self.counts = self.cohort.counts
        self.batch_size = batch_size
        self.epochs = epochs

    @property
    def num_clients(self) -> int:
        return self.cohort.num_clients

    def _gather(self, participants: np.ndarray):
        part = np.asarray(participants)
        if part.size == self.num_clients and \
                np.array_equal(part, np.arange(self.num_clients)):
            return self.cohort.x, self.cohort.y, self.cohort.w
        return self.cohort.x[part], self.cohort.y[part], self.cohort.w[part]

    def scbf_round(self, params, participants, lr, ckeys, skeys, dp_keys,
                   cfg: ScbfConfig):
        """Masked sparse uploads for every participant, one batched pass.

        ``params``: one pytree (sync) or a list of per-participant
        pytrees (fedbuff stale versions).
        """
        xs, ys, ws = self._gather(participants)
        stacked = isinstance(params, list)
        p = stack_pytrees(params) if stacked else tuple(params)
        masked, masks = _scbf_pass(
            p, xs, ys, ws, lr, jnp.stack(list(ckeys)),
            jnp.stack(list(skeys)), jnp.stack(list(dp_keys)),
            batch_size=self.batch_size, epochs=self.epochs,
            masked_loss=not self.cohort.uniform, stacked_params=stacked,
            upload_rate=cfg.upload_rate, selection_mode=cfg.selection,
            score_norm=cfg.score_norm, dp_noise=cfg.dp_noise_multiplier,
            dp_clip=cfg.dp_clip_norm)
        return _emit_payloads(masked, masks, len(participants))

    def fedavg_round(self, params, participants, lr, ckeys):
        """Full-weight training; returns (per-client params list, counts).

        Training runs stacked in one vmap; the returned list holds
        per-client views into that output so the aggregation strategy
        can reduce incrementally (core.server.fedavg_update).
        """
        xs, ys, ws = self._gather(participants)
        new_p = _fedavg_pass(tuple(params), xs, ys, ws, lr,
                             jnp.stack(list(ckeys)),
                             batch_size=self.batch_size, epochs=self.epochs,
                             masked_loss=not self.cohort.uniform)
        out = [jax.tree_util.tree_map(lambda l, i=i: l[i], new_p)
               for i in range(len(participants))]
        return out, self.counts[np.asarray(participants)]


class SequentialEngine:
    """The seed's per-client Python loop, kept as the reference path."""

    name = "sequential"

    def __init__(self, clients: Sequence[Tuple[np.ndarray, np.ndarray]],
                 batch_size: int, epochs: int):
        self.clients = [(jnp.asarray(x), jnp.asarray(y)) for x, y in clients]
        self.counts = np.array([x.shape[0] for x, _ in clients],
                               dtype=np.int64)
        self.batch_size = batch_size
        self.epochs = epochs

    @property
    def num_clients(self) -> int:
        return len(self.clients)

    def scbf_round(self, params, participants, lr, ckeys, skeys, dp_keys,
                   cfg: ScbfConfig):
        stacked = isinstance(params, list)
        payloads, stats = [], []
        for i, k in enumerate(participants):
            p0 = tuple(params[i]) if stacked else tuple(params)
            xc, yc = self.clients[int(k)]
            new_p = local_train(p0, xc, yc, lr, ckeys[i],
                                batch_size=self.batch_size,
                                epochs=self.epochs)
            g = client_delta(p0, new_p)
            masked, masks, _ = sel.select_gradients(
                g, cfg.upload_rate, cfg.selection, key=skeys[i],
                score_norm=cfg.score_norm)
            if cfg.dp_noise_multiplier > 0.0:
                masked = privacy.gaussian_mechanism(
                    tuple(masked), dp_keys[i], cfg.dp_noise_multiplier,
                    cfg.dp_clip_norm, masks=_reveal_masks(masked, masks))
            payloads.append(wire.encode(tuple(masked)))
            stats.append(sel.UploadStats.from_masks(masks))
        return payloads, stats

    def fedavg_round(self, params, participants, lr, ckeys):
        outs = []
        for i, k in enumerate(participants):
            xc, yc = self.clients[int(k)]
            outs.append(local_train(tuple(params), xc, yc, lr, ckeys[i],
                                    batch_size=self.batch_size,
                                    epochs=self.epochs))
        return outs, self.counts[np.asarray(participants)]


ENGINES = {"batched": BatchedEngine, "sequential": SequentialEngine}


def make_engine(kind: str, clients, batch_size: int, epochs: int):
    if kind not in ENGINES:
        raise ValueError(f"unknown engine {kind!r}; one of {sorted(ENGINES)}")
    return ENGINES[kind](clients, batch_size, epochs)
