"""Cohort execution engines — K local trainings as one XLA program.

The seed orchestrator ran its clients in a sequential Python loop: K
jit dispatches for training, then K eager channel-selection passes,
every global loop.  At cross-device scale (hundreds to thousands of
sampled clients per round) the Python dispatch overhead dominates the
actual math.  ``BatchedEngine`` stacks the sampled clients' shards into
a padded ``(P, n_max, d)`` cohort (repro.fed.cohort) and runs

    local-train  →  delta  →  channel-select  →  (optional DP noise)

for every participant inside a single ``jax.vmap``-ed jit
(``_scbf_pass``), reusing the exact ``lax.scan`` epoch bodies from
``repro.core.client``.  Only the wire encoding (host numpy, it models
bytes crossing the network) remains per-client.

``SequentialEngine`` keeps the seed's per-client loop as the reference
implementation: at full participation with equal shards the two produce
the same trajectories (see tests/test_fed_engine.py), and the gap
between them is what benchmarks/bench_fed_engine.py measures.

Because ``_scbf_pass`` is jitted on shapes, a raw participant axis
would retrace on nearly every round once sampling/dropout make P vary
(cross-silo healthcare FL treats per-round client variability as the
norm).  The engine therefore pads P up to a static *bucket* size
(``repro.fed.cohort.bucket_size``) and threads a per-slot validity mask
through train→delta→select→DP; padded slots compute garbage that the
mask zeroes and ``_emit_payloads`` drops, so valid slots stay
bit-identical to the unbucketed run while ``_scbf_pass`` compiles once
per bucket instead of once per distinct P.

With ``pods > 1`` the bucketed cohort additionally shards across
devices: the slot axis is placed on a 1-D ``("pod",)`` mesh
(launch/mesh.py, pod = federated client axis) and the vmap carries
``spmd_axis_name="pod"`` so one round runs as a single SPMD program —
exercised on CPU via XLA_FLAGS=--xla_force_host_platform_device_count.

Both engines are pure round executors: the driver (repro.core.scbf)
owns PRNG-key derivation, scheduling and aggregation, so an engine swap
can never change the random stream.

**Fused execution** (``FedConfig.fuse_rounds > 1``) goes one step
further: a whole *chunk* of S sync rounds — train → delta → select →
DP → **on-device aggregation** — runs as one jitted ``lax.scan``
(``_fused_scbf_rounds`` / ``_fused_fedavg_rounds``), so nothing crosses
the host inside the chunk.  The driver pre-plans the chunk into static
``(S, B)`` participant/validity arrays (``prepare_fused_plan``, where
every host→device transfer happens), and wire encoding moves off the
critical path: payload bytes are reconstructed from the scan's stacked
``(S, B)`` masked deltas at chunk boundaries (``emit_fused_payloads``),
so ``repro.comm.wire`` remains the single source of truth for upload
accounting.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from functools import lru_cache, partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import wire
from repro.config import ScbfConfig
from repro.core import privacy
from repro.core import selection as sel
from repro.core.client import (client_delta, local_train, local_train_impl,
                               masked_local_train_impl)
from repro.fed.cohort import (PaddedCohort, bucket_size, horizon_slot_plan,
                              pad_clients)
from repro.fed.strategy import fedavg_step, scbf_sum_step
from repro.obs import metrics as obsm
from repro.obs import trace as obstrace


def stack_pytrees(trees: Sequence):
    """Stack a list of identically-shaped pytrees along a new axis 0."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _reveal_masks(masked, masks):
    """Boolean reveal masks shaped exactly like the masked delta.

    ``select_gradients`` reports a mask entry per layer key (``None``
    for bias-free layers); the DP mechanism needs one boolean leaf per
    *transmitted* leaf so noise lands on every revealed coordinate,
    including revealed entries whose gradient is exactly zero.
    """
    return tuple({k: layer_masks[k] for k in layer_delta}
                 for layer_delta, layer_masks in zip(masked, masks))


def _slot_pass(p, x, y, w, lr, ck, sk, dk, v, nm, es=None, *,
               batch_size: int, epochs: int, masked_loss: bool,
               upload_rate: float, selection_mode: str, score_norm: bool,
               dp_noise: float, dp_clip: float, collect: bool = False):
    """Train + delta + channel-select (+ DP) for ONE cohort slot.

    The single traced body shared by the per-round pass and the fused
    chunk scan — sharing it is what keeps the two paths bit-identical.
    ``v`` is the slot-validity bit: padded slots compute garbage that is
    zeroed here (``jnp.where(True, x, 0)`` is ``x`` bitwise, so real
    slots are untouched).  ``nm`` is the optional SCBFwP neuron
    keep-mask tuple (mask-mode pruning): pruned neurons drop out of
    training, selection and DP at static shape; ``None`` traces the
    original unmasked program.

    ``collect=True`` (repro.obs device telemetry) additionally returns
    this slot's ``MetricsCarry`` — the loss comes from the training
    reverse pass (``with_loss``) and the byte/channel counts from the
    already-zeroed ``masked``/``masks``, so the parameter math is
    untouched and stays bit-identical.  ``es`` is the optional
    effective-geometry leaf-size vector (mask-mode SCBFwP byte pricing).
    """
    loss = None
    if masked_loss:
        tr = masked_local_train_impl(p, x, y, w, lr, ck,
                                     batch_size=batch_size,
                                     epochs=epochs, neuron_masks=nm,
                                     with_loss=collect)
    else:
        tr = local_train_impl(p, x, y, lr, ck,
                              batch_size=batch_size, epochs=epochs,
                              neuron_masks=nm, with_loss=collect)
    new_p, loss = tr if collect else (tr, None)
    g = client_delta(p, new_p)
    masked, masks, _ = sel.select_gradients(
        g, upload_rate, selection_mode, key=sk, score_norm=score_norm,
        neuron_masks=nm)
    if dp_noise > 0.0:
        masked = privacy.gaussian_mechanism(
            tuple(masked), dk, dp_noise, dp_clip,
            masks=_reveal_masks(masked, masks))
    masked = tuple({k: jnp.where(v, t, jnp.zeros_like(t))
                    for k, t in layer.items()} for layer in masked)
    masks = tuple({k: (None if m is None else jnp.logical_and(m, v))
                   for k, m in layer.items()} for layer in masks)
    if collect:
        return masked, masks, obsm.slot_metrics(loss, masked, masks, v,
                                                eff_sizes=es)
    return masked, masks


@partial(jax.jit, static_argnames=("batch_size", "epochs", "masked_loss",
                                   "stacked_params", "upload_rate",
                                   "selection_mode", "score_norm",
                                   "dp_noise", "dp_clip", "spmd_axis",
                                   "collect"))
def _scbf_pass(params, xs, ys, ws, lr, ckeys, skeys, dp_keys, valid,
               nmasks=None, eff_sizes=None, *,
               batch_size: int, epochs: int, masked_loss: bool,
               stacked_params: bool, upload_rate: float,
               selection_mode: str, score_norm: bool,
               dp_noise: float, dp_clip: float,
               spmd_axis: Optional[str] = None, collect: bool = False):
    """``_slot_pass`` for B slots in one vmap.

    ``params`` is either one shared pytree (sync rounds) or a B-stacked
    pytree (fedbuff: each participant trains from its own stale
    version).  ``nmasks`` (mask-mode SCBFwP) is one keep-mask tuple
    shared by every slot.  ``spmd_axis`` names the mesh axis the slot
    dimension is sharded over (None = single device).  Returns
    (masked_deltas, masks), both B-stacked — plus the round's reduced
    ``MetricsCarry`` when ``collect`` (``eff_sizes``: shared
    effective-geometry byte pricing, closed over, not vmapped).
    """
    p_ax = 0 if stacked_params else None

    def one(p, x, y, w, ck, sk, dk, v):
        return _slot_pass(p, x, y, w, lr, ck, sk, dk, v, nmasks, eff_sizes,
                          batch_size=batch_size, epochs=epochs,
                          masked_loss=masked_loss, upload_rate=upload_rate,
                          selection_mode=selection_mode,
                          score_norm=score_norm, dp_noise=dp_noise,
                          dp_clip=dp_clip, collect=collect)

    out = jax.vmap(one, in_axes=(p_ax, 0, 0, 0, 0, 0, 0, 0),
                   spmd_axis_name=spmd_axis)(
        params, xs, ys, ws, ckeys, skeys, dp_keys, valid)
    if collect:
        masked, masks, slot_m = out
        return masked, masks, obsm.reduce_slots(slot_m)
    return out


def _fused_scbf_rounds(params, x_all, y_all, w_all, part_idx, valid, admit,
                       lrs, ckeys, skeys, dp_keys, nmasks=None,
                       eff_sizes=None, *, batch_size: int,
                       epochs: int, masked_loss: bool, upload_rate: float,
                       selection_mode: str, score_norm: bool,
                       dp_noise: float, dp_clip: float,
                       spmd_axis: Optional[str] = None,
                       collect: bool = False):
    """S whole SCBF rounds as ONE device program (the fused round loop).

    ``lax.scan`` over the round axis: each step gathers its cohort from
    the device-resident ``(K, n_max, d)`` shards, runs the vmapped
    ``_slot_pass``, and folds the masked deltas into the carried model
    with ``strategy.scbf_sum_step`` — the server apply happens on
    device, with no wire decode and no host round-trip.  All-invalid
    rounds (empty cohorts, tail-chunk padding) pass the carry through
    bitwise untouched because their deltas are zeroed by the validity
    mask.  ``nmasks`` (mask-mode SCBFwP) is the chunk's neuron
    keep-mask tuple — run-constant *within* a chunk (the driver plans
    single-round chunks while pruning is still removing neurons, so a
    chunk never spans a mask update).  Returns
    (new_params, masked_deltas, masks) with the latter two stacked
    ``(S, B, ...)`` for off-critical-path wire encoding — plus the
    ``(S,)``-stacked per-round ``MetricsCarry`` when ``collect``
    (repro.obs device telemetry; the carry rides the scan ys, so the
    parameter math and the host-transfer discipline are untouched).

    ``admit`` is the (S, B) server-admission mask (repro.fed.faults):
    slots the admission gate will reject — corrupted, poisoned, quorum
    casualties — contribute exact zeros to the on-device aggregation
    while their *emitted* deltas stay untouched (the wire artifacts
    must still carry the corrupt bytes for accounting and events).
    Fault-free plans pass admit == valid, and ``jnp.where(True, t, 0)``
    is ``t`` bitwise, so the fault-free trajectory is bit-identical —
    and the program shape never changes, so the <= 2 compile bound
    holds with the fault model active.
    """
    def round_body(p, rnd):
        idx, v, adm, lr, ck, sk, dk = rnd
        xs, ys, ws = x_all[idx], y_all[idx], w_all[idx]

        def one(x, y, w, c, s, d, vv):
            return _slot_pass(p, x, y, w, lr, c, s, d, vv, nmasks,
                              eff_sizes,
                              batch_size=batch_size, epochs=epochs,
                              masked_loss=masked_loss,
                              upload_rate=upload_rate,
                              selection_mode=selection_mode,
                              score_norm=score_norm, dp_noise=dp_noise,
                              dp_clip=dp_clip, collect=collect)

        out = jax.vmap(one, in_axes=(0, 0, 0, 0, 0, 0, 0),
                       spmd_axis_name=spmd_axis)(
            xs, ys, ws, ck, sk, dk, v)
        if collect:
            masked, masks, slot_m = out
            ys_out = (masked, masks, obsm.reduce_slots(slot_m))
        else:
            masked, masks = out
            ys_out = (masked, masks)
        admitted = tuple(
            {k: jnp.where(adm.reshape(adm.shape + (1,) * (t.ndim - 1)),
                          t, jnp.zeros_like(t))
             for k, t in layer.items()} for layer in masked)
        return scbf_sum_step(p, admitted, neuron_masks=nmasks), ys_out

    new_p, ys_s = jax.lax.scan(
        round_body, tuple(params),
        (part_idx, valid, admit, lrs, ckeys, skeys, dp_keys))
    if collect:
        masked_s, masks_s, met_s = ys_s
        return new_p, masked_s, masks_s, met_s
    masked_s, masks_s = ys_s
    return new_p, masked_s, masks_s


def _fused_fedavg_rounds(params, x_all, y_all, w_all, part_idx, weights,
                         lrs, ckeys, *, batch_size: int, epochs: int,
                         masked_loss: bool,
                         spmd_axis: Optional[str] = None,
                         collect: bool = False):
    """S whole FedAvg rounds as one device program.

    Like ``_fused_scbf_rounds`` but full-weight: each scan step trains
    the cohort and replaces the carry with the example-weighted mean
    (``strategy.fedavg_step``; ``weights`` carries exact zeros on
    invalid slots, and an all-zero round keeps the carry unchanged).
    FedAvg ships dense weights, so nothing per-round needs to reach the
    host — only the final model is returned, plus the ``(S,)``-stacked
    ``FedAvgMetrics`` (loss / participant counts, slot validity derived
    from the zero-weight convention) when ``collect``.
    """
    def round_body(p, rnd):
        idx, wts, lr, ck = rnd
        xs, ys, ws = x_all[idx], y_all[idx], w_all[idx]

        def one(x, y, w, k):
            if masked_loss:
                return masked_local_train_impl(p, x, y, w, lr, k,
                                               batch_size=batch_size,
                                               epochs=epochs,
                                               with_loss=collect)
            return local_train_impl(p, x, y, lr, k,
                                    batch_size=batch_size, epochs=epochs,
                                    with_loss=collect)

        out = jax.vmap(one, in_axes=(0, 0, 0, 0),
                       spmd_axis_name=spmd_axis)(xs, ys, ws, ck)
        if collect:
            new_stack, losses = out
            valid = wts > 0.0
            met = obsm.FedAvgMetrics(
                loss_sum=jnp.sum(jnp.where(valid, losses, 0.0)
                                 ).astype(jnp.float32),
                participants=jnp.sum(valid.astype(jnp.int32)))
        else:
            new_stack, met = out, None
        return fedavg_step(p, new_stack, wts), met

    new_p, met_s = jax.lax.scan(round_body, tuple(params),
                                (part_idx, weights, lrs, ckeys))
    if collect:
        return new_p, met_s
    return new_p


@lru_cache(maxsize=None)
def _fused_programs():
    """The jitted fused-chunk programs, built on first use.

    The model carry is buffer-donated into the chunk call on backends
    that support donation (CPU ignores it, with a warning per compile)
    — and deciding that requires querying the backend, which
    *initializes* it.  Building the jits lazily keeps importing this
    module free of backend side effects: XLA_FLAGS / JAX_PLATFORMS set
    after import but before first use still take effect.
    """
    donate = (0,) if jax.default_backend() != "cpu" else ()
    scbf = partial(jax.jit,
                   static_argnames=("batch_size", "epochs", "masked_loss",
                                    "upload_rate", "selection_mode",
                                    "score_norm", "dp_noise", "dp_clip",
                                    "spmd_axis", "collect"),
                   donate_argnums=donate)(_fused_scbf_rounds)
    fedavg = partial(jax.jit,
                     static_argnames=("batch_size", "epochs", "masked_loss",
                                      "spmd_axis", "collect"),
                     donate_argnums=donate)(_fused_fedavg_rounds)
    return scbf, fedavg


@partial(jax.jit, static_argnames=("batch_size", "epochs", "masked_loss",
                                   "spmd_axis", "collect"))
def _fedavg_pass(params, xs, ys, ws, lr, ckeys, *,
                 batch_size: int, epochs: int, masked_loss: bool,
                 spmd_axis: Optional[str] = None, collect: bool = False):
    """Full-weight local training for B slots in one vmap.

    Padded slots need no validity gating here: their trained params are
    per-slot outputs that ``fedavg_round`` simply never reads (and with
    ``collect`` the caller slices the loss vector to real slots).
    """
    def one(p, x, y, w, ck):
        if masked_loss:
            return masked_local_train_impl(p, x, y, w, lr, ck,
                                           batch_size=batch_size,
                                           epochs=epochs,
                                           with_loss=collect)
        return local_train_impl(p, x, y, lr, ck,
                                batch_size=batch_size, epochs=epochs,
                                with_loss=collect)

    return jax.vmap(one, in_axes=(None, 0, 0, 0, 0),
                    spmd_axis_name=spmd_axis)(params, xs, ys, ws, ckeys)


def _compact_layers(layers, keep):
    """Host-side effective-geometry slicing of one slot's layer dicts.

    Mask-mode SCBFwP emission: ``keep[l]`` are the kept neuron ids of
    hidden layer l, and the sliced arrays are exactly what
    ``pruning.apply_structure`` would have produced — so wire encoding
    (bytes, bitmap sizes, dense reference) and mask accounting see the
    *effective* model, matching what a physically-compacted run ships.
    ``None`` leaves (bias-free masks) pass through.
    """
    out = []
    prev = None
    last = len(layers) - 1
    for l, layer in enumerate(layers):
        new = {}
        for kk, vv in layer.items():
            if vv is None:
                new[kk] = None
                continue
            a = np.asarray(vv)
            if kk == "w":
                if prev is not None:
                    a = a[prev]
                if l < last:
                    a = a[:, keep[l]]
            elif l < last:
                a = a[keep[l]]
            new[kk] = a
        if l < last:
            prev = keep[l]
        out.append(new)
    return tuple(out)


def _encode_slot(masked_host, masks_host, sl, keep=None):
    """Wire-encode one slot of a host-side stacked pass output.

    ``sl`` indexes the stacked leading axes — ``(i,)`` for a per-round
    pass, ``(r, i)`` for a fused chunk — so both paths share the exact
    same encode + accounting code (``repro.comm.wire`` stays the single
    source of truth for upload bytes).  ``keep`` (mask-mode SCBFwP)
    compacts the slot to its effective geometry before encoding.
    """
    mg = tuple({kk: vv[sl] for kk, vv in layer.items()}
               for layer in masked_host)
    mk = tuple({kk: (None if vv is None else vv[sl])
                for kk, vv in layer.items()} for layer in masks_host)
    if keep is not None:
        mg = _compact_layers(mg, keep)
        mk = _compact_layers(mk, keep)
    return wire.encode(mg), sel.UploadStats.from_masks(mk)


def _emit_payloads(masked_stacked, masks_stacked, num: int, keep=None
                   ) -> Tuple[List[wire.Payload], List[sel.UploadStats]]:
    """One device→host transfer, then per-client wire encoding.

    ``num`` is the real participant count P: slots P..B-1 of a bucketed
    pass are padding (already zeroed by the validity mask) and are never
    encoded — padded slots ship zero bytes.
    """
    with obstrace.span("encode", clients=num):
        masked_host = jax.device_get(masked_stacked)
        masks_host = jax.device_get(masks_stacked)
        payloads, stats = [], []
        for i in range(num):
            payload, st = _encode_slot(masked_host, masks_host, (i,), keep)
            payloads.append(payload)
            stats.append(st)
        return payloads, stats


def _host_round_metrics(payloads, stats, losses):
    """Sequential-path round telemetry, same dict shape as
    ``obsm.offload``.

    The reference engine already has everything on the host, so its
    numbers come straight from the encoded payloads (``repro.comm.wire``
    stays the byte source of truth) instead of a device carry.
    """
    return {
        "participants": len(payloads),
        "train_loss": (sum(losses) / len(losses)) if losses else 0.0,
        "sparse_bytes": int(sum(p.nbytes for p in payloads)),
        "codec_bytes": wire.codec_breakdown(payloads),
    }


def _host_fedavg_metrics(losses, num: int):
    """Sequential fedavg round telemetry: cohort-level aggregates only.

    The per-client loss list is reduced to its cohort mean HERE, before
    the dict crosses into ``LoopRecord``/events.jsonl — this function is
    a declared aggregation point in the privlint policy
    (repro.analysis.privrules), so per-client scalars must not be added
    to the dict.
    """
    return {
        "participants": num,
        "train_loss": (sum(losses) / len(losses)) if losses else 0.0,
    }


@dataclass
class FusedPlan:
    """Device-resident plan for one fused chunk of rounds.

    Built by ``BatchedEngine.prepare_fused_plan`` — every host→device
    transfer for the chunk happens there, so the chunk execution itself
    is transfer-free (provable under ``jax.transfer_guard("disallow")``,
    see tests/test_fused_rounds.py).
    """

    rounds: int                       # real rounds in the chunk (<= S)
    num_slots: int                    # B, constant across the whole run
    participants: List[np.ndarray]    # per real round (host ids)
    part_idx: jnp.ndarray             # (S, B) int32 cohort gather indices
    valid: jnp.ndarray                # (S, B) bool slot validity
    lrs: jnp.ndarray                  # (S,) float32 lr table slice
    ckeys: jnp.ndarray                # (S, B, 2) per-slot training keys
    skeys: jnp.ndarray                # (S, B, 2) selection keys
    dp_keys: jnp.ndarray              # (S, B, 2) DP noise keys
    weights: Optional[jnp.ndarray] = None   # (S, B) f32 — fedavg only
    eff_sizes: Optional[jnp.ndarray] = None  # (n_leaves,) i32 — obs byte
    # pricing under mask-mode SCBFwP (device-placed at plan build so the
    # chunk stays transfer-free); None prices full leaf sizes statically
    admit: Optional[jnp.ndarray] = None     # (S, B) bool server admission
    # mask (repro.fed.faults) — None means admit == valid (no faults)


def _pad_slots(arr, num_slots: int):
    """Pad axis 0 up to ``num_slots`` by repeating slot 0.

    Slot-0 content (not zeros) keeps padded slots numerically
    well-behaved — they train on a real shard, and everything they
    produce is zeroed by the validity mask and dropped before encoding.
    """
    p = arr.shape[0]
    if num_slots == p:
        return arr
    reps = jnp.broadcast_to(arr[:1], (num_slots - p,) + arr.shape[1:])
    return jnp.concatenate([jnp.asarray(arr), reps], axis=0)


def _pad_key_slots(keys, num_slots: int):
    """Pad a (P, 2) PRNG key row up to ``num_slots`` with *distinct*
    filler keys.

    Padded slots are validity-masked — nothing they produce survives —
    but repeating slot 0's key verbatim would make every padded slot
    draw slot 0's noise stream (privlint PL003); offsetting the second
    key word keeps each slot's stream distinct at zero cost, and the
    validity mask still guarantees bit-identical round outputs.
    """
    keys = jnp.asarray(keys)
    p = keys.shape[0]
    if num_slots == p:
        return keys
    pad = num_slots - p
    offs = jnp.stack([jnp.zeros(pad, jnp.uint32),
                      jnp.arange(1, pad + 1, dtype=jnp.uint32)], axis=1)
    return jnp.concatenate([keys, keys[:1] + offs], axis=0)


class BatchedEngine:
    """Vmapped bucketed-cohort execution: one XLA program per round.

    ``bucket`` picks the participant-padding policy
    (repro.fed.cohort.bucket_size); ``pods > 1`` shards the bucketed
    slot axis over a 1-D pod mesh so the round runs SPMD across
    devices.
    """

    name = "batched"

    def __init__(self, clients: Sequence[Tuple[np.ndarray, np.ndarray]],
                 batch_size: int, epochs: int, bucket: str = "pow2",
                 pods: int = 1):
        # validate the policy at construction, not on round 1
        bucket_size(1, 1, bucket)
        self.cohort: PaddedCohort = pad_clients(clients)
        self.counts = self.cohort.counts
        self.batch_size = batch_size
        self.epochs = epochs
        self.bucket = bucket
        self.pods = max(1, int(pods))
        if self.pods > 1:
            from repro.launch.mesh import make_pod_mesh
            from repro.sharding.rules import (cohort_shardings,
                                              fused_plan_shardings)
            self.mesh = make_pod_mesh(self.pods)
            self._slot_sharding, self._repl_sharding = \
                cohort_shardings(self.mesh)
            self._fused_slot_sharding, _ = fused_plan_shardings(self.mesh)
            from repro.sharding.rules import keep_mask_sharding
            self._mask_sharding = keep_mask_sharding(self.mesh)
        else:
            self.mesh = None
        self._cohort_replicated = False

    @property
    def num_clients(self) -> int:
        return self.cohort.num_clients

    @property
    def spmd_axis(self) -> Optional[str]:
        return "pod" if self.mesh is not None else None

    def _mesh_ctx(self):
        return self.mesh if self.mesh is not None else \
            contextlib.nullcontext()

    def _gather(self, participants: np.ndarray):
        part = np.asarray(participants)
        if part.size == self.num_clients and \
                np.array_equal(part, np.arange(self.num_clients)):
            return self.cohort.x, self.cohort.y, self.cohort.w
        return self.cohort.x[part], self.cohort.y[part], self.cohort.w[part]

    def _bucketed_inputs(self, participants, slot_arrays, key_arrays=(),
                         params=None):
        """Pad per-slot arrays up to the bucket; returns (B, arrays,
        keys, params, valid).  Data arrays pad by repeating slot 0
        (``_pad_slots``); PRNG key rows pad with distinct derived keys
        (``_pad_key_slots``) so padded slots never share a noise stream.
        With a pod mesh, per-slot arrays are placed with the slot axis
        sharded over ``pod`` and params replicated.
        """
        p_count = len(participants)
        b = bucket_size(p_count, self.num_clients, self.bucket, self.pods)
        valid = jnp.arange(b) < p_count
        out = [_pad_slots(jnp.asarray(a), b) for a in slot_arrays]
        keys = [_pad_key_slots(k, b) for k in key_arrays]
        if params is not None:
            params = jax.tree_util.tree_map(lambda l: _pad_slots(l, b),
                                            params)
        if self.mesh is not None:
            out = [jax.device_put(a, self._slot_sharding) for a in out]
            keys = [jax.device_put(k, self._slot_sharding) for k in keys]
            valid = jax.device_put(valid, self._slot_sharding)
            if params is not None:
                params = jax.device_put(params, self._slot_sharding)
        return b, out, keys, params, valid

    def scbf_round(self, params, participants, lr, ckeys, skeys, dp_keys,
                   cfg: ScbfConfig, nmasks=None, keep=None,
                   collect: bool = False):
        """Masked sparse uploads for every participant, one batched pass.

        ``params``: one pytree (sync) or a list of per-participant
        pytrees (fedbuff stale versions).  ``nmasks``/``keep`` are the
        mask-mode SCBFwP neuron keep-masks (device tuple threaded into
        the pass) and kept-index sets (host, for effective-geometry
        emission).  An empty round returns ``([], [])`` without
        dispatching a P=0 program.  ``collect`` (repro.obs) appends the
        round's offloaded device-telemetry dict to the return tuple.
        """
        p_count = len(participants)
        if not p_count:
            return ([], [], None) if collect else ([], [])
        xs, ys, ws = self._gather(participants)
        stacked = isinstance(params, list)
        p = stack_pytrees(params) if stacked else tuple(params)
        _, (xs, ys, ws), (ck, sk, dk), p_stk, valid = \
            self._bucketed_inputs(
                participants, (xs, ys, ws),
                key_arrays=(jnp.stack(list(ckeys)),
                            jnp.stack(list(skeys)),
                            jnp.stack(list(dp_keys))),
                params=p if stacked else None)
        if stacked:
            p = p_stk
        elif self.mesh is not None:
            p = jax.device_put(p, self._repl_sharding)
        if nmasks is not None and self.mesh is not None:
            nmasks = jax.device_put(tuple(nmasks), self._mask_sharding)
        eff = None
        if collect and keep is not None:
            ref = params[0] if stacked else params
            eff = jnp.asarray(obsm.effective_leaf_sizes(ref, keep))
        with self._mesh_ctx():
            out = _scbf_pass(
                p, xs, ys, ws, lr, ck, sk, dk, valid, nmasks, eff,
                batch_size=self.batch_size, epochs=self.epochs,
                masked_loss=not self.cohort.uniform, stacked_params=stacked,
                upload_rate=cfg.upload_rate, selection_mode=cfg.selection,
                score_norm=cfg.score_norm, dp_noise=cfg.dp_noise_multiplier,
                dp_clip=cfg.dp_clip_norm, spmd_axis=self.spmd_axis,
                collect=collect)
        if collect:
            masked, masks, met = out
            payloads, stats = _emit_payloads(masked, masks, p_count, keep)
            return payloads, stats, obsm.offload(met)
        masked, masks = out
        return _emit_payloads(masked, masks, p_count, keep)

    def fedavg_round(self, params, participants, lr, ckeys,
                     collect: bool = False):
        """Full-weight training; returns (per-client params list, counts).

        Training runs stacked in one vmap; the returned list holds
        per-client views into that output so the aggregation strategy
        can reduce incrementally (core.server.fedavg_update).  Padded
        bucket slots are simply never read.  ``collect`` appends the
        loss-only device-telemetry dict.
        """
        p_count = len(participants)
        if not p_count:
            return ([], self.counts[:0], None) if collect \
                else ([], self.counts[:0])
        xs, ys, ws = self._gather(participants)
        p = tuple(params)
        _, (xs, ys, ws), (ck,), _, valid = self._bucketed_inputs(
            participants, (xs, ys, ws),
            key_arrays=(jnp.stack(list(ckeys)),))
        if self.mesh is not None:
            p = jax.device_put(p, self._repl_sharding)
        with self._mesh_ctx():
            out = _fedavg_pass(p, xs, ys, ws, lr, ck,
                               batch_size=self.batch_size,
                               epochs=self.epochs,
                               masked_loss=not self.cohort.uniform,
                               spmd_axis=self.spmd_axis, collect=collect)
        if collect:
            new_p, losses = out
            # same validity-masked accounting as the fused path's
            # round_body: padded tail slots (real losses, trained on
            # slot 0's shard under distinct filler keys) are excluded
            # by mask rather than by slicing — bit-identical to the
            # old sliced sum, since adding the masked zeros cannot
            # move an f32 sum of finite values
            met = obsm.FedAvgMetrics(
                loss_sum=jnp.sum(jnp.where(valid, losses, 0.0)
                                 ).astype(jnp.float32),
                participants=jnp.sum(valid.astype(jnp.int32)))
            dm = obsm.offload(met)
        else:
            new_p = out
        res = [jax.tree_util.tree_map(lambda l, i=i: l[i], new_p)
               for i in range(p_count)]
        counts = self.counts[np.asarray(participants)]
        return (res, counts, dm) if collect else (res, counts)

    # ------------------------------------------------------------------
    # fused execution: S whole rounds per device program
    # ------------------------------------------------------------------

    def fused_num_slots(self, max_participants: int) -> int:
        """The run-constant slot count B for fused chunks.

        Sized to the scheduler's worst-case cohort (not per-round
        buckets): every chunk of the run then shares ONE compiled
        program, which is what keeps the fused path at <= 2 compiles
        across an arbitrarily-varying participation trace.
        """
        return bucket_size(max_participants, self.num_clients, self.bucket,
                           self.pods)

    def prepare_fused_plan(self, participants: Sequence[np.ndarray],
                           lrs: Sequence[float],
                           ckeys: Sequence, skeys: Sequence,
                           dp_keys: Sequence, horizon: int,
                           num_slots: int, weights=None,
                           eff_sizes=None, admit=None) -> FusedPlan:
        """Assemble + device-place one chunk's static (S, B) plan.

        Per-round key rows pad with distinct derived keys and a short
        tail chunk pads with all-invalid rounds, exactly mirroring the
        per-round path's ``_pad_slots``/``_pad_key_slots`` semantics —
        this is where every host→device transfer for the chunk happens.
        ``admit`` (repro.fed.faults): per-round (P,) bool admission
        rows; None admits every valid slot (the fault-free plan).
        """
        if self.mesh is not None and not self._cohort_replicated:
            # fused chunks gather cohorts on device, so the shards must
            # live replicated across the mesh (weights-never-shard-over-
            # pod applies to data here too: pod splits the *slot* axis).
            # Deferred to first fused use — per-round pod runs re-gather
            # and re-shard per round and never need the replicas.
            self.cohort = PaddedCohort(
                jax.device_put(self.cohort.x, self._repl_sharding),
                jax.device_put(self.cohort.y, self._repl_sharding),
                jax.device_put(self.cohort.w, self._repl_sharding),
                self.cohort.counts)
            self._cohort_replicated = True
        parts = [np.asarray(p) for p in participants]
        part_idx, valid = horizon_slot_plan(parts, num_slots, horizon)

        def pad_rows(rows, trailing):
            out = np.zeros((horizon, num_slots) + trailing, np.uint32)
            for r, k in enumerate(rows):
                k = np.asarray(k)
                if k.shape[0]:
                    out[r, :k.shape[0]] = k
                    pad = num_slots - k.shape[0]
                    if pad:
                        # distinct filler keys, mirroring _pad_key_slots:
                        # padded slots are validity-masked but must not
                        # share slot 0's noise stream (privlint PL003)
                        offs = np.zeros((pad,) + trailing, np.uint32)
                        offs[..., -1] = np.arange(1, pad + 1,
                                                  dtype=np.uint32)
                        out[r, k.shape[0]:] = k[0] + offs
            return out

        lr_arr = np.zeros(horizon, np.float32)
        lr_arr[:len(list(lrs))] = np.asarray(list(lrs), np.float32)
        wts = None
        if weights is not None:
            wts = np.zeros((horizon, num_slots), np.float32)
            for r, w in enumerate(weights):
                w = np.asarray(w, np.float32)
                wts[r, :w.shape[0]] = w

        if admit is None:
            admit_arr = np.asarray(valid, dtype=bool)
        else:
            admit_arr = np.zeros((horizon, num_slots), dtype=bool)
            for r, row in enumerate(admit):
                row = np.asarray(row, dtype=bool)
                admit_arr[r, :row.shape[0]] = row

        key_dim = (2,)
        arrs = {
            "part_idx": part_idx, "valid": valid, "admit": admit_arr,
            "ckeys": pad_rows(ckeys, key_dim),
            "skeys": pad_rows(skeys, key_dim),
            "dp_keys": pad_rows(dp_keys, key_dim),
        }
        if self.mesh is not None:
            dev = {k: jax.device_put(jnp.asarray(v),
                                     self._fused_slot_sharding)
                   for k, v in arrs.items()}
            lr_dev = jax.device_put(jnp.asarray(lr_arr),
                                    self._repl_sharding)
            wts_dev = None if wts is None else \
                jax.device_put(jnp.asarray(wts), self._fused_slot_sharding)
            eff_dev = None if eff_sizes is None else jax.device_put(
                jnp.asarray(eff_sizes, jnp.int32), self._repl_sharding)
        else:
            dev = {k: jnp.asarray(v) for k, v in arrs.items()}
            lr_dev = jnp.asarray(lr_arr)
            wts_dev = None if wts is None else jnp.asarray(wts)
            eff_dev = None if eff_sizes is None else \
                jnp.asarray(eff_sizes, jnp.int32)
        return FusedPlan(rounds=len(parts), num_slots=num_slots,
                         participants=parts, part_idx=dev["part_idx"],
                         valid=dev["valid"], lrs=lr_dev,
                         ckeys=dev["ckeys"], skeys=dev["skeys"],
                         dp_keys=dev["dp_keys"], weights=wts_dev,
                         eff_sizes=eff_dev, admit=dev["admit"])

    def fused_scbf_chunk(self, params, plan: FusedPlan, cfg: ScbfConfig,
                         nmasks=None, collect: bool = False):
        """Run one fused chunk: S rounds, zero host crossings inside.

        ``nmasks`` (mask-mode SCBFwP) is the chunk's neuron keep-mask
        tuple — device arrays, replicated across a pod mesh (keep-masks
        are model-geometry state and follow the weights-never-shard
        contract).  Returns (new_params, masked_deltas, masks) — the
        stacked outputs stay on device until ``emit_fused_payloads``
        pulls them for wire accounting at the chunk boundary — plus the
        (S,)-stacked on-device ``MetricsCarry`` when ``collect`` (the
        caller offloads it together with the payload transfer; nothing
        extra crosses the host inside the chunk).
        """
        p = tuple(params)
        if self.mesh is not None:
            p = jax.device_put(p, self._repl_sharding)
            if nmasks is not None:
                nmasks = jax.device_put(tuple(nmasks), self._mask_sharding)
        fused_scbf, _ = _fused_programs()
        admit = plan.admit if plan.admit is not None else plan.valid
        with self._mesh_ctx():
            return fused_scbf(
                p, self.cohort.x, self.cohort.y, self.cohort.w,
                plan.part_idx, plan.valid, admit, plan.lrs,
                plan.ckeys, plan.skeys, plan.dp_keys, nmasks,
                plan.eff_sizes,
                batch_size=self.batch_size, epochs=self.epochs,
                masked_loss=not self.cohort.uniform,
                upload_rate=cfg.upload_rate, selection_mode=cfg.selection,
                score_norm=cfg.score_norm,
                dp_noise=cfg.dp_noise_multiplier,
                dp_clip=cfg.dp_clip_norm, spmd_axis=self.spmd_axis,
                collect=collect)

    def fused_fedavg_chunk(self, params, plan: FusedPlan,
                           collect: bool = False):
        """Run one fused FedAvg chunk; returns only the final params
        (plus the (S,)-stacked ``FedAvgMetrics`` when ``collect``)."""
        if plan.weights is None:
            raise ValueError("fused fedavg needs the plan built with "
                             "per-slot example weights")
        p = tuple(params)
        if self.mesh is not None:
            p = jax.device_put(p, self._repl_sharding)
        _, fused_fedavg = _fused_programs()
        with self._mesh_ctx():
            return fused_fedavg(
                p, self.cohort.x, self.cohort.y, self.cohort.w,
                plan.part_idx, plan.weights, plan.lrs, plan.ckeys,
                batch_size=self.batch_size, epochs=self.epochs,
                masked_loss=not self.cohort.uniform,
                spmd_axis=self.spmd_axis, collect=collect)

    def emit_fused_payloads(self, masked_s, masks_s, plan: FusedPlan,
                            keep=None
                            ) -> List[Tuple[List[wire.Payload],
                                            List[sel.UploadStats]]]:
        """One device→host transfer for the whole chunk, then per-round
        wire encoding off the critical path.

        ``keep`` (mask-mode SCBFwP) compacts every slot to the
        effective geometry before encoding, so the reported bytes are
        what a physically-pruned model would ship.  Returns
        ``[(payloads, stats), ...]`` per *real* round; padding rounds
        and padded slots are never encoded and ship zero bytes.  The
        reconstructed payloads are byte-identical to what the per-round
        path emits because the masked deltas are.
        """
        with obstrace.span("encode", rounds=plan.rounds):
            masked_host = jax.device_get(masked_s)
            masks_host = jax.device_get(masks_s)
            out = []
            for r in range(plan.rounds):
                payloads, stats = [], []
                for i in range(int(plan.participants[r].size)):
                    payload, st = _encode_slot(masked_host, masks_host,
                                               (r, i), keep)
                    payloads.append(payload)
                    stats.append(st)
                out.append((payloads, stats))
            return out


class SequentialEngine:
    """The seed's per-client Python loop, kept as the reference path.

    Bucketing is a batched-engine concept (there is no shared program
    to retrace here), so ``bucket`` is accepted-and-ignored for
    signature parity; ``pods > 1`` is refused — the loop is inherently
    single-device.
    """

    name = "sequential"

    def __init__(self, clients: Sequence[Tuple[np.ndarray, np.ndarray]],
                 batch_size: int, epochs: int, bucket: str = "pow2",
                 pods: int = 1):
        if pods > 1:
            raise ValueError("the sequential engine is single-device; "
                             "pod sharding needs engine='batched'")
        self.clients = [(jnp.asarray(x), jnp.asarray(y)) for x, y in clients]
        self.counts = np.array([x.shape[0] for x, _ in clients],
                               dtype=np.int64)
        self.batch_size = batch_size
        self.epochs = epochs

    @property
    def num_clients(self) -> int:
        return len(self.clients)

    def scbf_round(self, params, participants, lr, ckeys, skeys, dp_keys,
                   cfg: ScbfConfig, nmasks=None, keep=None,
                   collect: bool = False):
        stacked = isinstance(params, list)
        payloads, stats = [], []
        losses = []
        for i, k in enumerate(participants):
            p0 = tuple(params[i]) if stacked else tuple(params)
            xc, yc = self.clients[int(k)]
            tr = local_train(p0, xc, yc, lr, ckeys[i],
                             batch_size=self.batch_size,
                             epochs=self.epochs, neuron_masks=nmasks,
                             with_loss=collect)
            new_p, loss = tr if collect else (tr, None)
            if collect:
                losses.append(loss)          # device scalar; fetched once below
            g = client_delta(p0, new_p)
            masked, masks, _ = sel.select_gradients(
                g, cfg.upload_rate, cfg.selection, key=skeys[i],
                score_norm=cfg.score_norm, neuron_masks=nmasks)
            if cfg.dp_noise_multiplier > 0.0:
                masked = privacy.gaussian_mechanism(
                    tuple(masked), dp_keys[i], cfg.dp_noise_multiplier,
                    cfg.dp_clip_norm, masks=_reveal_masks(masked, masks))
            masked, masks = tuple(masked), tuple(masks)
            if keep is not None:
                masked = _compact_layers(masked, keep)
                masks = _compact_layers(masks, keep)
            payloads.append(wire.encode(masked))
            stats.append(sel.UploadStats.from_masks(masks))
        if collect:
            losses = [float(x) for x in jax.device_get(losses)]
            return payloads, stats, _host_round_metrics(payloads, stats,
                                                        losses)
        return payloads, stats

    def fedavg_round(self, params, participants, lr, ckeys,
                     collect: bool = False):
        outs = []
        losses = []
        for i, k in enumerate(participants):
            xc, yc = self.clients[int(k)]
            tr = local_train(tuple(params), xc, yc, lr, ckeys[i],
                             batch_size=self.batch_size,
                             epochs=self.epochs, with_loss=collect)
            new_p, loss = tr if collect else (tr, None)
            if collect:
                losses.append(loss)          # device scalar; fetched once below
            outs.append(new_p)
        counts = self.counts[np.asarray(participants)]
        if collect:
            losses = [float(x) for x in jax.device_get(losses)]
            return outs, counts, _host_fedavg_metrics(losses, len(outs))
        return outs, counts


ENGINES = {"batched": BatchedEngine, "sequential": SequentialEngine}


def scbf_compile_count() -> int:
    """Compiled-variant count of the batched SCBF pass (jit cache size).

    One entry per traced (shape, static-args) combination — the number
    tests and benchmarks assert stays at "one per bucket", not "one per
    distinct P" (clear with ``reset_scbf_compile_count`` first).

    Reads jit's cache through the ``_cache_size`` introspection hook,
    which is not public API: if a jax upgrade removes it, fail with an
    actionable error instead of an AttributeError deep in a test (CI
    pins jax==0.4.37; there is no public per-function alternative —
    ``jax.monitoring`` compile events are process-global).
    """
    try:
        return int(_scbf_pass._cache_size())
    except AttributeError as e:
        raise RuntimeError(
            "jit cache introspection (_cache_size) is unavailable on this "
            "jax version; compile-count assertions need the pinned "
            "jax==0.4.37 API or an equivalent hook") from e


def reset_scbf_compile_count() -> None:
    try:
        _scbf_pass._clear_cache()
    except AttributeError as e:
        raise RuntimeError(
            "jit cache clearing (_clear_cache) is unavailable on this "
            "jax version; compile-count assertions need the pinned "
            "jax==0.4.37 API or an equivalent hook") from e


def fused_compile_count() -> int:
    """Compiled-variant count of the fused chunk programs (jit cache).

    The fused acceptance bar is "<= 2 compiles across a varying-P
    trace": because the plan is padded to a run-constant (S, B), every
    chunk — including the short tail — shares one compiled program.
    Same ``_cache_size`` introspection caveat as ``scbf_compile_count``.
    """
    scbf, fedavg = _fused_programs()
    try:
        return int(scbf._cache_size() + fedavg._cache_size())
    except AttributeError as e:
        raise RuntimeError(
            "jit cache introspection (_cache_size) is unavailable on this "
            "jax version; compile-count assertions need the pinned "
            "jax==0.4.37 API or an equivalent hook") from e


def reset_fused_compile_count() -> None:
    scbf, fedavg = _fused_programs()
    try:
        scbf._clear_cache()
        fedavg._clear_cache()
    except AttributeError as e:
        raise RuntimeError(
            "jit cache clearing (_clear_cache) is unavailable on this "
            "jax version; compile-count assertions need the pinned "
            "jax==0.4.37 API or an equivalent hook") from e


def make_engine(kind: str, clients, batch_size: int, epochs: int,
                bucket: str = "pow2", pods: int = 1):
    if kind not in ENGINES:
        raise ValueError(f"unknown engine {kind!r}; one of {sorted(ENGINES)}")
    return ENGINES[kind](clients, batch_size, epochs, bucket=bucket,
                         pods=pods)
