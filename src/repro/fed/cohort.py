"""Padded client cohorts — the batched engine's data layout.

Cross-device cohorts are ragged (Dirichlet hospital silos have very
different shard sizes), but one vmapped XLA program needs rectangular
inputs.  ``pad_clients`` stacks K client shards into ``(K, n_max, d)``
arrays, zero-padding short shards and carrying a ``(K, n_max)`` example
mask so padded rows are invisible to the loss (see
``repro.core.client.masked_local_train_impl``).

Padding overhead is bounded by the rag: for the paper's equal IID split
``n_max == n_k`` and the mask is all-ones, in which case the engine
skips the weighted loss entirely and runs the exact sequential
arithmetic (``uniform`` below).

``bucket_size`` is the second padding axis: the *participant* count P
varies round to round under sampling/dropout, and ``_scbf_pass`` is
jitted on shapes, so executing at raw P would recompile on nearly every
round.  Rounding P up to a small set of static bucket sizes keeps the
number of compiled programs at O(log K) while wasting < 2x slots in the
worst case on a single pod (see docs/FED_ENGINE.md §Bucketed
participant padding for the multi-pod qualification).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Tuple

import jax.numpy as jnp
import numpy as np


@dataclass
class PaddedCohort:
    """K client shards stacked for one vmapped local-training pass."""

    x: jnp.ndarray           # (K, n_max, d) features, zero-padded
    y: jnp.ndarray           # (K, n_max) labels, zero-padded
    w: jnp.ndarray           # (K, n_max) example mask: 1 real, 0 padding
    counts: np.ndarray       # (K,) real examples per client (host)

    @property
    def num_clients(self) -> int:
        return int(self.x.shape[0])

    @property
    def n_max(self) -> int:
        return int(self.x.shape[1])

    @property
    def uniform(self) -> bool:
        """True iff no padding exists — every shard fills n_max rows.

        The engine uses this (a host-side, shape-level fact) to run the
        unweighted loss, which makes the K=5 full-participation path
        arithmetically identical to the sequential loop.
        """
        return bool(np.all(self.counts == self.n_max))


BUCKET_POLICIES = ("pow2", "exact")


def bucket_size(num_participants: int, num_clients: int,
                policy: str = "pow2", multiple: int = 1) -> int:
    """Static slot count for a round with ``num_participants`` reporters.

    ``pow2``   next power of two, capped at the (rounded-up) client
               count: at most ``floor(log2 K) + 2`` distinct compiled
               programs (+1 of those only when K is not itself a power
               of two — the capped top bucket), and with a single pod
               the padded slots never exceed the real ones (< 2x
               waste).
    ``exact``  no bucketing — one compile per distinct P, the
               pre-bucketing behaviour, kept as the reference.

    The result is always a multiple of ``multiple`` (the pod-mesh device
    count) so the slot axis shards evenly across devices; note this
    rounding can exceed the 2x waste bound for cohorts smaller than the
    device count (P=1 on 4 pods runs 4 slots).
    """
    if policy not in BUCKET_POLICIES:
        raise ValueError(
            f"unknown bucket policy {policy!r}; one of {BUCKET_POLICIES}")
    if num_participants <= 0:
        return 0
    if num_participants > num_clients:
        raise ValueError(f"{num_participants} participants > "
                         f"{num_clients} clients")
    mult = max(1, int(multiple))

    def up(n: int) -> int:
        return -(-n // mult) * mult

    if policy == "exact":
        return up(num_participants)
    pow2 = 1 << (num_participants - 1).bit_length()
    return min(up(pow2), up(num_clients))


def horizon_slot_plan(participants: Sequence[np.ndarray], num_slots: int,
                      horizon: int) -> Tuple[np.ndarray, np.ndarray]:
    """Static ``(S, B)`` participant-index / validity arrays for a fused
    chunk of ``horizon`` rounds with ``num_slots`` slots each.

    Row r holds round r's participant ids left-aligned; padding slots
    repeat the round's slot 0 (mirroring the per-round engine's
    ``_pad_slots``, which keeps padded slots numerically well-behaved —
    their outputs are zeroed by the validity mask).  Rounds beyond
    ``len(participants)`` (a short tail chunk padded up to the fused
    horizon) and empty rounds are all-invalid: the scan body computes
    garbage for them and the validity mask zeroes every output, so the
    carry passes through bitwise untouched.
    """
    if len(participants) > horizon:
        raise ValueError(f"{len(participants)} planned rounds exceed the "
                         f"fused horizon {horizon}")
    part_idx = np.zeros((horizon, num_slots), dtype=np.int32)
    valid = np.zeros((horizon, num_slots), dtype=bool)
    for r, part in enumerate(participants):
        p = np.asarray(part, dtype=np.int32)
        if p.size > num_slots:
            raise ValueError(f"round {r}: {p.size} participants exceed "
                             f"{num_slots} fused slots")
        if p.size:
            part_idx[r, :p.size] = p
            part_idx[r, p.size:] = p[0]
            valid[r, :p.size] = True
    return part_idx, valid


def fused_chunk_len(loops_left: int, fuse_rounds: int,
                    prune_active: bool) -> int:
    """Rounds in the next fused chunk (per-prune-epoch chunk splits).

    While SCBFwP pruning is still removing neurons the keep-mask
    changes after *every* round, and a fused chunk's mask is a
    run-constant input — so the driver plans single-round chunks until
    the cumulative budget is exhausted, then full ``fuse_rounds``
    chunks.  Prune-phase chunks plan at horizon 1 (their own compiled
    program — a degenerate one-round scan) instead of padding to the
    ``(S, B)`` horizon, trading one extra compile for not executing
    S-1 masked-out garbage rounds per prune epoch; post-pruning chunks
    pad to the run-constant horizon as usual, so the whole run stays
    at <= 2 fused compiles.
    """
    if loops_left < 1:
        raise ValueError(f"no loops left to chunk ({loops_left})")
    if prune_active:
        return 1
    return min(int(fuse_rounds), loops_left)


def pad_clients(clients: Sequence[Tuple[np.ndarray, np.ndarray]]
                ) -> PaddedCohort:
    """Stack ragged client shards into a rectangular padded cohort."""
    if not clients:
        raise ValueError("pad_clients needs at least one client shard")
    counts = np.array([c[0].shape[0] for c in clients], dtype=np.int64)
    if np.any(counts == 0):
        raise ValueError("every client shard must have >= 1 example")
    n_max = int(counts.max())
    d = int(clients[0][0].shape[1])
    K = len(clients)
    x = np.zeros((K, n_max, d), dtype=np.float32)
    y = np.zeros((K, n_max), dtype=np.float32)
    w = np.zeros((K, n_max), dtype=np.float32)
    for k, (xc, yc) in enumerate(clients):
        n = int(xc.shape[0])
        x[k, :n] = xc
        y[k, :n] = np.asarray(yc).reshape(-1)
        w[k, :n] = 1.0
    return PaddedCohort(jnp.asarray(x), jnp.asarray(y), jnp.asarray(w),
                        counts)
