"""APoZ accumulation kernel — zero-counting for the pruning statistic.

APoZ(neuron j) = (1/B) Σ_b [act[b, j] == 0] over the validation set.
This kernel counts exact zeros per column of an activation tile and
accumulates int32 counts across the batch grid axis, fusing what the jnp
reference does as compare -> cast -> reduce (three HBM-width passes) into
one resident-tile pass.  Batch streams through the grid so the validation
set never has to fit at once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BB = 512
DEFAULT_BN = 256


def _apoz_kernel(a_ref, cnt_ref):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    zeros = (a_ref[...] == 0).astype(jnp.int32)
    cnt_ref[...] += jnp.sum(zeros, axis=0)


@functools.partial(jax.jit, static_argnames=("bb", "bn", "interpret"))
def apoz_counts_pallas(acts: jnp.ndarray, bb: int = DEFAULT_BB,
                       bn: int = DEFAULT_BN, interpret: bool = True):
    """acts (B, N) -> zero counts (N,) int32."""
    b, n = acts.shape
    assert b % bb == 0 and n % bn == 0, (acts.shape, bb, bn)
    grid = (b // bb, n // bn)
    return pl.pallas_call(
        _apoz_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bb, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bn,), lambda i, j: (j,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(acts)
