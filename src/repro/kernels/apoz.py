"""APoZ accumulation kernel + the batched jitted APoZ scorer.

APoZ(neuron j) = (1/B) Σ_b [act[b, j] == 0] over the validation set.
The Pallas kernel counts exact zeros per column of an activation tile
and accumulates int32 counts across the batch grid axis, fusing what the
jnp reference does as compare -> cast -> reduce (three HBM-width passes)
into one resident-tile pass.  Batch streams through the grid so the
validation set never has to fit at once.

``apoz_batch_fractions`` is the scorer the pruning subsystem actually
calls: ONE module-level jitted program (cached per param/batch shape,
never rebuilt per call — the per-call ``jax.jit(lambda ...)`` it
replaces retraced on every pruning step) that runs the MLP activation
pass and reduces each hidden layer to its per-neuron zero fraction.
Mask-mode SCBFwP passes ``neuron_masks`` so pruned neurons read exactly
zero (APoZ 1.0; the planner excludes them), and the fused round loop
calls this same scorer at chunk boundaries — the whole APoZ statistic
is computed on device.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.models.mlp_net import mlp_activations

DEFAULT_BB = 512
DEFAULT_BN = 256


def _apoz_kernel(a_ref, cnt_ref):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    zeros = (a_ref[...] == 0).astype(jnp.int32)
    cnt_ref[...] += jnp.sum(zeros, axis=0)


@functools.partial(jax.jit, static_argnames=("bb", "bn", "interpret"))
def apoz_counts_pallas(acts: jnp.ndarray, bb: int = DEFAULT_BB,
                       bn: int = DEFAULT_BN, interpret: bool = True):
    """acts (B, N) -> zero counts (N,) int32."""
    b, n = acts.shape
    assert b % bb == 0 and n % bn == 0, (acts.shape, bb, bn)
    grid = (b // bb, n // bn)
    return pl.pallas_call(
        _apoz_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bb, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bn,), lambda i, j: (j,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(acts)


def _zero_fraction(act: jnp.ndarray) -> jnp.ndarray:
    """Per-column exact-zero fraction of one (B, N) activation block.

    Dispatches to the Pallas counting kernel when the block tiles
    evenly (count / B equals the jnp mean exactly for any realistic
    validation-set size, so the dispatch never changes the statistic)
    and falls back to the jnp reduction otherwise.
    """
    b, n = act.shape
    if b % DEFAULT_BB == 0 and n % DEFAULT_BN == 0:
        return apoz_counts_pallas(act).astype(jnp.float32) / b
    return jnp.mean((act == 0.0).astype(jnp.float32), axis=0)


@jax.jit
def apoz_batch_fractions(params, xb, neuron_masks=None):
    """Per-hidden-layer zero fractions of one validation batch.

    The module-level jitted APoZ scorer: jit's shape-keyed cache means
    each (param-geometry, batch, mask) signature compiles exactly once
    per process, however many pruning steps call it.  Streaming callers
    (repro.core.pruning.apoz_scores) accumulate these per-batch
    fractions into the full-set statistic.
    """
    acts = mlp_activations(params, xb, neuron_masks)
    return [_zero_fraction(a) for a in acts]


def apoz_scorer_compile_count() -> int:
    """Compiled-variant count of the batched APoZ scorer (jit cache).

    Same ``_cache_size`` introspection caveat as
    ``repro.fed.engine.scbf_compile_count``: not public API, pinned to
    the CI jax version.
    """
    try:
        return int(apoz_batch_fractions._cache_size())
    except AttributeError as e:
        raise RuntimeError(
            "jit cache introspection (_cache_size) is unavailable on this "
            "jax version; compile-count assertions need the pinned "
            "jax==0.4.37 API or an equivalent hook") from e


def reset_apoz_scorer_compile_count() -> None:
    try:
        apoz_batch_fractions._clear_cache()
    except AttributeError as e:
        raise RuntimeError(
            "jit cache clearing (_clear_cache) is unavailable on this "
            "jax version; compile-count assertions need the pinned "
            "jax==0.4.37 API or an equivalent hook") from e
