"""Fused channel-norm kernel: one pass over a gradient matrix producing
BOTH row (input-channel) and column (output-channel) squared norms.

The naive jnp version reads G twice (once per reduction axis); this
kernel tiles G into (BM, BN) VMEM blocks — 128-aligned for the VPU lanes
— and accumulates both partial reductions in fp32 scratch while each
block is resident, halving HBM traffic on the pass the paper runs every
global loop for every client.

Grid: (M/BM, N/BN), row-major.  Output row norms (M,) accumulate across
the N grid axis, column norms (N,) across the M grid axis; accumulation
uses @pl.when-guarded zero-init, the standard Pallas reduction idiom.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BM = 256
DEFAULT_BN = 256


def _channel_norm_kernel(g_ref, row_ref, col_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    g = g_ref[...].astype(jnp.float32)
    sq = g * g

    # zero-init the accumulators on their first visit
    @pl.when(j == 0)
    def _():
        row_ref[...] = jnp.zeros_like(row_ref)

    @pl.when(i == 0)
    def _():
        col_ref[...] = jnp.zeros_like(col_ref)

    row_ref[...] += jnp.sum(sq, axis=1)
    col_ref[...] += jnp.sum(sq, axis=0)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def channel_norms_pallas(g: jnp.ndarray, bm: int = DEFAULT_BM,
                         bn: int = DEFAULT_BN, interpret: bool = True):
    """g (M, N) -> (row (M,) fp32, col (N,) fp32).

    M, N must be multiples of (bm, bn) — ops.py pads otherwise.
    """
    m, n = g.shape
    assert m % bm == 0 and n % bn == 0, (g.shape, bm, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _channel_norm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(g)
