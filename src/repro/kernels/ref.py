"""Pure-jnp oracles for the Pallas kernels (the ground truth the
per-kernel shape/dtype sweeps assert against)."""
from __future__ import annotations

import jax.numpy as jnp


def channel_norms_ref(g: jnp.ndarray):
    """Row and column squared norms of a gradient matrix.

    g (m, n) -> (row (m,), col (n,)) in fp32.
    """
    gf = g.astype(jnp.float32)
    return jnp.sum(gf * gf, axis=1), jnp.sum(gf * gf, axis=0)


def select_mask_ref(g: jnp.ndarray, row_score: jnp.ndarray,
                    col_score: jnp.ndarray, threshold) -> jnp.ndarray:
    """Masked gradient: keep g[i,j] iff row_score[i]+col_score[j] > thr."""
    keep = (row_score[:, None] + col_score[None, :]) > threshold
    return jnp.where(keep, g, jnp.zeros_like(g))


def apoz_counts_ref(acts: jnp.ndarray) -> jnp.ndarray:
    """Count of exact zeros per neuron (column) — acts (batch, n) -> (n,)
    int32.  APoZ = counts / batch."""
    return jnp.sum((acts == 0).astype(jnp.int32), axis=0)


def scbf_select_fused_ref(g: jnp.ndarray, row_score, col_score, threshold):
    """Fused select + upload count: (masked_g, kept_entries:int32)."""
    keep = (row_score[:, None] + col_score[None, :]) > threshold
    masked = jnp.where(keep, g, jnp.zeros_like(g))
    return masked, jnp.sum(keep.astype(jnp.int32))
