"""Pure-jnp oracles for the Pallas kernels (the ground truth the
per-kernel shape/dtype sweeps assert against)."""
from __future__ import annotations

import jax.numpy as jnp


def channel_norms_ref(g: jnp.ndarray):
    """Row and column squared norms of a gradient matrix.

    g (m, n) -> (row (m,), col (n,)) in fp32.
    """
    gf = g.astype(jnp.float32)
    return jnp.sum(gf * gf, axis=1), jnp.sum(gf * gf, axis=0)


def select_mask_ref(g: jnp.ndarray, row_score: jnp.ndarray,
                    col_score: jnp.ndarray, threshold) -> jnp.ndarray:
    """Masked gradient: keep g[i,j] iff row_score[i]+col_score[j] > thr."""
    keep = (row_score[:, None] + col_score[None, :]) > threshold
    return jnp.where(keep, g, jnp.zeros_like(g))


def apoz_counts_ref(acts: jnp.ndarray) -> jnp.ndarray:
    """Count of exact zeros per neuron (column) — acts (batch, n) -> (n,)
    int32.  APoZ = counts / batch."""
    return jnp.sum((acts == 0).astype(jnp.int32), axis=0)


def scbf_select_fused_ref(g: jnp.ndarray, row_score, col_score, threshold):
    """Fused select + upload count: (masked_g, kept_entries:int32)."""
    keep = (row_score[:, None] + col_score[None, :]) > threshold
    masked = jnp.where(keep, g, jnp.zeros_like(g))
    return masked, jnp.sum(keep.astype(jnp.int32))


def select_compact_ref(g: jnp.ndarray, row_score, col_score, threshold,
                       capacity: int = None):
    """Select-and-compact oracle: row-major COO buffers of the kept
    entries, (idx (capacity,) int32, vals (capacity,) fp32, count int32).
    Unused tail is idx=-1 / val=0; entries past capacity drop."""
    m, n = g.shape
    if capacity is None:
        capacity = m * n
    keep = ((row_score[:, None] + col_score[None, :]) > threshold).reshape(-1)
    (idx,) = jnp.nonzero(keep, size=capacity, fill_value=-1)
    idx = idx.astype(jnp.int32)
    vals = jnp.where(
        idx >= 0,
        g.reshape(-1).astype(jnp.float32)[jnp.maximum(idx, 0)],
        jnp.float32(0))
    return idx, vals, jnp.sum(keep.astype(jnp.int32))
