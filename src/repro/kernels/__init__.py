"""Pallas TPU kernels for SCBF's per-loop gradient pass.

The compute hot-spot the paper optimises (via pruning) is the per-loop
channel-norm + selection pass over every gradient matrix — a
bandwidth-bound reduction + masked rewrite.  Three fused kernels:

  channel_norm  — one pass over G producing row (input-channel) and
                  column (output-channel) squared norms
  select_mask   — threshold-masked gradient rewrite (the "Process
                  Gradients" step) fused with the pairwise score test
  apoz          — zero-fraction accumulation over activation tiles for
                  the APoZ pruning statistic

``ops.py`` exposes jit'd wrappers (with interpret=True on CPU);
``ref.py`` holds the pure-jnp oracles the tests sweep against.
"""
from repro.kernels.ops import (channel_norms, select_mask, apoz_counts,
                               scbf_select_fused)
