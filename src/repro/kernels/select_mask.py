"""Fused channel-selection kernel — the paper's "Process Gradients" step.

Given per-row and per-column channel scores and the α-quantile threshold,
rewrite each (BM, BN) gradient tile as

    g̃[i,j] = g[i,j]   if row[i] + col[j] > threshold else 0

and simultaneously count the kept entries (the upload-bytes statistic of
EXPERIMENTS.md §Paper-validation).  Fusing the pairwise score test into
the rewrite avoids materialising the (M, N) boolean mask in HBM — the
jnp reference builds it, tripling traffic on large gradient matrices.

The threshold arrives as a (1, 1) block in SMEM-style spec; the count
accumulates in an int32 (1,) output visited by every grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 256
DEFAULT_BN = 256


def _select_mask_kernel(thr_ref, g_ref, row_ref, col_ref, out_ref, cnt_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    thr = thr_ref[0]
    keep = (row_ref[...][:, None] + col_ref[...][None, :]) > thr
    g = g_ref[...]
    out_ref[...] = jnp.where(keep, g, jnp.zeros_like(g))
    cnt_ref[...] += jnp.sum(keep.astype(jnp.int32))[None]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def select_mask_pallas(g: jnp.ndarray, row: jnp.ndarray, col: jnp.ndarray,
                       threshold, bm: int = DEFAULT_BM,
                       bn: int = DEFAULT_BN, interpret: bool = True):
    """(masked g̃ like g, kept count (1,) int32)."""
    m, n = g.shape
    assert m % bm == 0 and n % bn == 0, (g.shape, bm, bn)
    grid = (m // bm, n // bn)
    thr = jnp.asarray(threshold, jnp.float32).reshape(1)
    return pl.pallas_call(
        _select_mask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),           # threshold
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),     # g
            pl.BlockSpec((bm,), lambda i, j: (i,)),          # row scores
            pl.BlockSpec((bn,), lambda i, j: (j,)),          # col scores
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), g.dtype),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(thr, g, row, col)
