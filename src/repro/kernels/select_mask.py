"""Fused channel-selection kernel — the paper's "Process Gradients" step.

Given per-row and per-column channel scores and the α-quantile threshold,
rewrite each (BM, BN) gradient tile as

    g̃[i,j] = g[i,j]   if row[i] + col[j] > threshold else 0

and simultaneously count the kept entries (the upload-bytes statistic of
EXPERIMENTS.md §Paper-validation).  Fusing the pairwise score test into
the rewrite avoids materialising the (M, N) boolean mask in HBM — the
jnp reference builds it, tripling traffic on large gradient matrices.

The threshold arrives as a (1, 1) block in SMEM-style spec; the count
accumulates in an int32 (1,) output visited by every grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 256
DEFAULT_BN = 256


def _select_mask_kernel(thr_ref, g_ref, row_ref, col_ref, out_ref, cnt_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    thr = thr_ref[0]
    keep = (row_ref[...][:, None] + col_ref[...][None, :]) > thr
    g = g_ref[...]
    out_ref[...] = jnp.where(keep, g, jnp.zeros_like(g))
    cnt_ref[...] += jnp.sum(keep.astype(jnp.int32))[None]


def _select_compact_kernel(thr_ref, g_ref, row_ref, col_ref,
                           idx_ref, val_ref, cnt_ref):
    """Fused threshold test + compaction of kept entries.

    Grid is 1-D over row blocks; each step appends its kept entries to
    the (capacity,) COO output buffers at the running offset carried in
    ``cnt_ref`` (the grid executes sequentially, so the offset is exact
    and the output order is row-major).  Entries past capacity drop.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        idx_ref[...] = jnp.full_like(idx_ref, -1)
        val_ref[...] = jnp.zeros_like(val_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    thr = thr_ref[0]
    keep = (row_ref[...][:, None] + col_ref[...][None, :]) > thr
    g = g_ref[...].astype(jnp.float32)
    bm, n = g.shape
    kp = keep.reshape(-1)
    kpi = kp.astype(jnp.int32)
    base = i * bm * n
    gidx = base + jax.lax.iota(jnp.int32, bm * n)      # global flat index
    off = cnt_ref[0]
    pos = off + jnp.cumsum(kpi) - kpi                  # exclusive prefix sum
    cap = idx_ref.shape[0]
    target = jnp.where(kp, pos, cap)                   # cap → dropped
    idx_ref[...] = idx_ref[...].at[target].set(gidx, mode="drop")
    val_ref[...] = val_ref[...].at[target].set(g.reshape(-1), mode="drop")
    cnt_ref[...] = (off + jnp.sum(kpi))[None]


@functools.partial(jax.jit, static_argnames=("bm", "capacity", "interpret"))
def select_compact_pallas(g: jnp.ndarray, row: jnp.ndarray,
                          col: jnp.ndarray, threshold,
                          bm: int = DEFAULT_BM,
                          capacity: int = None, interpret: bool = True):
    """(idx (capacity,) int32, vals (capacity,) fp32, count (1,) int32).

    One pass over g: the pairwise score test and the gather of kept
    entries into the COO buffer are fused, so the boolean mask and the
    dense masked gradient are never materialised as separate arrays.
    The output buffers ARE revisited by every grid step (the running
    offset forces it), so their traffic scales with grid * capacity —
    keep ``capacity`` near the expected kept count on large inputs
    rather than the m*n worst case.  Unused buffer tail is idx=-1 /
    val=0; ``count`` is the true kept total (compare against capacity
    to detect truncation).
    """
    m, n = g.shape
    assert m % bm == 0, (g.shape, bm)
    if capacity is None:
        capacity = m * n
    thr = jnp.asarray(threshold, jnp.float32).reshape(1)
    return pl.pallas_call(
        _select_compact_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),              # threshold
            pl.BlockSpec((bm, n), lambda i: (i, 0)),         # g row block
            pl.BlockSpec((bm,), lambda i: (i,)),             # row scores
            pl.BlockSpec((n,), lambda i: (0,)),              # col scores
        ],
        out_specs=[
            pl.BlockSpec((capacity,), lambda i: (0,)),
            pl.BlockSpec((capacity,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((capacity,), jnp.int32),
            jax.ShapeDtypeStruct((capacity,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(thr, g, row, col)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def select_mask_pallas(g: jnp.ndarray, row: jnp.ndarray, col: jnp.ndarray,
                       threshold, bm: int = DEFAULT_BM,
                       bn: int = DEFAULT_BN, interpret: bool = True):
    """(masked g̃ like g, kept count (1,) int32)."""
    m, n = g.shape
    assert m % bm == 0 and n % bn == 0, (g.shape, bm, bn)
    grid = (m // bm, n // bn)
    thr = jnp.asarray(threshold, jnp.float32).reshape(1)
    return pl.pallas_call(
        _select_mask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),           # threshold
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),     # g
            pl.BlockSpec((bm,), lambda i, j: (i,)),          # row scores
            pl.BlockSpec((bn,), lambda i, j: (j,)),          # col scores
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), g.dtype),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(thr, g, row, col)
