"""jit'd public wrappers around the Pallas kernels.

CPU runs use interpret=True (the kernel body executes in Python with
numpy semantics — correctness validation); on TPU the same calls compile
to Mosaic.  Inputs are padded up to block multiples here so the kernels
themselves stay branch-free; padding is score-neutral (zeros contribute
nothing to squared norms, padded entries are masked out of counts).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.apoz import apoz_counts_pallas
from repro.kernels.channel_norm import channel_norms_pallas
from repro.kernels.select_mask import (select_compact_pallas,
                                       select_mask_pallas)

_INTERPRET = jax.default_backend() == "cpu"


def _pad2(x, bm, bn, value=0.0):
    m, n = x.shape
    pm = (-m) % bm
    pn = (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)), constant_values=value)
    return x, m, n


def channel_norms(g: jnp.ndarray, bm: int = 256, bn: int = 256,
                  interpret: bool = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Row and column squared norms of g (M,N), fp32, via one fused pass."""
    interpret = _INTERPRET if interpret is None else interpret
    bm = min(bm, max(8, g.shape[0]))
    bn = min(bn, max(8, g.shape[1]))
    gp, m, n = _pad2(g, bm, bn)
    row, col = channel_norms_pallas(gp, bm=bm, bn=bn, interpret=interpret)
    return row[:m], col[:n]


def select_mask(g: jnp.ndarray, row: jnp.ndarray, col: jnp.ndarray,
                threshold, bm: int = 256, bn: int = 256,
                interpret: bool = None) -> jnp.ndarray:
    """Masked gradient g̃ (keep where row[i]+col[j] > threshold)."""
    interpret = _INTERPRET if interpret is None else interpret
    bm = min(bm, max(8, g.shape[0]))
    bn = min(bn, max(8, g.shape[1]))
    gp, m, n = _pad2(g, bm, bn)
    neg = jnp.float32(-jnp.inf)
    rowp = jnp.pad(row.astype(jnp.float32), (0, gp.shape[0] - m),
                   constant_values=neg)
    colp = jnp.pad(col.astype(jnp.float32), (0, gp.shape[1] - n),
                   constant_values=neg)
    out, _ = select_mask_pallas(gp, rowp, colp, threshold,
                                bm=bm, bn=bn, interpret=interpret)
    return out[:m, :n]


def scbf_select_fused(g: jnp.ndarray, row: jnp.ndarray, col: jnp.ndarray,
                      threshold, bm: int = 256, bn: int = 256,
                      interpret: bool = None):
    """(masked g̃, kept-entry count) in one kernel launch."""
    interpret = _INTERPRET if interpret is None else interpret
    bm = min(bm, max(8, g.shape[0]))
    bn = min(bn, max(8, g.shape[1]))
    gp, m, n = _pad2(g, bm, bn)
    neg = jnp.float32(-jnp.inf)
    rowp = jnp.pad(row.astype(jnp.float32), (0, gp.shape[0] - m),
                   constant_values=neg)
    colp = jnp.pad(col.astype(jnp.float32), (0, gp.shape[1] - n),
                   constant_values=neg)
    out, cnt = select_mask_pallas(gp, rowp, colp, threshold,
                                  bm=bm, bn=bn, interpret=interpret)
    return out[:m, :n], cnt[0]


def select_compact(g: jnp.ndarray, row: jnp.ndarray, col: jnp.ndarray,
                   threshold, capacity: int = None, bm: int = 256,
                   interpret: bool = None):
    """Fused select-and-compact: one pass turns g (M,N) into COO upload
    buffers (idx (capacity,) int32, vals (capacity,) fp32, count int32),
    keeping entries where row[i]+col[j] > threshold, without
    materialising the mask or the dense masked gradient as separate
    arrays.  Default capacity is M*N (never truncates) — but the
    output buffers are revisited every grid step, so pass a capacity
    near the expected kept count (e.g. from the upload rate) on large
    inputs and compare ``count`` against it to detect dropped entries.

    The running-offset compaction needs the grid to execute
    sequentially, which only interpret mode guarantees on every
    backend, so this kernel defaults to interpret=True everywhere (the
    other kernels compile on TPU); pass interpret=False only on a
    backend whose grid is sequential.
    """
    interpret = True if interpret is None else interpret
    m, n = g.shape
    if capacity is None:
        capacity = m * n
    bm = min(bm, max(8, m))
    pm = (-m) % bm
    gp = jnp.pad(g, ((0, pm), (0, 0))) if pm else g
    # padded rows get -inf scores so they are never selected; columns are
    # not padded, so kernel flat indices are already g's flat indices
    rowp = jnp.pad(row.astype(jnp.float32), (0, pm),
                   constant_values=jnp.float32(-jnp.inf))
    idx, vals, cnt = select_compact_pallas(gp, rowp, col.astype(jnp.float32),
                                           threshold, bm=bm,
                                           capacity=capacity,
                                           interpret=interpret)
    return idx, vals, cnt[0]


def apoz_counts(acts: jnp.ndarray, bb: int = 512, bn: int = 256,
                interpret: bool = None) -> jnp.ndarray:
    """Zero counts per neuron over the batch; APoZ = counts / batch."""
    interpret = _INTERPRET if interpret is None else interpret
    bb = min(bb, max(8, acts.shape[0]))
    bn = min(bn, max(8, acts.shape[1]))
    # pad batch rows with ones (non-zero → contribute no zero counts)
    ap, b, n = _pad2(acts, bb, bn, value=1.0)
    cnt = apoz_counts_pallas(ap, bb=bb, bn=bn, interpret=interpret)
    return cnt[:n]
