"""Sparse channel-exchange subsystem: wire formats + byte accounting.

Single source of truth for what SCBF ships over the network and what it
costs — see ``repro.comm.wire`` and docs/WIRE_FORMAT.md.
"""
from repro.comm.wire import (LayerPayload, Payload, apply_payloads,
                             bitmap_bytes, cheapest_bytes,
                             codec_breakdown, codec_bytes,
                             coo_bytes, decode, dense_bytes, encode,
                             encode_leaf, tree_dense_bytes)

__all__ = [
    "LayerPayload", "Payload", "apply_payloads", "bitmap_bytes",
    "cheapest_bytes", "codec_breakdown", "codec_bytes", "coo_bytes",
    "decode", "dense_bytes", "encode", "encode_leaf", "tree_dense_bytes",
]
