"""Sparse channel-exchange wire formats — what SCBF actually ships.

The paper's §3 communication claim is that uploading only the top-α
channel gradients saves bytes versus FedAvg's full-weight exchange.  The
seed simulated that claim with a flat 8-bytes-per-nonzero model, which
*loses* to dense once the edge-union of selected channels passes 50% of
entries.  This module replaces the simulation with real payloads and is
the single source of truth for upload-byte accounting.

Three codecs per layer (leaf), cheapest wins:

  ``coo``     int32 flat index + value per kept entry
              → nnz * (4 + itemsize) bytes
  ``bitmap``  1 bit per entry (packed) + values of kept entries
              → ceil(size / 8) + nnz * itemsize bytes
  ``dense``   every entry, no index structure
              → size * itemsize bytes

``min(coo, bitmap, dense) <= dense`` holds by construction, so the
sparse exchange can never cost more than FedAvg's dense one.  Encoding
is lossless: kept values travel in their original dtype, masked-out
entries decode back to exact zeros.

Payloads hold host (numpy) buffers — they model bytes crossing the
network, not device arrays — and are produced/consumed at the federated
loop boundary, outside any jit trace.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

INDEX_BYTES = 4                      # int32 flat index (coo)

CODECS = ("coo", "bitmap", "dense")


class PayloadError(ValueError):
    """A wire payload failed structural validation.

    Raised before any index reaches a device scatter: JAX's ``.at[]``
    silently *drops* out-of-range indices, so without this gate a
    truncated or corrupted payload would "succeed" while quietly losing
    updates.  Decoders and the server admission gate catch this and
    reject the payload rather than applying it.
    """


def coo_bytes(nnz: int, size: int, itemsize: int = 4) -> int:
    return nnz * (INDEX_BYTES + itemsize)


def bitmap_bytes(nnz: int, size: int, itemsize: int = 4) -> int:
    return math.ceil(size / 8) + nnz * itemsize


def dense_bytes(size: int, itemsize: int = 4) -> int:
    return size * itemsize


def codec_bytes(codec: str, nnz: int, size: int, itemsize: int = 4) -> int:
    if codec == "coo":
        return coo_bytes(nnz, size, itemsize)
    if codec == "bitmap":
        return bitmap_bytes(nnz, size, itemsize)
    if codec == "dense":
        return dense_bytes(size, itemsize)
    raise ValueError(f"unknown codec {codec!r}")


def cheapest_bytes(nnz: int, size: int, itemsize: int = 4
                   ) -> Tuple[str, int]:
    """(codec, bytes) of the cheapest encoding for nnz kept of size."""
    return min(((c, codec_bytes(c, nnz, size, itemsize)) for c in CODECS),
               key=lambda cb: cb[1])


@dataclass(frozen=True)
class LayerPayload:
    """One leaf of a delta pytree on the wire."""

    codec: str                       # coo | bitmap | dense
    shape: Tuple[int, ...]
    dtype: np.dtype
    nnz: int                         # kept (transmitted-value) entries
    nbytes: int                      # wire size under ``codec``
    idx: Optional[np.ndarray]        # (nnz,) int32 flat indices — coo only
    bitmap: Optional[np.ndarray]     # packed uint8 mask — bitmap only
    values: np.ndarray               # kept values (coo/bitmap) or full flat

    @property
    def size(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) if self.shape else 1

    def flat_indices(self) -> np.ndarray:
        """int32 flat indices of the transmitted entries (any codec)."""
        if self.codec == "coo":
            return self.idx
        if self.codec == "bitmap":
            mask = np.unpackbits(self.bitmap, count=self.size)
            return np.flatnonzero(mask).astype(np.int32)
        return np.arange(self.size, dtype=np.int32)


@dataclass(frozen=True)
class PayloadMeta:
    """Integrity envelope a sealed payload carries on the wire.

    ``checksum`` is a CRC-32 over every layer's header fields and
    buffers (``payload_checksum``), computed when the *sender* seals
    the payload — any post-seal corruption (bit flips in transit)
    fails verification server-side.  ``(client_id, round_index)`` is
    the dedup nonce: the server admits each (client, round) upload at
    most once, so replayed/duplicated payloads are rejected.
    """

    client_id: int
    round_index: int
    checksum: int

    @property
    def nonce(self) -> Tuple[int, int]:
        return (self.client_id, self.round_index)


@dataclass(frozen=True)
class Payload:
    """A full delta pytree on the wire (one client's upload).

    ``meta`` is the optional integrity envelope (``seal``): unsealed
    payloads still pass structural validation but skip checksum and
    dedup checks — sealing is the driver's job at the trust boundary.
    """

    treedef: jax.tree_util.PyTreeDef
    layers: Tuple[LayerPayload, ...]
    meta: Optional[PayloadMeta] = None

    @property
    def nbytes(self) -> int:
        return sum(lp.nbytes for lp in self.layers)

    @property
    def dense_nbytes(self) -> int:
        return sum(dense_bytes(lp.size, lp.dtype.itemsize)
                   for lp in self.layers)


def encode_leaf(leaf, codec: str = "auto") -> LayerPayload:
    """Encode one masked array; zeros are treated as masked-out."""
    a = np.asarray(leaf)
    flat = a.reshape(-1)
    nz = np.flatnonzero(flat).astype(np.int32)
    nnz, size, itemsize = int(nz.size), int(flat.size), flat.dtype.itemsize
    if codec == "auto":
        codec, nbytes = cheapest_bytes(nnz, size, itemsize)
    else:
        nbytes = codec_bytes(codec, nnz, size, itemsize)
    if codec == "coo":
        return LayerPayload(codec, a.shape, flat.dtype, nnz, nbytes,
                            idx=nz, bitmap=None, values=flat[nz].copy())
    if codec == "bitmap":
        mask = np.zeros(size, np.uint8)
        mask[nz] = 1
        return LayerPayload(codec, a.shape, flat.dtype, nnz, nbytes,
                            idx=None, bitmap=np.packbits(mask),
                            values=flat[nz].copy())
    return LayerPayload(codec, a.shape, flat.dtype, size, nbytes,
                        idx=None, bitmap=None, values=flat.copy())


def encode(tree, codec: str = "auto") -> Payload:
    """Encode a masked delta pytree; per leaf the cheapest codec wins."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return Payload(treedef, tuple(encode_leaf(l, codec) for l in leaves))


def codec_breakdown(payloads) -> dict:
    """Total wire bytes by winning codec over a batch of ``Payload``s.

    Keys are every ``CODECS`` name (zero-filled), so downstream
    telemetry (repro.obs) gets a stable schema whatever the deltas
    looked like this round.
    """
    out = {c: 0 for c in CODECS}
    for p in payloads:
        for lp in p.layers:
            out[lp.codec] += lp.nbytes
    return out


def validate_layer(lp: LayerPayload, leaf_shape: Optional[Tuple[int, ...]]
                   = None) -> None:
    """Structural validation of one wire leaf; raises ``PayloadError``.

    Checks everything a decoder is about to trust: codec name, nnz vs
    buffer sizes, index dtype and bounds ``[0, size)``, bitmap length
    and popcount, and (when ``leaf_shape`` is given) the declared shape
    against the server's parameter leaf.  This must run before any
    scatter: JAX drops out-of-range indices silently and numpy wraps
    negative ones, so unvalidated corruption would otherwise be applied
    *partially* instead of rejected.
    """
    if lp.codec not in CODECS:
        raise PayloadError(f"unknown codec {lp.codec!r}")
    if leaf_shape is not None and tuple(lp.shape) != tuple(leaf_shape):
        raise PayloadError(f"payload shape {tuple(lp.shape)} != "
                           f"param shape {tuple(leaf_shape)}")
    size = lp.size
    if not 0 <= lp.nnz <= size:
        raise PayloadError(f"nnz {lp.nnz} outside [0, {size}]")
    values = np.asarray(lp.values)
    if values.ndim != 1:
        raise PayloadError(f"values must be 1-D, got shape {values.shape}")
    if np.dtype(values.dtype) != np.dtype(lp.dtype):
        raise PayloadError(f"values dtype {values.dtype} != declared "
                           f"{np.dtype(lp.dtype)}")
    if lp.codec == "dense":
        if values.size != size:
            raise PayloadError(f"dense values size {values.size} != "
                               f"leaf size {size}")
        return
    if values.size != lp.nnz:
        raise PayloadError(f"{lp.codec} values size {values.size} != "
                           f"nnz {lp.nnz}")
    if lp.codec == "coo":
        idx = lp.idx
        if idx is None or not np.issubdtype(np.asarray(idx).dtype,
                                            np.integer):
            raise PayloadError("coo indices missing or non-integral")
        idx = np.asarray(idx)
        if idx.size != lp.nnz:
            raise PayloadError(f"coo idx size {idx.size} != nnz {lp.nnz}")
        if idx.size and (int(idx.min()) < 0 or int(idx.max()) >= size):
            raise PayloadError(
                f"coo index out of bounds: [{int(idx.min())}, "
                f"{int(idx.max())}] not within [0, {size})")
        return
    bitmap = lp.bitmap                                    # codec == bitmap
    if bitmap is None:
        raise PayloadError("bitmap payload missing its bitmap")
    bitmap = np.asarray(bitmap)
    if bitmap.dtype != np.uint8 or bitmap.size != math.ceil(size / 8):
        raise PayloadError(f"bitmap buffer {bitmap.dtype}[{bitmap.size}] "
                           f"!= uint8[{math.ceil(size / 8)}]")
    pop = int(np.unpackbits(bitmap, count=size).sum())
    tail = int(np.unpackbits(bitmap)[size:].sum())
    if pop != lp.nnz or tail:
        raise PayloadError(f"bitmap popcount {pop} (+{tail} tail bits) "
                           f"!= nnz {lp.nnz}")


def validate_payload(payload: Payload, params=None) -> None:
    """Validate every leaf of a payload (``PayloadError`` on failure).

    ``params``: optional server parameter pytree to check leaf count
    and shapes against — the same checks ``apply_payloads`` enforces.
    """
    shapes = None
    if params is not None:
        leaves = jax.tree_util.tree_leaves(params)
        if len(payload.layers) != len(leaves):
            raise PayloadError(
                f"payload has {len(payload.layers)} leaves, params have "
                f"{len(leaves)}")
        shapes = [tuple(np.shape(l)) for l in leaves]
    for i, lp in enumerate(payload.layers):
        try:
            validate_layer(lp, shapes[i] if shapes else None)
        except PayloadError as e:
            raise PayloadError(f"leaf {i}: {e}") from None


def payload_checksum(payload: Payload) -> int:
    """CRC-32 over every layer's header fields and wire buffers."""
    crc = 0
    for lp in payload.layers:
        header = f"{lp.codec}|{tuple(lp.shape)}|{np.dtype(lp.dtype)}|" \
                 f"{lp.nnz}".encode()
        crc = zlib.crc32(header, crc)
        if lp.idx is not None:
            crc = zlib.crc32(np.ascontiguousarray(lp.idx), crc)
        if lp.bitmap is not None:
            crc = zlib.crc32(np.ascontiguousarray(lp.bitmap), crc)
        crc = zlib.crc32(np.ascontiguousarray(lp.values), crc)
    return crc


def seal(payload: Payload, client_id: int, round_index: int) -> Payload:
    """Attach the integrity envelope: checksum + (client, round) nonce.

    Called by the sender at the trust boundary, after any client-side
    fault but before the bytes 'cross the network' — so wire-level
    corruption is detectable and replays are dedupable server-side.
    """
    meta = PayloadMeta(client_id=int(client_id),
                       round_index=int(round_index),
                       checksum=payload_checksum(payload))
    return dataclasses.replace(payload, meta=meta)


def verify_checksum(payload: Payload) -> bool:
    """True iff the sealed checksum matches the buffers (unsealed: True —
    there is nothing to verify against)."""
    if payload.meta is None:
        return True
    return payload_checksum(payload) == payload.meta.checksum


def decode_leaf(lp: LayerPayload) -> jnp.ndarray:
    validate_layer(lp)
    if lp.codec == "dense":
        flat = lp.values
    else:
        flat = np.zeros(lp.size, lp.dtype)
        flat[lp.flat_indices()] = lp.values
    return jnp.asarray(flat.reshape(lp.shape))


def decode(payload: Payload):
    """Lossless inverse of encode: masked entries come back exact zeros."""
    return jax.tree_util.tree_unflatten(
        payload.treedef, [decode_leaf(lp) for lp in payload.layers])


def tree_dense_bytes(tree) -> int:
    """Bytes a dense (FedAvg-style) exchange of this pytree would cost."""
    return sum(dense_bytes(l.size, np.dtype(l.dtype).itemsize)
               for l in jax.tree_util.tree_leaves(tree))


def apply_payloads(params, payloads: Sequence[Payload]):
    """W <- W + Σ_k decode(payload_k), without materialising K dense deltas.

    Per leaf, the client deltas accumulate **delta-first in client
    order** into one zero-initialised f32 buffer, which is then added to
    the parameters once: runs of consecutive coo/bitmap clients
    concatenate their (index, value) buffers into a single scatter-add
    (``.at[idx].add``), and each dense-codec client folds in as one
    vector add at its position in the order — so the per-coordinate
    accumulation order is the client order regardless of which codec
    each client's encoder picked.  Codec choice is data-dependent and
    must never change the arithmetic: this exact order is what the
    fused path's on-device slot-ordered reduction
    (``repro.fed.strategy.scbf_sum_step``) mirrors, making the two
    bit-identical.  (That parity additionally assumes the backend's
    scatter applies duplicate indices in update order — true of the
    backends we run, and pinned by the parity tests rather than by the
    XLA spec.)  Peak extra memory is one dense leaf plus the compact
    buffers — never K dense pytrees.
    """
    leaves, treedef = jax.tree_util.tree_flatten(params)
    n = len(leaves)
    # per leaf: ordered ops, each ("scatter", idx, val) | ("dense", val)
    ops: List[List[Tuple]] = [[] for _ in range(n)]
    for p in payloads:
        if len(p.layers) != n:
            raise PayloadError("payload structure does not match params")
        for i, lp in enumerate(p.layers):
            # full structural gate (bounds/dtype/nnz) before any scatter:
            # JAX would silently drop out-of-range indices (see
            # PayloadError) — a corrupt payload must fail, not half-apply
            validate_layer(lp, tuple(leaves[i].shape))
            if lp.codec == "dense":
                ops[i].append(("dense", lp.values.astype(np.float32)))
            else:
                ops[i].append(("scatter", lp.flat_indices(),
                               lp.values.astype(np.float32)))
    out = []
    for i, leaf in enumerate(leaves):
        flat = leaf.reshape(-1).astype(jnp.float32)
        if ops[i]:
            acc = jnp.zeros(flat.shape, jnp.float32)
            pend_idx: List[np.ndarray] = []
            pend_val: List[np.ndarray] = []

            def flush(acc):
                if pend_idx:
                    cat_idx = jnp.asarray(np.concatenate(pend_idx))
                    cat_val = jnp.asarray(np.concatenate(pend_val))
                    acc = acc.at[cat_idx].add(cat_val)
                    pend_idx.clear()
                    pend_val.clear()
                return acc

            for op in ops[i]:
                if op[0] == "scatter":
                    pend_idx.append(op[1])
                    pend_val.append(op[2])
                else:
                    acc = flush(acc) + jnp.asarray(op[1])
            acc = flush(acc)
            flat = flat + acc
        out.append(flat.reshape(leaf.shape).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
