"""Sparse channel-exchange wire formats — what SCBF actually ships.

The paper's §3 communication claim is that uploading only the top-α
channel gradients saves bytes versus FedAvg's full-weight exchange.  The
seed simulated that claim with a flat 8-bytes-per-nonzero model, which
*loses* to dense once the edge-union of selected channels passes 50% of
entries.  This module replaces the simulation with real payloads and is
the single source of truth for upload-byte accounting.

Three codecs per layer (leaf), cheapest wins:

  ``coo``     int32 flat index + value per kept entry
              → nnz * (4 + itemsize) bytes
  ``bitmap``  1 bit per entry (packed) + values of kept entries
              → ceil(size / 8) + nnz * itemsize bytes
  ``dense``   every entry, no index structure
              → size * itemsize bytes

``min(coo, bitmap, dense) <= dense`` holds by construction, so the
sparse exchange can never cost more than FedAvg's dense one.  Encoding
is lossless: kept values travel in their original dtype, masked-out
entries decode back to exact zeros.

Payloads hold host (numpy) buffers — they model bytes crossing the
network, not device arrays — and are produced/consumed at the federated
loop boundary, outside any jit trace.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

INDEX_BYTES = 4                      # int32 flat index (coo)

CODECS = ("coo", "bitmap", "dense")


def coo_bytes(nnz: int, size: int, itemsize: int = 4) -> int:
    return nnz * (INDEX_BYTES + itemsize)


def bitmap_bytes(nnz: int, size: int, itemsize: int = 4) -> int:
    return math.ceil(size / 8) + nnz * itemsize


def dense_bytes(size: int, itemsize: int = 4) -> int:
    return size * itemsize


def codec_bytes(codec: str, nnz: int, size: int, itemsize: int = 4) -> int:
    if codec == "coo":
        return coo_bytes(nnz, size, itemsize)
    if codec == "bitmap":
        return bitmap_bytes(nnz, size, itemsize)
    if codec == "dense":
        return dense_bytes(size, itemsize)
    raise ValueError(f"unknown codec {codec!r}")


def cheapest_bytes(nnz: int, size: int, itemsize: int = 4
                   ) -> Tuple[str, int]:
    """(codec, bytes) of the cheapest encoding for nnz kept of size."""
    return min(((c, codec_bytes(c, nnz, size, itemsize)) for c in CODECS),
               key=lambda cb: cb[1])


@dataclass(frozen=True)
class LayerPayload:
    """One leaf of a delta pytree on the wire."""

    codec: str                       # coo | bitmap | dense
    shape: Tuple[int, ...]
    dtype: np.dtype
    nnz: int                         # kept (transmitted-value) entries
    nbytes: int                      # wire size under ``codec``
    idx: Optional[np.ndarray]        # (nnz,) int32 flat indices — coo only
    bitmap: Optional[np.ndarray]     # packed uint8 mask — bitmap only
    values: np.ndarray               # kept values (coo/bitmap) or full flat

    @property
    def size(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) if self.shape else 1

    def flat_indices(self) -> np.ndarray:
        """int32 flat indices of the transmitted entries (any codec)."""
        if self.codec == "coo":
            return self.idx
        if self.codec == "bitmap":
            mask = np.unpackbits(self.bitmap, count=self.size)
            return np.flatnonzero(mask).astype(np.int32)
        return np.arange(self.size, dtype=np.int32)


@dataclass(frozen=True)
class Payload:
    """A full delta pytree on the wire (one client's upload)."""

    treedef: jax.tree_util.PyTreeDef
    layers: Tuple[LayerPayload, ...]

    @property
    def nbytes(self) -> int:
        return sum(lp.nbytes for lp in self.layers)

    @property
    def dense_nbytes(self) -> int:
        return sum(dense_bytes(lp.size, lp.dtype.itemsize)
                   for lp in self.layers)


def encode_leaf(leaf, codec: str = "auto") -> LayerPayload:
    """Encode one masked array; zeros are treated as masked-out."""
    a = np.asarray(leaf)
    flat = a.reshape(-1)
    nz = np.flatnonzero(flat).astype(np.int32)
    nnz, size, itemsize = int(nz.size), int(flat.size), flat.dtype.itemsize
    if codec == "auto":
        codec, nbytes = cheapest_bytes(nnz, size, itemsize)
    else:
        nbytes = codec_bytes(codec, nnz, size, itemsize)
    if codec == "coo":
        return LayerPayload(codec, a.shape, flat.dtype, nnz, nbytes,
                            idx=nz, bitmap=None, values=flat[nz].copy())
    if codec == "bitmap":
        mask = np.zeros(size, np.uint8)
        mask[nz] = 1
        return LayerPayload(codec, a.shape, flat.dtype, nnz, nbytes,
                            idx=None, bitmap=np.packbits(mask),
                            values=flat[nz].copy())
    return LayerPayload(codec, a.shape, flat.dtype, size, nbytes,
                        idx=None, bitmap=None, values=flat.copy())


def encode(tree, codec: str = "auto") -> Payload:
    """Encode a masked delta pytree; per leaf the cheapest codec wins."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return Payload(treedef, tuple(encode_leaf(l, codec) for l in leaves))


def codec_breakdown(payloads) -> dict:
    """Total wire bytes by winning codec over a batch of ``Payload``s.

    Keys are every ``CODECS`` name (zero-filled), so downstream
    telemetry (repro.obs) gets a stable schema whatever the deltas
    looked like this round.
    """
    out = {c: 0 for c in CODECS}
    for p in payloads:
        for lp in p.layers:
            out[lp.codec] += lp.nbytes
    return out


def decode_leaf(lp: LayerPayload) -> jnp.ndarray:
    if lp.codec == "dense":
        flat = lp.values
    else:
        flat = np.zeros(lp.size, lp.dtype)
        flat[lp.flat_indices()] = lp.values
    return jnp.asarray(flat.reshape(lp.shape))


def decode(payload: Payload):
    """Lossless inverse of encode: masked entries come back exact zeros."""
    return jax.tree_util.tree_unflatten(
        payload.treedef, [decode_leaf(lp) for lp in payload.layers])


def tree_dense_bytes(tree) -> int:
    """Bytes a dense (FedAvg-style) exchange of this pytree would cost."""
    return sum(dense_bytes(l.size, np.dtype(l.dtype).itemsize)
               for l in jax.tree_util.tree_leaves(tree))


def apply_payloads(params, payloads: Sequence[Payload]):
    """W <- W + Σ_k decode(payload_k), without materialising K dense deltas.

    Per leaf, the client deltas accumulate **delta-first in client
    order** into one zero-initialised f32 buffer, which is then added to
    the parameters once: runs of consecutive coo/bitmap clients
    concatenate their (index, value) buffers into a single scatter-add
    (``.at[idx].add``), and each dense-codec client folds in as one
    vector add at its position in the order — so the per-coordinate
    accumulation order is the client order regardless of which codec
    each client's encoder picked.  Codec choice is data-dependent and
    must never change the arithmetic: this exact order is what the
    fused path's on-device slot-ordered reduction
    (``repro.fed.strategy.scbf_sum_step``) mirrors, making the two
    bit-identical.  (That parity additionally assumes the backend's
    scatter applies duplicate indices in update order — true of the
    backends we run, and pinned by the parity tests rather than by the
    XLA spec.)  Peak extra memory is one dense leaf plus the compact
    buffers — never K dense pytrees.
    """
    leaves, treedef = jax.tree_util.tree_flatten(params)
    n = len(leaves)
    # per leaf: ordered ops, each ("scatter", idx, val) | ("dense", val)
    ops: List[List[Tuple]] = [[] for _ in range(n)]
    for p in payloads:
        if len(p.layers) != n:
            raise ValueError("payload structure does not match params")
        for i, lp in enumerate(p.layers):
            if tuple(lp.shape) != tuple(leaves[i].shape):
                raise ValueError(
                    f"leaf {i}: payload shape {lp.shape} != "
                    f"param shape {leaves[i].shape}")
            if lp.codec == "dense":
                ops[i].append(("dense", lp.values.astype(np.float32)))
            else:
                ops[i].append(("scatter", lp.flat_indices(),
                               lp.values.astype(np.float32)))
    out = []
    for i, leaf in enumerate(leaves):
        flat = leaf.reshape(-1).astype(jnp.float32)
        if ops[i]:
            acc = jnp.zeros(flat.shape, jnp.float32)
            pend_idx: List[np.ndarray] = []
            pend_val: List[np.ndarray] = []

            def flush(acc):
                if pend_idx:
                    cat_idx = jnp.asarray(np.concatenate(pend_idx))
                    cat_val = jnp.asarray(np.concatenate(pend_val))
                    acc = acc.at[cat_idx].add(cat_val)
                    pend_idx.clear()
                    pend_val.clear()
                return acc

            for op in ops[i]:
                if op[0] == "scatter":
                    pend_idx.append(op[1])
                    pend_val.append(op[2])
                else:
                    acc = flush(acc) + jnp.asarray(op[1])
            acc = flush(acc)
            flat = flat + acc
        out.append(flat.reshape(leaf.shape).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
