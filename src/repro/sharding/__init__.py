from repro.sharding.rules import (
    ShardingRules, param_shardings, activation_spec, batch_spec)
