"""Logical-axis -> mesh-axis sharding rules.

Params carry logical axis names (models/common.py).  The rules below map
them to the production mesh:

  * ``model`` (tensor parallel): experts first (expert parallelism), then
    fused head/kv projections, FFN intermediates, vocab, SSM inner dim —
    the FIRST divisible candidate on each tensor wins, so e.g. a MoE
    expert tensor (experts, embed, mlp) shards experts×model and embed×data
    while a dense FFN (embed, mlp) shards mlp×model and embed×data;
  * ``data`` (FSDP): the remaining largest divisible dim, preferring
    ``embed`` — weights are reduce-scattered/all-gathered by XLA around
    each layer, which is what makes the 236-400B configs fit;
  * ``pod``: NEVER used for weights — it is the federated/client axis
    (DESIGN.md §5): weights are replicated across pods and only the
    channel-masked gradient exchange crosses it.

Divisibility is checked per tensor; non-divisible candidates fall through
(e.g. mamba2's vocab 50280 is not 16-divisible, so its embedding shards
embed×model instead and vocab stays unsharded).

Activations use a separate small table (``activation_spec``) keyed by the
logical activation-axis names models pass to ``ctx.shard``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import split_ax

# priority order of logical axes for the 'model' mesh axis
MODEL_PRIORITY = ("experts", "heads", "kv", "mlp", "inner", "vocab",
                  "lora", "state")
# priority order for the 'data' (FSDP) mesh axis
DATA_PRIORITY = ("embed", "mlp", "vocab", "heads", "inner")
# never sharded
FROZEN = ("layers", "conv", "none")


@dataclass(frozen=True)
class ShardingRules:
    mesh_model: str = "model"
    mesh_data: str = "data"
    fsdp: bool = True                # shard weights over data axis too

    def spec_for(self, axes: str, shape: Tuple[int, ...], mesh: Mesh
                 ) -> P:
        names = split_ax(axes)
        assert len(names) == len(shape), (axes, shape)
        model_n = mesh.shape[self.mesh_model]
        data_n = mesh.shape[self.mesh_data]
        assign: list = [None] * len(shape)

        def place(mesh_axis: str, n: int, priority) -> Optional[int]:
            for logical in priority:
                for i, nm in enumerate(names):
                    if nm == logical and assign[i] is None \
                            and shape[i] % n == 0 and shape[i] >= n:
                        assign[i] = mesh_axis
                        return i
            return None

        place(self.mesh_model, model_n, MODEL_PRIORITY)
        if self.fsdp:
            place(self.mesh_data, data_n, DATA_PRIORITY)
        return P(*assign)


def param_shardings(axes_tree, mesh: Mesh,
                    rules: ShardingRules = ShardingRules(),
                    shapes_tree=None):
    """NamedSharding pytree for params given their logical-axes pytree.

    ``shapes_tree``: matching pytree of ShapeDtypeStruct/arrays (needed
    for divisibility checks).
    """
    def mk(axes, leaf):
        spec = rules.spec_for(axes, tuple(leaf.shape), mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map(mk, axes_tree, shapes_tree)


# ---------------------------------------------------------------------------
# Federated cohorts
# ---------------------------------------------------------------------------

def cohort_shardings(mesh: Mesh) -> Tuple[NamedSharding, NamedSharding]:
    """(slot_sharding, replicated) for a bucketed participant cohort.

    The pod axis is the federated client axis: per-participant arrays
    (``(B, n_max, d)`` shards, per-slot PRNG keys, the validity mask)
    split their leading slot axis over ``pod``; the global model is
    replicated — weights NEVER shard over pod (the contract above), the
    channel-masked gradient exchange is the only cross-pod traffic.
    """
    if "pod" not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no 'pod' axis")
    return (NamedSharding(mesh, P("pod")), NamedSharding(mesh, P()))


def keep_mask_sharding(mesh: Mesh) -> NamedSharding:
    """Sharding for SCBFwP neuron keep-masks: replicated, like weights.

    A keep-mask is model-geometry state — one ``(H_l,)`` validity
    vector per hidden layer, shared by every participant slot — so it
    follows the weights-never-shard-over-pod contract: replicated
    across the pod mesh, never split on the federated client axis.
    """
    if "pod" not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no 'pod' axis")
    return NamedSharding(mesh, P())


def fused_plan_shardings(mesh: Mesh) -> Tuple[NamedSharding, NamedSharding]:
    """(round_slot_sharding, replicated) for fused ``(S, B, ...)`` plans.

    A fused chunk scans over the round axis S (axis 0 — the scan never
    shards) while each round's slot axis B (axis 1) splits over ``pod``,
    exactly like the per-round cohort sharding with one leading round
    dimension.  The scan carry (the global model) stays replicated —
    the same weights-never-shard-over-pod contract as
    ``cohort_shardings``.
    """
    if "pod" not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no 'pod' axis")
    return (NamedSharding(mesh, P(None, "pod")), NamedSharding(mesh, P()))


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def activation_rules(mesh: Mesh, batch_shardable: bool = True,
                     group_axes=None, batch_override=None
                     ) -> Dict[str, object]:
    """logical activation axis -> mesh axes.

    ``group_axes`` / ``batch_override``: the federated train step pins
    both to ("data",) because the client axis already occupies "pod"
    (vmap with spmd_axis_name="pod") — inner constraints must not
    mention the vmapped axis.
    """
    b = (batch_axes(mesh) if batch_shardable else ()) \
        if batch_override is None else tuple(batch_override)
    g = b if group_axes is None else tuple(group_axes)
    return {
        "batch": b,
        "group": g,
        "kv_seq": ("model",),
        "vocab_act": ("model",),
        "mlp_act": ("model",),
        "expert": ("model",),
        "capacity": (),          # bucket capacity: keep with expert shard
        "heads_act": (),
        "none": (),
    }


def activation_spec(logical: Sequence[str], rules: Dict[str, object]) -> P:
    out = []
    for name in logical:
        ax = rules.get(name, ())
        out.append(tuple(ax) if ax else None)
    return P(*out)


def batch_spec(mesh: Mesh, batch_size: int) -> P:
    """Spec for the leading batch dim of inputs; falls back to replication
    when the batch doesn't divide (long_500k has batch 1)."""
    axes = batch_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in axes]))
    if batch_size % n == 0:
        return P(axes)
    if batch_size % mesh.shape[axes[-1]] == 0:
        return P(axes[-1])
    return P(None)


def make_shard_fn(mesh: Mesh, batch_shardable: bool = True,
                  group_axes=None, batch_override=None):
    """The ``ctx.shard`` callback used inside model code under the mesh."""
    rules = activation_rules(mesh, batch_shardable, group_axes,
                             batch_override)

    def shard(x, logical):
        spec = activation_spec(logical, rules)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))
    return shard
