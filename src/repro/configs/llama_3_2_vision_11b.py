"""Llama-3.2-Vision-11B — decoder with cross-attention image layers.
[hf:meta-llama/Llama-3.2-11B-Vision]

Assigned spec: 40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256,
cross-attention to vision embeddings every 5th layer.  The ViT vision
encoder + projector are a STUB: ``input_specs`` provides projected patch
embeddings of shape (batch, num_patch_tokens, d_model).
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    cross_attn_every=5,
    frontend="vision",
    num_patch_tokens=1024,
    rope_theta=500_000.0,
)
