"""The paper's own model: an MLP over 2917 binary medication features.

The paper (§2.2) describes an L-layer DNN taking 2917 binary inputs and
predicting binary mortality.  Exact hidden sizes are not published; we use
(256, 64) hidden units, which reaches the paper's AUC operating regime on
the synthetic cohort.  [Shao et al., ML4H@NeurIPS 2019]
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="mlp-medical",
    family="mlp",
    source="Shao et al. 2019 (this paper), §2.2",
    mlp_features=(2917, 256, 64, 1),
    activation="relu",
    dtype="float32",
)
