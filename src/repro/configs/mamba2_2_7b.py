"""Mamba2-2.7B — attention-free SSM with SSD. [arXiv:2405.21060]

Assigned spec: 64L d_model=2560 (attn-free) vocab=50280, ssm_state=128.
d_inner = 2*2560 = 5120, head_dim 64 -> 80 SSD heads, chunked scan.
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,                    # attention-free, no separate FFN (SSD block)
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
)
