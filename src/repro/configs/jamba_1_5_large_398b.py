"""Jamba-1.5-Large 398B — hybrid Mamba+attention 1:7, MoE. [arXiv:2403.19887]

Assigned spec: 72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536,
MoE 16e top-2, attention every 8th layer, MoE every other layer.
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    attention_every=8,         # 1 attention : 7 mamba
    num_experts=16,
    experts_per_token=2,
    moe_every=2,               # MoE on every other layer
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
)
