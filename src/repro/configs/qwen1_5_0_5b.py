"""Qwen1.5-0.5B — dense, QKV bias, MHA (kv == heads). [hf:Qwen/Qwen1.5-0.5B]

Assigned spec: 24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936.
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b",
    family="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
)
