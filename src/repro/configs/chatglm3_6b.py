"""ChatGLM3-6B — dense, 2d (half-dim) RoPE, GQA kv=2. [arXiv:2406.12793]

Assigned spec: 28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
ChatGLM applies rotary embedding to half of each head dim (rope_fraction=0.5).
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    source="arXiv:2406.12793",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    rope_fraction=0.5,
    qkv_bias=True,             # chatglm uses bias on QKV only
)
