"""Whisper-medium — encoder-decoder audio model. [arXiv:2212.04356]

Assigned spec: 24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.
The mel-spectrogram + conv frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings of shape (batch, 1500, d_model); we implement
the transformer encoder (24L) + decoder (24L) that consume them.
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=24,             # decoder layers
    encoder_layers=24,
    encoder_seq=1500,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    cross_attn_every=1,        # every decoder layer cross-attends
    frontend="audio",
    norm="layernorm",
    activation="gelu",
    rope_theta=0.0,            # whisper uses learned/sinusoidal pos — we use rope_theta=0 -> none (learned abs)
)
