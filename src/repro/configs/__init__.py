"""Architecture registry.

Every assigned architecture lives in its own module defining ``CONFIG``;
this package collects them into ``ARCHS`` and provides ``smoke_variant``
(the reduced config the per-arch smoke tests run on CPU: 2 layers,
d_model <= 512, <= 4 experts).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.config import ArchConfig

from repro.configs import (
    mlp_medical,
    deepseek_v2_236b,
    qwen2_5_32b,
    qwen1_5_0_5b,
    jamba_1_5_large_398b,
    whisper_medium,
    llama4_maverick_400b_a17b,
    qwen2_0_5b,
    mamba2_2_7b,
    chatglm3_6b,
    llama_3_2_vision_11b,
)

ARCHS: Dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        mlp_medical,
        deepseek_v2_236b,
        qwen2_5_32b,
        qwen1_5_0_5b,
        jamba_1_5_large_398b,
        whisper_medium,
        llama4_maverick_400b_a17b,
        qwen2_0_5b,
        mamba2_2_7b,
        chatglm3_6b,
        llama_3_2_vision_11b,
    )
}

# The ten pool-assigned architectures (paper's own MLP excluded).
ASSIGNED = [
    "deepseek-v2-236b",
    "qwen2.5-32b",
    "qwen1.5-0.5b",
    "jamba-1.5-large-398b",
    "whisper-medium",
    "llama4-maverick-400b-a17b",
    "qwen2-0.5b",
    "mamba2-2.7b",
    "chatglm3-6b",
    "llama-3.2-vision-11b",
]


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family variant for CPU smoke tests."""
    if cfg.family == "mlp":
        return dataclasses.replace(cfg, mlp_features=(64, 32, 8, 1))
    d_model = min(cfg.d_model, 256)
    head_dim = 32
    num_heads = max(2, min(4, cfg.num_heads))
    num_kv = max(1, min(num_heads, cfg.num_kv_heads)) if cfg.num_kv_heads else 0
    # keep GQA ratio-ish: kv <= heads and divides heads
    while num_kv and num_heads % num_kv:
        num_kv -= 1
    kw = dict(
        num_layers=2,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
    )
    if cfg.num_experts:
        kw.update(
            num_experts=4,
            experts_per_token=min(2, cfg.experts_per_token),
            num_shared_experts=min(1, cfg.num_shared_experts),
            moe_every=1 if cfg.moe_every == 1 else 2,
            first_dense_layers=min(cfg.first_dense_layers, 1),
        )
    if cfg.use_mla:
        kw.update(kv_lora_rank=32, qk_rope_dim=16)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.attention_every > 1:
        kw.update(attention_every=2)
    if cfg.cross_attn_every:
        kw.update(cross_attn_every=2, num_patch_tokens=8)
    if cfg.encoder_layers:
        kw.update(encoder_layers=2, encoder_seq=16)
    if cfg.frontend == "vision":
        kw.update(num_patch_tokens=8)
    return dataclasses.replace(cfg, **kw)
