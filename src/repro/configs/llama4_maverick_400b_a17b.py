"""Llama-4 Maverick 400B (17B active) — MoE top-1 + shared expert.
[hf:meta-llama/Llama-4-Scout-17B-16E family card]

Assigned spec: 48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048,
MoE 128e top-1, early fusion.  Early-fusion multimodality is stubbed: the
assigned input shapes are token-only; the config documents the fusion point
(vision patches would be inlined as tokens before the embedding sum).
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,                 # per-expert intermediate
    vocab_size=202048,
    num_experts=128,
    num_shared_experts=1,
    experts_per_token=1,
    moe_every=2,               # interleaved dense / MoE
    rope_theta=500_000.0,
)
