"""DeepSeek-V2 236B — MoE with Multi-head Latent Attention. [arXiv:2405.04434]

Assigned spec: 60L d_model=5120 128H (GQA kv=128) d_ff=1536 vocab=102400,
MoE 160e top-6, MLA kv_lora=512, 2 shared + 160 routed experts.
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    source="arXiv:2405.04434",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=1536,                 # per-expert intermediate size
    vocab_size=102400,
    use_mla=True,
    kv_lora_rank=512,
    qk_rope_dim=64,
    num_experts=160,
    num_shared_experts=2,
    experts_per_token=6,
    moe_every=1,
    first_dense_layers=1,      # DeepSeek-V2: first layer uses a dense FFN
    rope_theta=10000.0,
)
