"""Qwen2-0.5B — dense, GQA kv=2, QKV bias. [arXiv:2407.10671]

Assigned spec: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b",
    family="dense",
    source="arXiv:2407.10671",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
)
