"""Roofline terms from a compiled dry-run artifact.

compute term    = HLO_FLOPs_per_device / peak_FLOP/s
memory term     = HLO_bytes_per_device / HBM_bw
collective term = collective_bytes_per_device / link_bw

``cost_analysis()`` runs on the SPMD-partitioned (per-device) module, so
its flops/bytes are already per-chip — the "/ chips" in the brief's
formulas is folded in.  collective_bytes is NOT in cost_analysis: we parse
the partitioned HLO text and sum the output-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(start ops counted once, done ops skipped).  Best-effort classification of
cross-pod traffic from explicit replica groups (devices 0..255 = pod 0).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.config import HARDWARE, HardwareConfig

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of every array shape appearing in shape_str (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)
    total_bytes: int = 0
    cross_pod_bytes: int = 0     # best-effort (explicit replica groups only)

    def as_dict(self):
        return {"bytes_by_kind": self.bytes_by_kind,
                "count_by_kind": self.count_by_kind,
                "total_bytes": self.total_bytes,
                "cross_pod_bytes": self.cross_pod_bytes}


def _crosses_pod(line: str, pod_stride: int = 256) -> Optional[bool]:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        try:
            ids = [int(x) for x in m.group(1).split(",") if x.strip()]
            return len({i // pod_stride for i in ids}) > 1
        except ValueError:
            return None
    # iota format: replica_groups=[G,S]<=[512] — group stride unknown;
    # groups larger than one pod necessarily cross pods
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]", line)
    if m:
        g, s, total = map(int, m.groups())
        if total <= pod_stride:
            return False
        if s > pod_stride:
            return True
        return None
    return None


def parse_collectives(hlo_text: str, pod_stride: int = 256
                      ) -> CollectiveStats:
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", ls)
        if not m:
            continue
        rhs = m.group(1)
        opm = re.search(r"\b([a-z0-9\-]+)\(", rhs)
        if not opm:
            continue
        op = opm.group(1)
        base = None
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                base = c
                break
        if base is None:
            continue
        # output shape(s) precede the op name
        shape_str = rhs[:opm.start()]
        b = _shape_bytes(shape_str)
        st.bytes_by_kind[base] = st.bytes_by_kind.get(base, 0) + b
        st.count_by_kind[base] = st.count_by_kind.get(base, 0) + 1
        st.total_bytes += b
        cp = _crosses_pod(ls, pod_stride)
        if cp:
            st.cross_pod_bytes += b
    return st


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float,
                   hw: HardwareConfig = HARDWARE) -> Dict[str, float]:
    compute = flops_per_dev / hw.peak_flops
    memory = bytes_per_dev / hw.hbm_bw
    collective = coll_bytes_per_dev / hw.ici_bw
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    total = max(compute, memory, collective)
    terms["bound_fraction"] = (compute / total) if total > 0 else 0.0
    return terms
