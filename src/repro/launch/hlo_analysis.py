"""Loop-aware static analysis of compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` on XLA:CPU counts ``while`` bodies ONCE —
a 60-layer scanned model under-reports flops/bytes/collectives by ~60×.
This module re-derives the three roofline inputs directly from
``compiled.as_text()`` with loop weighting:

  * computations are parsed into instruction lists with a per-computation
    symbol table (operands in XLA text are untyped names);
  * ``while`` trip counts come from ``backend_config known_trip_count``
    (exact for ``lax.scan``/``fori_loop``), falling back to the largest
    integer constant in the loop condition;
  * flops: every ``dot`` contributes 2 · |output| · K (K = contracted
    extent from the lhs operand's dims), accumulated through the call
    graph (fusions, calls, while bodies × trip count);
  * HBM traffic: per top-level instruction in each computation,
    operand bytes + output bytes (post-fusion HLO means fusion boundaries
    are real buffer materialisation points), loop-weighted;
  * collectives: output-shape bytes per all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, loop-weighted, with
    best-effort cross-pod classification from replica groups.

Numbers are per-device (the partitioned module is one device's program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# Ops whose operands/outputs count as HBM traffic.  The partitioned module
# comes from the CPU backend, which barely fuses — counting every
# elementwise op would model an unfused program, not a TPU one.  We count
# only ops that materialise buffers even on TPU (matmuls, reductions,
# data movement, fusions); standalone elementwise/broadcast/transpose ops
# are assumed fused into a counted consumer.
_TRAFFIC_OPS = {
    "dot", "convolution", "fusion", "reduce", "reduce-window", "gather",
    "scatter", "dynamic-slice", "dynamic-update-slice", "sort",
    "concatenate", "select-and-scatter", "cholesky", "triangular-solve",
    "rng", "rng-bit-generator", "topk", "custom-call",
}


def _shape_info(shape_str: str) -> Tuple[int, List[List[int]]]:
    """(total bytes, list of dims) for every array shape in shape_str."""
    total = 0
    arrays = []
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x]
        n = 1
        for v in d:
            n *= v
        total += n * _DTYPE_BYTES[dtype]
        arrays.append(d)
    return total, arrays


@dataclass
class Instr:
    name: str
    op: str
    out_bytes: int
    out_dims: List[int]
    operands: List[str]
    line: str
    callees: List[str] = field(default_factory=list)
    body: Optional[str] = None
    cond: Optional[str] = None
    trip: int = 1


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    table: Dict[str, Instr] = field(default_factory=dict)


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[^\s(]+))"
    r"\s+([\w\-]+)\(([^)]*)\)(.*)$")
_CALLEE_ATTRS = ("to_apply", "calls", "body", "condition")


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        stripped = line.strip()
        if stripped == "}":
            cur = None
            continue
        head = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$",
                        stripped)
        if head and not line.startswith("  "):
            cur = Computation(head.group(2))
            comps[cur.name] = cur
            if head.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_str, op, args, attrs = m.groups()
        out_bytes, arrays = _shape_info(shape_str)
        out_dims = arrays[0] if arrays else []
        operands = re.findall(r"%([\w.\-]+)", args)
        ins = Instr(name=name, op=op, out_bytes=out_bytes,
                    out_dims=out_dims, operands=operands, line=line)
        for attr in _CALLEE_ATTRS:
            for mm in re.finditer(attr + r"=%?([\w.\-]+)", attrs):
                callee = mm.group(1)
                ins.callees.append(callee)
                if attr == "body":
                    ins.body = callee
                elif attr == "condition":
                    ins.cond = callee
        tm = re.search(r"known_trip_count[^0-9]*(\d+)", attrs)
        if tm:
            ins.trip = int(tm.group(1))
        cur.instrs.append(ins)
        cur.table[name] = ins
    return comps, entry


def _fallback_trip(comps: Dict[str, Computation], cond: Optional[str]) -> int:
    comp = comps.get(cond or "")
    if comp is None:
        return 1
    best = 1
    for ins in comp.instrs:
        for m in re.finditer(r"constant\((\d+)\)", ins.line):
            v = int(m.group(1))
            if 1 < v <= 10_000_000:
                best = max(best, v)
    return best


def _dot_flops(ins: Instr, comp: Computation) -> float:
    lhs = comp.table.get(ins.operands[0]) if ins.operands else None
    if lhs is None:
        return 0.0
    k = 1
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    if cm and cm.group(1):
        for i in cm.group(1).split(","):
            idx = int(i)
            if idx < len(lhs.out_dims):
                k *= lhs.out_dims[idx]
    out = 1
    for d in ins.out_dims:
        out *= d
    return 2.0 * out * k


@dataclass
class HloStats:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, float] = field(default_factory=dict)
    cross_pod_bytes: float = 0.0

    def scaled(self, mult: float) -> "HloStats":
        return HloStats(
            self.flops * mult, self.traffic_bytes * mult,
            self.collective_bytes * mult,
            {k: v * mult for k, v in self.collective_by_kind.items()},
            {k: v * mult for k, v in self.collective_counts.items()},
            self.cross_pod_bytes * mult)

    def add(self, other: "HloStats", traffic: bool = True):
        self.flops += other.flops
        if traffic:
            self.traffic_bytes += other.traffic_bytes
        self.collective_bytes += other.collective_bytes
        self.cross_pod_bytes += other.cross_pod_bytes
        for k, v in other.collective_by_kind.items():
            self.collective_by_kind[k] = self.collective_by_kind.get(k, 0) + v
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0) + v

    def as_dict(self):
        return {"flops": self.flops, "traffic_bytes": self.traffic_bytes,
                "collective_bytes": self.collective_bytes,
                "collective_by_kind": self.collective_by_kind,
                "collective_counts": self.collective_counts,
                "cross_pod_bytes": self.cross_pod_bytes}


def _crosses_pod(line: str, pod_stride: int) -> bool:
    """Does any replica group span devices from different pods?

    Handles explicit lists and the iota form
    ``[G,S]<=[d0,d1,...]T(perm)`` (decoded exactly with numpy).
    """
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        try:
            ids = [int(x) for x in m.group(1).split(",") if x.strip()]
            return len({i // pod_stride for i in ids}) > 1
        except ValueError:
            return False
    m = re.search(
        r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?",
        line)
    if m:
        import numpy as np
        g, s, reshape_s, perm_s = m.groups()
        g, s = int(g), int(s)
        dims = [int(x) for x in reshape_s.split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if perm_s:
            ids = ids.transpose([int(x) for x in perm_s.split(",")])
        groups = ids.reshape(g, s)
        pods = groups // pod_stride
        return bool((pods != pods[:, :1]).any())
    return False


# ops that force buffer materialisation even on TPU (used to classify
# fusion computations: a fusion containing none of these is a pure
# elementwise chain that TPU would fuse away — no HBM traffic counted)
_MATERIAL_OPS = {"dot", "convolution", "reduce", "reduce-window", "gather",
                 "scatter", "dynamic-slice", "dynamic-update-slice", "sort",
                 "concatenate", "while", "topk", "custom-call"}


def _elementwise_only(comps: Dict[str, Computation], name: str,
                      memo: Dict[str, bool], depth: int = 0) -> bool:
    if name in memo:
        return memo[name]
    comp = comps.get(name)
    if comp is None or depth > 32:
        return True
    memo[name] = True
    ok = True
    for ins in comp.instrs:
        if ins.op in _MATERIAL_OPS:
            ok = False
            break
        if ins.op == "fusion" and ins.callees and not \
                _elementwise_only(comps, ins.callees[0], memo, depth + 1):
            ok = False
            break
    memo[name] = ok
    return ok


def analyze(text: str, pod_stride: int = 256) -> HloStats:
    comps, entry = parse_module(text)
    if entry is None:
        entry = next(iter(comps), None)
        if entry is None:
            return HloStats()
    memo: Dict[str, HloStats] = {}
    ew_memo: Dict[str, bool] = {}

    def walk(name: str, depth: int = 0) -> HloStats:
        if name in memo:
            return memo[name]
        st = HloStats()
        comp = comps.get(name)
        if comp is None or depth > 64:
            return st
        memo[name] = st
        for ins in comp.instrs:
            base = None
            for c in _COLLECTIVES:
                if ins.op == c or ins.op == c + "-start":
                    base = c
                    break
            if base:
                st.collective_bytes += ins.out_bytes
                st.collective_by_kind[base] = \
                    st.collective_by_kind.get(base, 0) + ins.out_bytes
                st.collective_counts[base] = \
                    st.collective_counts.get(base, 0) + 1
                if _crosses_pod(ins.line, pod_stride):
                    st.cross_pod_bytes += ins.out_bytes
            if ins.op == "dot":
                st.flops += _dot_flops(ins, comp)
            count_traffic = ins.op in _TRAFFIC_OPS
            if ins.op == "fusion" and ins.callees and \
                    _elementwise_only(comps, ins.callees[0], ew_memo):
                count_traffic = False      # TPU would fuse this chain away
            if count_traffic:
                op_bytes = sum(comp.table[o].out_bytes
                               for o in ins.operands if o in comp.table)
                st.traffic_bytes += ins.out_bytes + op_bytes
            if ins.op == "while" and ins.body:
                trips = ins.trip if ins.trip > 1 else \
                    _fallback_trip(comps, ins.cond)
                st.add(walk(ins.body, depth + 1).scaled(trips))
                if ins.cond:
                    st.add(walk(ins.cond, depth + 1).scaled(trips))
            elif ins.callees:
                for callee in ins.callees:
                    # fusions/calls execute once per call site; their
                    # traffic is the call-site operands (already counted)
                    st.add(walk(callee, depth + 1), traffic=False)
        return st

    return walk(entry)
