"""Batched serving launcher: prefill a prompt batch, then decode.

Runs a reduced assigned architecture end-to-end on CPU (the full configs
serve through the same code path on the production mesh — proven by the
decode-shape dry-runs).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import functools
import time

import numpy as np


@functools.lru_cache(maxsize=None)
def _jitted_steps(bundle):
    """One (prefill, decode) jit pair per bundle.

    Building the wrappers inside ``main`` gave every invocation a fresh
    compilation cache (tracelint TL001); callers embedding this module
    (tests, notebooks) now reuse the compiled steps across calls.
    """
    import jax
    return jax.jit(bundle.prefill_step), jax.jit(bundle.decode_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.models import model_zoo

    cfg = configs.smoke_variant(configs.get(args.arch))
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(args.seed))

    B, P = args.batch, args.prompt_len
    key = jax.random.PRNGKey(args.seed + 1)
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)

    prefill, decode = _jitted_steps(bundle)

    batch = {"tokens": prompts, "caches": bundle.make_cache(B, args.cache_len)}
    if cfg.encoder_layers:
        batch["audio_embeds"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                                          jnp.bfloat16)
    elif cfg.frontend == "vision":
        batch["image_embeds"] = jnp.zeros((B, cfg.num_patch_tokens,
                                           cfg.d_model), jnp.bfloat16)

    t0 = time.time()
    logits, caches = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    def sample(logits, key):
        if args.temperature == 0.0:
            return jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        return jax.random.categorical(
            key, logits / args.temperature)[:, None].astype(jnp.int32)

    out = [sample(logits, key)]
    t0 = time.time()
    for t in range(args.gen - 1):
        key, sk = jax.random.split(key)
        pos = jnp.full((B, 1), P + t, jnp.int32)
        logits, caches = decode(params, {"token": out[-1], "pos": pos,
                                         "caches": caches})
        out.append(sample(logits, sk))
    jax.block_until_ready(out[-1])
    t_decode = time.time() - t0

    toks = np.concatenate([np.asarray(o) for o in out], axis=1)
    print(f"arch={cfg.name} batch={B} prompt={P} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({B*P/t_prefill:.0f} tok/s incl. compile)")
    print(f"decode:  {t_decode*1e3:.1f} ms "
          f"({B*(args.gen-1)/max(t_decode,1e-9):.0f} tok/s)")
    print("sample token ids[0]:", toks[0, :16])


if __name__ == "__main__":
    main()
