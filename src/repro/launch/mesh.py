"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; everyone else
sees the real single CPU device).

Mesh semantics (DESIGN.md §5):
  pod   — federated client axis (one pod = one hospital/client); SCBF's
          channel-masked gradient exchange is the ONLY cross-pod traffic
  data  — batch + FSDP weight sharding
  model — tensor/expert parallelism
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before importing jax")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(shape=(1, 1), axes=("data", "model")):
    """Single-device mesh for CPU smoke tests of the sharded code path."""
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_pod_mesh(num_pods: int):
    """1-D ``("pod",)`` mesh for federated cohort sharding.

    The pod axis is the federated client axis (DESIGN.md §5): the
    batched engine shards its bucketed ``(B, n_max, d)`` cohort over it,
    one group of participant slots per device, with weights replicated.
    On CPU, multiple pods come from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set before
    the first jax import (same contract as the dry-run).
    """
    devices = jax.devices()
    if len(devices) < num_pods:
        raise RuntimeError(
            f"need {num_pods} devices for a pod mesh, have {len(devices)} — "
            "on CPU set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{num_pods} before importing jax")
    return jax.make_mesh((num_pods,), ("pod",), devices=devices[:num_pods])
