"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun

The FIRST two lines below must run before ANY other import (jax locks the
device count on first init).  Smoke tests and benches do NOT import this
module, so they see the real single CPU device.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

# ruff: noqa: E402
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.config import INPUT_SHAPES, ShapeConfig, TrainConfig, ScbfConfig
from repro.core.distributed import make_federated_train_step
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline_terms
from repro.models import model_zoo
from repro.models import transformer as T
from repro.sharding.rules import (ShardingRules, batch_spec, make_shard_fn,
                                  param_shardings)

# dense/quadratic archs run long_500k with this sliding window
LONG_CONTEXT_WINDOW = 8192

# One jit wrapper per distinct combo signature.  The step closure is a
# deterministic function of the combo, so repeated dryrun_one() calls
# (sweep retries, notebook use) must reuse the wrapper and its
# compilation cache instead of rebuilding both (tracelint TL001).
_STEP_CACHE: Dict[tuple, Any] = {}


def _jitted_step(key: tuple, step, in_shardings, out_shardings):
    jitted = _STEP_CACHE.get(key)
    if jitted is None:
        jitted = _STEP_CACHE[key] = jax.jit(
            step, in_shardings=in_shardings, out_shardings=out_shardings)
    return jitted


def _decode_window(cfg, shape: ShapeConfig) -> int:
    if shape.name == "long_500k" and not cfg.supports_long_decode_natively:
        return LONG_CONTEXT_WINDOW
    return 0


def _cache_in_shardings(specs, mesh, bspec):
    """Shardings for the decode/prefill cache pytree (path-aware)."""
    def spec_for(path, sds):
        names = [getattr(p, "key", None) for p in path]
        stacked = "stack" in names
        leaf = names[-1]
        lead = (None,) if stacked else ()
        if leaf in ("k", "v"):
            s = lead + (bspec, "model", None, None)
        elif leaf in ("k_scale", "v_scale"):
            s = lead + (bspec, "model", None)
        elif leaf in ("ckv", "krope"):
            s = lead + (bspec, "model", None)
        elif leaf == "kpos":
            s = lead + (bspec, "model")
        elif leaf == "h":
            s = lead + (bspec, "model", None, None)
        elif leaf == "conv":
            s = lead + (bspec, None, "model")
        elif leaf == "ctx_tokens":
            s = (bspec, None, None)
        else:
            s = tuple([None] * len(sds.shape))
        # divisibility guard: replace non-divisible assignments with None
        out = []
        for dim, ax in zip(sds.shape, s):
            if ax is None:
                out.append(None)
            else:
                sizes = [mesh.shape[a] for a in
                         (ax if isinstance(ax, tuple) else (ax,))]
                n = int(np.prod(sizes))
                out.append(ax if (dim % n == 0 and dim >= n) else None)
        return NamedSharding(mesh, P(*out))
    return jax.tree_util.tree_map_with_path(spec_for, specs)


def _input_shardings(specs, mesh, shape: ShapeConfig, federated_k: int = 0):
    bspec_p = batch_spec(mesh, shape.global_batch)
    # unwrap P((axes,)) -> the axes entry for composing into larger specs
    b = bspec_p[0] if len(bspec_p) else None

    def leaf_spec(path, sds):
        names = [getattr(p, "key", None) for p in path]
        leaf = names[-1]
        if "caches" in names:
            return None  # handled by _cache_in_shardings
        lead = ("pod",) if federated_k else ()
        bb = ("data",) if federated_k else b
        if leaf in ("tokens", "targets", "token", "pos"):
            return NamedSharding(mesh, P(*lead, bb, None))
        if leaf in ("audio_embeds", "image_embeds"):
            return NamedSharding(mesh, P(*lead, bb, None, None))
        return NamedSharding(mesh, P())

    flat = jax.tree_util.tree_map_with_path(leaf_spec, specs)
    if isinstance(specs, dict) and "caches" in specs:
        flat["caches"] = _cache_in_shardings(specs["caches"], mesh, b)
    return flat


def dryrun_one(arch: str, shape_name: str, mesh_kind: str,
               federated: Optional[bool] = None,
               compressed: bool = False,
               q_chunk: int = 512,
               kv_quant: bool = False,
               fsdp: bool = True,
               moe_dshard: bool = False,
               moe_groups: int = 0,
               extra_tag: str = "") -> Dict[str, Any]:
    """Lower + compile one combination; returns the result record."""
    cfg = configs.get(arch)
    shape = INPUT_SHAPES[shape_name]
    multi_pod = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi_pod)
    if federated is None:
        federated = multi_pod and shape.kind == "train"

    window = _decode_window(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind, "window": window,
        "federated": bool(federated and shape.kind == "train"),
        "compressed": compressed, "kv_quant": kv_quant, "fsdp": fsdp,
        "q_chunk": q_chunk, "tag": extra_tag, "ok": False,
    }
    t0 = time.time()
    try:
        batch_shardable = shape.global_batch >= 16
        fed_train = bool(federated and shape.kind == "train")
        shard_fn = make_shard_fn(
            mesh, batch_shardable,
            group_axes=("data",) if fed_train else None,
            batch_override=("data",) if fed_train else None)
        if moe_groups < 0:
            moe_groups = 1       # explicit off
        elif moe_groups == 0:    # default: match the batch-sharded axes
            if federated and shape.kind == "train":
                moe_groups = mesh.shape["data"]
            elif batch_shardable:
                moe_groups = int(np.prod(
                    [mesh.shape[a] for a in mesh.axis_names
                     if a in ("pod", "data")]))
            else:
                moe_groups = 1
        bundle = model_zoo.build(cfg, shard=shard_fn, q_chunk=q_chunk,
                                 kv_quant=kv_quant, moe_dshard=moe_dshard,
                                 moe_groups=moe_groups)
        rec["moe_groups"] = moe_groups

        # --- param structs + shardings ---
        captured = {}
        def initfn(k):
            p, a = T.init_model(cfg, k)
            captured["axes"] = a
            return p
        p_sds = jax.eval_shape(initfn, jax.random.PRNGKey(0))
        axes = captured["axes"]
        p_shard = param_shardings(axes, mesh, ShardingRules(fsdp=fsdp),
                                  shapes_tree=p_sds)

        # --- inputs ---
        specs = bundle.input_specs(shape, window=window)
        fed_k = 0
        if rec["federated"]:
            fed_k = mesh.shape["pod"]
            # leading client axis over pods
            def add_k(s):
                return jax.ShapeDtypeStruct(
                    (fed_k, s.shape[0] // fed_k) + s.shape[1:], s.dtype)
            specs = jax.tree_util.tree_map(add_k, specs)
        in_sh = _input_shardings(specs, mesh, shape, federated_k=fed_k)

        # --- step fn ---
        if shape.kind == "train":
            if rec["federated"]:
                scbf = ScbfConfig(upload_rate=0.10,
                                  compressed_exchange=compressed)
                step = make_federated_train_step(
                    lambda p, b: bundle.loss_fn(p, b, window=window), scbf,
                    spmd_axis_name="pod")
                out_sh = (None, p_shard)
            else:
                step = lambda p, b: bundle.train_step(p, b)
                out_sh = (None, p_shard)
        elif shape.kind == "prefill":
            step = lambda p, b: bundle.prefill_step(p, b, window=window)
            out_sh = None
        else:
            step = lambda p, b: bundle.decode_step(p, b, window=window)
            out_sh = None

        combo = (arch, shape_name, mesh_kind, rec["federated"], compressed,
                 q_chunk, kv_quant, fsdp, moe_dshard, moe_groups, window,
                 extra_tag)
        with mesh:
            jitted = _jitted_step(combo, step, (p_shard, in_sh), out_sh)
            lowered = jitted.lower(p_sds, specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        # --- analyses ---
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        flops = float(cost.get("flops", 0.0))
        byt = float(cost.get("bytes accessed", 0.0))
        ma = compiled.memory_analysis()
        mem = {}
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, f, None)
            if v is not None:
                mem[f] = int(v)
        # loop-aware static analysis of the partitioned module
        # (cost_analysis counts while bodies once — see hlo_analysis.py)
        hlo = compiled.as_text()
        st = analyze(hlo)
        terms = roofline_terms(st.flops, st.traffic_bytes,
                               st.collective_bytes)

        tokens = shape.global_batch * (shape.seq_len
                                       if shape.kind != "decode" else 1)
        model_flops = 6.0 * cfg.active_param_count() * tokens
        chips = mesh.size
        rec.update({
            "ok": True,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "flops_per_dev": st.flops, "bytes_per_dev": st.traffic_bytes,
            "raw_cost_analysis": {"flops": flops, "bytes": byt},
            "memory": mem,
            "collectives": st.as_dict(),
            "terms": terms,
            "tokens": tokens,
            "model_flops_total": model_flops,
            "useful_flops_ratio": (model_flops / (st.flops * chips)
                                   if st.flops else 0.0),
            "params_total": cfg.param_count(),
            "params_active": cfg.active_param_count(),
            "chips": chips,
        })
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--compressed", action="store_true",
                    help="compressed SCBF cross-pod exchange (multi-pod train)")
    ap.add_argument("--q-chunk", type=int, default=512)
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache (decode shapes)")
    ap.add_argument("--no-fsdp", action="store_true",
                    help="replicate weights over the data axis")
    ap.add_argument("--moe-dshard", action="store_true",
                    help="d_model-sharded MoE dispatch/combine")
    ap.add_argument("--moe-groups", type=int, default=0,
                    help="grouped MoE routing (-1 off, 0 auto, N groups)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    combos = []
    if args.all:
        for arch in configs.ASSIGNED:
            for shape in INPUT_SHAPES:
                combos.append((arch, shape, args.mesh))
    else:
        combos.append((args.arch, args.shape, args.mesh))

    for arch, shape, mesh_kind in combos:
        rec = dryrun_one(arch, shape, mesh_kind, compressed=args.compressed,
                         q_chunk=args.q_chunk, kv_quant=args.kv_quant,
                         fsdp=not args.no_fsdp,
                         moe_dshard=args.moe_dshard,
                         moe_groups=args.moe_groups, extra_tag=args.tag)
        tag = f"_{args.tag}" if args.tag else ""
        fname = f"{arch}_{shape}_{mesh_kind}{tag}.json"
        with open(os.path.join(args.out, fname), "w") as f:
            json.dump(rec, f, indent=1)
        status = "OK " if rec["ok"] else "FAIL"
        extra = (f"dom={rec['terms']['dominant']}" if rec["ok"]
                 else rec.get("error", "")[:120])
        print(f"[{status}] {arch} {shape} {mesh_kind} "
              f"({rec['total_s']}s) {extra}", flush=True)


if __name__ == "__main__":
    main()
