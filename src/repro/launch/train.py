"""Training launchers.

Two modes:

* ``medical`` — the paper's experiment: SCBF / SCBFwP / FedAvg / FAwP on
  the (synthetic) 30,760 × 2,917 medical cohort, 5 clients.  Writes a
  CSV history per method.

* ``lm`` — federated SCBF fine-tuning of a reduced assigned architecture
  on the synthetic token stream, exercising the exact
  ``make_federated_train_step`` used by the multi-pod dry-run (on CPU
  with a host mesh).

Usage:
    PYTHONPATH=src python -m repro.launch.train --mode medical \
        --methods scbf,fedavg,scbfwp --loops 30 --out experiments/medical
    PYTHONPATH=src python -m repro.launch.train --mode lm \
        --arch qwen2-0.5b --steps 200 --clients 4
"""
from __future__ import annotations

import argparse
import csv
import dataclasses
import functools
import os
import time

import numpy as np


@functools.lru_cache(maxsize=None)
def _fed_lm_step(bundle, scbf, lr: float):
    """One jitted federated step per (bundle, scbf cfg, lr).

    ``ScbfConfig`` is frozen (value-hashed) and ``ModelBundle`` hashes
    by identity, so repeated ``run_lm`` calls against the same bundle
    reuse the wrapper and its compilation cache instead of retracing
    (tracelint TL001).
    """
    import jax
    from repro.core.distributed import make_federated_train_step
    return jax.jit(make_federated_train_step(
        lambda p, b: bundle.loss_fn(p, b), scbf, lr=lr))


import contextlib


def run_medical(args):
    import jax
    from repro.config import FedConfig, ScbfConfig, TrainConfig
    from repro.core.scbf import run_federated
    from repro.data.medical import generate_cohort
    from repro.obs import recording

    from repro.config import ClockConfig
    from repro.fed.faults import parse_fault_trace

    cohort = generate_cohort(seed=args.seed)
    os.makedirs(args.out, exist_ok=True)
    results = {}
    # --fault-trace / --deadline-quantile arm the chaos model
    # (docs/FED_ENGINE.md §Fault model & resilience): the fault trace
    # is seeded, so a chaos run replays bit-identically from its spec
    faults = parse_fault_trace(args.fault_trace) if getattr(
        args, "fault_trace", None) else None
    clock = None
    if getattr(args, "deadline_quantile", 0.0) > 0:
        clock = ClockConfig(enabled=True,
                            deadline_quantile=args.deadline_quantile,
                            deadline_action=getattr(args, "deadline_action",
                                                    "drop"))
    fed_kwargs = dict(
        engine=getattr(args, "engine", "batched"),
        sample_fraction=getattr(args, "sample_fraction", 1.0),
        dropout_rate=getattr(args, "dropout_rate", 0.0),
        straggler_rate=getattr(args, "straggler_rate", 0.0),
        partition=getattr(args, "partition", "iid"),
        dirichlet_alpha=getattr(args, "dirichlet_alpha", 0.5),
        min_valid_participants=getattr(args, "min_valid_participants", 0),
        max_update_norm=getattr(args, "max_update_norm", 0.0),
        norm_action=getattr(args, "norm_action", "reject"))
    if faults is not None:
        if "seed=" not in args.fault_trace:  # default the trace seed to --seed
            faults = dataclasses.replace(faults, seed=args.seed)
        fed_kwargs["faults"] = faults
    if clock is not None:
        fed_kwargs["clock"] = clock
    fed = FedConfig(**fed_kwargs)
    for method in args.methods.split(","):
        base = method.replace("wp", "")
        prune = method.endswith("wp")
        # SCBF sums K client deltas (paper Algorithm 1); FA averages.
        # Scale SCBF's local lr by 1/K for an equal effective server step.
        m_lr = args.lr / args.clients if base == "scbf" else args.lr
        cfg = TrainConfig(
            learning_rate=m_lr, global_loops=args.loops,
            local_epochs=args.local_epochs,
            local_batch_size=args.batch_size, seed=args.seed,
            scbf=ScbfConfig(upload_rate=args.upload_rate,
                            selection=args.selection,
                            num_clients=args.clients, prune=prune,
                            prune_rate=args.prune_rate,
                            prune_total=args.prune_total,
                            prune_impl=getattr(args, "prune_impl",
                                               "reshape"),
                            dp_noise_multiplier=getattr(
                                args, "dp_noise", 0.0)),
            fed=fed)
        # --events: one flight-recorder JSONL per method, feed it to
        # ``python -m repro.obs.report`` (docs/OBSERVABILITY.md)
        rec_ctx = recording(os.path.join(args.out, f"{method}.events.jsonl")) \
            if getattr(args, "events", False) else contextlib.nullcontext()
        with rec_ctx:
            res = run_federated(cohort, cfg, method=base, verbose=True)
        results[method] = res
        path = os.path.join(args.out, f"{res.method}.csv")
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(["loop", "auc_roc", "auc_pr", "upload_fraction",
                        "sparse_bytes", "dense_bytes", "wall_time",
                        "wall_is_amortized", "train_loss",
                        "flops_proxy", "hidden_sizes", "participants",
                        "epsilon"])
            for r in res.records:
                w.writerow([r.loop, r.auc_roc, r.auc_pr, r.upload_fraction,
                            r.sparse_bytes, r.dense_bytes, r.wall_time,
                            int(r.wall_is_amortized),
                            "" if r.train_loss is None else r.train_loss,
                            r.flops_proxy,
                            "x".join(map(str, r.hidden_sizes)),
                            r.num_participants,
                            "" if r.epsilon is None else r.epsilon])
        print(f"[{res.method}] best auc_roc={res.best('auc_roc'):.4f} "
              f"auc_pr={res.best('auc_pr'):.4f} "
              f"time={res.total_time():.1f}s upload={res.total_upload_bytes()/1e6:.1f}MB")
    return results


def run_lm(args):
    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.config import ScbfConfig
    from repro.data.tokens import SyntheticTokenStream
    from repro.models import model_zoo

    cfg = configs.smoke_variant(configs.get(args.arch))
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(args.seed))
    scbf = ScbfConfig(upload_rate=args.upload_rate, num_clients=args.clients)
    step = _fed_lm_step(bundle, scbf, args.lr)

    K, B, S = args.clients, args.batch_size, args.seq_len
    stream = SyntheticTokenStream(K * B, S, cfg.vocab_size, seed=args.seed)
    t0 = time.time()
    for i, nb in zip(range(args.steps), stream):
        batch = {k: jnp.asarray(v).reshape(K, B, S) for k, v in nb.items()}
        if cfg.frontend == "vision":
            batch["image_embeds"] = jnp.zeros(
                (K, B, cfg.num_patch_tokens, cfg.d_model), jnp.bfloat16)
        elif cfg.encoder_layers:
            batch["audio_embeds"] = jnp.zeros(
                (K, B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        loss, params = step(params, batch)
        if i % max(1, args.steps // 20) == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(loss):.4f} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["medical", "lm"], default="medical")
    ap.add_argument("--methods", default="scbf,fedavg,scbfwp,fedavgwp")
    ap.add_argument("--loops", type=int, default=30)
    ap.add_argument("--clients", type=int, default=5)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--local-epochs", type=int, default=2)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--upload-rate", type=float, default=0.10)
    ap.add_argument("--selection", default="positive")
    ap.add_argument("--prune-rate", type=float, default=0.10)
    ap.add_argument("--prune-total", type=float, default=0.47)
    ap.add_argument("--prune-impl", default="reshape",
                    choices=["reshape", "mask"],
                    help="mask = static keep-masks (no recompiles, "
                         "fused-path compatible; scbf only)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="experiments/medical")
    # cross-device federation scenarios (docs/FED_ENGINE.md)
    ap.add_argument("--engine", default="batched",
                    choices=["batched", "sequential"])
    ap.add_argument("--sample-fraction", type=float, default=1.0)
    ap.add_argument("--dropout-rate", type=float, default=0.0)
    ap.add_argument("--straggler-rate", type=float, default=0.0)
    ap.add_argument("--partition", default="iid",
                    choices=["iid", "dirichlet"])
    ap.add_argument("--dirichlet-alpha", type=float, default=0.5)
    ap.add_argument("--dp-noise", type=float, default=0.0,
                    help="DP noise multiplier on scbf uploads (0 = off)")
    # chaos / resilience (docs/FED_ENGINE.md §Fault model & resilience)
    ap.add_argument("--fault-trace", default=None,
                    help="seeded fault-injection spec, comma-separated "
                         "key=value pairs (e.g. 'crash=0.05,net_fail=0.1,"
                         "bitflip=0.02,nan=0.01'); keys: seed, crash, "
                         "net_fail, retries, backoff, duplicate, bitflip, "
                         "nan, poison, poison_scale")
    ap.add_argument("--deadline-quantile", type=float, default=0.0,
                    help="enable the simulated wall clock and cut each "
                         "cohort at this latency quantile (0 = off)")
    ap.add_argument("--deadline-action", default="drop",
                    choices=["drop", "spill"],
                    help="what happens to deadline misses: drop, or spill "
                         "into a staleness-weighted buffer")
    ap.add_argument("--min-valid-participants", type=int, default=0,
                    help="round quorum: retry with backoff when fewer "
                         "valid updates arrive (0 = off)")
    ap.add_argument("--max-update-norm", type=float, default=0.0,
                    help="server-side L2 norm bound on admitted updates "
                         "(0 = off)")
    ap.add_argument("--norm-action", default="reject",
                    choices=["reject", "clip"],
                    help="over-norm updates are rejected or clipped")
    ap.add_argument("--events", action="store_true",
                    help="write <out>/<method>.events.jsonl flight-recorder "
                         "logs (repro.obs; view with python -m "
                         "repro.obs.report)")
    # lm mode
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    args = ap.parse_args()
    if args.mode == "medical":
        run_medical(args)
    else:
        if args.mode == "lm" and args.batch_size == 256:
            args.batch_size = 4
        run_lm(args)


if __name__ == "__main__":
    main()
