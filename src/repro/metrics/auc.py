"""Exact AUC-ROC and AUC-PR in pure jnp (sort-based, matches sklearn).

The paper's evaluation is AUC-ROC / AUC-PR on a held-out test set; these
are the two indicators of Fig. 2 and §3.

Both metrics sort by score descending, accumulate TP/FP, and evaluate the
curve only at tie-block end points (the threshold set), exactly like
``sklearn.metrics.roc_auc_score`` / ``average_precision_score``.  The
"previous threshold point" is recovered with an exclusive ``cummax`` over
the masked (non-decreasing) coordinate, which keeps everything O(n log n)
and jit-compatible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _curve_points(scores, labels):
    scores = scores.reshape(-1).astype(jnp.float32)
    labels = labels.reshape(-1).astype(jnp.float32)
    order = jnp.argsort(-scores)
    s = scores[order]
    y = labels[order]
    tp = jnp.cumsum(y)
    fp = jnp.cumsum(1.0 - y)
    # threshold points: last index of each tied-score block
    is_end = jnp.concatenate([s[:-1] != s[1:],
                              jnp.ones((1,), dtype=bool)])
    return tp, fp, is_end


def _exclusive_cummax(x):
    return jnp.concatenate([jnp.zeros((1,), x.dtype),
                            jax.lax.cummax(x)[:-1]])


def auc_roc(scores: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Trapezoidal area under the ROC curve (tie-aware)."""
    tp, fp, is_end = _curve_points(scores, labels)
    pos = jnp.maximum(tp[-1], 1e-12)
    neg = jnp.maximum(fp[-1], 1e-12)
    tpr = tp / pos
    fpr = fp / neg
    tpr_m = jnp.where(is_end, tpr, 0.0)
    fpr_m = jnp.where(is_end, fpr, 0.0)
    prev_tpr = _exclusive_cummax(tpr_m)
    prev_fpr = _exclusive_cummax(fpr_m)
    area = jnp.where(is_end, (fpr - prev_fpr) * (tpr + prev_tpr) * 0.5, 0.0)
    return jnp.sum(area).astype(jnp.float32)


def auc_pr(scores: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Average precision (step-wise interpolation, sklearn-compatible)."""
    tp, fp, is_end = _curve_points(scores, labels)
    pos = jnp.maximum(tp[-1], 1e-12)
    precision = tp / jnp.maximum(tp + fp, 1e-12)
    recall = tp / pos
    recall_m = jnp.where(is_end, recall, 0.0)
    prev_recall = _exclusive_cummax(recall_m)
    ap = jnp.where(is_end, (recall - prev_recall) * precision, 0.0)
    return jnp.sum(ap).astype(jnp.float32)


def bce_elementwise(logits: jnp.ndarray, labels: jnp.ndarray
                    ) -> jnp.ndarray:
    """Numerically-stable per-example BCE from logits (no reduction)."""
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return (jnp.maximum(logits, 0) - logits * labels +
            jnp.log1p(jnp.exp(-jnp.abs(logits))))


def binary_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray
                         ) -> jnp.ndarray:
    """Numerically-stable mean BCE from logits."""
    return jnp.mean(bce_elementwise(logits.reshape(-1), labels.reshape(-1)))
