from repro.metrics.auc import auc_roc, auc_pr, binary_cross_entropy
