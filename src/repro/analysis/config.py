"""tracelint configuration: rule registry and defaults.

Kept importable without jax — the linter must run in a bare CI job
(and in pre-commit hooks) without initializing any backend.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence, Set

from repro.analysis.rules import ALL_RULES

DEFAULT_PATHS = ("src", "benchmarks", "examples")
DEFAULT_BASELINE = "analysis/baseline.json"
DEFAULT_PRIVACY_BASELINE = "analysis/privacy_baseline.json"
DEFAULT_SHAPE_BASELINE = "analysis/shape_baseline.json"

# package roots stripped when deriving dotted module names
SOURCE_ROOTS = ("src",)


@dataclass
class LintConfig:
    paths: Sequence[str] = DEFAULT_PATHS
    baseline: str = DEFAULT_BASELINE
    rules: Set[str] = field(default_factory=lambda: set(ALL_RULES))

    def selected_rules(self):
        unknown = self.rules - set(ALL_RULES)
        if unknown:
            raise ValueError(
                f"unknown rule(s) {sorted(unknown)}; "
                f"known: {sorted(ALL_RULES)}")
        return {code: ALL_RULES[code] for code in sorted(self.rules)}
