"""Findings, suppressions, baselines, and rendering.

A finding's **key** is line-number-free on purpose:

    <rule>:<file>:<symbol>:<ordinal>

(ordinal = n-th finding of that rule inside that symbol), so the
committed baseline survives unrelated edits that shift line numbers.
Suppression is per line: a ``# tracelint: disable=TL001`` (or
``# privlint: disable=PL001``, ``disable=TL001,TL002``, or a bare
``disable`` for all rules) comment on the flagged line or the line
directly above silences the finding at the source; for a finding inside
a decorated ``def``'s header (any decorator line through the ``def``
line) the comment may sit anywhere in that header or on the line above
it.  The baseline instead *records* a finding that stays visible in
``--list-baseline`` with a justification.  All three linters
(tracelint, privlint, and shapelint) share these semantics — the
rule-code filter is what scopes a comment to one tool.
"""
from __future__ import annotations

import ast
import json
import os
import re
from collections import Counter
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

_SUPPRESS_RE = re.compile(
    r"#\s*(?:tracelint|privlint|shapelint):"
    r"\s*disable(?:=(?P<codes>[A-Z0-9,\s]+))?")

BASELINE_VERSION = 1


@dataclass
class Finding:
    rule: str                 # "TL001"
    path: str                 # repo-relative path as scanned
    line: int
    col: int
    message: str
    symbol: str = "<module>"  # enclosing function qualname
    ordinal: int = 0          # n-th (rule, path, symbol) finding

    @property
    def key(self) -> str:
        return f"{self.rule}:{self.path}:{self.symbol}:{self.ordinal}"

    def as_dict(self) -> Dict:
        return {"key": self.key, "rule": self.rule, "file": self.path,
                "line": self.line, "col": self.col, "symbol": self.symbol,
                "message": self.message}

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"{self.message} [in {self.symbol}]")


def assign_ordinals(findings: List[Finding]) -> List[Finding]:
    """Stable per-(rule, path, symbol) ordinals, in (line, col) order."""
    counts: Counter = Counter()
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.col)):
        slot = (f.rule, f.path, f.symbol)
        f.ordinal = counts[slot]
        counts[slot] += 1
    return findings


def decorator_regions(tree: ast.AST) -> Dict[int, range]:
    """Lines inside a decorated def/class header → the whole header.

    A finding anchored to a decorator line (``@partial(jax.jit, ...)``)
    used to require the disable comment on that exact line; mapping every
    header line (first decorator .. the ``def``/``class`` line) to the
    full header lets the comment sit anywhere in it, or on the line
    directly above the first decorator.
    """
    regions: Dict[int, range] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)) and node.decorator_list:
            start = min(d.lineno for d in node.decorator_list)
            region = range(start - 1, node.lineno + 1)
            for ln in range(start, node.lineno + 1):
                regions[ln] = region
    return regions


def suppressed(finding: Finding, source_lines: Sequence[str],
               regions: Optional[Dict[int, range]] = None) -> bool:
    """True when a disable comment covers the finding's line."""
    lines = {finding.line, finding.line - 1}
    if regions and finding.line in regions:
        lines.update(regions[finding.line])
    for lineno in lines:
        if 1 <= lineno <= len(source_lines):
            m = _SUPPRESS_RE.search(source_lines[lineno - 1])
            if m:
                codes = m.group("codes")
                if codes is None:
                    return True
                if finding.rule in {c.strip()
                                    for c in codes.split(",") if c.strip()}:
                    return True
    return False


@dataclass
class Baseline:
    """The committed set of accepted findings (analysis/baseline.json)."""

    path: Optional[str] = None
    entries: Dict[str, Dict] = field(default_factory=dict)  # key -> record

    @classmethod
    def load(cls, path: Optional[str]) -> "Baseline":
        if path is None or not os.path.exists(path):
            return cls(path=path)
        with open(path, encoding="utf-8") as f:
            data = json.load(f)
        if data.get("version") != BASELINE_VERSION:
            raise ValueError(
                f"baseline {path}: unsupported version "
                f"{data.get('version')!r} (expected {BASELINE_VERSION})")
        return cls(path=path,
                   entries={e["key"]: e for e in data.get("findings", [])})

    def split(self, findings: Sequence[Finding]):
        """(new, accepted, stale-keys) for one run's findings."""
        new, accepted = [], []
        seen = set()
        for f in findings:
            if f.key in self.entries:
                accepted.append(f)
                seen.add(f.key)
            else:
                new.append(f)
        stale = [k for k in self.entries if k not in seen]
        return new, accepted, stale

    def write(self, path: str, findings: Sequence[Finding]) -> None:
        """Write ``findings`` as the new baseline, keeping any existing
        justifications for keys that persist."""
        records = []
        for f in sorted(findings, key=lambda f: f.key):
            rec = {"key": f.key, "rule": f.rule, "file": f.path,
                   "symbol": f.symbol, "message": f.message}
            old = self.entries.get(f.key)
            if old and old.get("justification"):
                rec["justification"] = old["justification"]
            else:
                rec["justification"] = "TODO: justify or fix"
            records.append(rec)
        with open(path, "w", encoding="utf-8") as f:
            json.dump({"version": BASELINE_VERSION, "findings": records},
                      f, indent=1)
            f.write("\n")


def render_report(new: Sequence[Finding], accepted: Sequence[Finding],
                  stale: Sequence[str], baseline_path: Optional[str],
                  files_scanned: int, tool: str = "tracelint") -> str:
    lines: List[str] = []
    for f in sorted(new, key=lambda f: (f.path, f.line, f.col)):
        lines.append(f.render())
    if new:
        lines.append("")
    lines.append(f"{tool}: {files_scanned} files, "
                 f"{len(new)} new finding(s), "
                 f"{len(accepted)} baselined, {len(stale)} stale "
                 f"baseline entr{'y' if len(stale) == 1 else 'ies'}")
    if new:
        lines.append(
            "  new findings fail the lint: fix them, suppress with "
            f"'# {tool}: disable=<rule>' where intended, or accept "
            "into the baseline with --write-baseline"
            + (f" ({baseline_path})" if baseline_path else ""))
    if stale:
        lines.append(
            "  stale entries no longer occur — refresh the baseline "
            "with --write-baseline to drop them")
    return "\n".join(lines)


def json_report(new: Sequence[Finding], accepted: Sequence[Finding],
                stale: Sequence[str], files_scanned: int) -> Dict:
    return {
        "version": BASELINE_VERSION,
        "files_scanned": files_scanned,
        "new": [f.as_dict() for f in sorted(
            new, key=lambda f: (f.path, f.line, f.col))],
        "baselined": [f.as_dict() for f in sorted(
            accepted, key=lambda f: (f.path, f.line, f.col))],
        "stale_baseline_keys": sorted(stale),
    }
