"""Abstract shape/dtype/padding-provenance interpretation (shapelint).

The repo's most recurring bug class is *padding discipline*: bucketed-P
cohort padding (PR 3), fused ``(S, B)`` horizon plans (PR 4), keep-masks
(PR 5), and fault-admit masks (PR 9) all create arrays whose trailing
slots are dead and must be validity-masked in every reduction,
denominator, and aggregation.  This module checks that statically, on
top of ``astgraph``'s pure-``ast`` call graph — nothing is imported or
executed, so it runs without JAX.

Abstract domain
---------------
Every value carries a :class:`Shape`:

* ``rank`` / ``dims`` — symbolic shape: known rank with (optionally)
  named dims (``("K", "B")``), or unknown (``rank=None``).
* ``dtype`` / ``weak`` — canonical short dtype ("f32", "f64", "bool",
  "i32", …) plus the weak-type flag for Python scalars; feeds the
  promotion-drift rule (SL003).
* ``prov`` — padding provenance lattice ``NONE(0) < ZEROED(1) <
  PADDED(2)``.  PADDED means the leading slot axis carries *garbage*
  filler values; ZEROED means the filler slots are exact zeros (sums
  are safe, means/extrema are not).  Seeded at the bucket-padding
  producers (``_pad_slots``/``pad_rows``/``horizon_slot_plan``…),
  cleared by ``jnp.where(valid, ·, 0)`` (→ ZEROED), mask
  multiplication (→ ZEROED), or slicing back to ``[:p_count]``
  (→ NONE).
* ``is_mask`` — boolean validity mask over slots; ``pad_count`` — a
  scalar that counts *all* slots including dead ones (``bucket_size``
  result, ``len(padded)``, ``padded.shape[0]``); ``masked_sum`` — a
  sum taken over a ZEROED axis (a safe numerator, but dividing it by a
  ``pad_count`` is exactly the SL002 bug); ``maskable`` — a quantity
  that can be zero (``Σmask``); ``guarded`` — a dominating positive
  guard (``jnp.maximum(·, 1)``) has been applied.

Function summaries are structural (tuples keep per-element shapes) and
interprocedural propagation is the same context-insensitive
caller-arg→callee-param forward fixpoint as ``taint.py``, including
``vmap``/``jit``/``partial`` unwrapping, ``lax.scan`` body seeding,
method-name-index fallback, and call-through-variable ``fnref``
support.  ``vmap`` maps over the slot axis, so seeding *strips*
padding provenance from the per-slot view and re-attaches it to the
mapped outputs; ``scan`` runs over rounds (``S``), so its per-step
``xs`` slices *keep* their slot-axis provenance.

The rule checks (SL001–SL006) are emitted during a recording pass
after the fixpoint converges; ``repro.analysis.shaperules`` declares
the policy tables and rule catalogue.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

from repro.analysis import astgraph
from repro.analysis.report import Finding
from repro.analysis.taint import (_CALL_WRAPPERS, _MUTATORS, _SCAN_NAMES,
                                  _STRUCTURAL_CALLS, name_matches)

# --- padding provenance lattice ----------------------------------------

NONE, ZEROED, PADDED = 0, 1, 2
PROV_NAMES = {NONE: "clean", ZEROED: "zero-filled", PADDED: "padded"}

MAX_FIXPOINT_ITERS = 24
_MAX_METHOD_TARGETS = 8

# reduction vocabulary, dispatched on the trailing dotted component
_SUM_FAMILY = {"sum", "nansum", "segment_sum", "logsumexp"}
_MEAN_FAMILY = {"mean", "nanmean", "average", "median", "quantile",
                "percentile", "std", "var"}
_EXTREME_FAMILY = {"max", "min", "amax", "amin", "argmax", "argmin",
                   "nanmax", "nanmin"}
_REDUCTIONS = _SUM_FAMILY | _MEAN_FAMILY | _EXTREME_FAMILY

# ops that produce nonfinite values when fed a zero/negative operand
_NONFINITE_OPS = {"log", "log2", "log10", "reciprocal", "sqrt"}

# positive-floor guards: jnp.maximum(x, 1), jnp.clip(x, 1e-6, ...), max()
_GUARD_CALLS = {"maximum", "fmax", "clip", "max"}

_CREATION_CALLS = {"zeros", "ones", "full", "empty", "zeros_like",
                   "ones_like", "full_like", "array", "asarray",
                   "arange", "linspace", "eye"}

_DTYPE_SHORT = {
    "float64": "f64", "double": "f64", "float_": "f64",
    "float32": "f32", "single": "f32",
    "float16": "f16", "bfloat16": "bf16",
    "int64": "i64", "int32": "i32", "int16": "i16", "int8": "i8",
    "uint32": "u32", "uint8": "u8",
    "bool_": "bool", "bool": "bool",
}

_ARITH_OPS = (ast.Add, ast.Sub, ast.Mult, ast.Div, ast.FloorDiv,
              ast.Mod, ast.Pow, ast.MatMult)


@dataclass(frozen=True)
class Shape:
    rank: Optional[int] = None
    dims: Tuple[str, ...] = ()
    dtype: str = ""
    weak: bool = False
    prov: int = NONE
    is_mask: bool = False
    pad_count: bool = False
    masked_sum: bool = False
    maskable: bool = False
    guarded: bool = False
    why: str = ""
    fnref: Tuple[str, ...] = ()


BOTTOM = Shape()

# an abstract value: a single Shape or a tuple of abstract values
Value = Union[Shape, tuple]


def _join_flat(a: Shape, b: Shape) -> Shape:
    # unknown rank/dims are "no information", not a conflict: joining
    # with BOTTOM (e.g. the initial summary) must not erase known facts
    if a.rank is None or b.rank is None:
        rank = a.rank if b.rank is None else b.rank
    else:
        rank = a.rank if a.rank == b.rank else None
    if not a.dims or not b.dims:
        dims = a.dims or b.dims
    else:
        dims = a.dims if a.dims == b.dims else ()
    if a.dtype == b.dtype:
        dtype = a.dtype
    elif not a.dtype or not b.dtype:
        dtype = a.dtype or b.dtype
    else:
        dtype = _promote(a.dtype, b.dtype)
    hi = a if a.prov >= b.prov else b
    fnref = a.fnref if not b.fnref else (
        b.fnref if not a.fnref else
        tuple(sorted(set(a.fnref) | set(b.fnref))))
    return Shape(rank=rank, dims=dims, dtype=dtype,
                 weak=a.weak or b.weak,
                 prov=max(a.prov, b.prov),
                 is_mask=a.is_mask or b.is_mask,
                 pad_count=a.pad_count or b.pad_count,
                 masked_sum=a.masked_sum or b.masked_sum,
                 maskable=a.maskable or b.maskable,
                 guarded=a.guarded or b.guarded,
                 why=hi.why or a.why or b.why,
                 fnref=fnref)


def _promote(a: str, b: str) -> str:
    """JAX-style binary promotion on the short-name lattice (coarse)."""
    order = ["bool", "i8", "u8", "i16", "i32", "u32", "i64",
             "bf16", "f16", "f32", "f64"]
    try:
        return a if order.index(a) >= order.index(b) else b
    except ValueError:
        return ""


def collapse(v: Value) -> Shape:
    """Fold a structured value to one flat Shape."""
    if isinstance(v, Shape):
        return v
    out = BOTTOM
    for el in v:
        out = _join_flat(out, collapse(el))
    return out


def join(a: Value, b: Value) -> Value:
    """Structural join; unequal-arity tuples align by prefix."""
    if isinstance(a, tuple) and isinstance(b, tuple):
        n = min(len(a), len(b))
        head = tuple(join(x, y) for x, y in zip(a[:n], b[:n]))
        tail = a[n:] if len(a) > len(b) else b[n:]
        return head + tail
    if isinstance(a, tuple) or isinstance(b, tuple):
        if isinstance(b, tuple):
            a, b = b, a
        return tuple(join(x, b) for x in a)
    return _join_flat(a, b)


def values_equal(a: Value, b: Value) -> bool:
    if isinstance(a, Shape) and isinstance(b, Shape):
        return a == b
    if isinstance(a, tuple) and isinstance(b, tuple) and len(a) == len(b):
        return all(values_equal(x, y) for x, y in zip(a, b))
    return False


def _map_shape(v: Value, fn) -> Value:
    if isinstance(v, Shape):
        return fn(v)
    return tuple(_map_shape(el, fn) for el in v)


def _strip_slots(v: Value) -> Value:
    """Erase every padding-related fact (sanctioned slot consumers)."""
    return _map_shape(v, lambda s: replace(
        s, prov=NONE, is_mask=False, pad_count=False, masked_sum=False,
        maskable=False, why=""))


def _per_slot(v: Value) -> Value:
    """A vmap-mapped view: the slot axis is gone inside the body."""
    return _map_shape(v, lambda s: replace(
        s, prov=NONE, is_mask=False, pad_count=False,
        rank=None if s.rank is None else max(s.rank - 1, 0), dims=()))


# --- policy ------------------------------------------------------------

@dataclass
class ShapePolicy:
    """Declared padding producers, sanctioned consumers, and guards.

    Patterns match a call's raw or import-resolved dotted name on whole
    component suffixes (same convention as the taint policy).
    """

    # calls whose result carries PADDED slots on the leading axis
    padded_producers: Tuple[str, ...] = ()
    # calls returning an opaque plan object whose *attributes* carry the
    # padding facts (see the *_attrs tables)
    plan_producers: Tuple[str, ...] = ()
    # calls whose scalar result counts all slots incl. dead ones
    pad_count_producers: Tuple[str, ...] = ()

    # attribute / string-key tables for opaque plan objects
    padded_attrs: Tuple[str, ...] = ()
    zeroed_attrs: Tuple[str, ...] = ()
    mask_attrs: Tuple[str, ...] = ()

    # parameter names seeded as validity masks when no caller is seen
    mask_params: Tuple[str, ...] = ()
    # variable names whose use as a slice bound clears provenance
    count_names: Tuple[str, ...] = ()

    # sanctioned slot-axis consumers: call results are provenance-free
    slot_reducers: Tuple[str, ...] = ()

    # denominators that can be zero by construction (SL006)
    zero_risk_denoms: Tuple[str, ...] = ()


# --- analysis ----------------------------------------------------------

class ShapeAnalysis:
    """Fixpoint + recording passes over one :class:`astgraph.CallGraph`."""

    def __init__(self, graph: astgraph.CallGraph, policy: ShapePolicy,
                 rules: Optional[Set[str]] = None):
        self.graph = graph
        self.policy = policy
        self.rules = rules          # None = all
        self.param_env: Dict[str, Dict[str, Value]] = {}
        self.summaries: Dict[str, Value] = {}
        self.fn_envs: Dict[str, Dict[str, Value]] = {}
        self.findings: List[Finding] = []
        self._changed = False
        self._method_index: Dict[str, List[astgraph.FunctionInfo]] = {}
        for mod in self.graph.modules.values():
            for cls, methods in mod.classes.items():
                for m in methods:
                    info = mod.functions.get(f"{cls}.{m}")
                    if info is not None:
                        self._method_index.setdefault(m, []).append(info)

    # -- driver --------------------------------------------------------

    def run(self) -> List[Finding]:
        order = list(self.graph.functions.values())
        for _ in range(MAX_FIXPOINT_ITERS):
            self._changed = False
            for fn in order:
                self._analyze(fn, record=False)
            if not self._changed:
                break
        for fn in order:
            self._analyze(fn, record=True)
        if self.rules is not None:
            self.findings = [f for f in self.findings
                             if f.rule in self.rules]
        return self.findings

    def _analyze(self, fn: astgraph.FunctionInfo, record: bool) -> None:
        mod = self.graph.modules[fn.module]
        ev = _Evaluator(self, mod, fn, record=record)
        summary = ev.run()
        old = self.summaries.get(fn.key, BOTTOM)
        new = join(old, summary)
        if not values_equal(old, new):
            self.summaries[fn.key] = new
            self._changed = True
        self.fn_envs[fn.key] = ev.env

    # -- interprocedural plumbing --------------------------------------

    def seed_param(self, fn_key: str, pname: str, val: Value) -> None:
        env = self.param_env.setdefault(fn_key, {})
        old = env.get(pname, BOTTOM)
        new = join(old, val)
        if not values_equal(old, new):
            env[pname] = new
            self._changed = True

    def resolve_call(self, mod: astgraph.ModuleInfo,
                     fn: astgraph.FunctionInfo, raw: Optional[str]
                     ) -> List[astgraph.FunctionInfo]:
        if not raw:
            return []
        local = astgraph._resolve_local(mod, fn, raw)
        if local is not None:
            return [local]
        resolved = mod.resolve(raw)
        hit = self.graph.by_dotted.get(resolved)
        if hit is not None:
            return [hit]
        if "." in raw:
            meth = raw.rsplit(".", 1)[-1]
            targets = self._method_index.get(meth, [])
            if 0 < len(targets) <= _MAX_METHOD_TARGETS:
                return list(targets)
        return []

    def emit(self, rule: str, mod: astgraph.ModuleInfo, node: ast.AST,
             message: str, fn: astgraph.FunctionInfo) -> None:
        self.findings.append(Finding(
            rule=rule, path=mod.path, line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0), message=message,
            symbol=fn.qualname))


class _Evaluator:
    """One statement-ordered abstract interpretation of one function.

    Same flow discipline as the taint evaluator: branches execute
    sequentially over one environment, loops once, and the surrounding
    fixpoint supplies convergence.
    """

    def __init__(self, owner: ShapeAnalysis, mod: astgraph.ModuleInfo,
                 fn: astgraph.FunctionInfo, record: bool):
        self.a = owner
        self.pol = owner.policy
        self.mod = mod
        self.fn = fn
        self.record = record
        self.env: Dict[str, Value] = {}
        self.returns: List[Value] = []

    # -- entry ---------------------------------------------------------

    def run(self) -> Value:
        if self.fn.parent is not None:
            parent = self.mod.functions.get(self.fn.parent)
            if parent is not None:
                self.env.update(self.a.fn_envs.get(parent.key, {}))
        seeded = self.a.param_env.get(self.fn.key, {})
        for pname in self.fn.params:
            v = seeded.get(pname, BOTTOM)
            if values_equal(v, BOTTOM) and pname in self.pol.mask_params:
                v = Shape(dtype="bool", is_mask=True,
                          why=f"validity mask '{pname}'")
            self.env[pname] = v
        self.exec_block(getattr(self.fn.node, "body", []))
        if not self.returns:
            return BOTTOM
        out: Value = self.returns[0]
        for r in self.returns[1:]:
            out = join(out, r)
        return out

    # -- statements ----------------------------------------------------

    def exec_block(self, stmts: Sequence[ast.stmt]) -> None:
        for st in stmts:
            self.exec_stmt(st)

    def exec_stmt(self, st: ast.stmt) -> None:
        if isinstance(st, ast.Assign):
            v = self.eval(st.value)
            for t in st.targets:
                self.bind(t, v)
        elif isinstance(st, ast.AnnAssign):
            if st.value is not None:
                self.bind(st.target, self.eval(st.value))
        elif isinstance(st, ast.AugAssign):
            v = join(self.eval(st.target), self.eval(st.value))
            self.bind(st.target, v, augmented=True)
        elif isinstance(st, ast.Return):
            self.returns.append(self.eval(st.value)
                                if st.value is not None else BOTTOM)
        elif isinstance(st, ast.Expr):
            self.eval(st.value)
        elif isinstance(st, ast.If):
            self.eval(st.test)
            self.exec_block(st.body)
            self.exec_block(st.orelse)
        elif isinstance(st, (ast.For, ast.AsyncFor)):
            self.bind(st.target, self._iter_element(self.eval(st.iter)))
            self.exec_block(st.body)
            self.exec_block(st.orelse)
        elif isinstance(st, ast.While):
            self.eval(st.test)
            self.exec_block(st.body)
            self.exec_block(st.orelse)
        elif isinstance(st, (ast.With, ast.AsyncWith)):
            for item in st.items:
                v = self.eval(item.context_expr)
                if item.optional_vars is not None:
                    self.bind(item.optional_vars, v)
            self.exec_block(st.body)
        elif isinstance(st, ast.Try):
            self.exec_block(st.body)
            for h in st.handlers:
                self.exec_block(h.body)
            self.exec_block(st.orelse)
            self.exec_block(st.finalbody)
        elif isinstance(st, (ast.Raise, ast.Assert)):
            for child in ast.iter_child_nodes(st):
                if isinstance(child, ast.expr):
                    self.eval(child)
        elif isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            pass
        elif isinstance(st, ast.Delete):
            for t in st.targets:
                if isinstance(t, ast.Name):
                    self.env.pop(t.id, None)

    @staticmethod
    def _iter_element(v: Value) -> Value:
        # iterating a padded container yields per-slot elements: the
        # slot axis is consumed by the loop itself
        return _map_shape(v, lambda s: replace(
            s, rank=None if s.rank is None else max(s.rank - 1, 0),
            dims=()))

    def bind(self, target: ast.expr, v: Value,
             augmented: bool = False) -> None:
        if isinstance(target, ast.Name):
            if augmented:
                v = join(self.env.get(target.id, BOTTOM), v)
            self.env[target.id] = v
        elif isinstance(target, (ast.Tuple, ast.List)):
            elts = target.elts
            if isinstance(v, tuple):
                star = next((i for i, e in enumerate(elts)
                             if isinstance(e, ast.Starred)), None)
                if star is None and len(elts) <= len(v):
                    for e, el in zip(elts, v):
                        self.bind(e, el)
                    return
                for e in elts:
                    self.bind(e.value if isinstance(e, ast.Starred)
                              else e, collapse(v))
            else:
                for e in elts:
                    self.bind(e.value if isinstance(e, ast.Starred)
                              else e, v)
        elif isinstance(target, ast.Starred):
            self.bind(target.value, v)
        elif isinstance(target, ast.Subscript):
            base = target.value
            if isinstance(base, ast.Name):
                self.env[base.id] = join(self.env.get(base.id, BOTTOM), v)
        elif isinstance(target, ast.Attribute):
            pass        # object state is not tracked

    # -- expressions ---------------------------------------------------

    def eval(self, node: ast.expr) -> Value:
        if isinstance(node, ast.Constant):
            return self._eval_constant(node)
        if isinstance(node, ast.Name):
            if node.id in self.env:
                return self.env[node.id]
            tgts = self.a.resolve_call(self.mod, self.fn, node.id)
            if tgts:
                return Shape(fnref=tuple(sorted(t.key for t in tgts)))
            return BOTTOM
        if isinstance(node, ast.Tuple):
            return tuple(self.eval(e) for e in node.elts)
        if isinstance(node, ast.List):
            out = BOTTOM
            for e in node.elts:
                out = join(out, collapse(self.eval(e)))
            return out
        if isinstance(node, (ast.Set, ast.Dict)):
            out = BOTTOM
            vals = node.values if isinstance(node, ast.Dict) else node.elts
            for e in vals:
                if e is not None:
                    out = join(out, collapse(self.eval(e)))
            return out
        if isinstance(node, ast.Starred):
            return self.eval(node.value)
        if isinstance(node, ast.BinOp):
            return self._eval_binop(node)
        if isinstance(node, ast.BoolOp):
            out = BOTTOM
            for e in node.values:
                out = join(out, collapse(self.eval(e)))
            return out
        if isinstance(node, ast.Compare):
            return self._eval_compare(node)
        if isinstance(node, ast.UnaryOp):
            return self.eval(node.operand)
        if isinstance(node, ast.IfExp):
            self.eval(node.test)
            return join(self.eval(node.body), self.eval(node.orelse))
        if isinstance(node, ast.Subscript):
            return self._eval_subscript(node)
        if isinstance(node, ast.Attribute):
            return self._eval_attribute(node)
        if isinstance(node, ast.Call):
            return self.eval_call(node)
        if isinstance(node, (ast.ListComp, ast.SetComp,
                             ast.GeneratorExp, ast.DictComp)):
            for gen in node.generators:
                self.bind(gen.target,
                          self._iter_element(self.eval(gen.iter)))
                for cond in gen.ifs:
                    self.eval(cond)
            if isinstance(node, ast.DictComp):
                self.eval(node.key)
                return collapse(self.eval(node.value))
            return collapse(self.eval(node.elt))
        if isinstance(node, ast.Lambda):
            return BOTTOM
        if isinstance(node, ast.JoinedStr):
            for v in node.values:
                if isinstance(v, ast.FormattedValue):
                    self.eval(v.value)
            return BOTTOM
        if isinstance(node, (ast.Await, ast.YieldFrom)):
            return self.eval(node.value)
        if isinstance(node, ast.Yield):
            return self.eval(node.value) if node.value else BOTTOM
        if isinstance(node, ast.NamedExpr):
            v = self.eval(node.value)
            self.bind(node.target, v)
            return v
        if isinstance(node, ast.Slice):
            for part in (node.lower, node.upper, node.step):
                if part is not None:
                    self.eval(part)
            return BOTTOM
        return BOTTOM

    @staticmethod
    def _eval_constant(node: ast.Constant) -> Shape:
        v = node.value
        if isinstance(v, bool):
            return Shape(rank=0, dtype="bool", weak=True)
        if isinstance(v, int):
            return Shape(rank=0, dtype="i32", weak=True)
        if isinstance(v, float):
            return Shape(rank=0, dtype="f32", weak=True)
        return BOTTOM

    # -- operators -----------------------------------------------------

    def _eval_binop(self, node: ast.BinOp) -> Value:
        lv = collapse(self.eval(node.left))
        rv = collapse(self.eval(node.right))
        arith = isinstance(node.op, _ARITH_OPS)
        out = _join_flat(lv, rv)
        if out.fnref:
            out = replace(out, fnref=())

        if arith:
            self._check_bool_arith(node, lv, rv)
            self._check_promotion(node, lv, rv)
            self._check_padded_broadcast(node, lv, rv)

        # mask multiplication / multiplication by exact-zero filler
        # zeros out the dead slots: ZEROED absorbs PADDED
        if isinstance(node.op, ast.Mult) and (
                lv.is_mask or rv.is_mask or
                ZEROED in (lv.prov, rv.prov)):
            out = replace(out, prov=ZEROED if out.prov else NONE,
                          is_mask=False)

        if isinstance(node.op, ast.Div):
            self._check_division(node, lv, rv)

        # `x + 1e-6` style floors guard a maskable denominator
        if isinstance(node.op, ast.Add) and (
                self._positive_literal(node.left) or
                self._positive_literal(node.right)):
            out = replace(out, maskable=False, guarded=True)

        # broadcasting: the result rank is the larger known rank
        if lv.rank is not None and rv.rank is not None:
            out = replace(out, rank=max(lv.rank, rv.rank),
                          dims=lv.dims if len(lv.dims) >= len(rv.dims)
                          else rv.dims)
        if arith and not isinstance(node.op, ast.MatMult):
            # arithmetic results are not masks/counters themselves
            out = replace(out, is_mask=False, pad_count=False)
        return out

    def _eval_compare(self, node: ast.Compare) -> Value:
        parts = [collapse(self.eval(node.left))]
        parts += [collapse(self.eval(c)) for c in node.comparators]
        ranks = [p.rank for p in parts if p.rank is not None]
        slotty = any(p.prov > NONE or p.pad_count for p in parts)
        names = {n.id for e in [node.left] + list(node.comparators)
                 for n in ast.walk(e) if isinstance(n, ast.Name)}
        if names & set(self.pol.count_names):
            slotty = True
        return Shape(rank=max(ranks) if ranks else None, dtype="bool",
                     is_mask=slotty,
                     why="validity mask" if slotty else "")

    def _eval_subscript(self, node: ast.Subscript) -> Value:
        base = self.eval(node.value)
        self.eval(node.slice)
        sl = node.slice

        # tuple summaries index structurally
        if isinstance(base, tuple) and isinstance(sl, ast.Constant) and \
                isinstance(sl.value, int) and \
                -len(base) <= sl.value < len(base):
            return base[sl.value]
        flat = collapse(base)

        # dict-style access on a plan payload: string keys hit the same
        # attribute tables as the plan object's attributes
        if isinstance(sl, ast.Constant) and isinstance(sl.value, str):
            table = self._attr_shape(sl.value)
            if table is not None:
                return table
            return flat

        # slicing back to the live prefix clears padding provenance:
        # `losses[:p_count]`
        slices = [sl.elts[0] if isinstance(sl, ast.Tuple) and sl.elts
                  else sl]
        if isinstance(sl, ast.Tuple):
            slices = list(sl.elts)
        for s in slices:
            if isinstance(s, ast.Slice) and s.upper is not None:
                upper_names = {n.id for n in ast.walk(s.upper)
                               if isinstance(n, ast.Name)}
                if upper_names & set(self.pol.count_names):
                    return replace(flat, prov=NONE, pad_count=False,
                                   why="sliced to live prefix")

        # integer indexing consumes the leading axis
        if isinstance(sl, ast.Constant) and isinstance(sl.value, int):
            return replace(flat, prov=NONE,
                           rank=None if flat.rank is None
                           else max(flat.rank - 1, 0), dims=())

        # `x[:, None]` expands rank but keeps provenance (feeds SL005)
        if isinstance(sl, ast.Tuple) and any(
                isinstance(e, ast.Constant) and e.value is None
                for e in sl.elts):
            return replace(flat, rank=None if flat.rank is None
                           else flat.rank + 1, dims=())
        return flat

    def _attr_shape(self, attr: str) -> Optional[Shape]:
        if attr in self.pol.padded_attrs:
            return Shape(prov=PADDED, dtype="i32",
                         why=f"padded plan leg '{attr}'")
        if attr in self.pol.zeroed_attrs:
            return Shape(prov=ZEROED, dtype="f32",
                         why=f"zero-filled plan leg '{attr}'")
        if attr in self.pol.mask_attrs:
            return Shape(dtype="bool", is_mask=True,
                         why=f"validity mask '{attr}'")
        return None

    def _eval_attribute(self, node: ast.Attribute) -> Value:
        table = self._attr_shape(node.attr)
        if table is not None:
            self.eval(node.value)
            return table
        base = collapse(self.eval(node.value))
        if node.attr == "shape":
            if base.prov > NONE:
                return Shape(pad_count=True, dtype="i32",
                             why="shape of a padded array")
            return BOTTOM
        if node.attr in ("ndim", "size", "dtype", "nbytes", "itemsize",
                         "sharding", "device", "name", "T"):
            return BOTTOM
        return base

    # -- calls ---------------------------------------------------------

    def _unwrap_callee(self, node: ast.Call
                       ) -> Tuple[Optional[str], List[ast.expr],
                                  Optional[str]]:
        """Peel ``jax.vmap(f, ...)(args)`` to (f, outer args, wrapper)."""
        func = node.func
        args = list(node.args)
        if isinstance(func, ast.Call):
            inner_name = astgraph.dotted_name(func.func)
            resolved = self.mod.resolve(inner_name) if inner_name else None
            if name_matches(_CALL_WRAPPERS, inner_name, resolved):
                for a in func.args:
                    nm = astgraph.dotted_name(a)
                    if nm and not name_matches(
                            _CALL_WRAPPERS, nm, self.mod.resolve(nm)):
                        wrapper = (inner_name or "").rsplit(".", 1)[-1]
                        return nm, args, wrapper
        return astgraph.dotted_name(func), args, None

    def eval_call(self, node: ast.Call) -> Value:
        pol = self.pol
        raw, pos_exprs, wrapper = self._unwrap_callee(node)
        resolved = self.mod.resolve(raw) if raw else None
        # method calls on expression receivers (`(x == 0).sum()`) have
        # no dotted name; the attribute still names the operation
        last = raw.rsplit(".", 1)[-1] if raw else (
            node.func.attr if isinstance(node.func, ast.Attribute)
            else "")

        pos: List[Value] = [self.eval(a) for a in pos_exprs]
        kwargs: Dict[Optional[str], Value] = {
            kw.arg: self.eval(kw.value) for kw in node.keywords}

        def flat_join() -> Shape:
            out = BOTTOM
            for v in pos + list(kwargs.values()):
                out = _join_flat(out, collapse(v))
            return out

        # container mutators on known locals: obj.append(x)
        if raw and "." in raw:
            base, meth = raw.rsplit(".", 1)
            if meth in _MUTATORS and "." not in base and base in self.env:
                self.env[base] = join(self.env.get(base, BOTTOM),
                                      flat_join())
                return BOTTOM

        # wrapper *construction*: `jax.jit(f)` is a reference to f
        if raw is None and isinstance(node.func, ast.Call):
            inner = astgraph.dotted_name(node.func.func)
            inner_res = self.mod.resolve(inner) if inner else None
            if inner and name_matches(_CALL_WRAPPERS, inner, inner_res) \
                    and len(node.args) == 1:
                tname = astgraph.dotted_name(node.args[0])
                tgts = self.a.resolve_call(self.mod, self.fn, tname) \
                    if tname else []
                if tgts:
                    return Shape(fnref=tuple(sorted(t.key for t in tgts)))

        # ---- structural / builtin special forms ---------------------
        if raw == "len" and pos:
            if collapse(pos[0]).prov > NONE:
                return Shape(rank=0, dtype="i32", pad_count=True,
                             why="len() of a padded array")
            return Shape(rank=0, dtype="i32")
        if raw in _STRUCTURAL_CALLS:
            return BOTTOM
        # Python scalar builtins produce weak host scalars
        if raw in ("int", "round") and pos:
            return Shape(rank=0, dtype="i32", weak=True)
        if raw == "float" and pos:
            return Shape(rank=0, dtype="f32", weak=True)
        if raw == "bool" and pos:
            return Shape(rank=0, dtype="bool", weak=True)
        if last == "count_nonzero" and pos:
            return Shape(rank=0, dtype="i32")
        if raw == "enumerate" and pos:
            return (BOTTOM, self._iter_element(pos[0]))
        if raw == "zip":
            return tuple(self._iter_element(p) for p in pos)
        if name_matches(_SCAN_NAMES, raw, resolved):
            return self._eval_scan(node, pos)
        if last in ("tree_map", "map") and raw and (
                "tree" in raw or "tree_util" in raw):
            return self._eval_tree_map(node, pos_exprs, pos)

        # ---- guards (before reduction/div checks use the result) ----
        if last in _GUARD_CALLS and pos:
            operand = collapse(pos[0])
            floor_pos = any(self._positive_literal(e)
                            for e in pos_exprs[1:]) or any(
                self._positive_literal(kw.value) for kw in node.keywords)
            if floor_pos:
                return replace(operand, maskable=False, guarded=True,
                               is_mask=False)
            return operand

        # ---- where / select: the sanctioned masking idiom ------------
        if last in ("where", "select") and len(pos) == 3:
            cond = collapse(pos[0])
            a_val = collapse(pos[1])
            b_zero = self._zero_expr(pos_exprs[2])
            if b_zero and (cond.is_mask or a_val.prov == PADDED):
                return replace(a_val, prov=ZEROED, is_mask=False,
                               why="validity-masked")
            return _join_flat(a_val, collapse(pos[2]))

        # ---- dtype casts --------------------------------------------
        cast = self._eval_cast(node, raw, last, pos, pos_exprs)
        if cast is not None:
            return cast

        # ---- reductions ---------------------------------------------
        if last in _REDUCTIONS:
            return self._eval_reduction(node, raw, last, pos, kwargs)
        if last in ("any", "all", "isfinite", "isnan", "isinf",
                    "logical_and", "logical_or", "logical_not") and pos:
            operand = collapse(pos[0])
            return Shape(dtype="bool", is_mask=operand.prov > NONE,
                         rank=None)

        # ---- nonfinite producers (SL006) ----------------------------
        if last in _NONFINITE_OPS and pos:
            operand = collapse(pos[0])
            if self.record and operand.maskable and not operand.guarded:
                self._emit("SL006", node,
                           f"{last}() of a maskable quantity "
                           f"({operand.why or 'can be zero'}) without a "
                           "dominating positive guard — produces "
                           "inf/nan when every slot is masked out "
                           "(guard with jnp.maximum(x, eps))")
            return replace(operand, is_mask=False)

        # ---- policy: padding producers ------------------------------
        if name_matches(pol.pad_count_producers, raw, resolved):
            return Shape(rank=0, dtype="i32", pad_count=True,
                         why=f"bucket capacity from {last}()")
        if name_matches(pol.plan_producers, raw, resolved):
            self._seed_targets(raw, pos, kwargs, wrapper)
            return Shape(why=f"fused plan from {last}()")
        if name_matches(pol.padded_producers, raw, resolved):
            self._seed_targets(raw, pos, kwargs, wrapper)
            return _map_shape(
                self._call_summary(raw) or BOTTOM,
                lambda s: replace(s, prov=PADDED,
                                  why=f"padded by {last}()"))

        # ---- policy: sanctioned slot reducers -----------------------
        if name_matches(pol.slot_reducers, raw, resolved):
            self._seed_targets(raw, pos, kwargs, wrapper)
            out = self._call_summary(raw)
            return _strip_slots(out) if out is not None else BOTTOM

        # ---- creation calls -----------------------------------------
        if last in _CREATION_CALLS:
            return self._eval_creation(node, last, pos, pos_exprs)

        # ---- SL006 zero-risk named denominators fall through to the
        # division check in _eval_binop; nothing to do here ------------

        # ---- interprocedural ----------------------------------------
        targets = self.a.resolve_call(self.mod, self.fn, raw)
        fval: Optional[Value] = None
        if not targets:
            if raw is not None and "." not in raw:
                fval = self.env.get(raw)
            elif raw is None:
                fval = self.eval(node.func)
            if fval is not None:
                targets = [self.a.graph.functions[k]
                           for k in collapse(fval).fnref
                           if k in self.a.graph.functions]
        if targets:
            out: Optional[Value] = None
            for tgt in targets:
                self._propagate_args(tgt, raw, pos, kwargs, wrapper)
                s = self.a.summaries.get(tgt.key, BOTTOM)
                out = s if out is None else join(out, s)
            if out is None:
                out = BOTTOM
            if wrapper in ("vmap", "pmap"):
                arg_prov = max([collapse(p).prov for p in pos] +
                               [collapse(v) .prov
                                for v in kwargs.values()] + [NONE])
                if arg_prov > NONE:
                    out = _map_shape(out, lambda s: replace(
                        s, prov=max(s.prov, arg_prov),
                        why=s.why or "vmapped over padded slots"))
            return out

        # unknown constructor-like call: opaque object
        if raw and raw.rsplit(".", 1)[-1][:1].isupper():
            return BOTTOM

        # unresolved method calls keep their receiver's facts
        recv: Value = BOTTOM
        if isinstance(node.func, ast.Attribute):
            if raw is None:
                recv = fval if fval is not None else BOTTOM
            else:
                base = raw.rsplit(".", 1)[0]
                if "." not in base and base in self.env:
                    recv = self.env[base]

        out = _join_flat(flat_join(), collapse(recv))
        return replace(out, fnref=()) if out.fnref else out

    # -- call helpers --------------------------------------------------

    def _call_summary(self, raw: Optional[str]) -> Optional[Value]:
        targets = self.a.resolve_call(self.mod, self.fn, raw)
        if not targets:
            return None
        out: Optional[Value] = None
        for tgt in targets:
            s = self.a.summaries.get(tgt.key, BOTTOM)
            out = s if out is None else join(out, s)
        return out

    def _seed_targets(self, raw: Optional[str], pos: List[Value],
                      kwargs: Dict[Optional[str], Value],
                      wrapper: Optional[str]) -> None:
        for tgt in self.a.resolve_call(self.mod, self.fn, raw):
            self._propagate_args(tgt, raw, pos, kwargs, wrapper)

    def _propagate_args(self, tgt: astgraph.FunctionInfo,
                        raw: Optional[str], pos: List[Value],
                        kwargs: Dict[Optional[str], Value],
                        wrapper: Optional[str] = None) -> None:
        if wrapper in ("vmap", "pmap"):
            # the body sees one slot at a time: strip the slot axis
            pos = [_per_slot(p) for p in pos]
            kwargs = {k: _per_slot(v) for k, v in kwargs.items()}
        params = list(tgt.params)
        if params and params[0] in ("self", "cls") and raw and \
                "." in raw:
            params = params[1:]
        for pname, val in zip(params, pos):
            self.a.seed_param(tgt.key, pname, val)
        star = collapse(tuple(pos)) if len(pos) > len(params) else None
        for k, val in kwargs.items():
            if k is None:
                for pname in params:
                    self.a.seed_param(tgt.key, pname, collapse(val))
            elif k in params:
                self.a.seed_param(tgt.key, k, val)
        if star is not None and star != BOTTOM:
            for pname in params:
                self.a.seed_param(tgt.key, pname, star)

    def _eval_scan(self, node: ast.Call, pos: List[Value]) -> Value:
        # scans here run over *rounds* (S); slot padding lives on the B
        # axis inside each per-step xs slice, so xs seeds keep their
        # provenance (unlike vmap, which maps over the slot axis)
        body_name = astgraph.dotted_name(node.args[0]) if node.args \
            else None
        init = pos[1] if len(pos) > 1 else BOTTOM
        xs = pos[2] if len(pos) > 2 else BOTTOM
        targets = self.a.resolve_call(self.mod, self.fn, body_name)
        if not targets and body_name and "." not in body_name:
            fval = self.env.get(body_name)
            if fval is not None:
                targets = [self.a.graph.functions[k]
                           for k in collapse(fval).fnref
                           if k in self.a.graph.functions]
        summary: Value = BOTTOM
        for tgt in targets:
            params = [p for p in tgt.params if p not in ("self",)]
            if params:
                self.a.seed_param(tgt.key, params[0], init)
            if len(params) > 1:
                self.a.seed_param(tgt.key, params[1], xs)
            summary = join(summary, self.a.summaries.get(tgt.key, BOTTOM))
        if isinstance(summary, tuple) and len(summary) == 2:
            return (join(summary[0], init), summary[1])
        return join(summary, init)

    def _eval_tree_map(self, node: ast.Call,
                       pos_exprs: List[ast.expr],
                       pos: List[Value]) -> Value:
        if not pos_exprs:
            return BOTTOM
        fn_expr, tree_vals = pos_exprs[0], pos[1:]
        arg = BOTTOM
        for v in tree_vals:
            arg = _join_flat(arg, collapse(v))
        # inline lambdas: evaluate the body with params bound to leaves
        if isinstance(fn_expr, ast.Lambda):
            saved = dict(self.env)
            for p in fn_expr.args.args:
                self.env[p.arg] = arg
            out = self.eval(fn_expr.body)
            self.env = saved
            return out
        fname = astgraph.dotted_name(fn_expr)
        targets = self.a.resolve_call(self.mod, self.fn, fname)
        if targets:
            out: Value = BOTTOM
            for tgt in targets:
                params = [p for p in tgt.params if p != "self"]
                for pname, v in zip(params, pos[1:]):
                    self.a.seed_param(tgt.key, pname, v)
                out = join(out, self.a.summaries.get(tgt.key, BOTTOM))
            return out
        return arg

    # -- dtype machinery -----------------------------------------------

    @staticmethod
    def _dtype_of_expr(node: ast.expr) -> str:
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            return _DTYPE_SHORT.get(node.value, "")
        name = astgraph.dotted_name(node)
        if not name:
            return ""
        last = name.rsplit(".", 1)[-1]
        if last == "float":
            return "f64"        # numpy: astype(float) is float64
        if last == "int":
            return "i64"
        return _DTYPE_SHORT.get(last, "")

    def _eval_cast(self, node: ast.Call, raw: Optional[str], last: str,
                   pos: List[Value], pos_exprs: List[ast.expr]
                   ) -> Optional[Value]:
        # x.astype(dt) / (expr).astype(dt) / jnp.astype(x, dt)
        if last == "astype":
            recv: Value = BOTTOM
            if isinstance(node.func, ast.Attribute):
                recv = self.eval(node.func.value)
            if values_equal(recv, BOTTOM) and pos:
                recv = pos[0]
            dt_expr = pos_exprs[-1] if pos_exprs else None
            dt = self._dtype_of_expr(dt_expr) if dt_expr is not None \
                else ""
            out = _map_shape(recv, lambda s: replace(
                s, dtype=dt or s.dtype, weak=False))
            self._maybe_sl003(node, dt, "astype")
            return out
        # dtype-constructor casts: np.float64(x), jnp.float32(x)
        dt = _DTYPE_SHORT.get(last, "")
        if dt and pos:
            self._maybe_sl003(node, dt, last)
            return _map_shape(pos[0], lambda s: replace(
                s, dtype=dt, weak=False))
        return None

    def _maybe_sl003(self, node: ast.AST, dt: str, what: str) -> None:
        if self.record and dt == "f64" and self.fn.in_trace:
            self._emit("SL003", node,
                       f"{what} creates a float64 value inside "
                       "jit-reachable code — under JAX's default x64 "
                       "setting this silently truncates (or, with x64 "
                       "enabled, doubles memory/retraces); pin an "
                       "explicit f32 dtype")

    def _check_promotion(self, node: ast.BinOp, lv: Shape,
                         rv: Shape) -> None:
        if not self.record or not self.fn.in_trace:
            return
        pair = {lv.dtype, rv.dtype}
        if pair == {"f32", "f64"} and not (lv.weak or rv.weak):
            self._emit("SL003", node,
                       "f32 × f64 arithmetic inside jit-reachable code "
                       "— silent promotion/truncation drift; cast one "
                       "operand explicitly")

    def _check_bool_arith(self, node: ast.BinOp, lv: Shape,
                          rv: Shape) -> None:
        if not self.record:
            return
        for side in (lv, rv):
            if side.dtype == "bool" and not side.weak:
                self._emit("SL004", node,
                           f"boolean {'mask ' if side.is_mask else ''}"
                           "value used arithmetically without an "
                           "explicit cast — integer promotion is "
                           "implicit and dtype-dependent; use "
                           ".astype(...) first")
                return

    def _check_padded_broadcast(self, node: ast.BinOp, lv: Shape,
                                rv: Shape) -> None:
        if not self.record:
            return
        for padded, other in ((lv, rv), (rv, lv)):
            if padded.prov == PADDED and other.prov == NONE and \
                    not other.is_mask and \
                    padded.rank is not None and other.rank is not None \
                    and other.rank not in (0, padded.rank):
                self._emit("SL005", node,
                           f"rank-{padded.rank} padded array "
                           f"({padded.why or 'dead slots'}) broadcasts "
                           f"against a rank-{other.rank} clean array — "
                           "padding provenance silently widens to the "
                           "broadcast result; mask before broadcasting")
                return

    def _check_division(self, node: ast.BinOp, num: Shape,
                        den: Shape) -> None:
        if not self.record:
            return
        if den.pad_count and (num.masked_sum or num.prov > NONE):
            self._emit("SL002", node,
                       "division by a slot count that includes padded "
                       f"slots ({den.why or 'bucket capacity'}) — the "
                       "denominator must be the number of *valid* "
                       "slots (Σmask), not the bucket size")
            return
        if den.maskable and not den.guarded:
            self._emit("SL006", node,
                       f"division by a maskable quantity "
                       f"({den.why or 'Σmask can be 0'}) without a "
                       "dominating positive guard — all-masked inputs "
                       "produce inf/nan (guard with jnp.maximum(x, 1))")
            return
        if isinstance(node.right, ast.Name) and \
                node.right.id in self.pol.zero_risk_denoms and \
                not den.guarded:
            self._emit("SL006", node,
                       f"division by '{node.right.id}' which can be "
                       "zero by construction — guard with "
                       "max(·, 1) before dividing")

    # -- reductions ----------------------------------------------------

    def _eval_reduction(self, node: ast.Call, raw: Optional[str],
                        last: str, pos: List[Value],
                        kwargs: Dict[Optional[str], Value]) -> Value:
        operand = BOTTOM
        if raw and "." in raw:
            base = raw.rsplit(".", 1)[0]
            if "." not in base and base in self.env:
                operand = collapse(self.env[base])   # x.sum()
        elif raw is None and isinstance(node.func, ast.Attribute):
            operand = collapse(self.eval(node.func.value))
        if values_equal(operand, BOTTOM) and pos:
            operand = collapse(pos[0])
        has_axis = any(kw.arg in ("axis", "dims") and not (
            isinstance(kw.value, ast.Constant) and kw.value.value is None)
            for kw in node.keywords)

        if self.record:
            if operand.dtype == "bool" and not operand.weak and \
                    last in (_SUM_FAMILY | _MEAN_FAMILY):
                self._emit("SL004", node,
                           f"{last}() over a boolean "
                           f"{'mask' if operand.is_mask else 'array'} "
                           "without an explicit cast — the result "
                           "dtype is an implicit integer promotion; "
                           "cast with .astype(jnp.int32) first")
            if operand.prov == PADDED and not has_axis:
                why = operand.why or "garbage filler values"
                self._emit("SL001", node,
                           f"{last}() reduces over an axis carrying "
                           f"padded slots ({why}) with no dominating "
                           "validity mask — mask with "
                           "jnp.where(valid, x, 0) or slice to the "
                           "live prefix first")
            elif operand.prov == ZEROED and not has_axis and \
                    last in _MEAN_FAMILY:
                self._emit("SL002", node,
                           f"{last}() over a zero-filled (masked) axis "
                           "counts the dead slots in its denominator — "
                           "use a masked sum divided by Σvalid instead")

        dtype = operand.dtype
        if operand.dtype == "bool":
            dtype = "i32" if last in _SUM_FAMILY else "f32"
        elif last in _MEAN_FAMILY and dtype.startswith(("i", "u")):
            dtype = "f32"
        return Shape(
            rank=0 if not has_axis else (
                None if operand.rank is None
                else max(operand.rank - 1, 0)),
            dtype=dtype,
            masked_sum=(last in _SUM_FAMILY and
                        operand.prov == ZEROED and not has_axis),
            maskable=operand.is_mask and last in _SUM_FAMILY,
            why=("Σmask" if operand.is_mask and last in _SUM_FAMILY
                 else ""))

    # -- creation ------------------------------------------------------

    def _eval_creation(self, node: ast.Call, last: str,
                       pos: List[Value], pos_exprs: List[ast.expr]
                       ) -> Value:
        dt = ""
        for kw in node.keywords:
            if kw.arg == "dtype":
                dt = self._dtype_of_expr(kw.value)
        if last in ("array", "asarray") and len(pos_exprs) > 1:
            dt = dt or self._dtype_of_expr(pos_exprs[1])
        if dt == "f64":
            self._maybe_sl003(node, dt, f"{last}(dtype=float64)")

        rank: Optional[int] = None
        dims: Tuple[str, ...] = ()
        if last in ("zeros", "ones", "full", "empty") and pos_exprs:
            shp = pos_exprs[0]
            if isinstance(shp, (ast.Tuple, ast.List)):
                rank = len(shp.elts)
                dims = tuple(
                    (e.id if isinstance(e, ast.Name) else
                     str(e.value) if isinstance(e, ast.Constant) else "?")
                    for e in shp.elts)
            elif isinstance(shp, (ast.Constant, ast.Name)):
                rank = 1
        elif last in ("arange", "linspace"):
            rank = 1
        elif last == "eye":
            rank = 2
        elif last.endswith("_like") and pos:
            src = collapse(pos[0])
            rank, dims = src.rank, src.dims

        pad = False
        if last == "arange" and pos and collapse(pos[0]).pad_count:
            # jnp.arange(bucket_size): indexes every slot incl. dead ones
            pad = True
        return Shape(rank=rank, dims=dims, dtype=dt or "f32",
                     pad_count=pad,
                     why="slot index range" if pad else "")

    # -- misc ----------------------------------------------------------

    @staticmethod
    def _positive_literal(node: ast.expr) -> bool:
        if isinstance(node, ast.Constant) and \
                isinstance(node.value, (int, float)) and \
                not isinstance(node.value, bool):
            return node.value > 0
        return False

    def _zero_expr(self, node: ast.expr) -> bool:
        if isinstance(node, ast.Constant) and \
                isinstance(node.value, (int, float)):
            return node.value == 0
        if isinstance(node, ast.Call):
            name = astgraph.dotted_name(node.func) or ""
            return name.rsplit(".", 1)[-1] in ("zeros", "zeros_like")
        if isinstance(node, ast.Name):
            v = collapse(self.env.get(node.id, BOTTOM))
            return v.why == "zeros"
        return False

    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        if self.record:
            self.a.emit(rule, self.mod, node, message, self.fn)
