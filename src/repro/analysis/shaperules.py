"""shapelint policy + rule catalogue (SL001–SL006).

The shape engine (``repro.analysis.shapes``) is generic; this module
pins it to *this* repo's padding architecture: which calls mint arrays
with dead slots, which objects carry the padding facts on attributes,
and which consumers are the sanctioned slot-axis reducers.

The repo has exactly four dead-slot sources, all checked here:

* bucketed-P cohort padding (PR 3): ``cohort.bucket_size`` picks the
  bucket capacity ``B ≥ p_count``; ``fed/engine._pad_slots`` /
  ``_pad_key_slots`` / ``pad_rows`` repeat-fill the tail slots.
* fused ``(S, B)`` horizon plans (PR 4): ``prepare_fused_plan`` /
  ``horizon_slot_plan`` bake per-round participant tables whose
  ``part_idx`` legs are padded, ``weights`` legs are exact zeros at
  dead slots, and ``valid`` legs are the validity masks.
* keep-masks (PR 5): mask-mode pruning ships full-geometry arrays with
  dead channels, consumed through the same masked reductions.
* fault-admit masks (PR 9): the server admission gate intersects
  ``valid`` with a per-round ``admit`` mask.

Rule catalogue
--------------
SL001  reduction (``sum/mean/max/…``) over an axis carrying padded
       slots with no dominating validity mask — garbage filler values
       enter the aggregate.
SL002  mean/division whose denominator counts padded slots — the
       "mean over B instead of Σvalid" bug: a correctly-masked sum
       divided by the bucket capacity instead of the valid count.
SL003  silent dtype promotion / float64 drift inside jit-reachable
       code — ``np.float64``, ``astype(float)``, ``dtype=float64``
       creation, or f32×f64 arithmetic.  Host-side accounting is
       exempt (``in_trace`` only).
SL004  boolean mask used arithmetically without an explicit cast —
       ``jnp.sum(valid)`` relies on implicit bool→int promotion.
SL005  rank-changing broadcast between a padded and an unpadded
       array — padding provenance silently widens to the result.
SL006  nonfinite-producing op (``log/sqrt/÷``) on a maskable quantity
       without a dominating positive guard — the all-slots-masked
       round produces inf/nan.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Set

from repro.analysis import astgraph, shapes
from repro.analysis.report import Finding

SHAPE_RULES = {
    "SL001": "reduction over a padded axis without a validity mask",
    "SL002": "mean/division whose denominator counts padded slots",
    "SL003": "silent dtype promotion / float64 drift in jit-reachable code",
    "SL004": "boolean mask used arithmetically without an explicit cast",
    "SL005": "rank-changing broadcast between padded and unpadded arrays",
    "SL006": "nonfinite-producing op on a maskable quantity without a guard",
}

POLICY = shapes.ShapePolicy(
    # -- dead-slot producers -------------------------------------------
    # repeat-fill padders: the tail slots hold copies/garbage
    padded_producers=("_pad_slots", "_pad_key_slots", "pad_clients",
                      "pad_rows"),
    # opaque plan builders whose attributes carry the facts below
    plan_producers=("horizon_slot_plan", "plan_horizon",
                    "prepare_fused_plan"),
    # scalar bucket capacities: count all slots incl. dead ones
    pad_count_producers=("cohort.bucket_size", "bucket_size"),
    # -- plan attribute / payload-key tables ---------------------------
    padded_attrs=("part_idx",),
    zeroed_attrs=("weights",),
    mask_attrs=("valid", "admit"),
    # parameter names that are validity masks even when no caller is
    # visible to the fixpoint (entry points, vmapped bodies)
    mask_params=("valid", "admit", "admit_mask", "keep_mask"),
    # slice bounds that restore the live prefix: `losses[:p_count]`
    count_names=("p_count", "n_valid"),
    # -- sanctioned slot-axis consumers --------------------------------
    # these functions own the masked-reduction idiom; their *results*
    # are provenance-free (their bodies are still analyzed)
    slot_reducers=("scbf_sum_step", "fedavg_step", "fedbuff_step",
                   "reduce_slots", "masked_quantile", "_emit_payloads",
                   "emit_fused_payloads"),
    # -- denominators that are zero by construction (SL006) ------------
    zero_risk_denoms=("decay_steps",),
)


def run_shape_rules(graph: astgraph.CallGraph,
                    rules: Optional[Sequence[str]] = None,
                    ) -> List[Finding]:
    """Run the shape fixpoint + SL rule checks over ``graph``."""
    selected: Optional[Set[str]] = None
    if rules is not None:
        selected = set(rules)
        unknown = selected - set(SHAPE_RULES)
        if unknown:
            raise ValueError(
                f"unknown shape rule(s): {', '.join(sorted(unknown))} "
                f"(known: {', '.join(sorted(SHAPE_RULES))})")
    analysis = shapes.ShapeAnalysis(graph, POLICY, rules=selected)
    return analysis.run()
