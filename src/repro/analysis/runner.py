"""Merged analysis runner — all three linters, one report, one exit code.

    PYTHONPATH=src python -m repro.analysis                # all linters
    PYTHONPATH=src python -m repro.analysis --trace        # tracelint only
    PYTHONPATH=src python -m repro.analysis --privacy      # privlint only
    PYTHONPATH=src python -m repro.analysis --shape        # shapelint only
    PYTHONPATH=src python -m repro.analysis --privacy --json-out  # stdout
    PYTHONPATH=src python -m repro.analysis --json-out report.json

Each tool keeps its own committed baseline (tracelint →
``analysis/baseline.json``, privlint →
``analysis/privacy_baseline.json``, shapelint →
``analysis/shape_baseline.json``) and its own suppression comment
prefix; the runner merges their reports and exits 1 when ANY tool
has new findings — this is the single entry point the CI lint job
calls.  Pure ``ast`` end to end: no JAX, no imports of scanned code.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional, Sequence

from repro.analysis import privlint, shapelint, tracelint
from repro.analysis.config import (DEFAULT_BASELINE, DEFAULT_PATHS,
                                   DEFAULT_PRIVACY_BASELINE,
                                   DEFAULT_SHAPE_BASELINE)
from repro.analysis.report import (Baseline, json_report, render_report)

_TOOLS = {
    "tracelint": (tracelint.run_paths, DEFAULT_BASELINE),
    "privlint": (privlint.run_paths, DEFAULT_PRIVACY_BASELINE),
    "shapelint": (shapelint.run_paths, DEFAULT_SHAPE_BASELINE),
}


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis",
        description="run the repo's static analyses (tracelint + "
                    "privlint + shapelint) with one merged report")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help=f"files/directories to lint "
                         f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--trace", action="store_true",
                    help="run tracelint (TL rules) only")
    ap.add_argument("--privacy", action="store_true",
                    help="run privlint (PL rules) only")
    ap.add_argument("--shape", action="store_true",
                    help="run shapelint (SL rules) only")
    ap.add_argument("--trace-baseline", default=DEFAULT_BASELINE,
                    help=f"tracelint baseline "
                         f"(default: {DEFAULT_BASELINE}; '' for none)")
    ap.add_argument("--privacy-baseline",
                    default=DEFAULT_PRIVACY_BASELINE,
                    help=f"privlint baseline (default: "
                         f"{DEFAULT_PRIVACY_BASELINE}; '' for none)")
    ap.add_argument("--shape-baseline",
                    default=DEFAULT_SHAPE_BASELINE,
                    help=f"shapelint baseline (default: "
                         f"{DEFAULT_SHAPE_BASELINE}; '' for none)")
    ap.add_argument("--json-out", nargs="?", const="-", default=None,
                    metavar="FILE",
                    help="write the merged machine-readable report to "
                         "FILE ('-' or no value: stdout)")
    args = ap.parse_args(argv)

    selected = [name for name, flag in
                (("tracelint", args.trace), ("privlint", args.privacy),
                 ("shapelint", args.shape))
                if flag] or list(_TOOLS)
    baselines = {"tracelint": args.trace_baseline or None,
                 "privlint": args.privacy_baseline or None,
                 "shapelint": args.shape_baseline or None}

    merged = {"version": 1, "tools": {}}
    reports: List[str] = []
    exit_code = 0
    for name in selected:
        run, _default = _TOOLS[name]
        try:
            baseline = Baseline.load(baselines[name])
        except (ValueError, json.JSONDecodeError) as e:
            print(f"{name}: bad baseline: {e}", file=sys.stderr)
            return 2
        try:
            findings, files_scanned = run(args.paths)
        except ValueError as e:
            print(f"{name}: {e}", file=sys.stderr)
            return 2
        new, accepted, stale = baseline.split(findings)
        merged["tools"][name] = json_report(new, accepted, stale,
                                            files_scanned)
        reports.append(render_report(new, accepted, stale,
                                     baselines[name], files_scanned,
                                     tool=name))
        if new:
            exit_code = 1

    if args.json_out is not None:
        if args.json_out == "-":
            json.dump(merged, sys.stdout, indent=1)
            sys.stdout.write("\n")
        else:
            with open(args.json_out, "w", encoding="utf-8") as f:
                json.dump(merged, f, indent=1)
                f.write("\n")

    print("\n".join(reports))
    return exit_code


if __name__ == "__main__":
    sys.exit(main())
