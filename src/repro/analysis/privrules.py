"""privlint policy + rule catalogue (PL001–PL006).

The taint engine (``repro.analysis.taint``) is generic; this module
pins it to *this* repo's privacy architecture: which functions mint
sensitive values, which calls are the sanctioned sanitizer chain

    channels/selection (→ SELECTED)
      → privacy.gaussian_mechanism + RDP accounting (→ DP-NOISED)
      → comm.wire.encode (→ WIRE)
      → strategy.*_step (server),

and which calls are sinks past the privacy boundary.  Patterns are
dotted-name *suffixes* matched on whole components, so
``"wire.encode"`` matches ``repro.comm.wire.encode`` from any import
alias but never ``Transformer.encode``.

Rule catalogue
--------------
PL001  un-sanitized value reaches ``wire.encode`` — a LOCAL/RAW value
       (dense delta, raw batch) would ship to the server un-noised.
PL002  noise ordering violation — ``gaussian_mechanism`` applied to an
       already-encoded or already-revealed value; the un-noised
       coordinates have left the boundary, noising after the fact is
       theatre.
PL003  PRNG key hygiene on the noise path — a loop-invariant key, a
       key consumed twice without a re-split, or one key element
       replicated across slots.  Correlated noise across clients or
       rounds voids the accountant's independence assumption.
PL004  accounting skew — a DP-noised payload is emitted with no
       accountant update anywhere on its caller chain (ε/δ spend
       untracked), or one function updates the release ledger twice
       for one emission (budget double-counted).
PL005  reveal/keep mask widened after noising — the Gaussian noise was
       calibrated to the pre-widening reveal set, so the extra
       coordinates ship with zero noise budget (includes the
       mask-mode compacted-geometry path).
PL006  telemetry/checkpoint sink (``obs.trace.event``, device metrics
       collection, ``LoopRecord``, ``ckpt.save_checkpoint``) receives
       a pre-DP per-client value — events.jsonl and checkpoints are
       outside the privacy boundary.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Set, Tuple

from repro.analysis import astgraph, taint
from repro.analysis.report import Finding

PRIV_RULES = {
    "PL001": "tainted value reaches the wire without the sanitizer chain",
    "PL002": "DP noise applied after encoding / to revealed coordinates",
    "PL003": "PRNG key reused across clients/rounds on the noise path",
    "PL004": "ε/δ accounting skipped or double-counted for a payload",
    "PL005": "reveal/keep mask widened after noising",
    "PL006": "telemetry/checkpoint sink receives pre-DP per-client values",
}

POLICY = taint.Policy(
    # -- sources -------------------------------------------------------
    # raw client examples/labels: the federated splitters and the
    # cohort attribute names they populate
    raw_sources=("dirichlet_split", "federated_split"),
    raw_attrs=("x_train", "y_train", "x_val", "y_val",
               "x_test", "y_test"),
    # per-client training artefacts: params, losses, dense deltas
    local_sources=("local_train", "local_train_impl",
                   "masked_local_train_impl", "client_delta"),
    # -- the sanctioned sanitizer chain --------------------------------
    selectors=("select_gradients", "apply_channel_mask"),
    noisers=("gaussian_mechanism",),
    encoders=("wire.encode", "wire.encode_leaf"),
    decoders=("wire.decode",),
    # cohort-level reductions: only aggregates cross to host telemetry
    aggregators=("metrics.offload", "metrics.reduce_slots",
                 "_host_round_metrics", "_host_fedavg_metrics",
                 "pruner.step", "pruner.compact"),
    # scalar eval metrics computed on the server's own eval pass
    metric_fns=("auc_roc", "auc_pr"),
    # -- accounting (PL004) --------------------------------------------
    accountant_calls=("epsilon_for", "amplified_epsilon_for",
                      "rdp_to_dp"),
    ledger_name_fragment="releases",
    # -- sinks past the privacy boundary (PL006) -----------------------
    telemetry_sinks=("trace.event", "trace.count", "slot_metrics",
                     "FedAvgMetrics", "LoopRecord", "save_checkpoint"),
    # -- key hygiene (PL003) -------------------------------------------
    key_makers=("PRNGKey", "random.split", "random.fold_in",
                "random.key"),
    key_replicators=("broadcast_to", "tile", "repeat"),
    # -- post-noise mask widening (PL005) ------------------------------
    wideners=("logical_or", "maximum", "bitwise_or", "concatenate",
              "append"),
    # shape-only constructors never carry data
    clean_calls=("zeros", "ones", "zeros_like", "ones_like", "arange",
                 "eye", "full", "full_like", "empty", "linspace"),
)


def run_privacy_rules(graph: astgraph.CallGraph,
                      rules: Optional[Sequence[str]] = None,
                      ) -> List[Finding]:
    """Run the taint fixpoint + PL rule checks over ``graph``."""
    selected: Optional[Set[str]] = None
    if rules is not None:
        selected = set(rules)
        unknown = selected - set(PRIV_RULES)
        if unknown:
            raise ValueError(
                f"unknown privacy rule(s): {', '.join(sorted(unknown))} "
                f"(known: {', '.join(sorted(PRIV_RULES))})")
    analysis = taint.TaintAnalysis(graph, POLICY, rules=selected)
    return analysis.run()
