"""Interprocedural taint-flow analysis for the client→server boundary.

The paper's privacy contract is a *dataflow* property: a client's raw
inputs may leave the device only after channel selection (→ SELECTED
coordinates), Gaussian noising under the RDP accountant (→ DP-NOISED),
and wire encoding.  This module implements the abstract interpretation
that checks it, on top of ``astgraph``'s pure-``ast`` call graph —
nothing is imported or executed, so it runs without JAX.

Abstract domain
---------------
Every value carries a :class:`Taint`:

* ``tier`` — sensitivity lattice ``RAW(3) > LOCAL(2) > SELECTED(1) >
  PUBLIC(0)``.  RAW is client examples/labels; LOCAL is anything
  derived from per-client training (params, grads, dense deltas,
  per-client losses); SELECTED is the channel-masked coordinate set the
  protocol is allowed to reveal; PUBLIC is config, shapes, and
  cohort-level aggregates.
* ``noised`` — the Gaussian mechanism has been applied (a *must* flag:
  joining a noised path with an un-noised one clears it).
* ``encoded`` / ``revealed`` — the value is (derived from) a wire
  payload / already-revealed coordinates (*may* flags).
* ``keyish`` — PRNG key material (feeds the key-hygiene rule).

Function summaries are structural: a function returning
``(masked, masks, metrics)`` keeps three per-element taints, so a LOCAL
metrics leg does not poison the SELECTED payload legs travelling in the
same tuple.  Unequal-arity joins (``collect=True`` returning an extra
element) align prefixes.

Interprocedural propagation is a context-insensitive forward fixpoint:
call-site argument taints join into callee parameter taints, callee
summaries flow back to call sites.  Calls through ``jax.vmap`` /
``jax.jit`` / ``functools.partial`` wrappers and ``lax.scan`` bodies are
unwrapped; attribute calls (``eng.scbf_round(...)``) resolve through a
repo-wide *method-name index* when the receiver class is unknown.
Closures see their enclosing function's environment.  Object attribute
state (``self.x = ...``) is deliberately not tracked, and class
constructors are opaque (PUBLIC) — sources therefore have to be
declared on the *functions* that produce sensitive values, which is
what :class:`Policy` does.

The rule checks themselves (PL001–PL006) run in a recording pass after
the fixpoint converges; ``repro.analysis.privrules`` declares the
policy tables and rule catalogue.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

from repro.analysis import astgraph
from repro.analysis.report import Finding

# --- sensitivity tiers -------------------------------------------------

PUBLIC, SELECTED, LOCAL, RAW = 0, 1, 2, 3
TIER_NAMES = {PUBLIC: "PUBLIC", SELECTED: "SELECTED",
              LOCAL: "LOCAL", RAW: "RAW"}

MAX_FIXPOINT_ITERS = 24
_MAX_METHOD_TARGETS = 8

# attribute reads that are structural (shape-like), never data
STRUCTURAL_ATTRS = {"shape", "ndim", "dtype", "size", "nbytes",
                    "itemsize", "sharding", "device", "devices", "name"}

# builtins that reveal structure/cardinality, not content — `len(keys)`
# must not make a slot count keyish
_STRUCTURAL_CALLS = {"len", "range", "isinstance", "issubclass",
                     "hasattr", "callable", "type", "id"}

# container mutators: obj.append(x) joins x into obj
_MUTATORS = {"append", "extend", "add", "insert", "update", "setdefault"}

# tracing wrappers whose first argument is the real callee
_CALL_WRAPPERS = ("jax.vmap", "vmap", "jax.jit", "jit", "jax.pmap",
                  "pmap", "functools.partial", "partial", "jax.remat",
                  "jax.checkpoint", "jax.grad", "grad",
                  "jax.value_and_grad", "value_and_grad")

_SCAN_NAMES = ("jax.lax.scan", "lax.scan")


@dataclass(frozen=True)
class Taint:
    tier: int = PUBLIC
    noised: bool = False
    encoded: bool = False
    revealed: bool = False
    keyish: bool = False
    why: str = ""
    # function keys this value may reference — how calls through
    # variables (`step = jax.jit(f); step(x)`) and jit-program tables
    # stay resolvable
    fnref: Tuple[str, ...] = ()


BOTTOM = Taint()

# an abstract value: a single Taint or a tuple of abstract values
Value = Union[Taint, tuple]


def _may(a: Taint, b: Taint, attr: str) -> bool:
    return bool(getattr(a, attr) or getattr(b, attr))


def _must(a: Taint, b: Taint, attr: str) -> bool:
    # PUBLIC operands are neutral: mixing a noised delta with a shape
    # scalar must not clear the noised flag.
    if a.tier == PUBLIC:
        return bool(getattr(b, attr))
    if b.tier == PUBLIC:
        return bool(getattr(a, attr))
    return bool(getattr(a, attr) and getattr(b, attr))


def _join_flat(a: Taint, b: Taint) -> Taint:
    hi = a if a.tier >= b.tier else b
    fnref = a.fnref if not b.fnref else (
        b.fnref if not a.fnref else
        tuple(sorted(set(a.fnref) | set(b.fnref))))
    return Taint(tier=max(a.tier, b.tier),
                 noised=_must(a, b, "noised"),
                 encoded=_may(a, b, "encoded"),
                 revealed=_may(a, b, "revealed"),
                 keyish=_may(a, b, "keyish"),
                 why=hi.why or a.why or b.why,
                 fnref=fnref)


def collapse(v: Value) -> Taint:
    """Fold a structured value to one flat Taint."""
    if isinstance(v, Taint):
        return v
    out = BOTTOM
    for el in v:
        out = _join_flat(out, collapse(el))
    return out


def join(a: Value, b: Value) -> Value:
    """Structural join; unequal-arity tuples align by prefix.

    Prefix alignment is what keeps the ``collect=True`` convention
    precise: a function returning ``(masked, masks)`` on one path and
    ``(masked, masks, metrics)`` on the other joins element-wise on the
    shared prefix and keeps the metrics leg separate, instead of
    flattening the whole summary to one (poisoned) taint.
    """
    if isinstance(a, tuple) and isinstance(b, tuple):
        n = min(len(a), len(b))
        head = tuple(join(x, y) for x, y in zip(a[:n], b[:n]))
        tail = a[n:] if len(a) > len(b) else b[n:]
        return head + tail
    if isinstance(a, tuple) or isinstance(b, tuple):
        if isinstance(b, tuple):
            a, b = b, a
        # tuple vs scalar: join the scalar into every element
        return tuple(join(x, b) for x in a)
    return _join_flat(a, b)


def values_equal(a: Value, b: Value) -> bool:
    if isinstance(a, Taint) and isinstance(b, Taint):
        return a == b
    if isinstance(a, tuple) and isinstance(b, tuple) and len(a) == len(b):
        return all(values_equal(x, y) for x, y in zip(a, b))
    return False


def _map_taint(v: Value, fn) -> Value:
    if isinstance(v, Taint):
        return fn(v)
    return tuple(_map_taint(el, fn) for el in v)


# --- policy ------------------------------------------------------------

@dataclass
class Policy:
    """Declared sources, sanitizers, and sinks (dotted-suffix patterns).

    Every pattern matches a call's raw *or* import-resolved dotted name
    on whole-component suffixes, so ``"wire.encode"`` matches
    ``repro.comm.wire.encode`` but not ``Transformer.encode``.
    """

    # sources
    raw_sources: Tuple[str, ...] = ()       # calls yielding raw examples
    raw_attrs: Tuple[str, ...] = ()         # attribute names (x_train, ...)
    local_sources: Tuple[str, ...] = ()     # per-client training/deltas

    # sanitizer chain
    selectors: Tuple[str, ...] = ()         # channel selection → SELECTED
    noisers: Tuple[str, ...] = ()           # gaussian mechanism → noised
    encoders: Tuple[str, ...] = ()          # wire encode (also the sink)
    decoders: Tuple[str, ...] = ()
    aggregators: Tuple[str, ...] = ()       # cohort-level reductions
    metric_fns: Tuple[str, ...] = ()        # scalar eval metrics → PUBLIC

    # accounting (PL004)
    accountant_calls: Tuple[str, ...] = ()
    ledger_name_fragment: str = "releases"

    # telemetry/checkpoint sinks (PL006)
    telemetry_sinks: Tuple[str, ...] = ()

    # key hygiene (PL003)
    key_makers: Tuple[str, ...] = ()        # PRNGKey/split/fold_in
    key_replicators: Tuple[str, ...] = ()   # broadcast_to/tile/repeat

    # post-noise mask widening (PL005)
    wideners: Tuple[str, ...] = ()

    clean_calls: Tuple[str, ...] = ()       # shape-only constructors


def name_matches(patterns: Sequence[str], raw: Optional[str],
                 resolved: Optional[str]) -> bool:
    for cand in (resolved, raw):
        if not cand:
            continue
        for pat in patterns:
            if cand == pat or cand.endswith("." + pat):
                return True
    return False


# --- analysis ----------------------------------------------------------

@dataclass
class _FnFacts:
    """Syntactic per-function facts gathered before the fixpoint."""
    is_accountant: bool = False
    ledger_updates: List[ast.AugAssign] = field(default_factory=list)
    has_encode: bool = False


class TaintAnalysis:
    """Fixpoint + recording passes over one :class:`astgraph.CallGraph`."""

    def __init__(self, graph: astgraph.CallGraph, policy: Policy,
                 rules: Optional[Set[str]] = None):
        self.graph = graph
        self.policy = policy
        self.rules = rules          # None = all
        self.param_env: Dict[str, Dict[str, Value]] = {}
        self.summaries: Dict[str, Value] = {}
        self.fn_envs: Dict[str, Dict[str, Value]] = {}
        self.callers: Dict[str, Set[str]] = {}
        self.callees: Dict[str, Set[str]] = {}
        self.facts: Dict[str, _FnFacts] = {}
        self.findings: List[Finding] = []
        self._changed = False
        self._method_index: Dict[str, List[astgraph.FunctionInfo]] = {}
        self._build_indexes()

    # -- setup ---------------------------------------------------------

    def _build_indexes(self) -> None:
        pol = self.policy
        for mod in self.graph.modules.values():
            for cls, methods in mod.classes.items():
                for m in methods:
                    info = mod.functions.get(f"{cls}.{m}")
                    if info is not None:
                        self._method_index.setdefault(m, []).append(info)
        for key, fn in self.graph.functions.items():
            facts = _FnFacts()
            for node in ast.walk(fn.node):
                if isinstance(node, ast.Call):
                    raw = astgraph.dotted_name(node.func)
                    mod = self.graph.modules.get(fn.module)
                    resolved = mod.resolve(raw) if (mod and raw) else None
                    if name_matches(pol.accountant_calls, raw, resolved):
                        facts.is_accountant = True
                    if name_matches(pol.encoders, raw, resolved):
                        facts.has_encode = True
                elif isinstance(node, ast.AugAssign):
                    tgt = node.target
                    base = tgt.value if isinstance(tgt, ast.Subscript) \
                        else tgt
                    name = astgraph.dotted_name(base)
                    if name and pol.ledger_name_fragment in \
                            name.rsplit(".", 1)[-1]:
                        facts.is_accountant = True
                        facts.ledger_updates.append(node)
            self.facts[key] = facts

    # -- driver --------------------------------------------------------

    def run(self) -> List[Finding]:
        order = list(self.graph.functions.values())
        for _ in range(MAX_FIXPOINT_ITERS):
            self._changed = False
            for fn in order:
                self._analyze(fn, record=False)
            if not self._changed:
                break
        for fn in order:
            self._analyze(fn, record=True)
        self._check_double_counts(order)
        if self.rules is not None:
            self.findings = [f for f in self.findings
                             if f.rule in self.rules]
        return self.findings

    def _check_double_counts(self,
                             order: List[astgraph.FunctionInfo]) -> None:
        """PL004 (double-count): two ledger updates for the same counter
        inside one emission-path function spends the budget twice."""
        for fn in order:
            facts = self.facts.get(fn.key)
            if facts is None or len(facts.ledger_updates) < 2:
                continue
            if not self.reaches_encode(fn.key):
                continue
            by_name: Dict[str, List[ast.AugAssign]] = {}
            for node in facts.ledger_updates:
                tgt = node.target
                base = tgt.value if isinstance(tgt, ast.Subscript) else tgt
                name = astgraph.dotted_name(base) or "?"
                by_name.setdefault(name, []).append(node)
            mod = self.graph.modules[fn.module]
            for name, nodes in by_name.items():
                if len(nodes) >= 2:
                    self.emit("PL004", mod, nodes[1],
                              f"release ledger '{name}' is updated "
                              f"{len(nodes)} times on one emission path "
                              "— the (ε, δ) budget for this payload is "
                              "double-counted", fn)

    def _analyze(self, fn: astgraph.FunctionInfo, record: bool) -> None:
        mod = self.graph.modules[fn.module]
        ev = _Evaluator(self, mod, fn, record=record)
        summary = ev.run()
        old = self.summaries.get(fn.key, BOTTOM)
        new = join(old, summary)
        if not values_equal(old, new):
            self.summaries[fn.key] = new
            self._changed = True
        self.fn_envs[fn.key] = ev.env

    # -- interprocedural plumbing --------------------------------------

    def seed_param(self, fn_key: str, pname: str, val: Value) -> None:
        env = self.param_env.setdefault(fn_key, {})
        old = env.get(pname, BOTTOM)
        new = join(old, val)
        if not values_equal(old, new):
            env[pname] = new
            self._changed = True

    def add_edge(self, caller: str, callee: str) -> None:
        self.callers.setdefault(callee, set()).add(caller)
        self.callees.setdefault(caller, set()).add(callee)

    def resolve_call(self, mod: astgraph.ModuleInfo,
                     fn: astgraph.FunctionInfo, raw: Optional[str]
                     ) -> List[astgraph.FunctionInfo]:
        """All plausible targets of a call named ``raw`` inside ``fn``."""
        if not raw:
            return []
        local = astgraph._resolve_local(mod, fn, raw)
        if local is not None:
            return [local]
        resolved = mod.resolve(raw)
        hit = self.graph.by_dotted.get(resolved)
        if hit is not None:
            return [hit]
        # attribute call on an unknown receiver: match by method name
        if "." in raw:
            meth = raw.rsplit(".", 1)[-1]
            targets = self._method_index.get(meth, [])
            if 0 < len(targets) <= _MAX_METHOD_TARGETS:
                return list(targets)
        return []

    def accountant_dominates(self, fn_key: str) -> bool:
        """Is ``fn_key`` or any transitive caller an accountant?

        ANY-ancestor semantics — an under-approximation (a second,
        unaccounted caller chain would be missed), but line-precise
        dominator analysis over an ast-level graph is not worth the
        false positives.
        """
        seen: Set[str] = set()
        queue = [fn_key]
        while queue:
            k = queue.pop()
            if k in seen:
                continue
            seen.add(k)
            facts = self.facts.get(k)
            if facts is not None and facts.is_accountant:
                return True
            queue.extend(self.callers.get(k, ()))
        return False

    def reaches_encode(self, fn_key: str) -> bool:
        seen: Set[str] = set()
        queue = [fn_key]
        while queue:
            k = queue.pop()
            if k in seen:
                continue
            seen.add(k)
            facts = self.facts.get(k)
            if facts is not None and facts.has_encode:
                return True
            queue.extend(self.callees.get(k, ()))
        return False

    def emit(self, rule: str, mod: astgraph.ModuleInfo, node: ast.AST,
             message: str, fn: astgraph.FunctionInfo) -> None:
        self.findings.append(Finding(
            rule=rule, path=mod.path, line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0), message=message,
            symbol=fn.qualname))


class _Evaluator:
    """One statement-ordered abstract interpretation of one function.

    Flow handling is deliberately simple: branches execute sequentially
    (if-body then else-body over one environment), loops once — the
    surrounding fixpoint supplies the convergence.  Within a function
    the *order* of statements is preserved, which is what the
    flow-sensitive rules (noise-after-encode, widen-after-noise, key
    reuse) need.
    """

    def __init__(self, owner: TaintAnalysis, mod: astgraph.ModuleInfo,
                 fn: astgraph.FunctionInfo, record: bool):
        self.a = owner
        self.pol = owner.policy
        self.mod = mod
        self.fn = fn
        self.record = record
        self.env: Dict[str, Value] = {}
        self.returns: List[Value] = []
        # flow-sensitive state for the ordering rules
        self.noise_lines: List[int] = []        # gaussian calls seen
        self.mask_names: Set[str] = set()       # reveal/keep masks
        self.key_uses: Dict[str, int] = {}      # key name -> first line
        self.loop_stack: List[Set[str]] = []    # names assigned per loop

    # -- entry ---------------------------------------------------------

    def run(self) -> Value:
        # closures: nested defs see the enclosing function's environment
        if self.fn.parent is not None:
            parent = self.mod.functions.get(self.fn.parent)
            if parent is not None:
                self.env.update(self.a.fn_envs.get(parent.key, {}))
        seeded = self.a.param_env.get(self.fn.key, {})
        for pname in self.fn.params:        # includes keyword-only
            self.env[pname] = seeded.get(pname, BOTTOM)
        body = getattr(self.fn.node, "body", [])
        self.exec_block(body)
        if not self.returns:
            return BOTTOM
        out: Value = self.returns[0]
        for r in self.returns[1:]:
            out = join(out, r)
        return out

    # -- statements ----------------------------------------------------

    def exec_block(self, stmts: Sequence[ast.stmt]) -> None:
        for st in stmts:
            self.exec_stmt(st)

    def exec_stmt(self, st: ast.stmt) -> None:
        if isinstance(st, ast.Assign):
            v = self.eval(st.value)
            for t in st.targets:
                self.bind(t, v, st)
        elif isinstance(st, ast.AnnAssign):
            if st.value is not None:
                self.bind(st.target, self.eval(st.value), st)
        elif isinstance(st, ast.AugAssign):
            v = join(self.eval(st.target), self.eval(st.value))
            self.bind(st.target, v, st, augmented=True)
        elif isinstance(st, ast.Return):
            self.returns.append(self.eval(st.value)
                                if st.value is not None else BOTTOM)
        elif isinstance(st, ast.Expr):
            self.eval(st.value)
        elif isinstance(st, ast.If):
            self.eval(st.test)
            self.exec_block(st.body)
            self.exec_block(st.orelse)
        elif isinstance(st, (ast.For, ast.AsyncFor)):
            elem = self.eval(st.iter)
            self.bind(st.target, elem, st)
            self.loop_stack.append(self._assigned_names(st))
            self.exec_block(st.body)
            self.loop_stack.pop()
            self.exec_block(st.orelse)
        elif isinstance(st, ast.While):
            self.eval(st.test)
            self.loop_stack.append(self._assigned_names(st))
            self.exec_block(st.body)
            self.loop_stack.pop()
            self.exec_block(st.orelse)
        elif isinstance(st, (ast.With, ast.AsyncWith)):
            for item in st.items:
                v = self.eval(item.context_expr)
                if item.optional_vars is not None:
                    self.bind(item.optional_vars, v, st)
            self.exec_block(st.body)
        elif isinstance(st, ast.Try):
            self.exec_block(st.body)
            for h in st.handlers:
                self.exec_block(h.body)
            self.exec_block(st.orelse)
            self.exec_block(st.finalbody)
        elif isinstance(st, (ast.Raise, ast.Assert)):
            for child in ast.iter_child_nodes(st):
                if isinstance(child, ast.expr):
                    self.eval(child)
        elif isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            pass        # analyzed as their own FunctionInfo
        elif isinstance(st, ast.Delete):
            for t in st.targets:
                if isinstance(t, ast.Name):
                    self.env.pop(t.id, None)
        # Import/Global/Pass/Break/Continue: nothing to do

    @staticmethod
    def _assigned_names(loop: ast.stmt) -> Set[str]:
        names: Set[str] = set()
        for node in ast.walk(loop):
            if isinstance(node, ast.Name) and \
                    isinstance(node.ctx, (ast.Store,)):
                names.add(node.id)
        return names

    def bind(self, target: ast.expr, v: Value, st: ast.stmt,
             augmented: bool = False) -> None:
        if isinstance(target, ast.Name):
            if augmented:
                v = join(self.env.get(target.id, BOTTOM), v)
            else:
                self.key_uses.pop(target.id, None)  # fresh key binding
            self.env[target.id] = v
        elif isinstance(target, (ast.Tuple, ast.List)):
            elts = target.elts
            if isinstance(v, tuple):
                star = next((i for i, e in enumerate(elts)
                             if isinstance(e, ast.Starred)), None)
                if star is None and len(elts) <= len(v):
                    for e, el in zip(elts, v):
                        self.bind(e, el, st)
                    return
                for e in elts:
                    self.bind(e.value if isinstance(e, ast.Starred) else e,
                              collapse(v), st)
            else:
                for e in elts:
                    self.bind(e.value if isinstance(e, ast.Starred) else e,
                              v, st)
        elif isinstance(target, ast.Starred):
            self.bind(target.value, v, st)
        elif isinstance(target, ast.Subscript):
            self._check_key_element_store(target, st)
            base = target.value
            if isinstance(base, ast.Name):
                self.env[base.id] = join(self.env.get(base.id, BOTTOM), v)
        elif isinstance(target, ast.Attribute):
            pass        # object state is not tracked

    def _check_key_element_store(self, target: ast.Subscript,
                                 st: ast.stmt) -> None:
        """PL003(c): ``out[r, p:] = k[0]`` — one key element fanned out
        over a slot range duplicates its noise stream across slots."""
        if not self.record or not isinstance(st, (ast.Assign,
                                                  ast.AnnAssign)):
            return
        has_slice = any(isinstance(n, ast.Slice)
                        for n in ast.walk(target.slice))
        if not has_slice:
            return
        value = st.value
        if isinstance(value, ast.Subscript) and not any(
                isinstance(n, ast.Slice) for n in ast.walk(value.slice)):
            if collapse(self.eval(value.value)).keyish:
                self._emit("PL003", st,
                           "a single PRNG key element is stored across a "
                           "slot range — every padded slot shares one "
                           "noise stream (use distinct derived keys for "
                           "filler slots)")

    # -- expressions ---------------------------------------------------

    def eval(self, node: ast.expr) -> Value:
        if isinstance(node, ast.Constant):
            return BOTTOM
        if isinstance(node, ast.Name):
            if node.id in self.env:
                return self.env[node.id]
            # a bare name that resolves to a known function is a
            # function reference (feeds call-through-variable support)
            tgts = self.a.resolve_call(self.mod, self.fn, node.id)
            if tgts:
                return Taint(PUBLIC,
                             fnref=tuple(sorted(t.key for t in tgts)))
            return BOTTOM
        if isinstance(node, ast.Tuple):
            return tuple(self.eval(e) for e in node.elts)
        if isinstance(node, ast.List):
            out = BOTTOM
            for e in node.elts:
                out = join(out, collapse(self.eval(e)))
            return out
        if isinstance(node, (ast.Set, ast.Dict)):
            out = BOTTOM
            vals = node.values if isinstance(node, ast.Dict) else node.elts
            for e in vals:
                if e is not None:
                    out = join(out, collapse(self.eval(e)))
            return out
        if isinstance(node, ast.Starred):
            return self.eval(node.value)
        if isinstance(node, ast.BinOp):
            v = join(collapse(self.eval(node.left)),
                     collapse(self.eval(node.right)))
            if isinstance(node.op, ast.BitOr):
                self._check_widening(node, [node.left, node.right])
            return v
        if isinstance(node, ast.BoolOp):
            out = BOTTOM
            for e in node.values:
                out = join(out, collapse(self.eval(e)))
            return out
        if isinstance(node, ast.Compare):
            out = collapse(self.eval(node.left))
            for e in node.comparators:
                out = join(out, collapse(self.eval(e)))
            return out
        if isinstance(node, ast.UnaryOp):
            return self.eval(node.operand)
        if isinstance(node, ast.IfExp):
            self.eval(node.test)
            return join(self.eval(node.body), self.eval(node.orelse))
        if isinstance(node, ast.Subscript):
            base = self.eval(node.value)
            self.eval(node.slice)
            if isinstance(base, tuple) and \
                    isinstance(node.slice, ast.Constant) and \
                    isinstance(node.slice.value, int) and \
                    -len(base) <= node.slice.value < len(base):
                return base[node.slice.value]
            return collapse(base)
        if isinstance(node, ast.Attribute):
            if node.attr in STRUCTURAL_ATTRS:
                return BOTTOM
            base = collapse(self.eval(node.value))
            if node.attr in self.pol.raw_attrs:
                return join(base, Taint(RAW,
                                        why=f"client data .{node.attr}"))
            return base
        if isinstance(node, ast.Call):
            return self.eval_call(node)
        if isinstance(node, (ast.ListComp, ast.SetComp,
                             ast.GeneratorExp, ast.DictComp)):
            for gen in node.generators:
                self.bind(gen.target, self.eval(gen.iter), ast.Pass())
                for cond in gen.ifs:
                    self.eval(cond)
            if isinstance(node, ast.DictComp):
                self.eval(node.key)
                return collapse(self.eval(node.value))
            return collapse(self.eval(node.elt))
        if isinstance(node, ast.Lambda):
            return BOTTOM
        if isinstance(node, ast.JoinedStr):
            out = BOTTOM
            for v in node.values:
                if isinstance(v, ast.FormattedValue):
                    out = join(out, collapse(self.eval(v.value)))
            return out
        if isinstance(node, (ast.Await, ast.YieldFrom)):
            return self.eval(node.value)
        if isinstance(node, ast.Yield):
            return self.eval(node.value) if node.value else BOTTOM
        if isinstance(node, ast.NamedExpr):
            v = self.eval(node.value)
            self.bind(node.target, v, ast.Pass())
            return v
        if isinstance(node, ast.Slice):
            for part in (node.lower, node.upper, node.step):
                if part is not None:
                    self.eval(part)
            return BOTTOM
        return BOTTOM

    # -- calls ---------------------------------------------------------

    def _unwrap_callee(self, node: ast.Call
                       ) -> Tuple[Optional[str], List[ast.expr]]:
        """Peel ``jax.vmap(f, ...)(args)`` / ``partial(jit, f)(args)``
        down to (dotted name of f, the outer argument list)."""
        func = node.func
        args = list(node.args)
        depth = 0
        while isinstance(func, ast.Call) and depth < 4:
            inner_name = astgraph.dotted_name(func.func)
            resolved = self.mod.resolve(inner_name) if inner_name else None
            if not name_matches(_CALL_WRAPPERS, inner_name, resolved):
                break
            cand = None
            for a in func.args:
                nm = astgraph.dotted_name(a)
                if nm and not name_matches(_CALL_WRAPPERS, nm,
                                           self.mod.resolve(nm)):
                    cand = nm
                    break
            if cand is None:
                break
            return cand, args
        return astgraph.dotted_name(func), args

    def eval_call(self, node: ast.Call) -> Value:
        pol = self.pol
        raw, pos_exprs = self._unwrap_callee(node)
        resolved = self.mod.resolve(raw) if raw else None

        pos: List[Value] = [self.eval(a) for a in pos_exprs]
        kwargs: Dict[Optional[str], Value] = {
            kw.arg: self.eval(kw.value) for kw in node.keywords}

        def all_vals() -> List[Value]:
            return pos + list(kwargs.values())

        def flat_join() -> Taint:
            out = BOTTOM
            for v in all_vals():
                out = join(out, collapse(v))
            return out

        # container mutators: obj.append(x) joins x into obj (only for
        # known locals — np.append etc. must fall through untouched)
        if raw and "." in raw:
            base, meth = raw.rsplit(".", 1)
            if meth in _MUTATORS and "." not in base and \
                    base in self.env:
                self.env[base] = join(self.env.get(base, BOTTOM),
                                      flat_join())
                return BOTTOM

        # wrapper *construction* (`partial(jax.jit, ...)(f)`): the value
        # is a reference to f, not a call of it
        if raw is None and isinstance(node.func, ast.Call):
            inner = astgraph.dotted_name(node.func.func)
            inner_res = self.mod.resolve(inner) if inner else None
            if inner and name_matches(_CALL_WRAPPERS, inner, inner_res) \
                    and len(node.args) == 1:
                tname = astgraph.dotted_name(node.args[0])
                tgts = self.a.resolve_call(self.mod, self.fn, tname) \
                    if tname else []
                if tgts:
                    return Taint(PUBLIC, fnref=tuple(
                        sorted(t.key for t in tgts)))

        # ---- special forms ------------------------------------------
        if raw in _STRUCTURAL_CALLS:
            return BOTTOM
        if raw in ("enumerate",) and pos:
            return (BOTTOM, pos[0])
        if raw in ("zip",):
            return tuple(pos)
        if name_matches(_SCAN_NAMES, raw, resolved):
            return self._eval_scan(node, pos)

        # ---- policy: producers --------------------------------------
        if name_matches(pol.key_makers, raw, resolved):
            return Taint(PUBLIC, keyish=True, why="PRNG key")
        if name_matches(pol.clean_calls, raw, resolved):
            return BOTTOM
        if name_matches(pol.raw_sources, raw, resolved):
            return Taint(RAW, why=f"raw client data from {raw}()")
        if name_matches(pol.local_sources, raw, resolved):
            return Taint(LOCAL,
                         why=f"per-client result of {raw}()")
        if name_matches(pol.metric_fns, raw, resolved):
            return Taint(PUBLIC, why="scalar eval metric")
        if name_matches(pol.aggregators, raw, resolved):
            return Taint(min(SELECTED, flat_join().tier),
                         why="cohort-level aggregate")
        if name_matches(pol.selectors, raw, resolved):
            return Taint(SELECTED,
                         why=f"channel-selected by {raw}()")

        # ---- policy: sanitizers with ordering checks ----------------
        if name_matches(pol.noisers, raw, resolved):
            return self._eval_noiser(node, pos, kwargs)
        if name_matches(pol.decoders, raw, resolved):
            return Taint(SELECTED, revealed=True, why="decoded payload")
        if name_matches(pol.encoders, raw, resolved):
            return self._eval_encoder(node, pos)
        if name_matches(pol.accountant_calls, raw, resolved):
            return Taint(PUBLIC, why="privacy accounting")

        # ---- sinks (orthogonal: check, then fall through) -----------
        if name_matches(pol.telemetry_sinks, raw, resolved):
            self._check_telemetry(node, raw, pos_exprs, pos, kwargs)

        # ---- mask widening / key replication ------------------------
        if name_matches(pol.wideners, raw, resolved):
            self._check_widening(node, pos_exprs)
        if name_matches(pol.key_replicators, raw, resolved) and pos:
            if collapse(pos[0]).keyish and self.record:
                self._emit("PL003", node,
                           f"{raw}() replicates PRNG key material — "
                           "replicated slots draw identical noise "
                           "(derive distinct keys instead)")

        # ---- interprocedural ----------------------------------------
        targets = self.a.resolve_call(self.mod, self.fn, raw)
        if not targets:
            # call through a variable holding a function reference
            fval: Optional[Value] = None
            if raw is not None and "." not in raw:
                fval = self.env.get(raw)
            elif raw is None:
                fval = self.eval(node.func)
            if fval is not None:
                targets = [self.a.graph.functions[k]
                           for k in collapse(fval).fnref
                           if k in self.a.graph.functions]
        if targets:
            out: Optional[Value] = None
            for tgt in targets:
                self._propagate_args(tgt, raw, pos, kwargs)
                self.a.add_edge(self.fn.key, tgt.key)
                s = self.a.summaries.get(tgt.key, BOTTOM)
                out = s if out is None else join(out, s)
            return out if out is not None else BOTTOM

        # unknown constructor-like call: opaque object, not a join of
        # its arguments (object attribute state is untracked, so taint
        # through containers must come from declared sources instead)
        if raw and raw.rsplit(".", 1)[-1][:1].isupper():
            return BOTTOM

        # unresolved *method* calls keep their receiver's taint —
        # `delta.astype(f32)` / `losses.pop()` must not launder; module
        # receivers (`jnp.sum`) evaluate to BOTTOM so they only join
        # their arguments
        recv: Value = BOTTOM
        if isinstance(node.func, ast.Attribute):
            if raw is None:
                recv = fval if fval is not None else BOTTOM
            else:
                base = raw.rsplit(".", 1)[0]
                if "." not in base and base in self.env:
                    recv = self.env[base]

        # default: taint-preserving combinator (jnp.sum, float, ...)
        out = join(flat_join(), collapse(recv))
        return replace(out, fnref=()) if out.fnref else out

    def _eval_scan(self, node: ast.Call, pos: List[Value]) -> Value:
        body_name = astgraph.dotted_name(node.args[0]) if node.args \
            else None
        init = pos[1] if len(pos) > 1 else BOTTOM
        xs = pos[2] if len(pos) > 2 else BOTTOM
        targets = self.a.resolve_call(self.mod, self.fn, body_name)
        summary: Value = BOTTOM
        for tgt in targets:
            params = [p for p in tgt.params if p not in ("self",)]
            if params:
                self.a.seed_param(tgt.key, params[0], init)
            if len(params) > 1:
                self.a.seed_param(tgt.key, params[1], xs)
            self.a.add_edge(self.fn.key, tgt.key)
            summary = join(summary, self.a.summaries.get(tgt.key, BOTTOM))
        if isinstance(summary, tuple) and len(summary) == 2:
            return (join(summary[0], init), summary[1])
        return join(summary, init)

    def _propagate_args(self, tgt: astgraph.FunctionInfo,
                        raw: Optional[str], pos: List[Value],
                        kwargs: Dict[Optional[str], Value]) -> None:
        params = list(tgt.params)           # includes keyword-only
        if params and params[0] in ("self", "cls") and raw and \
                "." in raw:
            params = params[1:]             # bound-method call
        for pname, val in zip(params, pos):
            self.a.seed_param(tgt.key, pname, val)
        star = collapse(tuple(pos)) if len(pos) > len(params) else None
        for k, val in kwargs.items():
            if k is None:           # **kwargs splat
                for pname in params:
                    self.a.seed_param(tgt.key, pname, collapse(val))
            elif k in params:
                self.a.seed_param(tgt.key, k, val)
        if star is not None and star != BOTTOM:
            for pname in params:
                self.a.seed_param(tgt.key, pname, star)

    # -- rule bodies ---------------------------------------------------

    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        if self.record:
            self.a.emit(rule, self.mod, node, message, self.fn)

    def _eval_noiser(self, node: ast.Call, pos: List[Value],
                     kwargs: Dict[Optional[str], Value]) -> Value:
        arg = collapse(pos[0]) if pos else BOTTOM
        if self.record and (arg.encoded or arg.revealed):
            self._emit("PL002", node,
                       "DP noise applied to already-encoded/revealed "
                       "coordinates — noise must come BEFORE "
                       "wire.encode, or the un-noised values have "
                       "already left the privacy boundary")
        # PL003 (a)/(b): key argument hygiene on the noise path
        key_expr = None
        for kw in node.keywords:
            if kw.arg == "key":
                key_expr = kw.value
        if key_expr is None and len(node.args) > 1:
            key_expr = node.args[1]
        if self.record and key_expr is not None:
            names = {n.id for n in ast.walk(key_expr)
                     if isinstance(n, ast.Name)}
            if self.loop_stack and names and not (
                    names & set().union(*self.loop_stack)):
                self._emit("PL003", node,
                           "PRNG key is loop-invariant at this noise "
                           "call — every iteration (client/round) "
                           "draws the SAME noise, which voids the "
                           "accountant's independence assumption")
            if isinstance(key_expr, ast.Name):
                prev = self.key_uses.get(key_expr.id)
                if prev is not None:
                    self._emit("PL003", node,
                               f"PRNG key '{key_expr.id}' already "
                               f"consumed by a noise call on line "
                               f"{prev} — split a fresh key per "
                               "release")
                else:
                    self.key_uses[key_expr.id] = node.lineno
        # PL005 setup: remember the reveal-mask names at this call
        masks_expr = next((kw.value for kw in node.keywords
                           if kw.arg == "masks"), None)
        if masks_expr is not None:
            self.mask_names |= {n.id for n in ast.walk(masks_expr)
                                if isinstance(n, ast.Name)}
        self.noise_lines.append(node.lineno)
        out = replace(arg, noised=True, keyish=False,
                      why=arg.why or "DP-noised")
        return out

    def _eval_encoder(self, node: ast.Call, pos: List[Value]) -> Value:
        arg = collapse(pos[0]) if pos else BOTTOM
        if self.record and arg.tier >= LOCAL and not arg.noised:
            what = arg.why or TIER_NAMES[arg.tier] + " value"
            self._emit("PL001", node,
                       f"{what} reaches the wire without the full "
                       "sanitizer chain (channel selection + "
                       "gaussian_mechanism) — the server would see "
                       "un-noised per-client data")
        if self.record and arg.noised and not \
                self.a.accountant_dominates(self.fn.key):
            self._emit("PL004", node,
                       "DP-noised payload is emitted but no caller "
                       "updates the privacy accountant (epsilon_for / "
                       "release ledger) — the spent ε/δ budget is "
                       "untracked for this release")
        # the argument's coordinates are now revealed: a later noise
        # call on the same variable is PL002
        for a in node.args:
            for n in ast.walk(a):
                if isinstance(n, ast.Name) and n.id in self.env:
                    self.env[n.id] = _map_taint(
                        self.env[n.id],
                        lambda t: replace(t, revealed=True))
        return Taint(PUBLIC, encoded=True, why="wire payload")

    def _check_widening(self, node: ast.AST,
                        arg_exprs: Sequence[ast.expr]) -> None:
        if not self.record or not self.noise_lines:
            return
        if getattr(node, "lineno", 0) <= self.noise_lines[0]:
            return
        names: Set[str] = set()
        for a in arg_exprs:
            for n in ast.walk(a):
                if isinstance(n, ast.Name):
                    names.add(n.id)
        hit = names & self.mask_names
        if hit:
            self._emit("PL005", node,
                       f"reveal/keep mask '{sorted(hit)[0]}' is widened "
                       "AFTER the DP noise was calibrated to it — the "
                       "extra coordinates ship with no noise budget "
                       "(post-DP coordinate leakage)")

    def _check_telemetry(self, node: ast.Call, raw: Optional[str],
                         pos_exprs: Sequence[ast.expr], pos: List[Value],
                         kwargs: Dict[Optional[str], Value]) -> None:
        if not self.record:
            return
        labelled = [(astgraph.dotted_name(e) or f"arg{i}", v)
                    for i, (e, v) in enumerate(zip(pos_exprs, pos))]
        labelled += [(k or "**kwargs", v) for k, v in kwargs.items()]
        for label, v in labelled:
            t = collapse(v)
            if t.tier >= LOCAL and not t.noised:
                self._emit("PL006", node,
                           f"telemetry/checkpoint sink {raw}() receives "
                           f"pre-DP per-client value '{label}' "
                           f"({t.why or TIER_NAMES[t.tier]}) — logs and "
                           "events.jsonl are outside the privacy "
                           "boundary")
                return
