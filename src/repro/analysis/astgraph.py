"""Module parsing and the jit-reachability call graph.

The analysis is purely syntactic (``ast``): no file under analysis is
ever imported, so linting cannot initialize a JAX backend or execute
benchmark code.  The graph answers one question the rules all share:
*which functions run under a JAX trace?*  A function is **in-trace**
when it is

* wrapped by ``jax.jit`` (call, decorator, or ``functools.partial``
  application),
* passed as the traced callable of ``lax.scan`` / ``jax.vmap`` /
  ``jax.grad`` / ``jax.value_and_grad`` / ``jax.checkpoint`` /
  ``pl.pallas_call``, or
* (transitively) called from an in-trace function, resolved through
  same-module names, ``self.`` methods, and ``from repro.x import y``
  style imports.

Resolution is best-effort: attribute calls on unknown objects
(``eng.scbf_round(...)``) produce no edge.  That under-approximation is
deliberate — rules that key on in-trace membership stay low
false-positive, and the committed baseline absorbs what slips through.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

# jax entry points whose FIRST argument is traced
_TRACING_WRAPPERS = {
    "jax.jit", "jit",
    "jax.vmap", "vmap",
    "jax.grad", "grad",
    "jax.value_and_grad", "value_and_grad",
    "jax.checkpoint", "checkpoint", "jax.remat", "remat",
    "jax.lax.scan", "lax.scan", "scan",
    "jax.lax.while_loop", "lax.while_loop",
    "jax.lax.fori_loop", "lax.fori_loop",
    "jax.lax.cond", "lax.cond",
    "jax.lax.map", "lax.map",
    "pallas_call", "pl.pallas_call", "pallas.pallas_call",
}

# names that mean "jax.jit" after alias resolution
_JIT_NAMES = {"jax.jit", "jit"}

_PARTIAL_NAMES = {"functools.partial", "partial"}

_CACHE_DECORATORS = {"functools.lru_cache", "lru_cache",
                     "functools.cache", "cache"}

SCALAR_ANNOTATIONS = {"int", "bool", "str", "float", "Optional[int]",
                      "Optional[str]", "Optional[bool]", "Optional[float]"}


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


@dataclass
class FunctionInfo:
    """One function (or method, or nested def) in one module."""

    qualname: str                    # e.g. "Engine.scbf_round" / "f.<g>"
    module: str                      # dotted module name
    node: ast.AST                    # FunctionDef | AsyncFunctionDef | Lambda
    lineno: int
    params: Tuple[str, ...] = ()     # positional + keyword parameter names
    posonly_params: Tuple[str, ...] = ()   # positional(-or-keyword) subset
    kwonly_params: Tuple[str, ...] = ()
    annotations: Dict[str, str] = field(default_factory=dict)
    parent: Optional[str] = None     # enclosing function qualname
    decorators: Tuple[str, ...] = ()
    static_params: Set[str] = field(default_factory=set)
    in_trace: bool = False
    calls: Set[str] = field(default_factory=set)       # resolved qualnames
    raw_calls: Set[str] = field(default_factory=set)   # unresolved names

    @property
    def key(self) -> str:
        return f"{self.module}:{self.qualname}"

    @property
    def cached_factory(self) -> bool:
        return any(d.split("(")[0] in _CACHE_DECORATORS
                   for d in self.decorators)


@dataclass
class ModuleInfo:
    path: str                        # path as given on the command line
    modname: str                     # dotted name ("repro.fed.engine")
    tree: ast.Module
    source_lines: List[str]
    imports: Dict[str, str] = field(default_factory=dict)  # alias -> full
    functions: Dict[str, FunctionInfo] = field(default_factory=dict)
    classes: Dict[str, Set[str]] = field(default_factory=dict)
    # module-level names bound to jit-wrapped callables
    jitted_symbols: Set[str] = field(default_factory=set)
    imports_jax: bool = False

    def resolve(self, name: str) -> str:
        """Expand the leading alias of a dotted name via the imports."""
        head, _, rest = name.partition(".")
        full = self.imports.get(head)
        if full is None:
            return name
        return f"{full}.{rest}" if rest else full


def module_name_for(path: str, roots: Sequence[str] = ("src",)) -> str:
    """Dotted module name for a file path (src-rooted when possible)."""
    norm = path.replace(os.sep, "/")
    for root in roots:
        marker = f"{root}/"
        if norm.startswith(marker):
            norm = norm[len(marker):]
            break
        idx = norm.find(f"/{root}/")
        if idx >= 0:
            norm = norm[idx + len(root) + 2:]
            break
    if norm.endswith(".py"):
        norm = norm[:-3]
    if norm.endswith("/__init__"):
        norm = norm[: -len("/__init__")]
    return norm.replace("/", ".")


def _collect_imports(tree: ast.Module) -> Tuple[Dict[str, str], bool]:
    imports: Dict[str, str] = {}
    has_jax = False
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                imports[alias.asname or alias.name.split(".")[0]] = \
                    alias.name if alias.asname else alias.name.split(".")[0]
                if alias.name == "jax" or alias.name.startswith("jax."):
                    has_jax = True
        elif isinstance(node, ast.ImportFrom) and node.module:
            for alias in node.names:
                imports[alias.asname or alias.name] = \
                    f"{node.module}.{alias.name}"
                if node.module == "jax" or node.module.startswith("jax."):
                    has_jax = True
    return imports, has_jax


def is_jit_expr(node: ast.AST, mod: ModuleInfo) -> bool:
    """Is this expression a ``jax.jit(...)`` / ``partial(jax.jit, ...)``
    construction (possibly applied: ``partial(jax.jit, ...)  (f)``)?"""
    if not isinstance(node, ast.Call):
        return False
    callee = dotted_name(node.func)
    if callee is not None:
        resolved = mod.resolve(callee)
        if resolved in _JIT_NAMES:
            return True
        if resolved in _PARTIAL_NAMES and node.args:
            first = dotted_name(node.args[0])
            if first is not None and mod.resolve(first) in _JIT_NAMES:
                return True
    # partial(jax.jit, ...)(f): the applied form
    if isinstance(node.func, ast.Call):
        return is_jit_expr(node.func, mod)
    return False


def _static_argnames_of(call: ast.Call) -> Set[str]:
    """Literal static_argnames from a jit/partial(jit, ...) call."""
    out: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            val = kw.value
            if isinstance(val, ast.Constant) and isinstance(val.value, str):
                out.add(val.value)
            elif isinstance(val, (ast.Tuple, ast.List)):
                for el in val.elts:
                    if isinstance(el, ast.Constant) and \
                            isinstance(el.value, str):
                        out.add(el.value)
    if isinstance(call.func, ast.Call):        # applied partial form
        out |= _static_argnames_of(call.func)
    return out


class _FunctionCollector(ast.NodeVisitor):
    """First pass: functions, classes, calls, module-level jit bindings."""

    def __init__(self, mod: ModuleInfo):
        self.mod = mod
        self.scope: List[str] = []         # enclosing class/function names
        self.fn_stack: List[FunctionInfo] = []

    # -- helpers ----------------------------------------------------------

    def _qual(self, name: str) -> str:
        return ".".join(self.scope + [name]) if self.scope else name

    def _add_function(self, node, name: str) -> FunctionInfo:
        args = node.args
        pos = [a.arg for a in args.posonlyargs + args.args]
        kwonly = [a.arg for a in args.kwonlyargs]
        ann: Dict[str, str] = {}
        for a in args.posonlyargs + args.args + args.kwonlyargs:
            if a.annotation is not None:
                try:
                    ann[a.arg] = ast.unparse(a.annotation)
                except Exception:           # pragma: no cover - ast quirk
                    pass
        decos = []
        static: Set[str] = set()
        for d in getattr(node, "decorator_list", []):
            dname = dotted_name(d.func if isinstance(d, ast.Call) else d)
            if dname is not None:
                decos.append(self.mod.resolve(dname))
            if is_jit_expr(d, self.mod) or (
                    dname is not None
                    and self.mod.resolve(dname) in _JIT_NAMES):
                static |= _static_argnames_of(d) \
                    if isinstance(d, ast.Call) else set()
        info = FunctionInfo(
            qualname=self._qual(name), module=self.mod.modname, node=node,
            lineno=node.lineno, params=tuple(pos + kwonly),
            posonly_params=tuple(pos), kwonly_params=tuple(kwonly),
            annotations=ann,
            parent=(self.fn_stack[-1].qualname if self.fn_stack else None),
            decorators=tuple(decos), static_params=static)
        self.mod.functions[info.qualname] = info
        return info

    # -- visitors ---------------------------------------------------------

    def visit_ClassDef(self, node: ast.ClassDef):
        self.mod.classes.setdefault(node.name, set())
        self.scope.append(node.name)
        self.generic_visit(node)
        self.scope.pop()

    def _visit_fn(self, node, name: str):
        info = self._add_function(node, name)
        if self.scope and self.scope[-1] in self.mod.classes and \
                not self.fn_stack:
            self.mod.classes[self.scope[-1]].add(name)
        self.scope.append(name)
        self.fn_stack.append(info)
        self.generic_visit(node)
        self.fn_stack.pop()
        self.scope.pop()

    def visit_FunctionDef(self, node):
        self._visit_fn(node, node.name)

    def visit_AsyncFunctionDef(self, node):
        self._visit_fn(node, node.name)

    def visit_Lambda(self, node: ast.Lambda):
        # lambdas participate as anonymous nodes of their enclosing fn
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        callee = dotted_name(node.func)
        if self.fn_stack and callee is not None:
            self.fn_stack[-1].raw_calls.add(callee)
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign):
        # module-level `name = jax.jit(f)` / `name = partial(jit,...)(f)`
        if not self.fn_stack and is_jit_expr(node.value, self.mod):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    self.mod.jitted_symbols.add(tgt.id)
        self.generic_visit(node)


def parse_module(path: str, roots: Sequence[str] = ("src",)
                 ) -> Optional[ModuleInfo]:
    with open(path, encoding="utf-8") as f:
        source = f.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError:
        return None
    mod = ModuleInfo(path=path, modname=module_name_for(path, roots),
                     tree=tree, source_lines=source.splitlines())
    mod.imports, mod.imports_jax = _collect_imports(tree)
    _FunctionCollector(mod).visit(tree)
    # jit-decorated defs are jitted symbols of the module
    for info in mod.functions.values():
        node = info.node
        for d in getattr(node, "decorator_list", []):
            if is_jit_expr(d, mod) or (
                    dotted_name(d) is not None
                    and mod.resolve(dotted_name(d)) in _JIT_NAMES):
                if info.parent is None:
                    mod.jitted_symbols.add(info.qualname)
    return mod


@dataclass
class CallGraph:
    """All parsed modules plus the resolved in-trace marking."""

    modules: Dict[str, ModuleInfo] = field(default_factory=dict)
    functions: Dict[str, FunctionInfo] = field(default_factory=dict)
    # fully-qualified "module.fn" -> FunctionInfo, for import resolution
    by_dotted: Dict[str, FunctionInfo] = field(default_factory=dict)

    def function_at(self, mod: ModuleInfo, node: ast.AST
                    ) -> Optional[FunctionInfo]:
        """Innermost FunctionInfo whose body contains ``node``."""
        best, best_span = None, None
        for info in mod.functions.values():
            n = info.node
            end = getattr(n, "end_lineno", n.lineno)
            if n.lineno <= node.lineno <= end:
                span = end - n.lineno
                if best_span is None or span <= best_span:
                    best, best_span = info, span
        return best


def _resolve_calls(graph: CallGraph) -> None:
    for mod in graph.modules.values():
        for info in mod.functions.values():
            for raw in info.raw_calls:
                # 1. same-module (respecting nesting / enclosing class)
                target = _resolve_local(mod, info, raw)
                if target is not None:
                    info.calls.add(target.key)
                    continue
                # 2. imported name -> another parsed module's function
                resolved = mod.resolve(raw)
                hit = graph.by_dotted.get(resolved)
                if hit is not None:
                    info.calls.add(hit.key)


def _resolve_local(mod: ModuleInfo, caller: FunctionInfo,
                   raw: str) -> Optional[FunctionInfo]:
    head, _, rest = raw.partition(".")
    if head == "self" and rest and "." not in rest:
        # method call within the caller's class
        cls = caller.qualname.split(".")[0]
        return mod.functions.get(f"{cls}.{rest}")
    if rest:
        return mod.functions.get(raw)       # explicit Class.method
    # nested def of the caller, then siblings up the chain, then module
    prefix = caller.qualname
    while True:
        hit = mod.functions.get(f"{prefix}.{head}" if prefix else head)
        if hit is not None:
            return hit
        if not prefix:
            return None
        prefix = prefix.rpartition(".")[0]


class _TraceRootMarker(ast.NodeVisitor):
    """Mark functions handed to tracing wrappers as in-trace roots."""

    def __init__(self, mod: ModuleInfo, roots: List[FunctionInfo]):
        self.mod = mod
        self.roots = roots
        self._scope: List[str] = []

    def _mark_name(self, name: Optional[str], caller_scope: List[str]):
        if name is None:
            return
        for depth in range(len(caller_scope), -1, -1):
            prefix = ".".join(caller_scope[:depth])
            qual = f"{prefix}.{name}" if prefix else name
            info = self.mod.functions.get(qual)
            if info is not None:
                self.roots.append(info)
                return

    def visit_FunctionDef(self, node):
        info = next((f for f in self.mod.functions.values()
                     if f.node is node), None)
        for d in node.decorator_list:
            dname = dotted_name(d.func if isinstance(d, ast.Call) else d)
            resolved = self.mod.resolve(dname) if dname else None
            if is_jit_expr(d, self.mod) or resolved in _TRACING_WRAPPERS:
                if info is not None:
                    self.roots.append(info)
        self._scope.append(node.name)
        self.generic_visit(node)
        self._scope.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_ClassDef(self, node):
        self._scope.append(node.name)
        self.generic_visit(node)
        self._scope.pop()

    def visit_Call(self, node: ast.Call):
        callee = dotted_name(node.func)
        resolved = self.mod.resolve(callee) if callee else None
        is_wrapper = resolved in _TRACING_WRAPPERS
        if is_jit_expr(node, self.mod) or is_wrapper:
            # the first positional argument is the traced callable
            if node.args:
                first = node.args[0]
                if isinstance(first, ast.Lambda):
                    pass                      # handled by enclosing scope
                else:
                    self._mark_name(dotted_name(first), self._scope)
            # partial(jax.jit, ...) has the callable as the 2nd arg
            if not is_wrapper and isinstance(node.func, ast.Name) is False \
                    and callee is not None and \
                    self.mod.resolve(callee) in _PARTIAL_NAMES and \
                    len(node.args) >= 2:
                self._mark_name(dotted_name(node.args[1]), self._scope)
        self.generic_visit(node)


def build_graph(paths: Sequence[str],
                roots: Sequence[str] = ("src",)) -> CallGraph:
    """Parse every .py file under ``paths`` and mark in-trace functions."""
    graph = CallGraph()
    for path in _iter_py_files(paths):
        mod = parse_module(path, roots)
        if mod is None:
            continue
        graph.modules[mod.modname] = mod
        for info in mod.functions.values():
            graph.functions[info.key] = info
            graph.by_dotted[f"{mod.modname}.{info.qualname}"] = info
    _resolve_calls(graph)

    trace_roots: List[FunctionInfo] = []
    for mod in graph.modules.values():
        _TraceRootMarker(mod, trace_roots).visit(mod.tree)
        # static_argnames attach to the function a jit wrapping names
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and is_jit_expr(node, mod):
                static = _static_argnames_of(node)
                if not static:
                    continue
                target = None
                if node.args:
                    target = dotted_name(node.args[0])
                cname = dotted_name(node.func)
                if target is None and cname is not None and \
                        mod.resolve(cname) in _PARTIAL_NAMES and \
                        len(node.args) >= 2:
                    target = dotted_name(node.args[1])
                if target is not None and target in mod.functions:
                    mod.functions[target].static_params |= static

    # BFS the call graph from the trace roots
    queue = list(trace_roots)
    seen: Set[str] = set()
    while queue:
        fn = queue.pop()
        if fn.key in seen:
            continue
        seen.add(fn.key)
        fn.in_trace = True
        for callee_key in fn.calls:
            callee = graph.functions.get(callee_key)
            if callee is not None and callee.key not in seen:
                queue.append(callee)
        # nested defs of an in-trace function trace with it
        for other in graph.modules[fn.module].functions.values():
            if other.parent == fn.qualname and other.key not in seen:
                queue.append(other)
    return graph


def _iter_py_files(paths: Sequence[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            out.append(p)
        elif os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = [d for d in dirnames
                               if not d.startswith(".")
                               and d != "__pycache__"]
                for fname in sorted(filenames):
                    if fname.endswith(".py"):
                        out.append(os.path.join(dirpath, fname))
    return sorted(set(out))
