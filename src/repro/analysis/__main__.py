"""``python -m repro.analysis`` — merged tracelint + privlint runner.

Use ``python -m repro.analysis.tracelint`` / ``.privlint`` for a single
tool with its full CLI (baseline writing, rule subsets, …).
"""
import sys

from repro.analysis.runner import main

sys.exit(main())
