"""``python -m repro.analysis`` — shorthand for the tracelint CLI."""
import sys

from repro.analysis.tracelint import main

sys.exit(main())
