"""tracelint — JAX-aware static analysis for the retrace/host-sync/
recompile bug class.

Every perf property this repo defends in CI (bit-identical fused
rounds, <= 2 compiles per run, zero host crossings inside a chunk) was
originally won by hand-fixing the same few bug shapes: per-call
``jax.jit`` construction (PR 1, PR 5), shape-keyed recompiles from
loop-varying argument shapes (PR 3), and silent device→host syncs on
the hot path (PR 4).  This package detects those shapes at lint time:

* ``astgraph``  — module parsing + the jit-reachability call graph
  (which functions end up *inside* a traced program).
* ``rules``     — the TL001..TL006 rule implementations.
* ``report``    — findings, suppression comments, baseline files,
  human/JSON rendering.
* ``config``    — rule registry and file discovery.
* ``tracelint`` — the CLI (``python -m repro.analysis.tracelint``).

See docs/STATIC_ANALYSIS.md for the rule catalogue and workflow.

Deliberately import-free: ``python -m repro.analysis.tracelint`` must
not find the submodule pre-imported in ``sys.modules`` (runpy warns),
and the package stays importable without jax installed.
"""
