"""The tracelint rules, TL001..TL006.

Each rule documents the historical bug it would have caught (PR number
and file — see docs/STATIC_ANALYSIS.md for the full catalogue) and errs
toward *under*-reporting: heuristics only fire on the specific shapes
that bit this repo, and every rule honors per-line suppression comments
plus the committed baseline.  Tracer-ness is approximated by the repo's
own calling convention, which the analyzer states explicitly:

* array/tracer values arrive as **positional, unannotated** parameters;
* static configuration arrives **keyword-only** or annotated with a
  Python scalar type (``int``/``bool``/``str``/``float``), or is named
  in the wrapping jit's ``static_argnames``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Set

from repro.analysis.astgraph import (CallGraph, FunctionInfo, ModuleInfo,
                                     SCALAR_ANNOTATIONS, dotted_name,
                                     is_jit_expr)
from repro.analysis.report import Finding

_HOST_SYNC_CASTS = {"float", "int", "bool"}
_NP_MATERIALIZE = {"np.asarray", "numpy.asarray", "np.array", "numpy.array"}
_DEVICE_GET = {"jax.device_get", "device_get"}
_STATIC_CALLS = {"len", "isinstance", "hasattr", "getattr", "type", "range",
                 "max", "min", "abs"}
# array methods that READ values: `x.sum()` on a tracer concretizes,
# even though the attribute access `x.sum` alone is structural
_VALUE_METHODS = {"sum", "max", "min", "mean", "prod", "any", "all",
                  "item", "tolist"}


def _enclosing(mod: ModuleInfo, graph: CallGraph,
               node: ast.AST) -> Optional[FunctionInfo]:
    return graph.function_at(mod, node)


def _finding(rule: str, mod: ModuleInfo, node: ast.AST, message: str,
             fn: Optional[FunctionInfo]) -> Finding:
    return Finding(rule=rule, path=mod.path, line=node.lineno,
                   col=getattr(node, "col_offset", 0), message=message,
                   symbol=fn.qualname if fn else "<module>")


def _tracer_params(fn: FunctionInfo) -> Set[str]:
    """Parameters this repo's convention marks as possibly-traced:
    positional, unannotated-or-array-annotated, non-static."""
    out = set()
    for p in fn.posonly_params:
        if p in ("self", "cls") or p in fn.static_params:
            continue
        if fn.annotations.get(p) in SCALAR_ANNOTATIONS:
            continue
        out.add(p)
    return out


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _is_static_expr(node: ast.AST, tracer_names: Set[str],
                    static_calls: Set[str] = frozenset()) -> bool:
    """Conservatively: does this expression avoid touching a tracer
    except through static accessors?

    Static accessors — uses that read *structure*, never array values:
    ``.shape``/``.ndim``/``.dtype``-style attributes, ``len()`` and
    friends, ``is None`` tests, ``"key" in pytree`` membership on a
    string constant, any other attribute access (pytrees and config
    objects travel as positional args, and branching on a *field* of
    one is structural), and calls to same-module shape-pure functions
    (``static_calls``).
    """
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id in tracer_names:
            if not _under_static_accessor(node, sub, static_calls):
                return False
    return True


def _under_static_accessor(root: ast.AST, target: ast.Name,
                           static_calls: Set[str] = frozenset()) -> bool:
    """Is ``target`` only reached via a static accessor inside root?"""
    class _V(ast.NodeVisitor):
        def __init__(self):
            self.ok = True

        def visit_Attribute(self, node):
            # any attribute read is structural: .shape/.dtype on arrays,
            # config fields on dataclasses, dict methods on pytrees.
            # (Reading array *values* needs a call or a subscript, both
            # of which stay flagged.)
            return

        def visit_Call(self, node):
            cname = dotted_name(node.func)
            if cname in _STATIC_CALLS or cname in static_calls:
                return
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr in _VALUE_METHODS:
                # `x.max()` reads values — look through the attribute
                # at its base (and the args) instead of exempting it
                self.visit(node.func.value)
                for a in node.args:
                    self.visit(a)
                return
            self.generic_visit(node)

        def visit_Compare(self, node):
            # `x is None` / `x is not None` is a static (python-level)
            # test even on a tracer-typed name
            if all(isinstance(op, (ast.Is, ast.IsNot))
                   for op in node.ops) and any(
                    isinstance(c, ast.Constant) and c.value is None
                    for c in node.comparators):
                return
            # `"key" in p`: membership of a string constant is a
            # structural test on a dict pytree, not a value read
            if all(isinstance(op, (ast.In, ast.NotIn))
                   for op in node.ops) and \
                    isinstance(node.left, ast.Constant) and \
                    isinstance(node.left.value, str):
                return
            self.generic_visit(node)

        def visit_Name(self, node):
            if node is target:
                self.ok = False

    v = _V()
    v.visit(root)
    return v.ok


def _shape_only_functions(mod: ModuleInfo) -> Set[str]:
    """Same-module functions that read their arguments only through
    static accessors (shapes, lens, structure) — calling one on a
    tracer is a static computation, e.g. ``num_channels(scores)``."""
    out: Set[str] = set()
    for fn in mod.functions.values():
        params = {p for p in fn.posonly_params if p not in ("self", "cls")}
        if not params:
            continue
        derived = set(params)
        iter_names: Set[int] = set()
        for node in ast.walk(fn.node):
            if isinstance(node, ast.For) and \
                    isinstance(node.iter, ast.Name) and \
                    node.iter.id in derived:
                derived |= _names_in(node.target)
                # iterating a pytree/array unrolls over structure —
                # shape-static, so the iter read itself is fine
                iter_names.add(id(node.iter))
        ok = True
        for node in ast.walk(fn.node):
            if isinstance(node, ast.Name) and id(node) not in iter_names \
                    and node.id in derived and \
                    isinstance(node.ctx, ast.Load):
                if not _under_static_accessor(fn.node, node):
                    ok = False
                    break
        if ok:
            out.add(fn.qualname)
    return out


# ---------------------------------------------------------------------------
# TL001 — per-call jax.jit construction
# ---------------------------------------------------------------------------

def _assignment_is_cached(mod: ModuleInfo, call: ast.Call) -> bool:
    """Cached-attribute wrapping: ``self._f = jax.jit(...)`` (or a dict
    slot) guarded by an ``if ... is None`` / ``not in`` / ``hasattr``
    style cache check is the accepted lazy-build idiom."""
    parents = _parent_chain(mod.tree, call)
    assigned_cache_slot = False
    for node in parents:
        if isinstance(node, ast.Assign):
            if any(isinstance(t, (ast.Attribute, ast.Subscript))
                   for t in node.targets):
                assigned_cache_slot = True
        if isinstance(node, ast.If) and assigned_cache_slot:
            test_src = ast.dump(node.test)
            if ("Is()" in test_src or "IsNot()" in test_src
                    or "NotIn()" in test_src or "In()" in test_src
                    or "hasattr" in test_src):
                return True
    return False


def _parent_chain(tree: ast.Module, target: ast.AST) -> List[ast.AST]:
    """Ancestors of ``target``, innermost first."""
    chain: List[ast.AST] = []

    def walk(node, ancestors):
        if node is target:
            chain.extend(reversed(ancestors))
            return True
        for child in ast.iter_child_nodes(node):
            if walk(child, ancestors + [node]):
                return True
        return False

    walk(tree, [])
    return chain


def check_tl001(mod: ModuleInfo, graph: CallGraph) -> Iterable[Finding]:
    """TL001: ``jax.jit(...)`` constructed inside a function body.

    The PR 1 bug (``scbf._evaluate`` re-wrapped ``jax.jit(mlp_forward)``
    per evaluation) and the PR 5 bug (``apoz_scores`` built
    ``jax.jit(lambda ...)`` per pruning step): a jit wrapper built
    inside a re-entered function gets a fresh compilation cache every
    call, so every call retraces and recompiles.  Module-level
    wrappings, ``lru_cache``-decorated factories, and cache-guarded
    attribute assignments are exempt.
    """
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call) and is_jit_expr(node, mod)):
            continue
        fn = _enclosing(mod, graph, node)
        if fn is None:
            continue                          # module level: the fix
        # partial(jax.jit, ...) *unapplied* inside a function is only
        # a builder; flag it all the same — it is called per-call —
        # except when it is immediately a decorator (handled as def).
        if any(node in getattr(f.node, "decorator_list", [])
               for f in mod.functions.values()):
            owner = next(f for f in mod.functions.values()
                         if node in getattr(f.node, "decorator_list", []))
            if owner.parent is None:
                continue                      # module-level decorated def
            fn = graph.functions.get(f"{mod.modname}:{owner.parent}")
        if fn is None:
            continue
        if fn.cached_factory:
            continue
        if any(graph.functions[f"{mod.modname}:{q}"].cached_factory
               for q in _ancestor_qualnames(fn)
               if f"{mod.modname}:{q}" in graph.functions):
            continue
        if _assignment_is_cached(mod, node):
            continue
        lam = " (on a lambda)" if node.args and \
            isinstance(node.args[0], ast.Lambda) else ""
        yield _finding(
            "TL001", mod, node,
            f"jax.jit constructed inside '{fn.qualname}'{lam}: the wrapper "
            "(and its compilation cache) is rebuilt on every call, so "
            "every call retraces — hoist to module level, an "
            "@functools.lru_cache factory, or a cache-guarded attribute",
            fn)


def _ancestor_qualnames(fn: FunctionInfo) -> List[str]:
    out = []
    qual = fn.parent
    while qual:
        out.append(qual)
        qual = qual.rpartition(".")[0] or None
    return out


# ---------------------------------------------------------------------------
# TL002 — host sync on traced values
# ---------------------------------------------------------------------------

def check_tl002(mod: ModuleInfo, graph: CallGraph) -> Iterable[Finding]:
    """TL002: device→host sync on a traced value.

    The PR 4 bug: ``float(lr)`` on a device scalar synced the host
    every round.  Inside in-trace functions this is a trace error or a
    silent constant-folding hazard; on the host tier, ``float()`` of an
    unannotated positional parameter (or of a known-jitted call) is the
    same bug wearing a loop — it blocks dispatch on device completion.
    """
    static_calls = _shape_only_functions(mod)
    for mod_fn in mod.functions.values():
        if not mod_fn.in_trace:
            continue
        tracers = _tracer_params(mod_fn)
        for node in _own_body_walk(mod, mod_fn):
            if isinstance(node, ast.Call):
                cname = dotted_name(node.func)
                resolved = mod.resolve(cname) if cname else None
                if isinstance(node.func, ast.Attribute) and \
                        node.func.attr == "item" and not node.args:
                    yield _finding(
                        "TL002", mod, node,
                        ".item() inside a traced function forces a "
                        "device→host sync (and fails under jit) — keep "
                        "the value on device or move the read to a "
                        "chunk boundary", mod_fn)
                elif resolved in _NP_MATERIALIZE or resolved in _DEVICE_GET:
                    yield _finding(
                        "TL002", mod, node,
                        f"{cname}(...) inside a traced function "
                        "materializes on host — use jnp, or hoist the "
                        "transfer out of the traced region", mod_fn)
                elif cname in _HOST_SYNC_CASTS and len(node.args) == 1 and \
                        not _is_static_expr(node.args[0], tracers,
                                            static_calls):
                    yield _finding(
                        "TL002", mod, node,
                        f"{cname}() on a traced value inside "
                        f"'{mod_fn.qualname}' syncs device→host (the "
                        "PR 4 lr bug) — keep it a jnp scalar, or make "
                        "the argument static", mod_fn)

    # host tier: float(<unannotated positional param>) or
    # float(<jitted call>) in a jax-importing module
    if not mod.imports_jax:
        return
    for mod_fn in mod.functions.values():
        if mod_fn.in_trace:
            continue
        tracers = _tracer_params(mod_fn)
        for node in _own_body_walk(mod, mod_fn):
            if not (isinstance(node, ast.Call)
                    and dotted_name(node.func) in _HOST_SYNC_CASTS
                    and len(node.args) == 1):
                continue
            arg = node.args[0]
            if isinstance(arg, ast.Name) and arg.id in tracers:
                yield _finding(
                    "TL002", mod, node,
                    f"{dotted_name(node.func)}() on parameter "
                    f"'{arg.id}' may hide a device→host sync if a "
                    "caller passes a device value — annotate the "
                    "parameter as a Python scalar or sync explicitly "
                    "at the call site", mod_fn)
            elif isinstance(arg, ast.Call):
                callee = dotted_name(arg.func)
                if callee is not None and \
                        _is_jitted_symbol(mod, graph, callee):
                    yield _finding(
                        "TL002", mod, node,
                        f"{dotted_name(node.func)}() directly on the "
                        f"jitted call '{callee}(...)' syncs device→host "
                        "per call — batch the reads or keep the value "
                        "on device", mod_fn)


def _is_jitted_symbol(mod: ModuleInfo, graph: CallGraph, name: str) -> bool:
    """Does ``name`` refer to a jit-wrapped callable?  Exact names only
    — an attribute access on one (``f._cache_size()``) is introspection,
    not a traced call.  Imported names resolve through the graph into
    the defining module's jitted symbols."""
    if name in mod.jitted_symbols:
        return True
    if name in mod.functions:
        return False
    resolved = mod.resolve(name)
    owner_name, _, sym = resolved.rpartition(".")
    owner = graph.modules.get(owner_name)
    return owner is not None and sym in owner.jitted_symbols


def _own_body_walk(mod: ModuleInfo, fn: FunctionInfo) -> Iterable[ast.AST]:
    """Walk fn's body but NOT the bodies of nested function defs (each
    nested def is its own FunctionInfo and is visited separately)."""
    own_nested = [f.node for f in mod.functions.values()
                  if f.parent == fn.qualname]

    def walk(node):
        for child in ast.iter_child_nodes(node):
            if child in own_nested:
                continue
            yield child
            yield from walk(child)

    yield from walk(fn.node)


# ---------------------------------------------------------------------------
# TL003 — Python branching on tracer values
# ---------------------------------------------------------------------------

def check_tl003(mod: ModuleInfo, graph: CallGraph) -> Iterable[Finding]:
    """TL003: ``if``/``while`` on a tracer inside a traced function.

    Python control flow on a traced value either raises a
    ConcretizationTypeError at trace time or — when the value happens
    to be concrete during tracing — silently bakes one branch into the
    compiled program (the shape-keyed cousin of the PR 3 recompile
    bug).  Use ``jnp.where`` / ``lax.cond`` / ``lax.while_loop``, or
    mark the argument static.
    """
    static_calls = _shape_only_functions(mod)
    for mod_fn in mod.functions.values():
        if not mod_fn.in_trace:
            continue
        tracers = _tracer_params(mod_fn)
        if not tracers:
            continue
        for node in _own_body_walk(mod, mod_fn):
            if not isinstance(node, (ast.If, ast.While)):
                continue
            if _is_static_expr(node.test, tracers, static_calls):
                continue
            kind = "if" if isinstance(node, ast.If) else "while"
            offenders = sorted(_names_in(node.test) & tracers)
            yield _finding(
                "TL003", mod, node,
                f"Python '{kind}' on traced value(s) "
                f"{', '.join(offenders)} inside '{mod_fn.qualname}' — "
                "this concretizes the tracer (or bakes in one branch); "
                "use jnp.where/lax.cond, or declare the argument in "
                "static_argnames", mod_fn)


# ---------------------------------------------------------------------------
# TL004 — loop-varying shapes flowing into jitted calls
# ---------------------------------------------------------------------------

def check_tl004(mod: ModuleInfo, graph: CallGraph) -> Iterable[Finding]:
    """TL004: jit call sites fed per-iteration shapes.

    The PR 3 bug: ``_scbf_pass`` is jitted on shapes, and a raw
    participant axis recompiled it on nearly every round once P varied.
    Heuristic: inside a ``for``/``while`` body, a call to a known
    jit-wrapped symbol whose arguments slice with loop-varying bounds
    (directly, or through a local assigned from such a slice) compiles
    once per distinct extent — pad to static buckets
    (repro.fed.cohort.bucket_size) or mark the extent static.
    """
    for node in ast.walk(mod.tree):
        if not isinstance(node, (ast.For, ast.While)):
            continue
        fn = _enclosing(mod, graph, node)
        if fn is not None and fn.in_trace:
            continue                        # in-trace loops are lax-land
        loop_vars = _loop_varying_names(node)
        shapey_locals = _loop_varying_sliced_locals(node, loop_vars)
        for call in ast.walk(node):
            if not isinstance(call, ast.Call):
                continue
            callee = dotted_name(call.func)
            if callee is None or not _is_jitted_symbol(mod, graph, callee):
                continue
            bad = _varying_shape_args(call, loop_vars, shapey_locals)
            if bad:
                yield _finding(
                    "TL004", mod, call,
                    f"jitted '{callee}' called with argument shape(s) "
                    f"that vary per iteration ({', '.join(sorted(bad))}) "
                    "— jit is shape-keyed, so each distinct extent "
                    "recompiles; pad to a static bucket "
                    "(fed.cohort.bucket_size) or hoist the slice",
                    fn)


def _loop_varying_names(loop: ast.AST) -> Set[str]:
    """Loop targets plus names assigned inside the loop body."""
    out: Set[str] = set()
    if isinstance(loop, ast.For):
        out |= _names_in(loop.target)
    for node in ast.walk(loop):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                out |= _names_in(t)
        elif isinstance(node, ast.AugAssign):
            out |= _names_in(node.target)
    return out


def _slice_varies(sub: ast.Subscript, loop_vars: Set[str]) -> bool:
    sl = sub.slice
    if not isinstance(sl, ast.Slice):
        return False
    # canonical fixed-stride stream `x[i:i + B]` with loop-invariant B:
    # the OFFSET varies but the extent does not, so jit sees the same
    # shape every iteration (plus at most one clamped tail) — not the
    # PR 3 recompile shape, where the extent itself varies
    if isinstance(sl.lower, ast.Name) and sl.lower.id in loop_vars and \
            isinstance(sl.upper, ast.BinOp) and \
            isinstance(sl.upper.op, ast.Add) and \
            isinstance(sl.upper.left, ast.Name) and \
            sl.upper.left.id == sl.lower.id and \
            not (_names_in(sl.upper.right) & loop_vars) and \
            (sl.step is None or not (_names_in(sl.step) & loop_vars)):
        return False
    for bound in (sl.lower, sl.upper, sl.step):
        if bound is not None and (_names_in(bound) & loop_vars):
            return True
    return False


def _loop_varying_sliced_locals(loop: ast.AST,
                                loop_vars: Set[str]) -> Set[str]:
    """Locals assigned (in the loop body) from a loop-varying slice."""
    out: Set[str] = set()
    for node in ast.walk(loop):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Subscript) and \
                        _slice_varies(sub, loop_vars):
                    out.add(node.targets[0].id)
    return out


def _varying_shape_args(call: ast.Call, loop_vars: Set[str],
                        shapey_locals: Set[str]) -> Set[str]:
    bad: Set[str] = set()
    for arg in list(call.args) + [kw.value for kw in call.keywords]:
        for sub in ast.walk(arg):
            if isinstance(sub, ast.Subscript) and \
                    _slice_varies(sub, loop_vars):
                base = dotted_name(sub.value) or "<expr>"
                bad.add(f"{base}[...]")
            if isinstance(sub, ast.Name) and sub.id in shapey_locals:
                bad.add(sub.id)
    return bad


# ---------------------------------------------------------------------------
# TL005 — pallas_call contract checks
# ---------------------------------------------------------------------------

def check_tl005(mod: ModuleInfo, graph: CallGraph) -> Iterable[Finding]:
    """TL005: statically-checkable ``pallas_call`` contract breaches.

    A BlockSpec index map must take one argument per grid axis and
    return one coordinate per block-shape axis; a mismatch compiles to
    garbage indexing (or a shape error deep inside Pallas) rather than
    failing at the call site.  Checked whenever the grid is a literal
    tuple (or a local assigned one) — rank is known even when the
    entries are expressions.
    """
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        callee = dotted_name(node.func)
        resolved = mod.resolve(callee) if callee else None
        if resolved is None or not resolved.endswith("pallas_call"):
            continue
        fn = _enclosing(mod, graph, node)
        grid_rank = _grid_rank(node, fn)
        specs: List[ast.Call] = []
        for kw in node.keywords:
            if kw.arg in ("in_specs", "out_specs"):
                vals = kw.value.elts if isinstance(
                    kw.value, (ast.List, ast.Tuple)) else [kw.value]
                for v in vals:
                    if isinstance(v, ast.Call) and \
                            (dotted_name(v.func) or "").endswith(
                                "BlockSpec"):
                        specs.append(v)
        for spec in specs:
            block_shape = spec.args[0] if spec.args else None
            index_map = spec.args[1] if len(spec.args) > 1 else None
            block_rank = len(block_shape.elts) if isinstance(
                block_shape, (ast.Tuple, ast.List)) else None
            if isinstance(index_map, ast.Lambda):
                arity = len(index_map.args.args)
                if grid_rank is not None and arity != grid_rank:
                    yield _finding(
                        "TL005", mod, spec,
                        f"BlockSpec index map takes {arity} argument(s) "
                        f"but the grid has {grid_rank} axis/axes — the "
                        "index map is called with one program id per "
                        "grid axis", fn)
                ret = index_map.body
                ret_rank = len(ret.elts) if isinstance(
                    ret, (ast.Tuple, ast.List)) else 1
                if block_rank is not None and ret_rank != block_rank:
                    yield _finding(
                        "TL005", mod, spec,
                        f"BlockSpec block shape has {block_rank} "
                        f"axis/axes but its index map returns "
                        f"{ret_rank} coordinate(s) — every block axis "
                        "needs exactly one index", fn)


def _grid_rank(call: ast.Call, fn: Optional[FunctionInfo]) -> Optional[int]:
    grid = None
    for kw in call.keywords:
        if kw.arg == "grid":
            grid = kw.value
    if grid is None:
        return None
    if isinstance(grid, (ast.Tuple, ast.List)):
        return len(grid.elts)
    if isinstance(grid, ast.Name) and fn is not None:
        # resolve a local `grid = (...)` assignment in the same function
        for node in ast.walk(fn.node):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                    isinstance(node.targets[0], ast.Name) and \
                    node.targets[0].id == grid.id and \
                    isinstance(node.value, (ast.Tuple, ast.List)):
                return len(node.value.elts)
    if isinstance(grid, ast.Constant) and isinstance(grid.value, int):
        return 1
    return None


# ---------------------------------------------------------------------------
# TL006 — device transfers inside host loops
# ---------------------------------------------------------------------------

def check_tl006(mod: ModuleInfo, graph: CallGraph) -> Iterable[Finding]:
    """TL006: per-iteration device→host transfers in host loops.

    The fused round loop exists because per-round host crossings
    (device_get, np.asarray of jitted outputs, .item()) serialize
    dispatch against device completion.  Inside ``for``/``while``
    bodies of host functions, each such call is one sync per iteration
    — batch them at chunk boundaries (the ``emit_fused_payloads``
    pattern).  Comprehensions are exempt: a single post-loop gather is
    the recommended fix, not a finding.
    """
    for node in ast.walk(mod.tree):
        if not isinstance(node, (ast.For, ast.While)):
            continue
        fn = _enclosing(mod, graph, node)
        if fn is not None and fn.in_trace:
            continue
        for call in _loop_body_calls(node):
            cname = dotted_name(call.func)
            resolved = mod.resolve(cname) if cname else None
            if resolved in _DEVICE_GET:
                yield _finding(
                    "TL006", mod, call,
                    "jax.device_get inside a host loop syncs every "
                    "iteration — accumulate on device and transfer "
                    "once at the chunk boundary", fn)
            elif resolved in _NP_MATERIALIZE and call.args:
                inner = call.args[0]
                if isinstance(inner, ast.Call):
                    inner_name = dotted_name(inner.func)
                    if inner_name is not None and \
                            _is_jitted_symbol(mod, graph, inner_name):
                        yield _finding(
                            "TL006", mod, call,
                            f"{cname}() of the jitted call "
                            f"'{inner_name}(...)' inside a host loop "
                            "transfers per iteration — keep results on "
                            "device and gather once after the loop", fn)
            elif isinstance(call.func, ast.Attribute) and \
                    call.func.attr == "block_until_ready" and \
                    not call.args:
                yield _finding(
                    "TL006", mod, call,
                    "block_until_ready() inside a host loop serializes "
                    "dispatch per iteration — block once after the "
                    "loop (or only around timed sections)", fn)


def _loop_body_calls(loop: ast.AST) -> Iterable[ast.Call]:
    """Calls in the loop body, skipping comprehensions and nested defs."""
    skip = (ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp,
            ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)

    def walk(node):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, skip):
                continue
            if isinstance(child, ast.Call):
                yield child
            yield from walk(child)

    yield from walk(loop)


ALL_RULES = {
    "TL001": check_tl001,
    "TL002": check_tl002,
    "TL003": check_tl003,
    "TL004": check_tl004,
    "TL005": check_tl005,
    "TL006": check_tl006,
}
