"""shapelint CLI — the padding/shape/dtype discipline lint gate.

    PYTHONPATH=src python -m repro.analysis.shapelint \
        src benchmarks examples --baseline analysis/shape_baseline.json

Runs the abstract shape/dtype/padding-provenance interpretation
(``repro.analysis.shapes`` with the policy in
``repro.analysis.shaperules``) over the call graph and reports
SL001–SL006 findings.  Exit status 0 when every finding is suppressed
in source (``# shapelint: disable=SLxxx``) or recorded in the committed
baseline with a justification; 1 when new findings exist (the CI gate);
2 on usage errors.  Pure ``ast`` — nothing under the scanned paths is
imported or executed, so the gate needs no JAX backend.

    --json-out FILE      machine-readable findings (new + baselined)
    --write-baseline     accept the current findings as the baseline
                         (existing justifications are preserved)
    --list-baseline      print the accepted findings and exit
    --rules SL001,SL004  run a subset of rules
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional, Sequence, Tuple

from repro.analysis import astgraph, shaperules
from repro.analysis.config import (DEFAULT_PATHS, DEFAULT_SHAPE_BASELINE,
                                   SOURCE_ROOTS)
from repro.analysis.report import (Baseline, Finding, assign_ordinals,
                                   decorator_regions, json_report,
                                   render_report, suppressed)


def run_paths(paths: Sequence[str],
              rules: Optional[Sequence[str]] = None,
              source_roots: Sequence[str] = SOURCE_ROOTS,
              ) -> Tuple[List[Finding], int]:
    """Lint ``paths``; returns (unsuppressed findings, files scanned)."""
    graph = astgraph.build_graph(tuple(paths), roots=source_roots)
    raw = shaperules.run_shape_rules(graph, rules=rules)
    findings: List[Finding] = []
    regions_by_path = {
        mod.path: (decorator_regions(mod.tree), mod.source_lines)
        for mod in graph.modules.values()}
    for f in raw:
        regions, source_lines = regions_by_path.get(f.path, (None, ()))
        if not suppressed(f, source_lines, regions):
            findings.append(f)
    return assign_ordinals(findings), len(graph.modules)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="shapelint",
        description="abstract shape/dtype/padding-provenance analysis "
                    "for the bucketed & fused federation paths")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help=f"files/directories to lint "
                         f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--baseline", default=DEFAULT_SHAPE_BASELINE,
                    help="committed accepted-findings file "
                         f"(default: {DEFAULT_SHAPE_BASELINE}; "
                         f"pass '' for none)")
    ap.add_argument("--json-out", default=None,
                    help="write a machine-readable report to this file")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept current findings into the baseline")
    ap.add_argument("--list-baseline", action="store_true",
                    help="print the baseline entries and exit")
    ap.add_argument("--rules", default=None,
                    help="comma-separated subset (e.g. SL001,SL004)")
    args = ap.parse_args(argv)

    baseline_path = args.baseline or None
    try:
        baseline = Baseline.load(baseline_path)
    except (ValueError, json.JSONDecodeError) as e:
        print(f"shapelint: bad baseline: {e}", file=sys.stderr)
        return 2

    if args.list_baseline:
        for key, rec in sorted(baseline.entries.items()):
            just = rec.get("justification", "")
            print(f"{key}\n    {just}" if just else key)
        print(f"{len(baseline.entries)} baselined finding(s)")
        return 0

    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
    try:
        findings, files_scanned = run_paths(args.paths, rules=rules)
    except ValueError as e:
        print(f"shapelint: {e}", file=sys.stderr)
        return 2

    new, accepted, stale = baseline.split(findings)

    if args.write_baseline:
        if baseline_path is None:
            print("shapelint: --write-baseline needs --baseline",
                  file=sys.stderr)
            return 2
        baseline.write(baseline_path, findings)
        print(f"shapelint: wrote {len(findings)} finding(s) to "
              f"{baseline_path} — fill in any TODO justifications")
        return 0

    if args.json_out:
        with open(args.json_out, "w", encoding="utf-8") as f:
            json.dump(json_report(new, accepted, stale, files_scanned),
                      f, indent=1)
            f.write("\n")

    print(render_report(new, accepted, stale, baseline_path,
                        files_scanned, tool="shapelint"))
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
