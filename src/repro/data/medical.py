"""Synthetic medical cohort matching the paper's private dataset shape.

Paper §2.2: 30,760 admissions, 2,917 distinct medicines, binary feature =
"patient took medicine m after admission", binary label = mortality
(alive/expired).  60% train / 10% validation / 30% test; the training set
is split equally into 5 local client datasets.

The hospital data is private, so this module *simulates the data gate*:

* medicine popularity follows a power law (a few very common drugs, a
  long tail), mean ~7 medicines per admission — typical of EHR medication
  tables;
* mortality comes from a planted sparse logistic model: ~150 medicines
  carry non-zero risk weights (some protective, some high-risk — e.g.
  pressors / comfort-care drugs correlate strongly with death in real
  cohorts), plus a handful of pairwise interactions and label noise;
* the weight scale is calibrated so a small MLP reaches AUC-ROC ≈ 0.97-0.98,
  the paper's operating regime, making the SCBF-vs-FedAvg comparison
  meaningful.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Tuple

import numpy as np


@dataclass
class MedicalCohort:
    x_train: np.ndarray
    y_train: np.ndarray
    x_val: np.ndarray
    y_val: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def num_features(self) -> int:
        return self.x_train.shape[1]


def generate_cohort(num_admissions: int = 30760,
                    num_medicines: int = 2917,
                    num_risk_medicines: int = 150,
                    num_interactions: int = 30,
                    mean_meds: float = 7.0,
                    label_noise: float = 0.01,
                    signal_scale: float = 3.0,
                    seed: int = 0) -> MedicalCohort:
    """Generate the synthetic cohort (numpy; this is a host-side pipeline)."""
    rng = np.random.default_rng(seed)

    # power-law medicine popularity, scaled to the target mean count
    pop = rng.pareto(1.2, size=num_medicines) + 1e-3
    pop = pop / pop.sum() * mean_meds
    pop = np.clip(pop, 0.0, 0.6)

    x = (rng.random((num_admissions, num_medicines)) < pop[None, :])
    x = x.astype(np.float32)

    # planted sparse logistic risk model — risk concentrates on *popular*
    # medicines (as in real EHR cohorts: pressors, opioids, comfort-care
    # drugs are both common and strongly mortality-associated), so the
    # signal actually fires on most admissions
    num_risk_medicines = min(num_risk_medicines, num_medicines // 2)
    risk_p = pop / pop.sum()
    risk_idx = rng.choice(num_medicines, size=num_risk_medicines,
                          replace=False, p=risk_p)
    w = np.zeros(num_medicines, dtype=np.float32)
    w[risk_idx] = rng.normal(0.0, 2.5, size=num_risk_medicines)

    logits = x @ w
    # pairwise interactions among risk medicines
    for _ in range(num_interactions):
        i, j = rng.choice(risk_idx, size=2, replace=False)
        coef = rng.normal(0.0, 3.0)
        logits += coef * x[:, i] * x[:, j]
    logits += rng.normal(0.0, 0.3, size=num_admissions)   # unobserved factors
    # center so mortality prevalence is realistic-ish but balanced enough
    # for stable AUC-PR (paper's AUC-PR ~0.97 implies a fairly balanced set)
    logits -= np.median(logits)
    # sharpen: push p towards 0/1 so the Bayes ceiling matches the paper's
    # ~0.98 AUC operating regime (label_noise below keeps it from being 1.0)
    logits *= signal_scale
    p = 1.0 / (1.0 + np.exp(-logits))
    y = (rng.random(num_admissions) < p).astype(np.float32)
    flip = rng.random(num_admissions) < label_noise
    y = np.where(flip, 1.0 - y, y)

    # 60 / 10 / 30 split (paper §2.2)
    perm = rng.permutation(num_admissions)
    n_train = int(0.6 * num_admissions)
    n_val = int(0.1 * num_admissions)
    tr, va, te = np.split(perm, [n_train, n_train + n_val])
    return MedicalCohort(
        x_train=x[tr], y_train=y[tr],
        x_val=x[va], y_val=y[va],
        x_test=x[te], y_test=y[te])


def federated_split(x: np.ndarray, y: np.ndarray, num_clients: int = 5,
                    seed: int = 0) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Equally divide the training set into ``num_clients`` local sets."""
    rng = np.random.default_rng(seed + 1)
    perm = rng.permutation(x.shape[0])
    n = (x.shape[0] // num_clients) * num_clients
    idx = np.split(perm[:n], num_clients)
    return [(x[i], y[i]) for i in idx]


def dirichlet_split(x: np.ndarray, y: np.ndarray, num_clients: int = 5,
                    alpha: float = 0.5, seed: int = 0,
                    min_per_client: int = 1
                    ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Label-skew Dirichlet partition — heterogeneous hospital silos.

    For each label class, client shares are drawn from Dir(alpha·1_K)
    and the class's examples are dealt out accordingly: small ``alpha``
    gives strongly non-IID silos (each hospital dominated by one
    outcome), large ``alpha`` recovers ~IID.  Every training example is
    assigned to exactly one client (examples are conserved); shards are
    topped up from the largest shard so none ends below
    ``min_per_client``.
    """
    if num_clients < 1:
        raise ValueError("num_clients must be >= 1")
    rng = np.random.default_rng(seed + 1)
    y = np.asarray(y).reshape(-1)
    shards: List[List[np.ndarray]] = [[] for _ in range(num_clients)]
    for c in np.unique(y):
        idx = np.flatnonzero(y == c)
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(num_clients, alpha))
        cuts = (np.cumsum(props)[:-1] * idx.size).astype(np.int64)
        for k, part in enumerate(np.split(idx, cuts)):
            shards[k].append(part)
    parts = [np.concatenate(s) if s else np.array([], dtype=np.int64)
             for s in shards]
    # rebalance: extreme alpha can leave a client empty, which no real
    # deployment (and no padded cohort) can represent
    for k in range(num_clients):
        while parts[k].size < min_per_client:
            donor = int(np.argmax([p.size for p in parts]))
            parts[k] = np.append(parts[k], parts[donor][-1])
            parts[donor] = parts[donor][:-1]
    out = []
    for p in parts:
        rng.shuffle(p)
        out.append((x[p], y[p]))
    return out


def batch_iterator(x: np.ndarray, y: np.ndarray, batch_size: int,
                   seed: int = 0, shuffle: bool = True
                   ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """One epoch of minibatches (drops the ragged tail)."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(x.shape[0]) if shuffle else np.arange(x.shape[0])
    for start in range(0, x.shape[0] - batch_size + 1, batch_size):
        sel = order[start:start + batch_size]
        yield x[sel], y[sel]
