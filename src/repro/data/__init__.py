from repro.data.medical import (
    MedicalCohort, generate_cohort, federated_split, dirichlet_split,
    batch_iterator)
from repro.data.tokens import synthetic_lm_batch, SyntheticTokenStream
