"""Synthetic LM token pipeline for the transformer examples/smoke tests.

Generates structured (learnable) token streams: a first-order Markov chain
over the vocabulary with a few high-probability transitions, so a small
LM's loss visibly decreases within a few hundred steps.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


def synthetic_lm_batch(batch: int, seq: int, vocab: int, seed: int = 0
                       ) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    # sticky Markov structure: token t+1 = (t * a + b) mod vocab w.p. 0.8
    a, b = 31, 17
    toks = np.empty((batch, seq + 1), dtype=np.int32)
    toks[:, 0] = rng.integers(0, vocab, size=batch)
    follow = rng.random((batch, seq)) < 0.8
    noise = rng.integers(0, vocab, size=(batch, seq))
    for t in range(seq):
        det = (toks[:, t] * a + b) % vocab
        toks[:, t + 1] = np.where(follow[:, t], det, noise[:, t])
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class SyntheticTokenStream:
    """Infinite iterator of synthetic LM batches."""

    def __init__(self, batch: int, seq: int, vocab: int, seed: int = 0):
        self.batch, self.seq, self.vocab = batch, seq, vocab
        self.seed = seed
        self._step = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        out = synthetic_lm_batch(self.batch, self.seq, self.vocab,
                                 seed=self.seed + self._step)
        self._step += 1
        return out
