"""SGD (optionally with momentum), as an (init, update) pair.

Gradient transformations follow the optax convention:
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p,
        params, updates)


def sgd(learning_rate, momentum: float = 0.0) -> Optimizer:
    lr_fn = learning_rate if callable(learning_rate) else (lambda _: learning_rate)

    def init(params):
        if momentum == 0.0:
            return {"step": jnp.zeros((), jnp.int32)}
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree_util.tree_map(
                lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
        }

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr = lr_fn(step)
        if momentum == 0.0:
            updates = jax.tree_util.tree_map(
                lambda g: -lr * g.astype(jnp.float32), grads)
            return updates, {"step": step}
        mu = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(jnp.float32),
            state["mu"], grads)
        updates = jax.tree_util.tree_map(lambda m: -lr * m, mu)
        return updates, {"step": step, "mu": mu}

    return Optimizer(init, update)
