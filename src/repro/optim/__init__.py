"""Pure-JAX optimizers (no optax in this environment)."""
from repro.optim.sgd import sgd
from repro.optim.adam import adam, adamw
from repro.optim.schedules import constant, cosine_decay, linear_warmup_cosine

from repro.config import TrainConfig


def from_config(cfg: TrainConfig):
    if cfg.optimizer == "sgd":
        return sgd(cfg.learning_rate, momentum=cfg.momentum)
    if cfg.optimizer == "adam":
        return adam(cfg.learning_rate)
    if cfg.optimizer == "adamw":
        return adamw(cfg.learning_rate, weight_decay=cfg.weight_decay)
    raise ValueError(f"unknown optimizer {cfg.optimizer!r}")
