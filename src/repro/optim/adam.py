"""Adam / AdamW in pure JAX (fp32 moments, bias-corrected)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.sgd import Optimizer


def adam(learning_rate, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    return adamw(learning_rate, b1=b1, b2=b2, eps=eps, weight_decay=0.0)


def adamw(learning_rate, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    lr_fn = learning_rate if callable(learning_rate) else (lambda _: learning_rate)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
        }

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr = lr_fn(step)
        f32 = lambda g: g.astype(jnp.float32)
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * f32(g), state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(f32(g)),
            state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            u = -lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay and p is not None:
                u = u - lr * weight_decay * p.astype(jnp.float32)
            return u

        if params is None:
            updates = jax.tree_util.tree_map(
                lambda m_, v_: upd(m_, v_, None), m, v)
        else:
            updates = jax.tree_util.tree_map(upd, m, v, params)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)
