"""Channel selection + upload accounting — paper §2.1 "Sort Norms" /
"Process Gradients" / "Update Server" steps.

``select_gradients`` is the full paper pipeline for the MLP family:
layer scores → α-quantile threshold → exact edge masks → masked gradients.
``upload_stats`` turns masks into the paper's §3 communication numbers
(fraction of parameters revealed; bytes for dense vs. sparse encodings).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import wire
from repro.core import channels


@dataclass
class UploadStats:
    uploaded_params: int          # non-zero gradient entries uploaded
    total_params: int
    dense_bytes: int              # dense exchange (what FedAvg ships)
    sparse_bytes: int             # cheapest wire encoding (repro.comm.wire)
    upload_fraction: float

    @classmethod
    def from_masks(cls, masks: Sequence[dict]) -> "UploadStats":
        """Accounting from boolean masks; byte math delegates to
        ``repro.comm.wire`` so ``sparse_bytes <= dense_bytes`` holds by
        construction (cheapest of coo/bitmap/dense per mask array).
        ``None`` entries (e.g. bias masks of bias-free layers) cost
        nothing — they correspond to no transmitted tensor.
        """
        up, total, sparse = 0, 0, 0
        for m in masks:
            for v in m.values():
                if v is None:
                    continue
                # host numpy on purpose: masks arrive per client, and the
                # batched engine calls this K times per round — a device
                # reduction per mask would serialise the host loop
                nnz, size = int(np.sum(np.asarray(v))), int(v.size)
                up += nnz
                total += size
                sparse += wire.cheapest_bytes(nnz, size, itemsize=4)[1]
        dense = total * 4
        return cls(up, total, dense, sparse, up / max(total, 1))


def select_gradients(grads: Sequence[dict], upload_rate: float,
                     selection: str = "positive",
                     key: jax.Array | None = None,
                     score_norm: bool = False,
                     neuron_masks=None
                     ) -> Tuple[list, list, jnp.ndarray]:
    """The paper's channel-selection pipeline for MLP gradients.

    positive: upload channels with norm above the (1-α)-quantile (top α).
    negative: discard channels below the α-quantile (upload the top 1-α).

    ``neuron_masks`` (mask-mode SCBFwP): per-hidden-layer keep-masks.
    Pruned neurons score ``-inf`` (channels.layer_scores), the quantile
    ranks the effective channel population only, and the edge rule can
    never select an edge through a pruned neuron — all at static shape,
    so the selection of a masked-pruned model matches a
    physically-compacted one.

    Returns (masked_grads, masks, threshold).
    """
    scores = channels.layer_scores(grads, normalize=score_norm,
                                   neuron_masks=neuron_masks)
    thr = channels.channel_quantile(scores, upload_rate,
                                    selection=selection, key=key,
                                    masked=neuron_masks is not None)
    masked, masks = channels.apply_channel_mask(grads, scores, thr)
    return masked, masks, thr


def tree_sub(a, b):
    """Gradient pytree a - b (the paper's G = W_after - W_before)."""
    return jax.tree_util.tree_map(lambda x, y: x - y, a, b)


def tree_add(a, b):
    return jax.tree_util.tree_map(lambda x, y: x + y, a, b)


def tree_scale(a, c):
    return jax.tree_util.tree_map(lambda x: x * c, a)


def tree_zeros_like(a):
    return jax.tree_util.tree_map(jnp.zeros_like, a)
