"""The paper's contribution: Stochastic Channel-Based Federated Learning."""
from repro.core import channels, pruning, selection
from repro.core.scbf import LoopRecord, RunResult, run_federated
from repro.core.fedavg import run_fedavg
from repro.core.server import fedavg_update, scbf_update
from repro.core.client import client_delta, local_train
from repro.core import privacy
