"""SCBF / SCBFwP orchestrator — the paper's Algorithm 1, faithfully.

One ``global loop``:
  1. the round scheduler picks the reporting cohort (full participation
     reproduces the paper; sampling / dropout / stragglers / buffered
     async are the cross-device scenarios of repro.fed.scheduler);
  2. the cohort engine trains every participant and channel-selects its
     delta (top-α channels by norm) — as one vmapped XLA program
     (repro.fed.engine.BatchedEngine) or the reference per-client loop;
  3. the aggregation strategy folds the uploads into the server:
     W <- W + Σ_k ΔW̃_k for SCBF (repro.fed.strategy);
  4. (SCBFwP) while the cumulative pruned fraction is below θ_total,
     prune θ of the server's hidden neurons by APoZ on the validation
     set and push the pruned structure to all clients;
  5. evaluate AUC-ROC / AUC-PR on the test set.

``run_federated`` is a thin driver over those three pluggable parts: it
owns PRNG-key derivation (so engine choice never changes the random
stream), the lr schedule (precomputed as a host-side table — no
per-loop device sync), differential privacy on the upload path
(optionally with subsampled-RDP amplification), and the per-loop
records with the communication accounting used by EXPERIMENTS.md
(§Paper-validation) and benchmarks/fig2.

With ``FedConfig.fuse_rounds = S > 1`` (sync mode, batched engine) the
driver switches to the **fused round loop** (``_run_fused``): S rounds
are pre-planned into one static device program — train → delta →
select → DP → on-device aggregation inside a single ``lax.scan`` — and
the trajectory stays bit-identical to the per-round path while
evaluation coarsens to chunk boundaries (docs/FED_ENGINE.md §Fused
round loop).  SCBFwP runs fused too when
``ScbfConfig.prune_impl = "mask"``: pruning becomes a static-shape
keep-mask (repro.core.pruning.Pruner) so geometry stays run-constant —
per-prune-epoch chunk splits, on-device APoZ at chunk boundaries, an
optional one-shot compaction when the budget is exhausted, and <= 2
fused compiles per run (docs/FED_ENGINE.md §Pruning on the fused
path).  Reshape-mode pruning keeps the per-round path.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ScbfConfig, TrainConfig
from repro.core import privacy, pruning
from repro.data.medical import MedicalCohort, dirichlet_split, federated_split
from repro.metrics.auc import auc_pr, auc_roc
from repro.models.mlp_net import init_mlp, mlp_forward
from repro.obs import checks as obschecks
from repro.obs import metrics as obsm
from repro.obs import trace as obstrace
from repro.optim import schedules


@dataclass
class LoopRecord:
    loop: int
    auc_roc: float               # last-known when evaluated=False
    auc_pr: float
    upload_fraction: float       # fraction of params revealed this loop
    sparse_bytes: int            # what SCBF actually ships
    dense_bytes: int             # what FedAvg would ship for the same model
    wall_time: float             # seconds for the loop (train+select+update)
    flops_proxy: float           # ~params * examples (pruning shrinks this)
    hidden_sizes: Tuple[int, ...] = ()
    num_participants: int = 0    # clients whose updates arrived this loop
    epsilon: Optional[float] = None   # cumulative DP ε (None: DP off)
    # False when this loop skipped evaluation (TrainConfig.eval_every,
    # or a fused loop that is not a chunk boundary): auc_roc/auc_pr then
    # carry the most recent evaluation so figures stay well-defined
    evaluated: bool = True
    # set only under ScbfConfig.dp_amplification: ``epsilon`` is then
    # the tighter of the subsampled-amplified and unamplified bounds
    # (both are valid) and this keeps the unamplified one for reference
    epsilon_unamplified: Optional[float] = None
    # mean per-participant train loss from the on-device telemetry
    # (repro.obs; None when collection was off this run)
    train_loss: Optional[float] = None
    # True on the fused path: ``wall_time`` is chunk wall / rounds — a
    # fair amortized figure, NOT a per-round measurement (the S rounds
    # ran as one device program, so no per-round wall exists)
    wall_is_amortized: bool = False


@dataclass
class RunResult:
    method: str
    records: List[LoopRecord] = field(default_factory=list)
    dp_delta: Optional[float] = None  # δ of the reported (ε, δ); None: DP off
    final_params: Optional[Tuple] = None  # the trained global model
    # flight-recorder watchdogs (repro.obs): compile-count deltas, span
    # and host-offload counters — populated only when the run executed
    # under an active ``obs.trace.recording`` (None otherwise)
    telemetry: Optional[dict] = None

    @property
    def final(self) -> LoopRecord:
        return self.records[-1]

    @property
    def final_epsilon(self) -> Optional[float]:
        """Cumulative (ε, δ)-DP ε spent over the whole run (None: DP off)."""
        return self.records[-1].epsilon if self.records else None

    def best(self, key: str = "auc_roc") -> float:
        return max(getattr(r, key) for r in self.records)

    def total_time(self) -> float:
        return sum(r.wall_time for r in self.records)

    def total_upload_bytes(self) -> int:
        return sum(r.sparse_bytes for r in self.records)


# module-level jit so every _evaluate call shares one compilation cache
# (a per-call jax.jit(...) wrapper recompiled on every evaluation)
_mlp_forward_jit = jax.jit(mlp_forward)


def _evaluate(params, x, y, batch: int = 8192, neuron_masks=None):
    with obstrace.span("eval", examples=int(x.shape[0])):
        scores = []
        for s in range(0, x.shape[0], batch):
            scores.append(np.asarray(_mlp_forward_jit(
                tuple(params), jnp.asarray(x[s:s + batch]), neuron_masks)))
        sc = jnp.asarray(np.concatenate(scores))
        yy = jnp.asarray(y)
        return float(auc_roc(sc, yy)), float(auc_pr(sc, yy))


def _compile_counts():
    """(scbf, fused) jit-cache sizes for the run_end watchdog delta.

    None when the pinned-jax introspection hook is unavailable — the
    flight recorder then simply omits the compile counters rather than
    failing a training run over a diagnostics read.
    """
    from repro.fed.engine import fused_compile_count, scbf_compile_count
    try:
        return scbf_compile_count(), fused_compile_count()
    except RuntimeError:
        return None


def _finish_telemetry(result: RunResult, counts0) -> None:
    """Fold recorder counters + compile deltas into ``RunResult`` and
    emit the closing ``run_end`` event (no-op when not recording)."""
    rec = obstrace.get_recorder()
    if rec is None:
        return
    tel = dict(rec.counters)
    counts1 = _compile_counts()
    if counts0 is not None and counts1 is not None:
        tel["scbf_compiles"] = counts1[0] - counts0[0]
        tel["fused_compiles"] = counts1[1] - counts0[1]
    result.telemetry = tel
    rec.event("run_end", **tel)


def _partition(cohort: MedicalCohort, train_cfg: TrainConfig):
    fed = train_cfg.fed
    if fed.partition == "dirichlet":
        return dirichlet_split(cohort.x_train, cohort.y_train,
                               train_cfg.scbf.num_clients,
                               alpha=fed.dirichlet_alpha,
                               seed=train_cfg.seed)
    if fed.partition == "iid":
        return federated_split(cohort.x_train, cohort.y_train,
                               train_cfg.scbf.num_clients,
                               seed=train_cfg.seed)
    raise ValueError(f"unknown partition {fed.partition!r}; iid|dirichlet")


def _lr_schedule(train_cfg: TrainConfig):
    if train_cfg.lr_schedule == "cosine":
        return schedules.cosine_decay(train_cfg.learning_rate,
                                      max(train_cfg.global_loops - 1, 1))
    return schedules.constant(train_cfg.learning_rate)


def _lr_table(train_cfg: TrainConfig) -> np.ndarray:
    """Host-side lr table for the whole run, one device dispatch total.

    The loop used to ``float(lr_fn(...))`` every round — a device→host
    sync on the hot path.  Evaluating the schedule vmapped once kills
    that, and the same table slices into the fused path's (S,) lr
    array, so both paths read identical f32 values.
    """
    fn = _lr_schedule(train_cfg)
    steps = jnp.arange(max(train_cfg.global_loops, 1))
    return np.asarray(jax.vmap(fn)(steps), dtype=np.float32)


def _should_eval(loop: int, total_loops: int, eval_every: int) -> bool:
    """Evaluate every N loops, plus always the final loop."""
    return loop == total_loops - 1 or (loop + 1) % max(eval_every, 1) == 0


def _derive_round_keys(key, num_clients: int, part, P: int):
    """(next_key, ckeys, skeys, dp_keys) for one round — THE key-stream
    contract, shared by the per-round loop and the fused pre-planner so
    the two paths consume identical randomness by construction: one
    4-way split per round, training keys indexed by *client id* (so
    client k's key is independent of who else was sampled), and
    selection / DP keys split per participant.  Empty rounds still
    advance the stream (the 4-way split) but return empty key rows.
    """
    key, kc, ks, kd = jax.random.split(key, 4)
    if P:
        ckeys_all = jax.random.split(kc, num_clients)
        ckeys = ckeys_all[np.asarray(part)]
        skeys = jax.random.split(ks, P)
        dp_keys = jax.random.split(kd, P)
    else:
        empty = np.zeros((0, 2), np.uint32)
        ckeys = skeys = dp_keys = empty
    return key, ckeys, skeys, dp_keys


def run_federated(cohort: MedicalCohort,
                  train_cfg: TrainConfig,
                  method: str = "scbf",
                  mlp_features: Optional[Tuple[int, ...]] = None,
                  verbose: bool = False,
                  engine: Optional[str] = None) -> RunResult:
    """Run one federated experiment.

    method: "scbf" | "fedavg", with pruning controlled by
    ``train_cfg.scbf.prune`` (→ SCBFwP / FAwP).  ``engine`` overrides
    ``train_cfg.fed.engine`` ("batched" vmapped cohort | "sequential"
    reference loop); both consume the same PRNG stream, and for
    equal-size shards (the paper's IID split) they produce identical
    trajectories.  The batched engine buckets the per-round participant
    count (``fed.bucket``) so varying P under sampling/dropout does not
    recompile, and shards the bucketed cohort over a pod mesh when
    ``fed.pods > 1`` (docs/FED_ENGINE.md).  ``fed.fuse_rounds > 1``
    runs whole chunks of sync rounds as one device program with
    on-device aggregation (bit-identical trajectory; evaluation at
    chunk boundaries only), falling back to the per-round loop for
    reshape-mode pruning, fedbuff, or the sequential engine —
    mask-mode pruning (``scbf.prune_impl="mask"``) runs fused
    first-class.  Rounds where every
    sampled client drops out are skipped cleanly (no P=0 dispatch).
    Ragged cohorts (Dirichlet) batch differently —
    the padded engine runs ``n_max // B`` masked batches per epoch
    while the sequential loop runs ``n_k // B`` — so there the engine
    choice selects between two legitimate trainings, not two
    implementations of one (docs/FED_ENGINE.md §Caveats).
    """
    # deferred: repro.fed modules import repro.core.* at module scope, so
    # importing them here (not at module top) keeps repro.core importable
    # from either direction
    from repro.fed.clock import SimClock
    from repro.fed.engine import make_engine
    from repro.fed.faults import (FaultInjector, Resilience,
                                  apply_payload_faults)
    from repro.fed.scheduler import make_scheduler
    from repro.fed.strategy import (AdmissionPolicy, RoundContribution,
                                    admit_payloads, make_strategy)

    cfg: ScbfConfig = train_cfg.scbf
    fed = train_cfg.fed
    if method not in ("scbf", "fedavg"):
        raise ValueError(method)
    if cfg.dp_noise_multiplier > 0 and method != "scbf":
        raise ValueError("dp_noise_multiplier applies to the sparse scbf "
                         "upload path; method='fedavg' ships full weights "
                         "with no DP mechanism — refusing to run with a "
                         "privacy guarantee silently off")
    if cfg.prune and cfg.prune_impl not in ("reshape", "mask"):
        raise ValueError(f"unknown prune_impl {cfg.prune_impl!r}; "
                         "one of ('reshape', 'mask')")
    mask_prune = cfg.prune and cfg.prune_impl == "mask"
    if mask_prune and method != "scbf":
        raise ValueError("prune_impl='mask' threads neuron keep-masks "
                         "through the sparse scbf pipeline; "
                         "method='fedavg' (FAwP) prunes by reshaping — "
                         "use prune_impl='reshape'")
    if fed.mode == "fedbuff":
        if method != "scbf":
            raise ValueError("fedbuff buffers sparse scbf uploads; "
                             "method must be 'scbf'")
        if cfg.prune and not mask_prune:
            raise ValueError("reshape pruning changes shapes under "
                             "in-flight clients; fedbuff needs "
                             "prune_impl='mask' (run-constant geometry)")

    # ---- resilience configuration (repro.fed.clock / .faults) ----
    clock_on = fed.clock.enabled
    faults_on = fed.faults.enabled
    spill_mode = clock_on and fed.clock.deadline_action == "spill"
    if faults_on and method != "scbf":
        raise ValueError(
            "fault injection corrupts the sparse scbf upload pipeline; "
            "method='fedavg' ships full weight pytrees with no wire "
            "payload to corrupt — refusing a silently-inert fault plan")
    if fed.max_update_norm > 0 and method != "scbf":
        raise ValueError(
            "max_update_norm bounds sparse scbf payload norms; the "
            "fedavg path has no payload to gate — refusing to run with "
            "a configured bound silently off")
    if fed.min_valid_participants > 0 and fed.mode == "fedbuff":
        raise ValueError(
            "round-level quorum retries re-plan the round; fedbuff "
            "planning mutates in-flight client state on every plan "
            "call, so a replanned attempt would corrupt it — "
            "min_valid_participants needs sync mode")
    if spill_mode and cfg.prune:
        raise ValueError(
            "deadline spilling delivers payloads emitted against an "
            "earlier round's keep-masks; pruning changes the masks "
            "between emission and arrival, so the spilled indices "
            "would remap wrong — use deadline_action='drop' with "
            "pruning")

    feats = mlp_features or (cohort.num_features, 256, 64, 1)
    key = jax.random.PRNGKey(train_cfg.seed)
    key, init_key = jax.random.split(key)
    params = init_mlp(feats, init_key)

    clients = _partition(cohort, train_cfg)
    eng = make_engine(engine or fed.engine, clients,
                      train_cfg.local_batch_size, train_cfg.local_epochs,
                      bucket=fed.bucket, pods=fed.pods)
    clock = SimClock(cfg.num_clients, fed.clock, seed=train_cfg.seed) \
        if clock_on else None
    scheduler = make_scheduler(fed, cfg.num_clients, train_cfg.seed,
                               clock=clock)
    injector = FaultInjector(cfg.num_clients, fed.faults) \
        if faults_on else None
    # the admission gate arms whenever payloads can be hostile (fault
    # injection) or a norm bound is configured; otherwise the strategies
    # keep their zero-overhead fault-free hot path
    policy = AdmissionPolicy(max_update_norm=fed.max_update_norm,
                             norm_action=fed.norm_action) \
        if (faults_on or fed.max_update_norm > 0) else None
    strategy = make_strategy(method, cfg, fed, policy=policy)
    resil = Resilience(scheduler, clock, injector, fed)
    if fed.min_valid_participants > 0 and \
            fed.min_valid_participants > scheduler.max_participants:
        raise ValueError(
            f"min_valid_participants={fed.min_valid_participants} can "
            f"never be met: the scheduler samples at most "
            f"{scheduler.max_participants} clients per round — every "
            "round would exhaust its retries and miss quorum")
    state = strategy.init(params)
    # fedbuff only: stale version snapshots (sync trains on the current
    # params, so keeping the initial model alive would be pure waste)
    history = {0: params} if fed.mode == "fedbuff" else None
    # spill mode: round-keyed snapshots — a spilled client trains from
    # the params of the round it was sampled in, delivered rounds later
    round_history = {0: params} if spill_mode else None
    # host-side lr table: one device dispatch for the whole run instead
    # of a float() sync per loop, and the fused path's (S,) lr array
    lrs = _lr_table(train_cfg)

    if cfg.dp_noise_multiplier < 0:
        raise ValueError(
            f"dp_noise_multiplier must be >= 0, got "
            f"{cfg.dp_noise_multiplier}: the DP gate is "
            f"'dp_noise_multiplier > 0', so a negative value would "
            f"silently run without DP while looking configured")
    dp_on = method == "scbf" and cfg.dp_noise_multiplier > 0
    if dp_on:
        # fail fast on an unknown accountant or a classic-bound run
        # outside its eps <= 1 domain, not after a full training loop
        privacy.epsilon_for(cfg.dp_noise_multiplier, cfg.dp_delta,
                            loops=1, accountant=cfg.dp_accountant)
    amplify = dp_on and cfg.dp_amplification
    amp_q = 1.0
    if amplify:
        if clock_on:
            raise ValueError(
                "subsampled amplification assumes a uniform i.i.d. "
                "per-round sample; the simulated clock restricts "
                "sampling to currently-available clients (diurnal "
                "churn), which is not one — refusing to report a "
                "silently-wrong amplified ε")
        if fed.mode == "fedbuff":
            raise ValueError(
                "subsampled amplification assumes an i.i.d. per-round "
                "sample; fedbuff participation is not one — refusing to "
                "report a silently-wrong amplified ε")
        if cfg.dp_accountant != "rdp":
            raise ValueError("dp_amplification is an RDP analysis; it "
                             f"composes on the subsampled RDP curve, so "
                             f"dp_accountant={cfg.dp_accountant!r} cannot "
                             "back the reported ε — use 'rdp'")
        # q from the scheduler's own cohort-size formula, so the
        # reported amplification always matches the sampling performed
        amp_q = min(1.0, scheduler.max_participants / cfg.num_clients)
        privacy.amplified_epsilon_for(cfg.dp_noise_multiplier, amp_q,
                                      cfg.dp_delta, rounds=1)  # fail fast
    # ε composes per *release*, not per loop: under sampling, dropout or
    # fedbuff a client uploads in only some rounds, so the spend is
    # tracked per client and the worst (most-releasing) client reported.
    # (The amplified curve instead composes over rounds — every round is
    # one inclusion trial for every client.)
    dp_releases = np.zeros(cfg.num_clients, dtype=np.int64)
    pruner = None
    if cfg.prune:
        # fedbuff keeps full-geometry stale snapshots alive for its
        # in-flight clients, so the one-shot mask-mode compaction must
        # stay off there (mixed geometries could never stack)
        pruner = pruning.Pruner(
            params, cohort.x_val, prune_rate=cfg.prune_rate,
            prune_total=cfg.prune_total, impl=cfg.prune_impl,
            compact=cfg.prune_compact and fed.mode != "fedbuff")
    result = RunResult(method=method + ("wp" if cfg.prune else ""),
                       dp_delta=cfg.dp_delta if dp_on else None)

    # ---- flight recorder (repro.obs, docs/OBSERVABILITY.md) ----
    # device telemetry turns on under an active recorder or by explicit
    # config; the compile-count watchdog only samples while recording
    # (it touches jit caches, and un-recorded runs shouldn't)
    collect = train_cfg.obs.device_metrics or \
        obstrace.get_recorder() is not None
    counts0 = _compile_counts() if obstrace.get_recorder() is not None \
        else None
    obstrace.event(
        "run_start", method=result.method, loops=train_cfg.global_loops,
        clients=cfg.num_clients, engine=eng.name,
        fuse_rounds=int(fed.fuse_rounds), mode=fed.mode,
        dp_sigma=(cfg.dp_noise_multiplier * cfg.dp_clip_norm)
        if dp_on else None,
        prune=cfg.prune, prune_impl=cfg.prune_impl if cfg.prune else None)

    def _epsilons(loop: int):
        """(epsilon, epsilon_unamplified) for the record of ``loop``."""
        if not dp_on:
            return None, None
        un = privacy.epsilon_for(cfg.dp_noise_multiplier, cfg.dp_delta,
                                 loops=int(dp_releases.max()),
                                 accountant=cfg.dp_accountant)
        if amplify:
            # both accountings are valid upper bounds — amplified
            # composes over rounds, unamplified over per-client
            # releases — so report the tighter of the two: under
            # dropout with q ≈ 1 the release ledger can actually win
            # (fewer releases than rounds, no amplification to offset)
            amp = privacy.amplified_epsilon_for(
                cfg.dp_noise_multiplier, amp_q, cfg.dp_delta,
                rounds=loop + 1)
            return min(amp, un), un
        return un, None

    init_params = params
    known = {"roc": None, "pr": None}

    def _metrics(params_now, do_eval: bool, nmasks=None):
        """(auc_roc, auc_pr, evaluated) — last-known when not evaluating.

        ``nmasks`` evaluates the masked model (mask-mode SCBFwP): the
        pruned-and-masked network is the model the run is training, so
        it is the one the records must score.  Before any evaluation
        has happened the last-known model is the initial one, scored
        lazily so the default config (eval_every=1, unfused) never pays
        for it.
        """
        if do_eval:
            known["roc"], known["pr"] = _evaluate(params_now,
                                                  cohort.x_test,
                                                  cohort.y_test,
                                                  neuron_masks=nmasks)
            return known["roc"], known["pr"], True
        if known["roc"] is None:
            known["roc"], known["pr"] = _evaluate(init_params,
                                                  cohort.x_test,
                                                  cohort.y_test)
        return known["roc"], known["pr"], False

    if int(fed.fuse_rounds) < 1:
        raise ValueError(f"fuse_rounds must be >= 1, got {fed.fuse_rounds}")
    # the fused path needs: sync planning (fedbuff wants per-round server
    # feedback), static shapes (reshape pruning changes them mid-run;
    # MASK pruning keeps geometry run-constant and fuses first-class),
    # and the batched engine (there is no sequential program to fuse) —
    # anything else falls back to the per-round loop below
    use_fused = (int(fed.fuse_rounds) > 1 and fed.mode == "sync"
                 and (not cfg.prune or mask_prune)
                 and eng.name == "batched" and not spill_mode)
    if use_fused:
        # the fused path aggregates on device from per-slot admit masks
        # decided at PLAN time (repro.fed.faults); a host-side admission
        # verdict that cannot be predicted at plan time would silently
        # diverge from what the device folded in — refuse those combos
        # up front rather than diverge
        if fed.max_update_norm > 0 and not faults_on:
            raise ValueError(
                "the fused path cannot run a host-side norm gate over "
                "its on-device aggregation; arm the fault model "
                "(FaultConfig.enabled) so admission is planned, or use "
                "fuse_rounds=1")
        if faults_on and fed.max_update_norm > 0 \
                and fed.norm_action == "clip":
            raise ValueError(
                "norm_action='clip' rescales admitted payloads on the "
                "host; the fused path aggregates the raw on-device "
                "deltas, so clipping cannot take effect — use "
                "norm_action='reject' or fuse_rounds=1")
        if faults_on and fed.faults.poison_rate > 0 \
                and not (fed.max_update_norm > 0
                         and fed.norm_action == "reject"):
            raise ValueError(
                "poisoned (norm-inflated) updates are only excludable "
                "at plan time when a reject-mode norm gate is armed "
                "(max_update_norm > 0, norm_action='reject'); without "
                "one the fused path would fold poison into the model — "
                "arm the gate or use fuse_rounds=1")
        _run_fused(cohort, train_cfg, method, eng, resil, state, key,
                   lrs, dp_releases, result, _epsilons, _metrics, verbose,
                   pruner, collect, injector=injector, policy=policy)
        _finish_telemetry(result, counts0)
        return result

    prev_eps = 0.0
    for loop in range(train_cfg.global_loops):
        # one span is the loop's single wall-clock source: the region it
        # covers (schedule → train → aggregate → prune) is exactly what
        # the old hand-rolled perf_counter pair measured — evaluation
        # stays outside, as before
        with obstrace.span("round", loop=loop) as sp:
            lr = float(lrs[loop])
            ar = resil.plan_round(loop, state.version)
            plan = ar.plan
            part = plan.participants
            P = plan.num_participants
            if method == "scbf":
                # aborted quorum attempts trained and uploaded before
                # the server discarded them — their privacy spend is
                # real and must never be under-reported.  Each aborted
                # attempt is a DISTINCT (simulated) upload, so two
                # increments on this path are two releases, not one
                # double-counted — charging them is conservative in
                # exactly the direction DP accounting must err.
                for aborted in ar.aborted_arrivers:
                    if aborted.size:
                        dp_releases[np.asarray(aborted)] += 1  # privlint: disable=PL004

            key, ckeys, skeys, dp_keys = _derive_round_keys(
                key, cfg.num_clients, part, P)

            payloads, stats, dm = [], [], None
            wire_payloads = []
            if P:
                if fed.mode == "fedbuff":
                    params_for = [history[state.version - int(tau)]
                                  for tau in plan.staleness]
                elif spill_mode:
                    # spilled arrivals trained from the round they were
                    # sampled in (staleness = rounds in flight)
                    params_for = [round_history[loop - int(tau)]
                                  for tau in plan.staleness]
                else:
                    params_for = state.params
                if method == "scbf":
                    nmasks = pruner.masks if pruner is not None else None
                    keep_eff = pruner.emission_keep if pruner is not None \
                        else None
                    out = eng.scbf_round(
                        params_for, part, lr, ckeys, skeys, dp_keys, cfg,
                        nmasks=nmasks, keep=keep_eff, collect=collect)
                    (payloads, stats, dm) = out if collect else \
                        (out[0], out[1], None)
                    dp_releases[np.asarray(part)] += 1
                    wire_payloads = payloads
                    nx = eng.counts[np.asarray(part)]
                    stal = np.asarray(plan.staleness)
                    cl = np.asarray(part)
                    if injector is not None and payloads:
                        # client faults → seal → wire faults → replays
                        wire_payloads, dup_src = apply_payload_faults(
                            payloads, cl, ar.corrupt, ar.duplicated,
                            loop, ar.attempts - 1, fed.faults,
                            fed.max_update_norm)
                        if dup_src:
                            nx = np.concatenate([nx, nx[dup_src]])
                            stal = np.concatenate([stal, stal[dup_src]])
                            cl = np.concatenate([cl, cl[dup_src]])
                    # mask mode ships effective-geometry payloads whose
                    # checksums seal the wire bytes; the strategy admits
                    # on those and expands the survivors to the server's
                    # full geometry just before application
                    expand = None
                    if keep_eff is not None:
                        expand = (lambda ps, _k=keep_eff,
                                  _ref=state.params:
                                  pruning.expand_payloads(ps, _k, _ref))
                    contrib = RoundContribution(
                        num_examples=nx, staleness=stal,
                        payloads=wire_payloads, clients=cl,
                        expand=expand)
                else:
                    out = eng.fedavg_round(params_for, part, lr, ckeys,
                                           collect=collect)
                    (client_params, counts, dm) = out if collect else \
                        (out[0], out[1], None)
                    contrib = RoundContribution(
                        num_examples=counts, staleness=plan.staleness,
                        client_params=client_params,
                        clients=np.asarray(part))
                if ar.quorum_ok:
                    state = strategy.aggregate(state, contrib)
                # terminal quorum miss: the cohort trained and uploaded,
                # but the server refuses to step on a sub-quorum round
                # (the planner already emitted the quorum_miss event)
            params = state.params
            if fed.mode == "fedbuff":
                history[state.version] = params
                live = scheduler.referenced_versions() | {state.version}
                history = {v: p for v, p in history.items() if v in live}
            elif spill_mode:
                round_history[loop + 1] = params
                live = scheduler.referenced_rounds() | {loop + 1}
                round_history = {r: p for r, p in round_history.items()
                                 if r in live}

            # ---- communication accounting ----
            if method == "scbf":
                up_frac = float(np.mean([s.upload_fraction
                                         for s in stats])) if stats else 0.0
                # measured bytes of the encoded payloads (single source
                # of truth: repro.comm.wire), not a mask-count model —
                # wire_payloads includes replayed duplicates: bytes that
                # really crossed the network
                sparse_bytes = int(np.sum([p.nbytes
                                           for p in wire_payloads])) \
                    if wire_payloads else 0
                dense_bytes = int(np.sum([p.dense_nbytes
                                          for p in payloads])) \
                    if payloads else 0
            else:
                total = sum(int(np.prod(l["w"].shape))
                            + int(l["b"].shape[0]) for l in params)
                up_frac = 1.0 if P else 0.0
                dense_bytes = total * 4 * P
                sparse_bytes = dense_bytes

            # ---- pruning (SCBFwP / FAwP) ----
            if pruner is not None and pruner.active:
                # reshape: returns the compacted pytree; mask: updates
                # the keep-masks in place and returns params unchanged
                params = pruner.step(params)
                state = dataclasses.replace(state, params=params)
                obstrace.event("prune", loop=loop,
                               hidden=list(pruner.hidden_sizes()))
            if pruner is not None and pruner.should_compact:
                # mask mode, budget exhausted: one-shot compaction
                params = pruner.compact(params)
                state = dataclasses.replace(state, params=params)
                obstrace.event("compact", loop=loop,
                               hidden=list(pruner.hidden_sizes()))

        wall = sp.elapsed
        roc, pr, evaluated = _metrics(
            params, _should_eval(loop, train_cfg.global_loops,
                                 train_cfg.eval_every),
            pruner.masks if pruner is not None else None)
        eps, eps_un = _epsilons(loop)
        if pruner is not None:
            # effective model: identical whether neurons are masked,
            # compacted, or (reshape mode) physically gone
            n_params = pruner.effective_param_count(params)
            hidden = pruner.hidden_sizes()
        else:
            n_params = sum(int(np.prod(l["w"].shape)) + int(l["b"].shape[0])
                           for l in params)
            hidden = tuple(pruning.hidden_sizes(params))
        rec = LoopRecord(
            loop=loop, auc_roc=roc, auc_pr=pr,
            upload_fraction=up_frac,
            sparse_bytes=sparse_bytes, dense_bytes=dense_bytes,
            wall_time=wall,
            flops_proxy=float(n_params) * cohort.x_train.shape[0],
            hidden_sizes=hidden,
            num_participants=P,
            epsilon=eps, evaluated=evaluated, epsilon_unamplified=eps_un,
            train_loss=dm.get("train_loss") if dm else None)
        result.records.append(rec)
        if train_cfg.debug_checks:
            # host-side chunk-boundary assertions on already-offloaded
            # values; the traced program is identical either way
            obschecks.verify_round(params, dm, where=f"loop {loop}")
        obstrace.event("round", **_round_event_fields(
            rec, plan, pruner, dm, eps_step=(eps - prev_eps)
            if eps is not None else None))
        prev_eps = eps if eps is not None else 0.0
        if verbose:
            print(f"[{result.method}] loop {loop:02d} "
                  f"auc_roc={roc:.4f} auc_pr={pr:.4f} "
                  f"upload={up_frac:.2%} hidden={rec.hidden_sizes} "
                  f"clients={P} t={wall:.2f}s")
    result.final_params = params
    _finish_telemetry(result, counts0)
    return result


def _round_event_fields(rec: LoopRecord, plan, pruner, dm,
                        eps_step=None) -> dict:
    """The ``round`` event's field dict (docs/OBSERVABILITY.md schema).

    One builder for both loop shapes so the per-round and fused paths
    emit identical event structure: LoopRecord scalars + scheduler
    telemetry (sampled/dropped/stragglers/staleness) + keep-mask density
    + the on-device metrics dict when collection was on.
    """
    out = {
        "loop": rec.loop, "participants": rec.num_participants,
        "upload_fraction": round(rec.upload_fraction, 6),
        "sparse_bytes": rec.sparse_bytes, "dense_bytes": rec.dense_bytes,
        "wall": round(rec.wall_time, 6),
        "wall_is_amortized": rec.wall_is_amortized,
        "hidden": list(rec.hidden_sizes),
        "evaluated": rec.evaluated,
    }
    if rec.epsilon is not None:
        out["epsilon"] = rec.epsilon
        if eps_step is not None:
            out["epsilon_step"] = eps_step
    if pruner is not None:
        out["keep_density"] = round(
            sum(pruner.hidden_sizes()) / max(pruner.original_hidden, 1),
            6)
    if plan is not None and hasattr(plan, "telemetry"):
        out.update(plan.telemetry())
    if dm:
        for k in ("train_loss", "selected", "codec_bytes"):
            if dm.get(k) is not None:
                out[k] = dm[k]
    return out


def _run_fused(cohort: MedicalCohort, train_cfg: TrainConfig, method: str,
               eng, resil, state, key, lrs: np.ndarray,
               dp_releases: np.ndarray, result: RunResult,
               _epsilons, _metrics, verbose: bool, pruner=None,
               collect: bool = False, injector=None, policy=None) -> None:
    """The fused round loop: S sync rounds per device program.

    Each chunk is pre-planned into static (S, B) participant/validity
    arrays (``scheduler.plan_horizon`` + ``eng.prepare_fused_plan``),
    its PRNG keys pre-split from the *same stream* the per-round loop
    would consume, and its lr values sliced from the precomputed table —
    then train → delta → select → DP → on-device aggregation runs as
    one ``lax.scan`` with zero host crossings (fed/engine
    ``_fused_scbf_rounds``).  Wire encoding happens once per chunk from
    the returned (S, B) masked deltas, so per-round upload accounting is
    byte-identical to the per-round path.  Evaluation coarsens to chunk
    boundaries (docs/FED_ENGINE.md §Fused round loop).

    SCBFwP (``pruner``, always mask-mode here): geometry stays
    run-constant, the keep-mask tuple rides into each chunk as a plain
    input, and chunks shrink to single rounds while pruning is still
    removing neurons (``fused_chunk_len``) so the APoZ → mask update at
    each chunk boundary lands at exactly the per-round cadence — the
    keep-mask trajectory is the per-round loop's by construction.
    Prune-phase chunks plan at horizon 1 (a degenerate one-round scan,
    still on-device aggregation and zero host crossings) rather than
    padding to S — one extra compiled program instead of S-1 garbage
    rounds per prune epoch — and the post-pruning phase pads to the
    run-constant (S, B) horizon as usual, so a whole SCBFwP run costs
    at most two fused compiles: the horizon-1 masked program and the
    horizon-S program (post-compaction geometry when ``prune_compact``,
    masked full geometry otherwise).
    """
    from repro.fed.cohort import fused_chunk_len
    from repro.fed.faults import apply_payload_faults
    from repro.fed.strategy import RoundContribution, admit_payloads

    cfg: ScbfConfig = train_cfg.scbf
    fed = train_cfg.fed
    scheduler = resil.scheduler
    S = int(fed.fuse_rounds)
    B = eng.fused_num_slots(scheduler.max_participants)
    total_loops = train_cfg.global_loops

    def _model_stats():
        """(n_params, hidden_sizes) of the current effective model."""
        if pruner is not None:
            return (pruner.effective_param_count(state.params),
                    pruner.hidden_sizes())
        n = sum(int(np.prod(l["w"].shape)) + int(l["b"].shape[0])
                for l in state.params)
        return n, tuple(pruning.hidden_sizes(state.params))

    if min(S, total_loops) > 1:
        # the first chunk's non-boundary records will need last-known
        # metrics, so the initial-model evaluation always happens — do
        # it NOW, before the chunk call donates the initial params'
        # buffers on backends that support donation (a lazy evaluation
        # afterwards would read deleted arrays)
        _metrics(state.params, True,
                 pruner.masks if pruner is not None else None)

    loop0 = 0
    prev_eps = 0.0
    while loop0 < total_loops:
        prune_active = pruner is not None and pruner.active
        chunk = fused_chunk_len(total_loops - loop0, S, prune_active)
        # the chunk span replaces the hand-rolled perf_counter pair: it
        # covers plan → keys → chunk dispatch → emit → prune, and (while
        # recording) annotates the region in device profiles so
        # jax.profiler traces line up with the event log
        with obstrace.span("fused_chunk", annotate=train_cfg.obs.annotate,
                           loop0=loop0, rounds=chunk) as sp:
            # the resilient planner replaces plan_horizon: same
            # scheduler.plan sequence underneath (bit-parity when the
            # fault model is off), plus fault outcomes and quorum
            # resolved per round at plan time — which is what lets the
            # admission verdicts fold into the static (S, B) admit mask
            ars = [resil.plan_round(loop0 + i, state.version)
                   for i in range(chunk)]
            plans = [ar.plan for ar in ars]
            parts, cks, sks, dks, wts = [], [], [], [], []
            for ar, plan in zip(ars, plans):
                part = plan.participants
                P = plan.num_participants
                # _derive_round_keys is the single key-stream contract,
                # so the fused pre-planner consumes EXACTLY what the
                # per-round loop would have
                key, ck, sk, dk = _derive_round_keys(key, cfg.num_clients,
                                                     part, P)
                cks.append(np.asarray(ck))
                sks.append(np.asarray(sk))
                dks.append(np.asarray(dk))
                parts.append(part)
                if method == "fedavg":
                    if P and ar.quorum_ok:
                        n = eng.counts[np.asarray(part)].astype(np.float64)
                        wts.append((n / n.sum()).astype(np.float32))
                    else:
                        # quorum-missed rounds must not step: all-zero
                        # weights pass the fedavg carry through bitwise
                        wts.append(np.zeros(P, np.float32))
            keep_eff = pruner.emission_keep if pruner is not None else None
            eff = obsm.effective_leaf_sizes(state.params, keep_eff) \
                if (collect and method == "scbf" and keep_eff is not None) \
                else None
            admits = [ar.admit_mask() for ar in ars] if resil.active \
                else None
            fplan = eng.prepare_fused_plan(
                parts, lrs[loop0:loop0 + chunk], cks, sks, dks,
                horizon=1 if prune_active else S, num_slots=B,
                weights=wts if method == "fedavg" else None,
                eff_sizes=eff, admit=admits)
            round_metrics = None
            if method == "scbf":
                out = eng.fused_scbf_chunk(
                    state.params, fplan, cfg,
                    nmasks=pruner.masks if pruner is not None else None,
                    collect=collect)
                if collect:
                    new_params, masked_s, masks_s, met_s = out
                else:
                    new_params, masked_s, masks_s = out
                emitted = eng.emit_fused_payloads(
                    masked_s, masks_s, fplan, keep=keep_eff)
                if collect:
                    # the chunk-boundary offload: ONE device_get for the
                    # whole chunk's telemetry, alongside the payload pull
                    round_metrics = obsm.offload(met_s,
                                                 rounds=fplan.rounds)
            else:
                out = eng.fused_fedavg_chunk(state.params, fplan,
                                             collect=collect)
                if collect:
                    new_params, met_s = out
                    round_metrics = obsm.offload(met_s,
                                                 rounds=fplan.rounds)
                else:
                    new_params = out
                emitted = [([], [])] * chunk
            # a round bumps the version iff it passed quorum AND at
            # least one slot was admitted — the same rule ScbfSum's
            # admission gate applies on the per-round path (fault-free,
            # admit == valid, this is the old "any participants" count)
            applied = sum(1 for ar in ars
                          if ar.quorum_ok and bool(ar.admit_mask().any()))
            state = dataclasses.replace(state, params=new_params,
                                        version=state.version + applied)
            if train_cfg.debug_checks:
                # host-side, on the values the chunk already offloaded
                obschecks.verify_round(state.params, round_metrics,
                                       where=f"chunk@loop {loop0}")
            if prune_active:
                # chunk boundary == per-round cadence while pruning
                # (chunks are 1 round long): APoZ on device, mask update
                # on host
                pruner.step(state.params)
                obstrace.event("prune", loop=loop0,
                               hidden=list(pruner.hidden_sizes()))
                if pruner.should_compact:
                    state = dataclasses.replace(
                        state, params=pruner.compact(state.params))
                    obstrace.event("compact", loop=loop0,
                                   hidden=list(pruner.hidden_sizes()))
        wall_each = sp.elapsed / chunk

        n_params, hidden = _model_stats()
        for r, (ar, plan) in enumerate(zip(ars, plans)):
            loop = loop0 + r
            P = plan.num_participants
            payloads, stats = emitted[r]
            dm = round_metrics[r] if round_metrics is not None else None
            if method == "scbf":
                # aborted quorum attempts are distinct uploads (fresh
                # keys each attempt): two increments = two releases
                for aborted in ar.aborted_arrivers:
                    if aborted.size:
                        dp_releases[np.asarray(aborted)] += 1  # privlint: disable=PL004
                wire_payloads = payloads
                if injector is not None and payloads:
                    # re-run the fault pipeline + the REAL admission
                    # gate on the emitted wire artifacts: events/counts
                    # match the per-round path, and the verdicts are
                    # checked against the plan the device already
                    # folded in (any divergence is a hard error, never
                    # a silent one)
                    cl = np.asarray(plan.participants)
                    wire_payloads, dup_src = apply_payload_faults(
                        payloads, cl, ar.corrupt, ar.duplicated, loop,
                        ar.attempts - 1, fed.faults, fed.max_update_norm)
                    if ar.quorum_ok:
                        if dup_src:
                            cl = np.concatenate([cl, cl[dup_src]])
                        gate_contrib = RoundContribution(
                            num_examples=np.zeros(len(wire_payloads),
                                                  np.int64),
                            staleness=np.zeros(len(wire_payloads),
                                               np.int64),
                            payloads=wire_payloads, clients=cl)
                        _, kept_idx = admit_payloads(state, gate_contrib,
                                                     policy)
                        planned = {i for i in range(P)
                                   if not ar.will_reject[i]}
                        if set(kept_idx) != planned:
                            raise RuntimeError(
                                f"fused admission mismatch at loop "
                                f"{loop}: the device folded slots "
                                f"{sorted(planned)} but the admission "
                                f"gate admitted {sorted(kept_idx)} — "
                                "an update failed a gate the planner "
                                "could not predict (e.g. a natural "
                                "nonfinite or norm violation); rerun "
                                "with fuse_rounds=1")
                up_frac = float(np.mean([s.upload_fraction
                                         for s in stats])) if stats else 0.0
                sparse_bytes = int(np.sum([p.nbytes
                                           for p in wire_payloads])) \
                    if wire_payloads else 0
                dense_bytes = int(np.sum([p.dense_nbytes
                                          for p in payloads])) \
                    if payloads else 0
                if P:
                    dp_releases[np.asarray(plan.participants)] += 1
            else:
                up_frac = 1.0 if P else 0.0
                dense_bytes = n_params * 4 * P
                sparse_bytes = dense_bytes
            do_eval = (r == chunk - 1) and _should_eval(
                loop, total_loops, train_cfg.eval_every)
            roc, pr, evaluated = _metrics(
                state.params, do_eval,
                pruner.masks if pruner is not None else None)
            eps, eps_un = _epsilons(loop)
            rec = LoopRecord(
                loop=loop, auc_roc=roc, auc_pr=pr,
                upload_fraction=up_frac,
                sparse_bytes=sparse_bytes, dense_bytes=dense_bytes,
                wall_time=wall_each,
                flops_proxy=float(n_params) * cohort.x_train.shape[0],
                hidden_sizes=hidden, num_participants=P,
                epsilon=eps, evaluated=evaluated,
                epsilon_unamplified=eps_un,
                train_loss=(dm or {}).get("train_loss")
                if (dm and P) else None,
                wall_is_amortized=True)
            result.records.append(rec)
            obstrace.event("round", **_round_event_fields(
                rec, plan, pruner, dm if P else None,
                eps_step=(eps - prev_eps) if eps is not None else None))
            prev_eps = eps if eps is not None else 0.0
            if verbose:
                print(f"[{result.method}] loop {loop:02d} "
                      f"auc_roc={roc:.4f} auc_pr={pr:.4f} "
                      f"upload={up_frac:.2%} hidden={rec.hidden_sizes} "
                      f"clients={P} t={wall_each:.2f}s"
                      + ("" if evaluated else " (metrics carried)"))
        loop0 += chunk
    result.final_params = state.params
