"""SCBF / SCBFwP orchestrator — the paper's Algorithm 1, faithfully.

One ``global loop``:
  1. the round scheduler picks the reporting cohort (full participation
     reproduces the paper; sampling / dropout / stragglers / buffered
     async are the cross-device scenarios of repro.fed.scheduler);
  2. the cohort engine trains every participant and channel-selects its
     delta (top-α channels by norm) — as one vmapped XLA program
     (repro.fed.engine.BatchedEngine) or the reference per-client loop;
  3. the aggregation strategy folds the uploads into the server:
     W <- W + Σ_k ΔW̃_k for SCBF (repro.fed.strategy);
  4. (SCBFwP) while the cumulative pruned fraction is below θ_total,
     prune θ of the server's hidden neurons by APoZ on the validation
     set and push the pruned structure to all clients;
  5. evaluate AUC-ROC / AUC-PR on the test set.

``run_federated`` is a thin driver over those three pluggable parts: it
owns PRNG-key derivation (so engine choice never changes the random
stream), the lr schedule, differential privacy on the upload path, and
the per-loop records with the communication accounting used by
EXPERIMENTS.md (§Paper-validation) and benchmarks/fig2.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ScbfConfig, TrainConfig
from repro.core import privacy, pruning
from repro.data.medical import MedicalCohort, dirichlet_split, federated_split
from repro.metrics.auc import auc_pr, auc_roc
from repro.models.mlp_net import init_mlp, mlp_forward
from repro.optim import schedules


@dataclass
class LoopRecord:
    loop: int
    auc_roc: float
    auc_pr: float
    upload_fraction: float       # fraction of params revealed this loop
    sparse_bytes: int            # what SCBF actually ships
    dense_bytes: int             # what FedAvg would ship for the same model
    wall_time: float             # seconds for the loop (train+select+update)
    flops_proxy: float           # ~params * examples (pruning shrinks this)
    hidden_sizes: Tuple[int, ...] = ()
    num_participants: int = 0    # clients whose updates arrived this loop
    epsilon: Optional[float] = None   # cumulative DP ε (None: DP off)


@dataclass
class RunResult:
    method: str
    records: List[LoopRecord] = field(default_factory=list)
    dp_delta: Optional[float] = None  # δ of the reported (ε, δ); None: DP off

    @property
    def final(self) -> LoopRecord:
        return self.records[-1]

    @property
    def final_epsilon(self) -> Optional[float]:
        """Cumulative (ε, δ)-DP ε spent over the whole run (None: DP off)."""
        return self.records[-1].epsilon if self.records else None

    def best(self, key: str = "auc_roc") -> float:
        return max(getattr(r, key) for r in self.records)

    def total_time(self) -> float:
        return sum(r.wall_time for r in self.records)

    def total_upload_bytes(self) -> int:
        return sum(r.sparse_bytes for r in self.records)


# module-level jit so every _evaluate call shares one compilation cache
# (a per-call jax.jit(...) wrapper recompiled on every evaluation)
_mlp_forward_jit = jax.jit(mlp_forward)


def _evaluate(params, x, y, batch: int = 8192):
    scores = []
    for s in range(0, x.shape[0], batch):
        scores.append(np.asarray(_mlp_forward_jit(
            tuple(params), jnp.asarray(x[s:s + batch]))))
    sc = jnp.asarray(np.concatenate(scores))
    yy = jnp.asarray(y)
    return float(auc_roc(sc, yy)), float(auc_pr(sc, yy))


def _partition(cohort: MedicalCohort, train_cfg: TrainConfig):
    fed = train_cfg.fed
    if fed.partition == "dirichlet":
        return dirichlet_split(cohort.x_train, cohort.y_train,
                               train_cfg.scbf.num_clients,
                               alpha=fed.dirichlet_alpha,
                               seed=train_cfg.seed)
    if fed.partition == "iid":
        return federated_split(cohort.x_train, cohort.y_train,
                               train_cfg.scbf.num_clients,
                               seed=train_cfg.seed)
    raise ValueError(f"unknown partition {fed.partition!r}; iid|dirichlet")


def _lr_schedule(train_cfg: TrainConfig):
    if train_cfg.lr_schedule == "cosine":
        return schedules.cosine_decay(train_cfg.learning_rate,
                                      max(train_cfg.global_loops - 1, 1))
    return schedules.constant(train_cfg.learning_rate)


def run_federated(cohort: MedicalCohort,
                  train_cfg: TrainConfig,
                  method: str = "scbf",
                  mlp_features: Optional[Tuple[int, ...]] = None,
                  verbose: bool = False,
                  engine: Optional[str] = None) -> RunResult:
    """Run one federated experiment.

    method: "scbf" | "fedavg", with pruning controlled by
    ``train_cfg.scbf.prune`` (→ SCBFwP / FAwP).  ``engine`` overrides
    ``train_cfg.fed.engine`` ("batched" vmapped cohort | "sequential"
    reference loop); both consume the same PRNG stream, and for
    equal-size shards (the paper's IID split) they produce identical
    trajectories.  The batched engine buckets the per-round participant
    count (``fed.bucket``) so varying P under sampling/dropout does not
    recompile, and shards the bucketed cohort over a pod mesh when
    ``fed.pods > 1`` (docs/FED_ENGINE.md).  Rounds where every sampled
    client drops out are skipped cleanly (no P=0 dispatch).  Ragged cohorts (Dirichlet) batch differently —
    the padded engine runs ``n_max // B`` masked batches per epoch
    while the sequential loop runs ``n_k // B`` — so there the engine
    choice selects between two legitimate trainings, not two
    implementations of one (docs/FED_ENGINE.md §Caveats).
    """
    # deferred: repro.fed modules import repro.core.* at module scope, so
    # importing them here (not at module top) keeps repro.core importable
    # from either direction
    from repro.fed.engine import make_engine
    from repro.fed.scheduler import make_scheduler
    from repro.fed.strategy import RoundContribution, make_strategy

    cfg: ScbfConfig = train_cfg.scbf
    fed = train_cfg.fed
    if method not in ("scbf", "fedavg"):
        raise ValueError(method)
    if cfg.dp_noise_multiplier > 0 and method != "scbf":
        raise ValueError("dp_noise_multiplier applies to the sparse scbf "
                         "upload path; method='fedavg' ships full weights "
                         "with no DP mechanism — refusing to run with a "
                         "privacy guarantee silently off")
    if fed.mode == "fedbuff":
        if method != "scbf":
            raise ValueError("fedbuff buffers sparse scbf uploads; "
                             "method must be 'scbf'")
        if cfg.prune:
            raise ValueError("pruning changes shapes under in-flight "
                             "clients; unsupported in fedbuff mode")

    feats = mlp_features or (cohort.num_features, 256, 64, 1)
    key = jax.random.PRNGKey(train_cfg.seed)
    key, init_key = jax.random.split(key)
    params = init_mlp(feats, init_key)

    clients = _partition(cohort, train_cfg)
    eng = make_engine(engine or fed.engine, clients,
                      train_cfg.local_batch_size, train_cfg.local_epochs,
                      bucket=fed.bucket, pods=fed.pods)
    scheduler = make_scheduler(fed, cfg.num_clients, train_cfg.seed)
    strategy = make_strategy(method, cfg, fed)
    state = strategy.init(params)
    # fedbuff only: stale version snapshots (sync trains on the current
    # params, so keeping the initial model alive would be pure waste)
    history = {0: params} if fed.mode == "fedbuff" else None
    lr_fn = _lr_schedule(train_cfg)

    dp_on = method == "scbf" and cfg.dp_noise_multiplier > 0
    if dp_on:
        # fail fast on an unknown accountant or a classic-bound run
        # outside its eps <= 1 domain, not after a full training loop
        privacy.epsilon_for(cfg.dp_noise_multiplier, cfg.dp_delta,
                            loops=1, accountant=cfg.dp_accountant)
    # ε composes per *release*, not per loop: under sampling, dropout or
    # fedbuff a client uploads in only some rounds, so the spend is
    # tracked per client and the worst (most-releasing) client reported
    dp_releases = np.zeros(cfg.num_clients, dtype=np.int64)
    original_hidden = sum(f for f in feats[1:-1])
    pruned_so_far = 0
    result = RunResult(method=method + ("wp" if cfg.prune else ""),
                       dp_delta=cfg.dp_delta if dp_on else None)

    for loop in range(train_cfg.global_loops):
        t0 = time.perf_counter()
        lr = float(lr_fn(jnp.asarray(loop)))
        plan = scheduler.plan(loop, state.version)
        part = plan.participants
        P = plan.num_participants

        # one split per round regardless of engine or cohort size; every
        # client k's training key is ckeys_all[k], independent of who
        # else was sampled
        key, kc, ks, kd = jax.random.split(key, 4)
        ckeys_all = jax.random.split(kc, cfg.num_clients)

        payloads, stats = [], []
        if P:
            ckeys = ckeys_all[np.asarray(part)]
            if fed.mode == "fedbuff":
                params_for = [history[state.version - int(tau)]
                              for tau in plan.staleness]
            else:
                params_for = state.params
            if method == "scbf":
                skeys = jax.random.split(ks, P)
                dp_keys = jax.random.split(kd, P)
                payloads, stats = eng.scbf_round(
                    params_for, part, lr, ckeys, skeys, dp_keys, cfg)
                dp_releases[np.asarray(part)] += 1
                contrib = RoundContribution(
                    num_examples=eng.counts[np.asarray(part)],
                    staleness=plan.staleness, payloads=payloads)
            else:
                client_params, counts = eng.fedavg_round(params_for, part,
                                                         lr, ckeys)
                contrib = RoundContribution(
                    num_examples=counts, staleness=plan.staleness,
                    client_params=client_params)
            state = strategy.aggregate(state, contrib)
        params = state.params
        if fed.mode == "fedbuff":
            history[state.version] = params
            live = scheduler.referenced_versions() | {state.version}
            history = {v: p for v, p in history.items() if v in live}

        # ---- communication accounting ----
        if method == "scbf":
            up_frac = float(np.mean([s.upload_fraction for s in stats])) \
                if stats else 0.0
            # measured bytes of the encoded payloads (single source of
            # truth: repro.comm.wire), not a mask-count model
            sparse_bytes = int(np.sum([p.nbytes for p in payloads])) \
                if payloads else 0
            dense_bytes = int(np.sum([p.dense_nbytes for p in payloads])) \
                if payloads else 0
        else:
            total = sum(int(np.prod(l["w"].shape)) + int(l["b"].shape[0])
                        for l in params)
            up_frac = 1.0 if P else 0.0
            dense_bytes = total * 4 * P
            sparse_bytes = dense_bytes

        # ---- pruning (SCBFwP / FAwP) ----
        if cfg.prune and pruned_so_far < int(cfg.prune_total * original_hidden):
            apoz = pruning.apoz_scores(params, cohort.x_val)
            keep = pruning.plan_prune(apoz, cfg.prune_rate, pruned_so_far,
                                      original_hidden, cfg.prune_total)
            new_params = pruning.apply_structure(params, keep)
            pruned_so_far = original_hidden - sum(
                pruning.hidden_sizes(new_params))
            params = new_params
            state = dataclasses.replace(state, params=params)

        wall = time.perf_counter() - t0
        roc, pr = _evaluate(params, cohort.x_test, cohort.y_test)
        n_params = sum(int(np.prod(l["w"].shape)) + int(l["b"].shape[0])
                       for l in params)
        rec = LoopRecord(
            loop=loop, auc_roc=roc, auc_pr=pr,
            upload_fraction=up_frac,
            sparse_bytes=sparse_bytes, dense_bytes=dense_bytes,
            wall_time=wall,
            flops_proxy=float(n_params) * cohort.x_train.shape[0],
            hidden_sizes=tuple(pruning.hidden_sizes(params)),
            num_participants=P,
            epsilon=privacy.epsilon_for(cfg.dp_noise_multiplier,
                                        cfg.dp_delta,
                                        loops=int(dp_releases.max()),
                                        accountant=cfg.dp_accountant)
            if dp_on else None)
        result.records.append(rec)
        if verbose:
            print(f"[{result.method}] loop {loop:02d} "
                  f"auc_roc={roc:.4f} auc_pr={pr:.4f} "
                  f"upload={up_frac:.2%} hidden={rec.hidden_sizes} "
                  f"clients={P} t={wall:.2f}s")
    return result
