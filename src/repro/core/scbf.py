"""SCBF / SCBFwP orchestrator — the paper's Algorithm 1, faithfully.

One ``global loop``:
  1. every client downloads the server weights and trains locally;
  2. each client channel-selects its delta (top-α channels by norm,
     positive or negative selection) and uploads the masked delta;
  3. server: W <- W + Σ_k ΔW̃_k;
  4. (SCBFwP) while the cumulative pruned fraction is below θ_total,
     prune θ of the server's hidden neurons by APoZ on the validation
     set and push the pruned structure to all clients;
  5. evaluate AUC-ROC / AUC-PR on the test set.

Returns per-loop records with the communication accounting used by
EXPERIMENTS.md (§Paper-validation) and benchmarks/fig2.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import wire
from repro.config import ScbfConfig, TrainConfig
from repro.core import pruning, selection
from repro.core.client import client_delta, local_train
from repro.core.server import fedavg_update, scbf_update
from repro.data.medical import MedicalCohort, federated_split
from repro.metrics.auc import auc_pr, auc_roc
from repro.models.mlp_net import init_mlp, mlp_forward


@dataclass
class LoopRecord:
    loop: int
    auc_roc: float
    auc_pr: float
    upload_fraction: float       # fraction of params revealed this loop
    sparse_bytes: int            # what SCBF actually ships
    dense_bytes: int             # what FedAvg would ship for the same model
    wall_time: float             # seconds for the loop (train+select+update)
    flops_proxy: float           # ~params * examples (pruning shrinks this)
    hidden_sizes: Tuple[int, ...] = ()


@dataclass
class RunResult:
    method: str
    records: List[LoopRecord] = field(default_factory=list)

    @property
    def final(self) -> LoopRecord:
        return self.records[-1]

    def best(self, key: str = "auc_roc") -> float:
        return max(getattr(r, key) for r in self.records)

    def total_time(self) -> float:
        return sum(r.wall_time for r in self.records)

    def total_upload_bytes(self) -> int:
        return sum(r.sparse_bytes for r in self.records)


# module-level jit so every _evaluate call shares one compilation cache
# (a per-call jax.jit(...) wrapper recompiled on every evaluation)
_mlp_forward_jit = jax.jit(mlp_forward)


def _evaluate(params, x, y, batch: int = 8192):
    scores = []
    for s in range(0, x.shape[0], batch):
        scores.append(np.asarray(_mlp_forward_jit(
            tuple(params), jnp.asarray(x[s:s + batch]))))
    sc = jnp.asarray(np.concatenate(scores))
    yy = jnp.asarray(y)
    return float(auc_roc(sc, yy)), float(auc_pr(sc, yy))


def run_federated(cohort: MedicalCohort,
                  train_cfg: TrainConfig,
                  method: str = "scbf",
                  mlp_features: Optional[Tuple[int, ...]] = None,
                  verbose: bool = False) -> RunResult:
    """Run one federated experiment.

    method: "scbf" | "fedavg", with pruning controlled by
    ``train_cfg.scbf.prune`` (→ SCBFwP / FAwP).
    """
    cfg: ScbfConfig = train_cfg.scbf
    if method not in ("scbf", "fedavg"):
        raise ValueError(method)

    feats = mlp_features or (cohort.num_features, 256, 64, 1)
    key = jax.random.PRNGKey(train_cfg.seed)
    key, init_key = jax.random.split(key)
    params = init_mlp(feats, init_key)

    clients = federated_split(cohort.x_train, cohort.y_train,
                              cfg.num_clients, seed=train_cfg.seed)
    clients = [(jnp.asarray(x), jnp.asarray(y)) for x, y in clients]

    original_hidden = sum(f for f in feats[1:-1])
    pruned_so_far = 0
    result = RunResult(method=method + ("wp" if cfg.prune else ""))

    for loop in range(train_cfg.global_loops):
        t0 = time.perf_counter()
        lr = train_cfg.learning_rate
        if train_cfg.lr_schedule == "cosine":
            import math
            frac = loop / max(train_cfg.global_loops - 1, 1)
            lr = lr * 0.5 * (1 + math.cos(math.pi * frac))
        key, *ckeys = jax.random.split(key, cfg.num_clients + 1)

        client_params, payloads, stats = [], [], []
        for k, (xc, yc) in enumerate(clients):
            new_p = local_train(tuple(params), xc, yc,
                                lr, ckeys[k],
                                batch_size=train_cfg.local_batch_size,
                                epochs=train_cfg.local_epochs)
            client_params.append(new_p)
            if method == "scbf":
                g = client_delta(params, new_p)
                key, skey = jax.random.split(key)
                masked, masks, _ = selection.select_gradients(
                    g, cfg.upload_rate, cfg.selection, key=skey,
                    score_norm=cfg.score_norm)
                # the actual upload: cheapest-codec wire payload, not a
                # dense zero-masked tensor
                payloads.append(wire.encode(tuple(masked)))
                stats.append(selection.UploadStats.from_masks(masks))

        if method == "scbf":
            # server scatter-adds the decoded compact buffers in place —
            # no K dense deltas are materialised
            params = scbf_update(params, payloads=payloads)
            up_frac = float(np.mean([s.upload_fraction for s in stats]))
            # measured bytes of the encoded payloads (single source of
            # truth: repro.comm.wire), not a mask-count model
            sparse_bytes = int(np.sum([p.nbytes for p in payloads]))
            dense_bytes = int(np.sum([p.dense_nbytes for p in payloads]))
        else:
            params = fedavg_update(client_params)
            total = sum(int(np.prod(l["w"].shape)) + int(l["b"].shape[0])
                        for l in params)
            up_frac = 1.0
            dense_bytes = total * 4 * cfg.num_clients
            sparse_bytes = dense_bytes

        # ---- pruning (SCBFwP / FAwP) ----
        if cfg.prune and pruned_so_far < int(cfg.prune_total * original_hidden):
            apoz = pruning.apoz_scores(params, cohort.x_val)
            keep = pruning.plan_prune(apoz, cfg.prune_rate, pruned_so_far,
                                      original_hidden, cfg.prune_total)
            new_params = pruning.apply_structure(params, keep)
            pruned_so_far = original_hidden - sum(
                pruning.hidden_sizes(new_params))
            params = new_params

        wall = time.perf_counter() - t0
        roc, pr = _evaluate(params, cohort.x_test, cohort.y_test)
        n_params = sum(int(np.prod(l["w"].shape)) + int(l["b"].shape[0])
                       for l in params)
        rec = LoopRecord(
            loop=loop, auc_roc=roc, auc_pr=pr,
            upload_fraction=up_frac,
            sparse_bytes=sparse_bytes, dense_bytes=dense_bytes,
            wall_time=wall,
            flops_proxy=float(n_params) * cohort.x_train.shape[0],
            hidden_sizes=tuple(pruning.hidden_sizes(params)))
        result.records.append(rec)
        if verbose:
            print(f"[{result.method}] loop {loop:02d} "
                  f"auc_roc={roc:.4f} auc_pr={pr:.4f} "
                  f"upload={up_frac:.2%} hidden={rec.hidden_sizes} "
                  f"t={wall:.2f}s")
    return result
