"""SCBF at pod scale — the paper's star topology mapped onto a TPU mesh.

The multi-pod mesh's ``pod`` axis is the federated client axis: each pod
is a hospital that must not reveal raw data OR raw gradients.  One
federated train step is:

  1. each pod computes gradients on its own batch shard
     (``jax.vmap`` over a leading client axis that is sharded over
     ``pod`` — XLA keeps everything pod-local);
  2. each pod computes *factored channel scores* for its gradient pytree
     (core/channels.py) and masks it to the top-α channels — the
     paper's "Process Gradients" step;
  3. the masked gradients are summed across pods — the paper's
     ``W <- W + Σ_k ΔW̃_k`` server update, realised as the all-reduce XLA
     inserts over the ``pod`` axis.  This is the only cross-pod traffic.

With ``compressed_exchange`` (beyond-paper, §Perf) the masked rows are
top-k gathered into an (α·rows)-sized buffer before the exchange, so the
cross-pod collective term actually shrinks by ~α instead of shipping
masked-out zeros.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.config import ScbfConfig
from repro.core import channels


def make_federated_train_step(loss_fn: Callable, scbf: ScbfConfig,
                              lr: float = 1e-3,
                              spmd_axis_name: str = None) -> Callable:
    """Returns step(params, batch) -> (mean_loss, new_params).

    ``batch`` leaves carry a leading client axis (K, ...) that the launch
    code shards over the mesh ``pod`` axis.  Pass
    ``spmd_axis_name="pod"`` under the production mesh so every batched
    intermediate (including sharding constraints inside the model) stays
    pinned to its client's pod — without it GSPMD is free to rebalance
    client computation across pods, which both violates the federated
    locality story and wrecks the collective schedule.
    """

    def client_grad(params, client_batch):
        return jax.value_and_grad(loss_fn)(params, client_batch)

    def step(params, batch):
        losses, grads_k = jax.vmap(client_grad, in_axes=(None, 0),
                                   spmd_axis_name=spmd_axis_name)(
            params, batch)                              # leaves (K, ...)

        if scbf.compressed_exchange:
            # compact exchange: only each client's (idx, vals) top-α
            # buffers cross the pod boundary; the dense sum is rebuilt by
            # local scatter-adds AFTER the gather, so cross-pod bytes are
            # ~K·α·params instead of params
            summed = _compressed_sum(grads_k, scbf.upload_rate)
        else:
            masked_k = jax.vmap(
                lambda g: channels.apply_factored_mask(
                    g, scbf.upload_rate, scbf.selection)[0],
                spmd_axis_name=spmd_axis_name)(grads_k)
            # server update sum over the pod-sharded K axis is the
            # cross-pod all-reduce of the (dense, masked) gradients
            summed = jax.tree_util.tree_map(lambda g: jnp.sum(g, axis=0),
                                            masked_k)
        new = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32) -
                          lr * g.astype(jnp.float32)).astype(p.dtype),
            params, summed)
        return jnp.mean(losses), new

    return step


def _compressed_sum(grads_k, rate: float):
    """Σ_k of top-α-channel compressed client gradients.

    Every leaf carries a leading client axis (K, ..., n).  Per client we
    take the top-k output channels by factored score and exchange only
    (indices (K,k), values (K,...,k)); the dense sum is reassembled with
    K local scatter-adds.  The cross-pod traffic is the compact buffers.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads_k)
    out = []
    for leaf in leaves:
        K = leaf.shape[0]
        if leaf.ndim - 1 < 2:
            out.append(jnp.sum(leaf, axis=0))
            continue
        n = leaf.shape[-1]
        k = max(1, int(rate * n))
        lf = leaf.astype(jnp.float32)
        axes = tuple(range(1, leaf.ndim - 1))
        scores = jnp.sum(lf * lf, axis=axes)               # (K, n)
        _, idx = jax.lax.top_k(scores, k)                  # (K, k)
        idx_b = idx.reshape((K,) + (1,) * (leaf.ndim - 2) + (k,))
        vals = jnp.take_along_axis(
            lf, jnp.broadcast_to(idx_b, leaf.shape[:-1] + (k,)), axis=-1)
        # one vectorised segment scatter-add over the stacked (K·k)
        # buffers — .at[].add sums duplicate channel indices, so clients
        # that selected the same channel accumulate exactly as the old
        # per-client Python loop did, without K sequential scatters
        flat_idx = idx.reshape(K * k)                       # (K*k,)
        flat_vals = jnp.moveaxis(vals, 0, -2).reshape(
            vals.shape[1:-1] + (K * k,))                    # (..., K*k)
        dense = jnp.zeros(leaf.shape[1:], jnp.float32)
        dense = dense.at[..., flat_idx].add(flat_vals)
        out.append(dense.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def _compressed_masked(grads, rate: float):
    """Top-k channel gather/scatter: zeros outside the top-α channels
    like the dense mask, but the values cross the pod boundary as an
    (α·rows) buffer — top_k + gather before, scatter after.

    Semantically identical to apply_factored_mask (same selected set when
    there are no score ties); structurally it shrinks the all-reduce.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    _, scores = channels.factored_scores(grads)
    out = []
    for leaf, s in zip(leaves, scores):
        if s is None:
            out.append(leaf)
            continue
        n = s.shape[0]
        k = max(1, int(rate * n))
        _, idx = jax.lax.top_k(s, k)                   # (k,) channel ids
        vals = jnp.take(leaf, idx, axis=-1)            # (..., k) gathered
        dense = jnp.zeros_like(leaf)
        dense = _scatter_last(dense, idx, vals)
        out.append(dense)
    return jax.tree_util.tree_unflatten(treedef, out)


def _scatter_last(dense, idx, vals):
    """Scatter vals (..., k) into dense (..., n) at last-axis idx (k,)."""
    return dense.at[..., idx].set(vals)


def client_batch_shape(global_batch: int, num_clients: int, seq: int
                       ) -> Tuple[int, int, int]:
    assert global_batch % num_clients == 0
    return (num_clients, global_batch // num_clients, seq)
