"""APoZ neuron pruning — paper §2.1 "Pruning Process" (SCBFwP).

APoZ (Average Percentage of Zeros, Hu et al. 2016 [33]) of neuron i in
layer l is the fraction of validation examples for which its post-ReLU
activation is exactly zero.  Each pruning step removes the θ (prune_rate)
fraction of *remaining* hidden neurons with the highest APoZ, until
θ_total of the original neurons are gone.  The server prunes on the
validation set and pushes the pruned structure to every client
(Algorithm 1).

Two implementations of "remove a neuron" (``ScbfConfig.prune_impl``):

``reshape``  host-side numpy slicing between global loops
             (``apply_structure``): later loops train/upload strictly
             smaller models — the paper's 57% wall-time saving — but
             every step changes array shapes, so every jitted program
             recompiles per step and the fused round loop cannot run.

``mask``     static-shape per-layer keep-masks (``update_keep_masks``):
             geometry stays run-constant and a ``(H_l,)`` validity mask
             zeroes pruned neurons in forward/backward, channel
             selection, DP and aggregation — no recompiles, fused-path
             compatible.  ``Pruner`` optionally compacts physically
             (one ``apply_structure`` call, one extra compile) the
             moment the cumulative budget is exhausted, so the flop and
             byte savings still materialise for the rest of the run.

``Pruner`` is the driver-side state machine shared by the per-round and
fused loops in ``repro.core.scbf`` — sharing it is what makes the two
paths' keep-mask trajectories identical by construction.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import wire
from repro.kernels.apoz import apoz_batch_fractions


def apoz_scores(params: Sequence[dict], x_val: np.ndarray,
                batch_size: int = 2048,
                neuron_masks=None) -> List[np.ndarray]:
    """APoZ per hidden neuron, streamed over the validation set.

    Delegates each batch to the module-level jitted scorer
    (``repro.kernels.apoz.apoz_batch_fractions``) — one compile per
    (param-geometry, batch, mask) signature for the whole process, not
    one per call.  Partial tail batches (and validation sets smaller
    than one batch) weight into the mean by their true size.  An empty
    validation set cannot rank neurons and raises instead of crashing
    with an unbound accumulator.
    """
    if int(np.asarray(x_val).shape[0]) == 0:
        raise ValueError("APoZ pruning needs a non-empty validation set; "
                         "got 0 examples (disable pruning or provide "
                         "validation data)")
    totals, count = None, 0
    for start in range(0, x_val.shape[0], batch_size):
        xb = jnp.asarray(x_val[start:start + batch_size])
        frac = apoz_batch_fractions(tuple(params), xb, neuron_masks)
        n = xb.shape[0]
        if totals is None:
            totals = [np.asarray(f) * n for f in frac]
        else:
            totals = [t + np.asarray(f) * n for t, f in zip(totals, frac)]
        count += n
    return [t / count for t in totals]


def _step_budget(prune_rate: float, already_pruned: int,
                 original_hidden: int, prune_total: float) -> int:
    """Neurons to remove this step: θ of the REMAINING neurons.

    Paper §2.1 prunes θ of what is still there each loop (geometric
    decay), capped so the cumulative removal never exceeds
    ``prune_total`` of the original count.  (The budget was previously
    computed as θ of the *original* count, contradicting both the paper
    and this module's own docstring — see tests/test_pruning.py
    ``test_plan_prune_budget_is_theta_of_remaining``.)
    """
    remaining = original_hidden - already_pruned
    budget = int(prune_rate * remaining)
    allow = int(prune_total * original_hidden) - already_pruned
    return max(0, min(budget, allow))


def _greedy_remove(apoz: Sequence[np.ndarray], keep: List[np.ndarray],
                   budget: int) -> List[np.ndarray]:
    """Remove up to ``budget`` currently-kept neurons, highest APoZ
    first, never emptying a layer.  Mutates and returns the boolean
    keep-masks.

    Already-removed neurons rank ``-inf`` so they can never be removed
    twice (in mask mode their activations are exactly zero, i.e. APoZ
    1.0 — without the guard they would win every step).  Ties break by
    stable sort: equal-APoZ neurons go earliest-layer, lowest-index
    first, deterministically.
    """
    flat = np.concatenate([np.where(k, np.asarray(a, np.float64), -np.inf)
                           for a, k in zip(apoz, keep)])
    owner = np.concatenate([np.full(a.shape[0], l)
                            for l, a in enumerate(apoz)])
    layer_off = np.cumsum([0] + [a.shape[0] for a in apoz])
    order = np.argsort(-flat, kind="stable")
    removed = 0
    for idx in order:
        if removed >= budget:
            break
        if not np.isfinite(flat[idx]):        # only already-removed left
            break
        l = owner[idx]
        local = idx - layer_off[l]
        if keep[l].sum() <= 1:                # never empty a layer
            continue
        keep[l][local] = False
        removed += 1
    return keep


def plan_prune(apoz: Sequence[np.ndarray], prune_rate: float,
               already_pruned: int, original_hidden: int,
               prune_total: float) -> List[np.ndarray]:
    """Indices of neurons to KEEP per hidden layer (reshape mode).

    Removes the globally-highest-APoZ θ-of-remaining neurons this loop
    (``_step_budget``), capped so the cumulative removal stays within
    ``prune_total`` of the original count.  At least one neuron per
    layer is always kept.
    """
    budget = _step_budget(prune_rate, already_pruned, original_hidden,
                          prune_total)
    keep = [np.ones(a.shape[0], bool) for a in apoz]
    keep = _greedy_remove(apoz, keep, budget)
    return [np.where(m)[0] for m in keep]


def update_keep_masks(apoz: Sequence[np.ndarray],
                      keep_masks: Sequence[np.ndarray], prune_rate: float,
                      prune_total: float) -> List[np.ndarray]:
    """One mask-mode pruning step over run-constant geometry.

    ``keep_masks`` are full-size boolean masks (True = still alive);
    the returned masks have this step's θ-of-remaining highest-APoZ
    *kept* neurons switched off.  Same greedy core, same budget rule,
    and same tie behaviour as ``plan_prune``, so for equal APoZ scores
    the mask-mode removal trajectory is the reshape-mode one.
    """
    keep = [np.asarray(m, bool).copy() for m in keep_masks]
    original_hidden = sum(m.shape[0] for m in keep)
    already = original_hidden - sum(int(np.count_nonzero(m))
                                    for m in keep)
    budget = _step_budget(prune_rate, already, original_hidden, prune_total)
    return _greedy_remove(apoz, keep, budget)


def apply_structure(params: Sequence[dict], keep: Sequence[np.ndarray]
                    ) -> Tuple[dict, ...]:
    """Slice an MLP param pytree down to the kept hidden neurons.

    ``keep[l]`` are kept output indices of layer l (hidden layers only;
    the output layer keeps all units).
    """
    new = []
    prev_keep: np.ndarray | None = None
    for l, layer in enumerate(params):
        w, b = layer["w"], layer["b"]
        if prev_keep is not None:
            w = w[prev_keep, :]
        if l < len(params) - 1:
            w = w[:, keep[l]]
            b = b[keep[l]]
            prev_keep = keep[l]
        new.append({"w": w, "b": b})
    return tuple(new)


def hidden_sizes(params: Sequence[dict]) -> List[int]:
    return [int(layer["w"].shape[1]) for layer in params[:-1]]


def expand_payloads(payloads: Sequence[wire.Payload],
                    keep: Sequence[np.ndarray],
                    params: Sequence[dict]) -> List[wire.Payload]:
    """Remap effective-geometry wire payloads onto the full geometry.

    Mask-mode clients ship payloads in the *effective* coordinate
    system — the broadcast keep sets define it identically on both ends
    — while the server stores run-constant full-geometry tensors.  This
    maps each payload's flat indices back to original neuron ids (w:
    rows through ``keep[l-1]``, columns through ``keep[l]``; b: through
    ``keep[l]``; the input and output layers are never remapped) so
    ``wire.apply_payloads`` / ``wire.decode`` work against the full
    params.  Values are untouched and every expanded leaf becomes a coo
    scatter, so the accumulation stays client-ordered — exactly what
    the fused path's on-device ``strategy.scbf_sum_step`` mirrors.
    ``nbytes`` keeps the *shipped* (effective) size: expansion is
    server-side bookkeeping, not wire traffic.
    """
    is_lp = lambda x: isinstance(x, wire.LayerPayload)  # noqa: E731
    out = []
    last = len(params) - 1
    for p in payloads:
        layers = jax.tree_util.tree_unflatten(p.treedef, p.layers)
        expanded = []
        for l, layer in enumerate(layers):
            keep_in = keep[l - 1] if l > 0 else None
            keep_out = keep[l] if l < last else None
            new = {}
            for kk, lp in layer.items():
                full_shape = tuple(np.shape(params[l][kk]))
                idx = lp.flat_indices()
                if kk == "w":
                    r, c = idx // lp.shape[1], idx % lp.shape[1]
                    if keep_in is not None:
                        r = keep_in[r]
                    if keep_out is not None:
                        c = keep_out[c]
                    fidx = r * full_shape[1] + c
                else:
                    fidx = keep_out[idx] if keep_out is not None else idx
                new[kk] = wire.LayerPayload(
                    "coo", full_shape, lp.dtype, lp.nnz, lp.nbytes,
                    idx=np.asarray(fidx, np.int32), bitmap=None,
                    values=lp.values)
            expanded.append(new)
        flat, treedef = jax.tree_util.tree_flatten(tuple(expanded),
                                                   is_leaf=is_lp)
        out.append(wire.Payload(treedef, tuple(flat)))
    return out


class Pruner:
    """SCBFwP pruning state for one federated run (both driver loops).

    Owns the keep bookkeeping (original-geometry indices), the per-loop
    step (APoZ → budget → removal), and — in mask mode — the device
    keep-masks plus the optional one-shot physical compaction once the
    cumulative budget is exhausted.  Effective sizes are always
    reported from the keep sets, so records read identically whether a
    neuron is masked or physically gone.
    """

    def __init__(self, params, x_val, *, prune_rate: float,
                 prune_total: float, impl: str = "reshape",
                 compact: bool = True):
        if impl not in ("reshape", "mask"):
            raise ValueError(f"unknown prune_impl {impl!r}; "
                             "one of ('reshape', 'mask')")
        self.impl = impl
        self.compact_enabled = compact
        self.prune_rate = prune_rate
        self.prune_total = prune_total
        self.x_val = x_val
        self._full_hidden = hidden_sizes(params)
        self.original_hidden = sum(self._full_hidden)
        self.limit = int(prune_total * self.original_hidden)
        # kept neuron ids per hidden layer, in ORIGINAL geometry
        self.keep: List[np.ndarray] = [np.arange(h)
                                       for h in self._full_hidden]
        self.masks: Optional[Tuple[jnp.ndarray, ...]] = None
        if impl == "mask":
            self.masks = tuple(jnp.ones((h,), jnp.float32)
                               for h in self._full_hidden)
        self.compacted = False
        self._stalled = False

    @property
    def mask_mode(self) -> bool:
        return self.impl == "mask"

    @property
    def pruned_so_far(self) -> int:
        return self.original_hidden - sum(len(k) for k in self.keep)

    @property
    def active(self) -> bool:
        """More pruning steps to come — i.e. the cumulative budget is
        not exhausted AND the next step can actually remove something.

        A step can be a guaranteed no-op two ways: the per-step budget
        truncates to zero (``int(θ · remaining)`` with a small
        remainder) or the never-empty-a-layer cap stalled the previous
        step (``_stalled``).  Both are permanent — remaining only
        shrinks through pruning — so treating them as "done" here is
        what lets the fused driver return to full S-round chunks and
        ``should_compact`` fire instead of looping single-round chunks
        (and APoZ sweeps) forever.
        """
        if self._stalled or self.pruned_so_far >= self.limit:
            return False
        return _step_budget(self.prune_rate, self.pruned_so_far,
                            self.original_hidden, self.prune_total) > 0

    def hidden_sizes(self) -> Tuple[int, ...]:
        """Effective (kept) hidden sizes — what the records report."""
        return tuple(len(k) for k in self.keep)

    def effective_param_count(self, params) -> int:
        """Parameters of the effective model (masked or compacted)."""
        sizes = ([int(params[0]["w"].shape[0])]
                 + [len(k) for k in self.keep]
                 + [int(params[-1]["w"].shape[1])])
        return sum(a * b + b for a, b in zip(sizes[:-1], sizes[1:]))

    @property
    def emission_keep(self) -> Optional[List[np.ndarray]]:
        """Keep sets for wire emission, or None when shapes are already
        physical.  Mask-mode payloads/stats are sliced to this geometry
        so byte accounting matches what a compacted model would ship.
        """
        if self.mask_mode and not self.compacted:
            return self.keep
        return None

    def _keep_bool(self) -> List[np.ndarray]:
        out = []
        for h, k in zip(self._full_hidden, self.keep):
            m = np.zeros(h, bool)
            m[k] = True
            out.append(m)
        return out

    def step(self, params):
        """One pruning step on the post-aggregation server params.

        Returns the params to continue with: reshape mode returns the
        compacted pytree (caller must adopt it); mask mode returns
        ``params`` unchanged and updates ``self.masks`` in place.
        """
        if not self.active:
            return params
        before = self.pruned_so_far
        if self.mask_mode:
            apoz = apoz_scores(params, self.x_val,
                               neuron_masks=self.masks)
            new_keep = update_keep_masks(apoz, self._keep_bool(),
                                         self.prune_rate, self.prune_total)
            self.keep = [np.where(m)[0] for m in new_keep]
            self.masks = tuple(jnp.asarray(m.astype(np.float32))
                               for m in new_keep)
            if self.pruned_so_far == before:
                self._stalled = True      # never-empty cap: no progress
            return params
        apoz = apoz_scores(params, self.x_val)
        keep_local = plan_prune(apoz, self.prune_rate, self.pruned_so_far,
                                self.original_hidden, self.prune_total)
        # map compacted-geometry indices back to original neuron ids
        self.keep = [k_glob[k_loc]
                     for k_glob, k_loc in zip(self.keep, keep_local)]
        if self.pruned_so_far == before:
            self._stalled = True          # never-empty cap: no progress
            return params                 # identity slice: skip it
        return apply_structure(params, keep_local)

    @property
    def should_compact(self) -> bool:
        """Mask mode only: pruning is finished, something was pruned,
        and the one-shot physical compaction has not happened yet."""
        return (self.mask_mode and self.compact_enabled and not self.active
                and not self.compacted and self.pruned_so_far > 0)

    def compact(self, params):
        """One-shot physical compaction of a fully-pruned masked model.

        Slices the frozen-but-still-resident pruned coordinates out so
        the remaining loops run (and ship) the physically smaller model
        — one extra compile, after which ``masks`` is None and every
        path behaves exactly as an unpruned model of the new geometry.
        """
        params = apply_structure(params, self.keep)
        self.masks = None
        self.compacted = True
        return params
