"""APoZ neuron pruning — paper §2.1 "Pruning Process" (SCBFwP).

APoZ (Average Percentage of Zeros, Hu et al. 2016 [33]) of neuron i in
layer l is the fraction of validation examples for which its post-ReLU
activation is exactly zero.  Each pruning step removes the θ (prune_rate)
fraction of *remaining* hidden neurons with the highest APoZ, until
θ_total of the original neurons are gone.  The server prunes on the
validation set and pushes the pruned structure to every client
(Algorithm 1) — here that is ``prune_structure`` returning per-layer kept
indices, and ``apply_structure`` slicing any compatible param pytree.

Pruning *really* changes shapes (host-side numpy slicing between global
loops), so later loops train/upload strictly smaller models — that is
where the paper's 57% wall-time saving comes from.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.mlp_net import mlp_activations


def apoz_scores(params: Sequence[dict], x_val: np.ndarray,
                batch_size: int = 2048) -> List[np.ndarray]:
    """APoZ per hidden neuron, streamed over the validation set."""
    acts_fn = jax.jit(lambda p, xb: [jnp.mean(a == 0.0, axis=0)
                                     for a in mlp_activations(p, xb)])
    totals, count = None, 0
    for start in range(0, x_val.shape[0], batch_size):
        xb = jnp.asarray(x_val[start:start + batch_size])
        frac = acts_fn(tuple(params), xb)
        n = xb.shape[0]
        if totals is None:
            totals = [np.asarray(f) * n for f in frac]
        else:
            totals = [t + np.asarray(f) * n for t, f in zip(totals, frac)]
        count += n
    return [t / max(count, 1) for t in totals]


def plan_prune(apoz: Sequence[np.ndarray], prune_rate: float,
               already_pruned: int, original_hidden: int,
               prune_total: float) -> List[np.ndarray]:
    """Indices of neurons to KEEP per hidden layer.

    Removes the globally-highest-APoZ ``prune_rate * original_hidden``
    neurons this loop, capped so the cumulative removal stays within
    ``prune_total`` of the original count.  At least one neuron per layer
    is always kept.
    """
    budget = int(prune_rate * original_hidden)
    remaining_allow = int(prune_total * original_hidden) - already_pruned
    budget = max(0, min(budget, remaining_allow))

    flat = np.concatenate(apoz)
    owner = np.concatenate([np.full(a.shape[0], l)
                            for l, a in enumerate(apoz)])
    order = np.argsort(-flat)                     # most-zero first
    keep_mask = [np.ones(a.shape[0], bool) for a in apoz]
    layer_off = np.cumsum([0] + [a.shape[0] for a in apoz])
    removed = 0
    for idx in order:
        if removed >= budget:
            break
        l = owner[idx]
        local = idx - layer_off[l]
        if keep_mask[l].sum() <= 1:               # never empty a layer
            continue
        if keep_mask[l][local]:
            keep_mask[l][local] = False
            removed += 1
    return [np.where(m)[0] for m in keep_mask]


def apply_structure(params: Sequence[dict], keep: Sequence[np.ndarray]
                    ) -> Tuple[dict, ...]:
    """Slice an MLP param pytree down to the kept hidden neurons.

    ``keep[l]`` are kept output indices of layer l (hidden layers only;
    the output layer keeps all units).
    """
    new = []
    prev_keep: np.ndarray | None = None
    for l, layer in enumerate(params):
        w, b = layer["w"], layer["b"]
        if prev_keep is not None:
            w = w[prev_keep, :]
        if l < len(params) - 1:
            w = w[:, keep[l]]
            b = b[keep[l]]
            prev_keep = keep[l]
        new.append({"w": w, "b": b})
    return tuple(new)


def hidden_sizes(params: Sequence[dict]) -> List[int]:
    return [int(layer["w"].shape[1]) for layer in params[:-1]]
