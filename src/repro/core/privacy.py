"""Differential privacy on SCBF uploads — the paper's stated future work
("Differential privacy could be further conducted on our models to
evaluate the privacy-preserving ability quantitatively", §4).

Gaussian mechanism on the *masked* client delta: clip the upload to an
L2 bound S, add N(0, σ²S²) noise to the revealed entries only (masked
entries stay exactly zero — the channel mask itself is the paper's
primary privacy device; DP hardens what IS revealed).

Accounting: Rényi differential privacy (RDP) by default.  The Gaussian
mechanism with noise multiplier σ is (α, α/(2σ²))-RDP at every order
α > 1 (Mironov 2017); RDP composes by *addition* over loops, and the
total converts to (ε, δ)-DP via the improved bound of Balle et al.
2020 / Canonne-Kamath-Steinke, minimised over a grid of orders.  The
classic bound σ = sqrt(2 ln(1.25/δ)) / ε (Dwork & Roth Thm. A.1) is
kept as a conservative fallback, but it is only a theorem for ε ≤ 1 —
outside that domain it reports meaningless numbers, so the classic
accountant refuses per-release ε > 1 instead of fabricating one.

When only a ``sample_fraction`` of clients participates per round,
``amplified_epsilon_for`` composes the subsampled-Gaussian RDP bound
(Mironov et al. 2019) instead — privacy amplification by subsampling —
which is dramatically tighter at small sampling rates.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def clip_tree(tree, max_norm: float):
    """Scale the whole pytree so its global L2 norm is <= max_norm."""
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree_util.tree_leaves(tree))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(
        lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), norm


def _check_delta(delta: float) -> None:
    """(ε, δ)-DP is vacuous outside δ ∈ (0, 1) — δ ≥ 1 is satisfied by
    releasing the data in the clear, and the RDP→DP conversion would
    happily report a small *finite* ε for it.  Refuse loudly."""
    if not 0.0 < delta < 1.0:
        raise ValueError(
            f"delta must be in (0, 1) for a meaningful DP guarantee, "
            f"got {delta} (delta >= 1 is satisfied by publishing the "
            f"raw data; delta <= 0 is unsatisfiable)")


def gaussian_mechanism(tree, key, noise_multiplier: float, max_norm: float,
                       masks=None):
    """Clip to max_norm and add N(0, (noise_multiplier*max_norm)^2) to the
    revealed entries.

    ``masks`` (a pytree of boolean reveal masks matching ``tree``) says
    which coordinates are released and must therefore carry noise.  The
    (ε, δ) analysis assumes noise on *every* released coordinate — a
    revealed entry whose gradient happens to be exactly zero (e.g. a
    ReLU-dead unit inside a selected channel) would otherwise ship
    noiselessly and leak its exact value.  Without ``masks`` the reveal
    set falls back to ``leaf != 0``, which is only sound when zeros are
    never released (dense uploads).

    ``noise_multiplier`` must be strictly positive: σ = 0 would release
    the clipped values in the clear while the caller *believes* DP is
    on.  Callers that want DP off must not call the mechanism at all
    (gate on ``dp_noise_multiplier > 0`` like the engines do).
    """
    if noise_multiplier <= 0.0:
        raise ValueError(
            f"gaussian_mechanism called with noise_multiplier="
            f"{noise_multiplier}: zero/negative noise would release the "
            f"clipped update in the clear under a DP-looking code path. "
            f"Gate the call on dp_noise_multiplier > 0 to run without "
            f"DP, and report epsilon=inf for such runs.")
    if max_norm <= 0.0:
        raise ValueError(
            f"clip bound max_norm must be > 0, got {max_norm} — a "
            f"non-positive bound zeroes the upload or voids the "
            f"sensitivity analysis the (ε, δ) guarantee rests on")
    clipped, _ = clip_tree(tree, max_norm)
    leaves, treedef = jax.tree_util.tree_flatten(clipped)
    mask_leaves = jax.tree_util.tree_leaves(masks) if masks is not None \
        else [None] * len(leaves)
    if len(mask_leaves) != len(leaves):
        raise ValueError("masks structure does not match tree")
    keys = jax.random.split(key, len(leaves))
    out = []
    sigma = noise_multiplier * max_norm
    for k, leaf, m in zip(keys, leaves, mask_leaves):
        noise = jax.random.normal(k, leaf.shape, jnp.float32) * sigma
        mask = (leaf != 0) if m is None else m
        out.append(jnp.where(mask, leaf.astype(jnp.float32) + noise,
                             0.0).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


# RDP order grid: dense near 1 (small-ε regime), sparse integer tail
# for heavy composition.  Matches the grids used by the standard
# moments-accountant implementations.
RDP_ORDERS: Tuple[float, ...] = tuple(
    [1.0 + x / 10.0 for x in range(1, 100)]
    + list(range(11, 64)) + [128.0, 256.0, 512.0, 1024.0])


def gaussian_rdp(noise_multiplier: float, order: float,
                 steps: int = 1) -> float:
    """RDP ε of ``steps`` Gaussian releases at one Rényi order α.

    One release is (α, α/(2σ²))-RDP; composition adds."""
    if order <= 1.0:
        raise ValueError(f"RDP order must be > 1, got {order}")
    return steps * order / (2.0 * noise_multiplier ** 2)


def rdp_to_dp(rdp_curve, orders, delta: float) -> float:
    """Convert an RDP curve to (ε, δ)-DP, minimised over orders.

    Uses the improved conversion (Balle et al. 2020, Thm. 21 /
    Canonne-Kamath-Steinke):
        ε = ε_RDP(α) + log((α−1)/α) − (log δ + log α)/(α − 1).
    """
    _check_delta(delta)
    best = math.inf
    for eps_a, a in zip(rdp_curve, orders):
        if a <= 1.0:
            continue
        eps = eps_a + math.log1p(-1.0 / a) \
            - (math.log(delta) + math.log(a)) / (a - 1.0)
        best = min(best, eps)
    return max(best, 0.0)


# integer Rényi orders for the subsampled-Gaussian bound (it is an
# integer-order theorem); dense low tail, sparse high tail like above
SUBSAMPLED_ORDERS: Tuple[int, ...] = tuple(
    list(range(2, 64)) + [128, 256, 512, 1024])


def subsampled_gaussian_rdp(noise_multiplier: float, q: float, order: int,
                            steps: int = 1) -> float:
    """RDP ε of ``steps`` Poisson-subsampled Gaussian releases at one
    integer order α ≥ 2 (Mironov, Talwar & Zhang 2019, Thm. 11):

        ε(α) = 1/(α−1) · log Σ_{j=0}^{α} C(α,j) (1−q)^{α−j} q^j
                                        · exp(j(j−1)/(2σ²))

    evaluated in log-space so large orders / small σ cannot overflow.
    Composition adds over steps.  ``q`` is each record's per-release
    inclusion probability; q = 1 reduces exactly to the unamplified
    Gaussian curve α/(2σ²).
    """
    a = int(order)
    if a != order or a < 2:
        raise ValueError(f"subsampled RDP is an integer-order (>= 2) "
                         f"bound, got {order}")
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"sampling rate must be in [0, 1], got {q}")
    if q == 0.0:
        return 0.0
    if q == 1.0:
        return gaussian_rdp(noise_multiplier, float(a), steps)
    s2 = noise_multiplier ** 2
    log_terms = []
    for j in range(a + 1):
        lt = (math.lgamma(a + 1) - math.lgamma(j + 1)
              - math.lgamma(a - j + 1)
              + (a - j) * math.log1p(-q)
              + j * math.log(q)
              + j * (j - 1) / (2.0 * s2))
        log_terms.append(lt)
    m = max(log_terms)
    lse = m + math.log(sum(math.exp(t - m) for t in log_terms))
    return steps * lse / (a - 1)


def amplified_epsilon_for(noise_multiplier: float, q: float,
                          delta: float = 1e-5, rounds: int = 1) -> float:
    """Cumulative (ε, δ) ε of ``rounds`` subsampled Gaussian releases.

    Composes the subsampled RDP curve additively over *rounds* (every
    round is one inclusion trial for every client, so the composition
    count is the number of rounds elapsed — not per-client release
    counts as in the unamplified accounting) and converts once via the
    improved RDP→DP bound.

    The bound is for Poisson subsampling; the sync scheduler samples a
    fixed-size cohort without replacement, for which using the nominal
    inclusion probability ``q = m/K`` is the standard approximation —
    and dropout only ever *lowers* the realised inclusion probability,
    so the reported ε is conservative in that direction.  NOT valid for
    fedbuff participation (not an i.i.d. per-round sample); the driver
    refuses that combination rather than reporting a wrong ε.
    """
    _check_delta(delta)
    if noise_multiplier <= 0:
        return math.inf
    if rounds <= 0:
        return 0.0
    if q >= 1.0:
        return epsilon_for(noise_multiplier, delta, loops=rounds)
    curve = [subsampled_gaussian_rdp(noise_multiplier, q, a, rounds)
             for a in SUBSAMPLED_ORDERS]
    return rdp_to_dp(curve, [float(a) for a in SUBSAMPLED_ORDERS], delta)


def epsilon_for(noise_multiplier: float, delta: float = 1e-5,
                loops: int = 1, accountant: str = "rdp") -> float:
    """Cumulative (ε, δ) ε of ``loops`` Gaussian releases.

    ``rdp`` (default): compose on the Gaussian RDP curve, convert once.
    ``classic``: σ = sqrt(2 ln(1.25/δ))/ε per release, composed
    linearly — valid only while the per-release ε ≤ 1, and refused
    (ValueError) outside that domain rather than reporting a number the
    theorem does not back.  σ ≤ 0 reports ε = ∞ honestly (no noise, no
    guarantee); δ outside (0, 1) is refused (``_check_delta``).
    """
    _check_delta(delta)
    if noise_multiplier <= 0:
        return math.inf
    if loops <= 0:
        return 0.0
    if accountant == "rdp":
        curve = [gaussian_rdp(noise_multiplier, a, loops)
                 for a in RDP_ORDERS]
        return rdp_to_dp(curve, RDP_ORDERS, delta)
    if accountant == "classic":
        eps_loop = math.sqrt(2.0 * math.log(1.25 / delta)) / noise_multiplier
        if eps_loop > 1.0:
            raise ValueError(
                f"classic Gaussian bound needs per-release eps <= 1, got "
                f"{eps_loop:.3f} (noise_multiplier={noise_multiplier}); "
                "use accountant='rdp'")
        return eps_loop * loops
    raise ValueError(f"unknown accountant {accountant!r}; rdp|classic")


def sigma_for(epsilon: float, delta: float = 1e-5, loops: int = 1,
              accountant: str = "rdp") -> float:
    """Noise multiplier achieving cumulative (ε, δ) over ``loops``.

    ``rdp`` inverts ``epsilon_for`` by bisection (ε is strictly
    decreasing in σ); ``classic`` uses the closed form, within its
    ε ≤ 1 validity domain only.
    """
    _check_delta(delta)
    if epsilon <= 0:
        raise ValueError(f"epsilon must be > 0, got {epsilon}")
    if accountant == "classic":
        eps_loop = epsilon / loops      # linear composition
        if eps_loop > 1.0:
            raise ValueError(
                f"classic Gaussian bound is only valid for per-release "
                f"eps <= 1, got {eps_loop:.3f}; use accountant='rdp'")
        return math.sqrt(2.0 * math.log(1.25 / delta)) / eps_loop
    if accountant != "rdp":
        raise ValueError(f"unknown accountant {accountant!r}; rdp|classic")
    lo, hi = 1e-6, 1.0
    while epsilon_for(hi, delta, loops) > epsilon:
        hi *= 2.0
        if hi > 1e12:
            raise ValueError("no noise multiplier reaches the target eps")
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if epsilon_for(mid, delta, loops) > epsilon:
            lo = mid
        else:
            hi = mid
    return hi
