"""Differential privacy on SCBF uploads — the paper's stated future work
("Differential privacy could be further conducted on our models to
evaluate the privacy-preserving ability quantitatively", §4).

Gaussian mechanism on the *masked* client delta: clip the upload to an
L2 bound S, add N(0, σ²S²) noise to the revealed entries only (masked
entries stay exactly zero — the channel mask itself is the paper's
primary privacy device; DP hardens what IS revealed).

Accounting: per-loop (ε, δ) for the Gaussian mechanism via the classic
bound σ = sqrt(2 ln(1.25/δ)) / ε, composed naively over loops (a tight
RDP accountant is a drop-in upgrade; the naive bound is conservative).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def clip_tree(tree, max_norm: float):
    """Scale the whole pytree so its global L2 norm is <= max_norm."""
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree_util.tree_leaves(tree))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(
        lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), norm


def gaussian_mechanism(tree, key, noise_multiplier: float, max_norm: float,
                       masks=None):
    """Clip to max_norm and add N(0, (noise_multiplier*max_norm)^2) to the
    revealed entries.

    ``masks`` (a pytree of boolean reveal masks matching ``tree``) says
    which coordinates are released and must therefore carry noise.  The
    (ε, δ) analysis assumes noise on *every* released coordinate — a
    revealed entry whose gradient happens to be exactly zero (e.g. a
    ReLU-dead unit inside a selected channel) would otherwise ship
    noiselessly and leak its exact value.  Without ``masks`` the reveal
    set falls back to ``leaf != 0``, which is only sound when zeros are
    never released (dense uploads).
    """
    clipped, _ = clip_tree(tree, max_norm)
    leaves, treedef = jax.tree_util.tree_flatten(clipped)
    mask_leaves = jax.tree_util.tree_leaves(masks) if masks is not None \
        else [None] * len(leaves)
    if len(mask_leaves) != len(leaves):
        raise ValueError("masks structure does not match tree")
    keys = jax.random.split(key, len(leaves))
    out = []
    sigma = noise_multiplier * max_norm
    for k, leaf, m in zip(keys, leaves, mask_leaves):
        noise = jax.random.normal(k, leaf.shape, jnp.float32) * sigma
        mask = (leaf != 0) if m is None else m
        out.append(jnp.where(mask, leaf.astype(jnp.float32) + noise,
                             0.0).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def epsilon_for(noise_multiplier: float, delta: float = 1e-5,
                loops: int = 1) -> float:
    """Conservative (ε, δ) accounting: per-loop Gaussian-mechanism ε,
    composed linearly over loops."""
    if noise_multiplier <= 0:
        return math.inf
    eps_loop = math.sqrt(2.0 * math.log(1.25 / delta)) / noise_multiplier
    return eps_loop * loops


def sigma_for(epsilon: float, delta: float = 1e-5) -> float:
    """Noise multiplier achieving (ε, δ) per loop."""
    return math.sqrt(2.0 * math.log(1.25 / delta)) / epsilon
