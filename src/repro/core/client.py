"""Local client training — paper Algorithm 1 "Train the client model".

Each client runs plain minibatch SGD on its private shard for
``local_epochs`` epochs and reports the *delta* G = W_after - W_before.
The whole epoch is a ``lax.scan`` over pre-shuffled batches inside one
jit, so per-loop Python overhead stays negligible even at 5 clients ×
30 global loops (pruning changes shapes between loops, which simply
retriggers jit's shape-keyed cache).

``local_train_impl`` / ``masked_local_train_impl`` are the unjitted
bodies: the federation engine (repro.fed.engine) vmaps them across a
whole client cohort so K local trainings run as one XLA program.  The
masked variant carries a per-example weight vector so padded cohort
rows (repro.fed.cohort) contribute nothing to the loss or gradient.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.metrics.auc import bce_elementwise, binary_cross_entropy
from repro.models.mlp_net import mlp_forward


def bce_loss(params, xb, yb, neuron_masks=None):
    return binary_cross_entropy(mlp_forward(params, xb, neuron_masks), yb)


def masked_bce_loss(params, xb, yb, wb, neuron_masks=None):
    """Weighted-mean BCE; zero-weight (padding) examples contribute 0."""
    per = bce_elementwise(mlp_forward(params, xb, neuron_masks), yb)
    return jnp.sum(per * wb) / jnp.maximum(jnp.sum(wb), 1.0)


def local_train_impl(params: Tuple[dict, ...], x: jnp.ndarray,
                     y: jnp.ndarray, lr: float, key: jax.Array,
                     batch_size: int = 256, epochs: int = 1,
                     neuron_masks=None, with_loss: bool = False):
    """SGD over the client shard; returns the updated params.

    ``neuron_masks`` (mask-mode SCBFwP) masks pruned hidden neurons out
    of the forward pass: their parameter gradients are then exactly
    zero, so the reported delta never touches a pruned coordinate and
    the trained shapes stay run-constant.  ``None`` is the original
    unmasked trace.

    ``with_loss=True`` (device telemetry, repro.obs) returns
    ``(params, mean_loss)`` instead — the per-step losses via
    ``value_and_grad``, whose forward value is a byproduct of the
    reverse pass the plain path already runs, so the parameter
    trajectory stays bit-identical and no extra forward pass is paid.
    """
    n = (x.shape[0] // batch_size) * batch_size

    def one_epoch(carry, key):
        params, acc = carry
        perm = jax.random.permutation(key, x.shape[0])[:n]
        xb = x[perm].reshape(-1, batch_size, x.shape[1])
        yb = y[perm].reshape(-1, batch_size)

        if with_loss:
            vg_fn = jax.value_and_grad(bce_loss)

            def step(c, batch):
                p, a = c
                loss, g = vg_fn(p, batch[0], batch[1], neuron_masks)
                p = jax.tree_util.tree_map(lambda w, gw: w - lr * gw, p, g)
                return (p, a + loss), None
        else:
            grad_fn = jax.grad(bce_loss)

            def step(c, batch):
                p, a = c
                g = grad_fn(p, batch[0], batch[1], neuron_masks)
                p = jax.tree_util.tree_map(lambda w, gw: w - lr * gw, p, g)
                return (p, a), None

        (params, acc), _ = jax.lax.scan(step, (params, acc), (xb, yb))
        return (params, acc), None

    keys = jax.random.split(key, epochs)
    (params, acc), _ = jax.lax.scan(one_epoch, (params, jnp.float32(0.0)),
                                    keys)
    if with_loss:
        steps = max((n // batch_size) * epochs, 1)
        return params, acc / steps
    return params


def masked_local_train_impl(params: Tuple[dict, ...], x: jnp.ndarray,
                            y: jnp.ndarray, w: jnp.ndarray, lr: float,
                            key: jax.Array, batch_size: int = 256,
                            epochs: int = 1, neuron_masks=None,
                            with_loss: bool = False):
    """``local_train_impl`` with per-example weights (1 real / 0 padding).

    Batches are drawn from the padded shard; the weighted-mean loss
    renormalises by the real examples in each batch, so a client whose
    shard is mostly padding still takes correctly-scaled steps (a batch
    of pure padding is a no-op).

    ``with_loss=True`` returns ``(params, mean_loss)`` where the mean
    is example-weighted across all steps (Σ loss·weight_sum / Σ
    weight_sum), so padded batches dilute nothing.
    """
    n = (x.shape[0] // batch_size) * batch_size

    def one_epoch(carry, key):
        params, num, den = carry
        perm = jax.random.permutation(key, x.shape[0])[:n]
        xb = x[perm].reshape(-1, batch_size, x.shape[1])
        yb = y[perm].reshape(-1, batch_size)
        wb = w[perm].reshape(-1, batch_size)

        if with_loss:
            vg_fn = jax.value_and_grad(masked_bce_loss)

            def step(c, batch):
                p, nu, de = c
                loss, g = vg_fn(p, batch[0], batch[1], batch[2],
                                neuron_masks)
                p = jax.tree_util.tree_map(lambda a, ga: a - lr * ga, p, g)
                wsum = jnp.sum(batch[2])
                return (p, nu + loss * wsum, de + wsum), None
        else:
            grad_fn = jax.grad(masked_bce_loss)

            def step(c, batch):
                p, nu, de = c
                g = grad_fn(p, batch[0], batch[1], batch[2], neuron_masks)
                p = jax.tree_util.tree_map(lambda a, ga: a - lr * ga, p, g)
                return (p, nu, de), None

        (params, num, den), _ = jax.lax.scan(step, (params, num, den),
                                             (xb, yb, wb))
        return (params, num, den), None

    keys = jax.random.split(key, epochs)
    init = (params, jnp.float32(0.0), jnp.float32(0.0))
    (params, num, den), _ = jax.lax.scan(one_epoch, init, keys)
    if with_loss:
        return params, num / jnp.maximum(den, 1.0)
    return params


local_train = partial(jax.jit, static_argnames=("batch_size", "epochs",
                                                "with_loss"))(
    local_train_impl)

masked_local_train = partial(
    jax.jit, static_argnames=("batch_size", "epochs", "with_loss"))(
    masked_local_train_impl)


def client_delta(params_before, params_after):
    """The paper's gradient matrix G for one training loop."""
    return jax.tree_util.tree_map(lambda a, b: a - b,
                                  params_after, params_before)
