"""Federated Averaging baseline (McMahan et al. 2016) — the paper's
comparator.  Thin wrapper over the shared orchestrator so both methods
run the exact same local-training / evaluation / pruning code paths.
"""
from __future__ import annotations

from repro.config import TrainConfig
from repro.core.scbf import RunResult, run_federated
from repro.data.medical import MedicalCohort


def run_fedavg(cohort: MedicalCohort, train_cfg: TrainConfig,
               verbose: bool = False, **kw) -> RunResult:
    return run_federated(cohort, train_cfg, method="fedavg",
                         verbose=verbose, **kw)
