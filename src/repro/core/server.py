"""Central-server update rules.

SCBF (paper Algorithm 1):      W <- W + Σ_k ΔW̃_k   (sum of masked deltas)
Federated Averaging (McMahan): W <- Σ_k (n_k/n) W_k (weight average;
equal client sizes here, so a plain mean).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def scbf_update(server_params, masked_deltas: Sequence):
    """W <- W + Σ_k ΔW̃_k (the paper sums — it does not average)."""
    total = masked_deltas[0]
    for d in masked_deltas[1:]:
        total = jax.tree_util.tree_map(jnp.add, total, d)
    return jax.tree_util.tree_map(jnp.add, server_params, total)


def fedavg_update(client_params: Sequence):
    """W <- mean_k W_k (equal-size clients)."""
    n = float(len(client_params))
    summed = client_params[0]
    for p in client_params[1:]:
        summed = jax.tree_util.tree_map(jnp.add, summed, p)
    return jax.tree_util.tree_map(lambda s: s / n, summed)
