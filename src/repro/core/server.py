"""Central-server update rules.

SCBF (paper Algorithm 1):      W <- W + Σ_k ΔW̃_k   (sum of masked deltas)
Federated Averaging (McMahan): W <- Σ_k (n_k/n) W_k (weight average;
equal client sizes here, so a plain mean).

``scbf_update`` accepts either dense masked-delta pytrees or encoded
wire payloads (repro.comm.wire).  The payload path scatter-adds each
client's compact (index, value) buffers straight into the server
parameters — the K dense deltas are never materialised.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.comm import wire


def scbf_update(server_params, masked_deltas: Optional[Sequence] = None,
                *, payloads: Optional[Sequence["wire.Payload"]] = None):
    """W <- W + Σ_k ΔW̃_k (the paper sums — it does not average).

    Pass ``masked_deltas`` (dense zero-masked pytrees, the simulation
    path) or ``payloads`` (encoded uploads, the real sparse exchange);
    the two are numerically equivalent because encoding is lossless.
    """
    if (masked_deltas is None) == (payloads is None):
        raise ValueError("pass exactly one of masked_deltas | payloads")
    if payloads is not None:
        return wire.apply_payloads(server_params, payloads)
    total = masked_deltas[0]
    for d in masked_deltas[1:]:
        total = jax.tree_util.tree_map(jnp.add, total, d)
    return jax.tree_util.tree_map(jnp.add, server_params, total)


def fedavg_update(client_params: Sequence, weights: Sequence = None):
    """W <- Σ_k w_k W_k (default: equal weights, the plain mean).

    ``weights`` are normalised client weights (e.g. n_k/n for McMahan's
    example-weighted average over unequal shards).  Accumulation is
    incremental — one running pytree, never a K-stacked copy of the
    model — so the server-side memory cost stays O(1) in K.
    """
    if weights is None:
        n = float(len(client_params))
        summed = client_params[0]
        for p in client_params[1:]:
            summed = jax.tree_util.tree_map(jnp.add, summed, p)
        return jax.tree_util.tree_map(lambda s: s / n, summed)
    if len(weights) != len(client_params):
        raise ValueError("one weight per client required")
    summed = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) * float(weights[0]),
        client_params[0])
    for w, p in zip(weights[1:], client_params[1:]):
        summed = jax.tree_util.tree_map(
            lambda s, x: s + x.astype(jnp.float32) * float(w), summed, p)
    return jax.tree_util.tree_map(
        lambda s, ref: s.astype(ref.dtype), summed, client_params[0])
