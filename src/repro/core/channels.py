"""Channel-norm algebra — the paper's §2.1 "Compute Channel Norms" step.

A *channel* is a path through one neuron per layer of an L-layer network;
its index is a vector i = [i1, …, iL].  The paper stores every channel's
squared gradient norm in an L-dimensional tensor

    T[i1, …, iL] = Σ_j (g_j^(i))²  .

Key structural fact (which the paper does not exploit but we do): the
channel norm is **separable** —

    T[i1, …, iL] = Σ_{l=1..L} s_l[i_l],
    s_l[i] = Σ_p G_l[p, i]² + (∂b_l[i])²

where ``s_l[i]`` is the squared norm of all gradient entries *feeding*
neuron i of layer l (its incoming-edge gradient column plus its bias
gradient).  The l=1 term absorbs the input-edge gradients (the paper's
g_0).  Separability gives us three things:

  1. the exact tensor ``T`` is a broadcast-sum of L vectors (O(Π m_l)
     memory only when materialised — fine for the paper's own MLP where
     Π m_l = 256·64·1 = 16384);
  2. an **implicit α-quantile** for large products via stochastic channel
     sampling (this is where the method's name — *stochastic* — earns its
     keep at scale);
  3. an exact **edge-selection rule** without materialising T: an edge
     (p→q) of layer l lies on some above-threshold channel iff the best
     completion through the remaining layers clears the threshold:

         s_{l-1}[p] + s_l[q] + Σ_{j∉{l-1,l}} max_i s_j[i]  >  q_α
     (for l=1 only the s_1[q] + Σ_{j≠1} max term applies, since channel
     indices do not include the input neuron).

All scores are computed in fp32 regardless of gradient dtype.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

# Materialise T exactly up to this many channels; sample beyond it.
MAX_MATERIALIZED = 1 << 22


def layer_scores(grads: Sequence[dict], normalize: bool = False,
                 neuron_masks: Sequence[jnp.ndarray] | None = None
                 ) -> List[jnp.ndarray]:
    """Per-layer neuron scores s_l for an MLP gradient pytree.

    ``grads`` is a sequence of {"w": (fan_in, fan_out), "b": (fan_out,)}.
    Returns a list of L fp32 vectors, s_l of shape (m_l,).

    ``normalize=True`` divides each layer's scores by their mean.  The
    paper sums raw per-layer norms, which makes the selection sensitive
    to inter-layer gradient scale (a layer whose scores have small spread
    contributes nothing to the ranking, so selected channels spray across
    its neurons and the edge-union balloons — see EXPERIMENTS.md
    §Paper-validation note 3).  Normalisation is our beyond-paper option
    that equalises the layers' influence.

    ``neuron_masks`` (mask-mode SCBFwP): per-hidden-layer keep-masks.
    Pruned neurons score ``-inf``, which removes them from every
    downstream consumer at static shape — the masked quantile skips
    non-finite channels, ``max`` ignores them (kept scores are >= 0),
    and the edge rule's pair-sums through a pruned neuron are ``-inf``
    so no pruned edge can clear any threshold.  The output layer is
    never masked.  Normalisation averages over kept neurons only.
    """
    scores = []
    for l, g in enumerate(grads):
        w = g["w"].astype(jnp.float32)
        s = jnp.sum(w * w, axis=0)
        if "b" in g and g["b"] is not None:
            b = g["b"].astype(jnp.float32)
            s = s + b * b
        m = None
        if neuron_masks is not None and l < len(neuron_masks):
            m = neuron_masks[l]
        if normalize:
            if m is None:
                mean = jnp.mean(s)
            else:
                mean = jnp.sum(s * m) / jnp.maximum(jnp.sum(m), 1.0)
            s = s / jnp.maximum(mean, 1e-30)
        if m is not None:
            s = jnp.where(m > 0, s, -jnp.inf)
        scores.append(s)
    return scores


def masked_quantile(values: jnp.ndarray, q: float) -> jnp.ndarray:
    """q-quantile over the finite entries of a flat score vector.

    The mask-mode replacement for ``jnp.quantile``: invalid channels
    arrive as ``-inf`` (layer_scores), an ascending sort pushes them to
    the front, and the quantile position is taken over the finite tail
    only — same linear interpolation as ``jnp.quantile``, static shapes
    throughout (the finite count is a traced scalar).
    """
    vals = jnp.sort(values)
    n = vals.shape[0]
    n_valid = jnp.sum(jnp.isfinite(vals).astype(jnp.int32))
    pos = (n - n_valid) + q * jnp.maximum(n_valid - 1, 0)
    lo = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, n - 1)
    hi = jnp.clip(jnp.ceil(pos).astype(jnp.int32), 0, n - 1)
    frac = pos - jnp.floor(pos)
    return vals[lo] * (1.0 - frac) + vals[hi] * frac


def materialize_channel_tensor(scores: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """The exact L-dimensional channel-norm tensor T (broadcast sum)."""
    L = len(scores)
    t = jnp.zeros([1] * L, jnp.float32)
    for l, s in enumerate(scores):
        shape = [1] * L
        shape[l] = s.shape[0]
        t = t + s.reshape(shape)
    return t


def num_channels(scores: Sequence[jnp.ndarray]) -> int:
    n = 1
    for s in scores:
        n *= int(s.shape[0])
    return n


def channel_quantile(scores: Sequence[jnp.ndarray], upload_rate: float,
                     *, selection: str = "positive",
                     key: jax.Array | None = None,
                     num_samples: int = 1 << 16,
                     masked: bool = False) -> jnp.ndarray:
    """Threshold q such that ~``upload_rate`` of channels have T > q
    (positive selection) or ~``upload_rate`` have T < q (negative).

    Exact when the channel tensor is small enough to materialise;
    stochastic (sampled channels) otherwise.

    ``masked=True`` (mask-mode SCBFwP): ``scores`` carry ``-inf`` on
    pruned neurons.  The materialised path takes the quantile over the
    *valid* (finite) channels only — the effective channel population of
    the masked-pruned model, matching what a physically-compacted model
    would rank — and the stochastic path samples kept neurons only
    (categorical over the keep-mask).  ``masked=False`` keeps the exact
    original arithmetic, bit for bit.
    """
    if selection not in ("positive", "negative"):
        raise ValueError(f"selection must be positive|negative, got {selection}")
    q = (1.0 - upload_rate) if selection == "positive" else upload_rate
    if num_channels(scores) <= MAX_MATERIALIZED:
        t = materialize_channel_tensor(scores).reshape(-1)
        if masked:
            return masked_quantile(t, q)
        return jnp.quantile(t, q)
    if key is None:
        key = jax.random.PRNGKey(0)
    keys = jax.random.split(key, len(scores))
    sampled = jnp.zeros((num_samples,), jnp.float32)
    for k, s in zip(keys, scores):
        if masked:
            logits = jnp.where(jnp.isfinite(s), 0.0, -jnp.inf)
            idx = jax.random.categorical(k, logits, shape=(num_samples,))
        else:
            idx = jax.random.randint(k, (num_samples,), 0, s.shape[0])
        sampled = sampled + s[idx]
    return jnp.quantile(sampled, q)


def max_completion(scores: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Σ_l max_i s_l[i] — the best possible channel score."""
    return sum(jnp.max(s) for s in scores)


def apply_channel_mask(grads: Sequence[dict], scores: Sequence[jnp.ndarray],
                       threshold: jnp.ndarray) -> Tuple[list, list]:
    """Mask an MLP gradient pytree to the selected channels.

    Returns (masked_grads, per_layer_bool_masks).  Masking uses the exact
    edge rule; the pairwise combination s_{l-1}[p] + s_l[q] is evaluated
    lazily as an outer sum so no (fan_in × fan_out) score matrix outlives
    the mask computation.
    """
    L = len(scores)
    maxes = jnp.stack([jnp.max(s) for s in scores])
    total_max = jnp.sum(maxes)
    masked, masks = [], []
    for l, g in enumerate(grads):
        w = g["w"]
        if l == 0:
            rest = total_max - maxes[0]
            col_ok = scores[0] + rest > threshold               # (m_1,)
            w_mask = jnp.broadcast_to(col_ok[None, :], w.shape)
            b_mask = col_ok
        else:
            rest = total_max - maxes[l - 1] - maxes[l]
            pair = scores[l - 1][:, None] + scores[l][None, :] + rest
            w_mask = pair > threshold
            # bias of neuron q is on a selected channel iff its best channel is
            b_mask = (jnp.max(scores[l - 1]) + scores[l] + rest) > threshold
        mg = {"w": jnp.where(w_mask, w, jnp.zeros_like(w))}
        has_bias = "b" in g and g["b"] is not None
        if has_bias:
            mg["b"] = jnp.where(b_mask, g["b"], jnp.zeros_like(g["b"]))
        masked.append(mg)
        # bias-free layers transmit no bias tensor: mask is None so the
        # upload accounting does not count phantom entries
        masks.append({"w": w_mask, "b": b_mask if has_bias else None})
    return masked, masks


# ---------------------------------------------------------------------------
# Factored channel scores for arbitrary pytrees (the at-scale adaptation —
# DESIGN.md §3).  Channel == output feature of each weight tensor.
# ---------------------------------------------------------------------------

def factored_scores(grads) -> Tuple[list, list]:
    """Per-tensor output-channel scores for any gradient pytree.

    Returns (leaves, scores): for every leaf with ndim >= 2, the fp32
    squared-norm over all axes except the last (the output-feature axis).
    Leaves with ndim < 2 get ``None`` (always uploaded — they are the
    norm/bias scalars, <0.1% of parameters).
    """
    leaves = jax.tree_util.tree_leaves(grads)
    scores = []
    for leaf in leaves:
        if leaf.ndim >= 2:
            g = leaf.astype(jnp.float32)
            axes = tuple(range(leaf.ndim - 1))
            scores.append(jnp.sum(g * g, axis=axes))
        else:
            scores.append(None)
    return leaves, scores


def factored_threshold(scores: Sequence, upload_rate: float,
                       selection: str = "positive") -> jnp.ndarray:
    """Global α-quantile across every tensor's channel-score pool."""
    if upload_rate >= 1.0:
        return jnp.asarray(-jnp.inf, jnp.float32)   # upload everything
    pool = [s.reshape(-1) for s in scores if s is not None]
    if not pool:
        # no >=2-D leaves → nothing to rank; upload everything rather
        # than crash on an empty concatenate
        return jnp.asarray(-jnp.inf, jnp.float32)
    q = (1.0 - upload_rate) if selection == "positive" else upload_rate
    return jnp.quantile(jnp.concatenate(pool), q)


def apply_factored_mask(grads, upload_rate: float,
                        selection: str = "positive"):
    """Mask a gradient pytree to its top-``upload_rate`` output channels.

    Channel scores pool globally across tensors, so busier layers upload
    more — the Law-of-Use-and-Disuse intuition at model scale.
    Returns (masked_grads, uploaded_fraction).
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    _, scores = factored_scores(grads)
    thr = factored_threshold(scores, upload_rate, selection)
    masked, kept, total = [], 0.0, 0.0
    for leaf, s in zip(leaves, scores):
        if s is None:
            masked.append(leaf)
            kept += leaf.size
            total += leaf.size
            continue
        # >= so score ties at the threshold keep their channels (a strict
        # > drops every channel when all scores are equal, e.g. uniform
        # gradients — an upload_rate > 0 must never upload nothing)
        keep = s >= thr                                        # (fan_out,)
        m = jnp.where(keep, leaf.astype(jnp.float32),
                      0.0).astype(leaf.dtype)
        masked.append(m)
        per_chan = leaf.size // s.shape[0]
        kept += jnp.sum(keep.astype(jnp.int32)) * per_chan
        total += leaf.size
    frac = kept / total
    return jax.tree_util.tree_unflatten(treedef, masked), frac
