"""Param construction helpers.

Every init function builds TWO parallel pytrees with identical structure:
``params`` (the arrays) and ``axes`` (logical axis names per array
dimension, encoded as a comma-joined string leaf — strings are pytree
leaves, tuples are not).  ``sharding/rules.py`` later maps logical axes
onto the mesh.  Keeping both trees side by side in the same code path
means they can never drift apart.

Logical axis vocabulary:
  layers   — stacked scan axis (never sharded)
  vocab    — vocabulary dim
  embed    — d_model
  heads    — fused attention head output (H*hd)
  kv       — fused KV head output (KV*hd)
  mlp      — FFN intermediate
  experts  — MoE expert axis
  inner    — SSM d_inner
  state    — SSM state dim
  lora     — MLA compressed-KV dim
  conv     — conv kernel tap axis
  none     — never sharded
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


def ax(*names: str) -> str:
    return ",".join(names)


def split_ax(axes: str):
    return tuple(axes.split(",")) if axes else ()


def trunc_normal(key, shape, scale, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def dense_init(key, fin: int, fout: int, axes: str,
               dtype, bias: bool = False, scale: Optional[float] = None):
    """(params, axes) for a dense layer.  fan-in scaled init.

    ``axes`` e.g. "embed,mlp".
    """
    scale = scale if scale is not None else 1.0 / math.sqrt(fin)
    p = {"w": trunc_normal(key, (fin, fout), scale, dtype)}
    a = {"w": axes}
    if bias:
        p["b"] = jnp.zeros((fout,), dtype)
        a["b"] = split_ax(axes)[1]
    return p, a


def norm_init(dim: int, dtype, bias: bool = False):
    p = {"scale": jnp.ones((dim,), dtype)}
    a = {"scale": "embed"}
    if bias:
        p["bias"] = jnp.zeros((dim,), dtype)
        a["bias"] = "embed"
    return p, a


def stack_inits(init_fn, keys):
    """vmap an (params, axes) init over a key batch; prepend 'layers'."""
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, axes = init_fn(keys[0])
    axes = jax.tree_util.tree_map(lambda a: "layers," + a if a else "layers",
                                  axes)
    return params, axes


def merge(*pairs_named):
    """merge(("attn", (p,a)), ("mlp", (p,a)), ...) -> (params, axes)."""
    params, axes = {}, {}
    for name, (p, a) in pairs_named:
        params[name], axes[name] = p, a
    return params, axes
