"""Core transformer layers: norms, RoPE, GQA / MLA / cross attention, MLPs.

Conventions
-----------
* every ``*_init`` returns ``(params, axes)`` (see models/common.py);
* every ``*_apply`` takes ``(params, x, ctx, ...)`` and returns either
  ``y`` or ``(y, new_cache)``;
* ``ctx`` is a ``ModelCtx`` carrying the arch config, dtype and a
  ``shard(x, logical_axes)`` callback — identity on CPU smoke tests, a
  ``with_sharding_constraint`` under the production mesh;
* attention is *query-chunked* with an explicit sharding constraint on
  the (.., q_chunk, kv_len) score block, so 32k-token prefill compiles
  with bounded per-device live memory (DESIGN.md §5);
* decode caches are ring buffers ``{"k","v","kpos"}`` — ``kpos`` holds the
  absolute position per slot (−1 = empty), which makes full-cache,
  sliding-window and prefix-filled caches all mask uniformly.

All softmax/norm math runs in fp32; activations stay in the model dtype.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ArchConfig
from repro.models.common import dense_init, merge, norm_init

NEG_INF = -1e30


@dataclass
class ModelCtx:
    cfg: ArchConfig
    dtype: jnp.dtype
    shard: Callable = lambda x, axes: x          # (array, logical axes) -> array
    q_chunk: int = 512                           # attention query chunk
    decode_window: int = 0                       # ring-buffer length override
    kv_quant: bool = False                       # int8 KV cache (§Perf iter)
    moe_dshard: bool = False                     # d_model-sharded MoE combine
    moe_groups: int = 1                          # grouped (per-data-shard)
                                                 # routing; 1 = global


# ---------------------------------------------------------------------------
# int8 KV-cache quantization (beyond-paper §Perf optimization): store k/v
# as int8 + per-(token, head) fp32 scale — halves decode's dominant
# memory-roofline term (cache reads) at <0.5% attention error.
# ---------------------------------------------------------------------------

def quantize_kv(x):
    """x (B,S,KV,hd) -> (int8 values, (B,S,KV) scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_apply(p, x):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + 1e-6)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_apply(p, x):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + 1e-6)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def norm_apply(p, x, kind: str):
    return rmsnorm_apply(p, x) if kind == "rmsnorm" else layernorm_apply(p, x)


# ---------------------------------------------------------------------------
# Rotary embeddings (full / partial-dim "2d" / none)
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
         fraction: float = 1.0) -> jnp.ndarray:
    """Apply RoPE to x (..., S, H, hd) with positions (..., S).

    fraction < 1 rotates only the first ``fraction*hd`` dims (ChatGLM's
    2d RoPE); theta == 0 disables RoPE entirely (whisper).
    """
    if theta == 0.0:
        return x
    hd = x.shape[-1]
    rot = int(hd * fraction)
    rot -= rot % 2
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                           # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = xr[..., :half].astype(jnp.float32), xr[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


def sinusoidal_positions(positions: jnp.ndarray, dim: int) -> jnp.ndarray:
    """Sinusoidal absolute position embedding (whisper-style stub)."""
    half = dim // 2
    freq = 10000.0 ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention core — query-chunked, fp32 softmax, window/causal masks
# ---------------------------------------------------------------------------

def _attend(q, k, v, kpos, qpos, ctx: ModelCtx, causal: bool, window: int):
    """q (B,Sq,KV,G,hd); k,v (B,T,KV,hd); kpos (B,T) abs position or -1.

    Returns (B,Sq,KV,G,hd).  Scores are sharded on the T axis ("kv_seq")
    so 32k contexts keep per-device blocks bounded.
    """
    hd = q.shape[-1]
    scale = hd ** -0.5
    s = jnp.einsum("bqkgh,btkh->bkgqt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = ctx.shard(s, ("batch", "none", "none", "none", "kv_seq"))
    valid = (kpos[:, None, None, None, :] >= 0)
    if causal:
        rel = qpos[:, None, None, :, None] - kpos[:, None, None, None, :]
        valid &= rel >= 0
        if window:
            valid &= rel < window
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkh->bqkgh", p.astype(v.dtype), v)
    return o


def attention_core(q, k, v, kpos, qpos, ctx: ModelCtx,
                   causal: bool = True, window: int = 0):
    """Query-chunked attention.  q (B,Sq,H,hd) grouped to KV heads."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    chunk = ctx.q_chunk
    if Sq <= chunk or Sq % chunk:
        o = _attend(qg, k, v, kpos, qpos, ctx, causal, window)
        return o.reshape(B, Sq, H, hd)

    nc = Sq // chunk
    qc = qg.reshape(B, nc, chunk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    pc = qpos.reshape(B, nc, chunk).transpose(1, 0, 2)

    # remat the chunk: backward recomputes the (chunk × T) score block
    # instead of stacking softmax residuals per chunk in HBM — the
    # flash-attention memory profile (EXPERIMENTS.md §Perf, iter 1)
    attend = jax.checkpoint(
        lambda qi, pi: _attend(qi, k, v, kpos, pi, ctx, causal, window))

    def body(_, qp):
        qi, pi = qp
        return None, attend(qi, pi)

    _, oc = lax.scan(body, None, (qc, pc))
    o = oc.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KV, G, hd)
    return o.reshape(B, Sq, H, hd)


# ---------------------------------------------------------------------------
# GQA self-attention (with optional cross-attention mode)
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ArchConfig, dtype):
    H, KV, hd, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    kq, kk, kv_, ko = jax.random.split(key, 4)
    return merge(
        ("q", dense_init(kq, D, H * hd, "embed,heads", dtype, cfg.qkv_bias)),
        ("k", dense_init(kk, D, KV * hd, "embed,kv", dtype, cfg.qkv_bias)),
        ("v", dense_init(kv_, D, KV * hd, "embed,kv", dtype, cfg.qkv_bias)),
        ("o", dense_init(ko, H * hd, D, "heads,embed", dtype)),
    )


def _dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def gqa_apply(p, x, ctx: ModelCtx, positions, *, kv_x=None, kv_positions=None,
              cache=None, causal=True, window: int = 0):
    """Self- or cross-attention.

    cache: {"k": (B,T,KV,hd), "v": ..., "kpos": (B,T)} ring buffer; when
    given, x is the new token block written at ``positions``.
    Returns (y, new_cache) (new_cache None for cache-less calls).
    """
    cfg = ctx.cfg
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    src = x if kv_x is None else kv_x
    q = _dense(p["q"], x).reshape(B, S, H, hd)
    q = rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    q = ctx.shard(q, ("batch", "none", "none", "none"))

    if cache is not None and kv_x is not None and S == 1:
        # cross-attention DECODE: reuse the K/V computed at prefill —
        # recomputing them per generated token was 25× the useful decode
        # FLOPs on whisper (§Perf iter 8)
        k, v, kpos = cache["k"], cache["v"], cache["kpos"]
        o = attention_core(q, k, v, kpos, positions, ctx, causal=False,
                           window=0)
        new_cache = cache
    elif cache is None or kv_x is not None:
        Skv = src.shape[1]
        kpos = (jnp.broadcast_to(jnp.arange(Skv)[None], (B, Skv))
                if kv_positions is None else kv_positions)
        k = _dense(p["k"], src).reshape(B, Skv, KV, hd)
        v = _dense(p["v"], src).reshape(B, Skv, KV, hd)
        if kv_x is None:                      # self-attention gets RoPE
            k = rope(k, kpos, cfg.rope_theta, cfg.rope_fraction)
        k = ctx.shard(k, ("batch", "kv_seq", "none", "none"))
        v = ctx.shard(v, ("batch", "kv_seq", "none", "none"))
        o = attention_core(q, k, v, kpos, positions, ctx, causal, window)
        # cross-attention PREFILL with a cache: store K/V for decode
        new_cache = ({"k": k.astype(cache["k"].dtype),
                      "v": v.astype(cache["v"].dtype), "kpos": kpos}
                     if (cache is not None and kv_x is not None) else None)
    else:
        k_new = _dense(p["k"], src).reshape(B, S, KV, hd)
        v_new = _dense(p["v"], src).reshape(B, S, KV, hd)
        k_new = rope(k_new, positions, cfg.rope_theta, cfg.rope_fraction)
        T = cache["k"].shape[1]
        slot = positions % T                                  # ring buffer
        if ctx.kv_quant:
            kq, ks = quantize_kv(k_new)
            vq, vs = quantize_kv(v_new)
            kc = _ring_write(cache["k"], kq, slot)
            vc = _ring_write(cache["v"], vq, slot)
            ksc = _ring_write(cache["k_scale"], ks, slot)
            vsc = _ring_write(cache["v_scale"], vs, slot)
            kpos = _ring_write(cache["kpos"], positions, slot)
            kc = ctx.shard(kc, ("batch", "kv_seq", "none", "none"))
            vc = ctx.shard(vc, ("batch", "kv_seq", "none", "none"))
            k = dequantize_kv(kc, ksc, x.dtype)
            v = dequantize_kv(vc, vsc, x.dtype)
            new_cache = {"k": kc, "v": vc, "k_scale": ksc,
                         "v_scale": vsc, "kpos": kpos}
        else:
            k = _ring_write(cache["k"], k_new, slot)
            v = _ring_write(cache["v"], v_new, slot)
            kpos = _ring_write(cache["kpos"], positions, slot)
            k = ctx.shard(k, ("batch", "kv_seq", "none", "none"))
            v = ctx.shard(v, ("batch", "kv_seq", "none", "none"))
            new_cache = {"k": k, "v": v, "kpos": kpos}
        o = attention_core(q, k, v, kpos, positions, ctx, causal, window)

    y = _dense(p["o"], o.reshape(B, S, H * hd))
    return y, new_cache


def _ring_write(buf, new, slot):
    """Write new (B,S,...) into buf (B,T,...) at per-token slots (B,S)."""
    B, S = slot.shape
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S))
    return buf.at[bidx, slot].set(new.astype(buf.dtype))


def attn_cache_init(cfg: ArchConfig, batch: int, cache_len: int, dtype):
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, cache_len, KV, hd), dtype),
        "v": jnp.zeros((batch, cache_len, KV, hd), dtype),
        "kpos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2), absorbed decode path
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ArchConfig, dtype):
    D, H, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    r, rd = cfg.kv_lora_rank, cfg.qk_rope_dim
    kq, kd, ku, ko = jax.random.split(key, 4)
    return merge(
        # per-head query: nope part (hd) + rope part (rd)
        ("q", dense_init(kq, D, H * (hd + rd), "embed,heads", dtype)),
        # compressed kv (lora) + shared rope key
        ("kv_down", dense_init(kd, D, r + rd, "embed,lora", dtype)),
        # decompress: k_nope (hd) + v (hd) per head
        ("kv_up", dense_init(ku, r, H * 2 * hd, "lora,heads", dtype)),
        ("o", dense_init(ko, H * hd, D, "heads,embed", dtype)),
    )


def mla_cache_init(cfg: ArchConfig, batch: int, cache_len: int, dtype):
    return {
        "ckv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, cache_len, cfg.qk_rope_dim), dtype),
        "kpos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def _mla_qkv(p, x, ctx, positions):
    cfg = ctx.cfg
    B, S, _ = x.shape
    H, hd, rd, r = cfg.num_heads, cfg.head_dim, cfg.qk_rope_dim, cfg.kv_lora_rank
    q = _dense(p["q"], x).reshape(B, S, H, hd + rd)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    down = _dense(p["kv_down"], x)                       # (B,S,r+rd)
    ckv, krope = down[..., :r], down[..., r:]
    krope = rope(krope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, ckv, krope


def _mla_attend(q_nope, q_rope, ckv, krope, kpos, qpos, p, ctx):
    """Absorbed MLA attention: scores in compressed (lora) space.

    q_nope (B,S,H,hd), q_rope (B,S,H,rd); ckv (B,T,r), krope (B,T,rd).
    """
    cfg = ctx.cfg
    B, S, H, hd = q_nope.shape
    r = cfg.kv_lora_rank
    wu = p["kv_up"]["w"].reshape(r, H, 2 * hd)
    wk = wu[..., :hd]                                    # (r,H,hd)
    wv = wu[..., hd:]                                    # (r,H,hd)
    # absorb k-decompression into q:  q' = q_nope · wkᵀ  -> (B,S,H,r)
    q_abs = jnp.einsum("bshd,rhd->bshr", q_nope.astype(jnp.float32),
                       wk.astype(jnp.float32))
    scale = (hd + cfg.qk_rope_dim) ** -0.5
    s = (jnp.einsum("bshr,btr->bhst", q_abs, ckv.astype(jnp.float32)) +
         jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                    krope.astype(jnp.float32))) * scale
    s = ctx.shard(s, ("batch", "none", "none", "kv_seq"))
    valid = (kpos[:, None, None, :] >= 0) & \
        (qpos[:, None, :, None] >= kpos[:, None, None, :])
    s = jnp.where(valid, s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    # attend in compressed space, then decompress through wv
    o_c = jnp.einsum("bhst,btr->bshr", pr, ckv.astype(jnp.float32))
    o = jnp.einsum("bshr,rhd->bshd", o_c, wv.astype(jnp.float32))
    return o.astype(ckv.dtype)


def mla_apply(p, x, ctx: ModelCtx, positions, *, cache=None):
    cfg = ctx.cfg
    B, S, _ = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    q_nope, q_rope, ckv, krope = _mla_qkv(p, x, ctx, positions)
    if cache is None:
        kpos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        # chunk the query axis like attention_core
        chunk = ctx.q_chunk
        if S > chunk and S % chunk == 0:
            nc = S // chunk
            resh = lambda a: a.reshape(B, nc, chunk, *a.shape[2:]) \
                .transpose(1, 0, 2, *range(3, a.ndim + 1))
            qn, qr, pp = resh(q_nope), resh(q_rope), \
                positions.reshape(B, nc, chunk).transpose(1, 0, 2)

            attend = jax.checkpoint(
                lambda qni, qri, pi: _mla_attend(qni, qri, ckv, krope,
                                                 kpos, pi, p, ctx))

            def body(_, args):
                qni, qri, pi = args
                return None, attend(qni, qri, pi)

            _, oc = lax.scan(body, None, (qn, qr, pp))
            o = oc.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
        else:
            o = _mla_attend(q_nope, q_rope, ckv, krope, kpos, positions, p, ctx)
        new_cache = None
    else:
        T = cache["ckv"].shape[1]
        slot = positions % T
        ckv_c = _ring_write(cache["ckv"], ckv, slot)
        krope_c = _ring_write(cache["krope"], krope, slot)
        kpos = _ring_write(cache["kpos"], positions, slot)
        ckv_c = ctx.shard(ckv_c, ("batch", "kv_seq", "none"))
        o = _mla_attend(q_nope, q_rope, ckv_c, krope_c, kpos, positions, p, ctx)
        new_cache = {"ckv": ckv_c, "krope": krope_c, "kpos": kpos}
    y = _dense(p["o"], o.reshape(B, S, H * hd))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ArchConfig, dtype, d_ff: Optional[int] = None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    gated = cfg.activation != "gelu"
    if gated:
        k1, k2, k3 = jax.random.split(key, 3)
        return merge(
            ("wi", dense_init(k1, D, F, "embed,mlp", dtype)),
            ("wg", dense_init(k2, D, F, "embed,mlp", dtype)),
            ("wo", dense_init(k3, F, D, "mlp,embed", dtype)),
        )
    k1, k2 = jax.random.split(key)
    return merge(
        ("wi", dense_init(k1, D, F, "embed,mlp", dtype, bias=True)),
        ("wo", dense_init(k2, F, D, "mlp,embed", dtype, bias=True)),
    )


def mlp_apply(p, x, ctx: ModelCtx):
    act = jax.nn.silu if ctx.cfg.activation != "gelu" else jax.nn.gelu
    h = _dense(p["wi"], x)
    if "wg" in p:
        h = act(h) * _dense(p["wg"], x)
    else:
        h = act(h)
    h = ctx.shard(h, ("batch", "none", "mlp_act"))
    return _dense(p["wo"], h)
