"""ArchConfig -> runnable model bundle.

A bundle packages everything the launcher, dry-run, smoke tests and the
federated trainer need:

    init(key)                       -> (params, logical_axes)
    loss_fn(params, batch)          -> scalar loss
    train_step(params, batch, lr)   -> (loss, new_params)       (pure SGD)
    prefill_step(params, batch)     -> (last_logits, caches)
    decode_step(params, batch)      -> (logits, new_caches)
    input_specs(shape, window)      -> pytree of ShapeDtypeStruct
    make_cache(batch, cache_len)    -> concrete zero caches (small configs)

Decode shapes lower ``decode_step`` — ONE token against a ``seq_len`` KV
cache.  ``long_500k`` on quadratic-attention archs uses the sliding-window
variant (window passed in; cache length == window), recorded per-run in
EXPERIMENTS.md.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ArchConfig, ShapeConfig
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import transformer as T
from repro.models.common import DTYPES
from repro.models.layers import ModelCtx


@dataclass(eq=False)      # identity hash: bundles key per-bundle jit caches
class ModelBundle:
    cfg: ArchConfig
    ctx: ModelCtx
    init: Callable
    loss_fn: Callable
    train_step: Callable
    prefill_step: Callable
    decode_step: Callable
    input_specs: Callable
    make_cache: Callable


def _aux_from_batch(params, cfg: ArchConfig, batch, ctx) -> Optional[jnp.ndarray]:
    """Cross-attention context tokens: encoder output (audio) or projected
    patch embeddings (vision)."""
    if cfg.encoder_layers:
        return T.encode(params, cfg, batch["audio_embeds"], ctx)
    if cfg.frontend == "vision":
        return batch["image_embeds"]
    return None


def _cache_len(cfg: ArchConfig, shape: ShapeConfig, window: int) -> int:
    return min(shape.seq_len, window) if window else shape.seq_len


def build(cfg: ArchConfig, shard: Callable = lambda x, a: x,
          q_chunk: int = 512, remat: bool = True,
          kv_quant: bool = False, moe_dshard: bool = False,
          moe_groups: int = 1) -> ModelBundle:
    dtype = DTYPES[cfg.dtype]
    ctx = ModelCtx(cfg=cfg, dtype=dtype, shard=shard, q_chunk=q_chunk,
                   kv_quant=kv_quant, moe_dshard=moe_dshard,
                   moe_groups=moe_groups)

    def init(key):
        return T.init_model(cfg, key)

    # ------------------------------------------------------------- train
    def loss_fn(params, batch, window: int = 0):
        aux = _aux_from_batch(params, cfg, batch, ctx)
        h, _ = T.forward_hidden(params, cfg, batch["tokens"], ctx, aux=aux,
                                remat=remat,
                                window=window or cfg.sliding_window)
        return T.chunked_ce_loss(params, cfg, h, batch["targets"], ctx)

    def train_step(params, batch, lr: float = 1e-3):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)
                          ).astype(p.dtype), params, grads)
        return loss, new

    # ----------------------------------------------------------- serving
    def prefill_step(params, batch, window: int = 0):
        """Writes the whole prompt into fresh caches; returns last logits."""
        aux = _aux_from_batch(params, cfg, batch, ctx)
        caches = batch["caches"]
        tokens = batch["tokens"]
        h, new_caches = T.forward_hidden(params, cfg, tokens, ctx, aux=aux,
                                         caches=caches, remat=False,
                                         window=window or cfg.sliding_window)
        logits = T.logits_from_hidden(params, cfg, h[:, -1:, :])[:, 0]
        extras = {}
        if aux is not None:
            extras["ctx_tokens"] = aux
        return logits, {"layers": new_caches, **extras}

    def decode_step(params, batch, window: int = 0):
        """One token (B,1) at absolute position pos (B,1) against caches."""
        caches = batch["caches"]
        aux = caches.get("ctx_tokens")
        h, new_caches = T.forward_hidden(
            params, cfg, batch["token"], ctx, positions=batch["pos"],
            aux=aux, caches=caches["layers"], remat=False,
            window=window or cfg.sliding_window)
        logits = T.logits_from_hidden(params, cfg, h[:, 0, :])
        out = {"layers": new_caches}
        if aux is not None:
            out["ctx_tokens"] = aux
        return logits, out

    # ------------------------------------------------------ cache pytree
    def _layer_cache_struct(spec: T.LayerSpec, batch: int, cache_len: int,
                            as_struct: bool):
        mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if as_struct \
            else (lambda s, d: (jnp.full(s, -1, d) if d == jnp.int32
                                else jnp.zeros(s, d)))
        c: Dict[str, Any] = {}
        if spec.kind == "attn":
            if cfg.use_mla:
                c["attn"] = {
                    "ckv": mk((batch, cache_len, cfg.kv_lora_rank), dtype),
                    "krope": mk((batch, cache_len, cfg.qk_rope_dim), dtype),
                    "kpos": mk((batch, cache_len), jnp.int32),
                }
            elif kv_quant:
                c["attn"] = {
                    "k": mk((batch, cache_len, cfg.num_kv_heads,
                             cfg.head_dim), jnp.int8),
                    "v": mk((batch, cache_len, cfg.num_kv_heads,
                             cfg.head_dim), jnp.int8),
                    "k_scale": mk((batch, cache_len, cfg.num_kv_heads),
                                  jnp.float32),
                    "v_scale": mk((batch, cache_len, cfg.num_kv_heads),
                                  jnp.float32),
                    "kpos": mk((batch, cache_len), jnp.int32),
                }
            else:
                c["attn"] = {
                    "k": mk((batch, cache_len, cfg.num_kv_heads,
                             cfg.head_dim), dtype),
                    "v": mk((batch, cache_len, cfg.num_kv_heads,
                             cfg.head_dim), dtype),
                    "kpos": mk((batch, cache_len), jnp.int32),
                }
        else:
            c["mamba"] = {
                "h": mk((batch, cfg.num_ssm_heads, cfg.ssm_state,
                         cfg.ssm_head_dim), jnp.float32),
                "conv": mk((batch, cfg.ssm_conv_width - 1,
                            cfg.d_inner + 2 * cfg.ssm_state), dtype),
            }
        if spec.cross:
            # cross-attention K/V computed once at prefill (§Perf iter 8)
            t_ctx = (cfg.encoder_seq if cfg.encoder_layers
                     else cfg.num_patch_tokens)
            c["cross"] = {
                "k": mk((batch, t_ctx, cfg.num_kv_heads, cfg.head_dim),
                        dtype),
                "v": mk((batch, t_ctx, cfg.num_kv_heads, cfg.head_dim),
                        dtype),
                "kpos": mk((batch, t_ctx), jnp.int32),
            }
        return c

    def cache_pytree(batch: int, cache_len: int, as_struct: bool):
        prefix, unit, repeats = T.unit_pattern(cfg)
        out: Dict[str, Any] = {}
        if prefix:
            out["prefix"] = [
                _layer_cache_struct(s, batch, cache_len, as_struct)
                for s in prefix]
        unit_c = {f"l{i}": _layer_cache_struct(s, batch, cache_len, as_struct)
                  for i, s in enumerate(unit)}
        if as_struct:
            out["stack"] = jax.tree_util.tree_map(
                lambda sds: jax.ShapeDtypeStruct((repeats,) + sds.shape,
                                                 sds.dtype), unit_c)
        else:
            out["stack"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (repeats,) + a.shape).copy(),
                unit_c)
        return out

    def make_cache(batch: int, cache_len: int):
        return cache_pytree(batch, cache_len, as_struct=False)

    # ------------------------------------------------------- input specs
    def input_specs(shape: ShapeConfig, window: int = 0):
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        if shape.kind == "train":
            specs = {"tokens": sds((B, S), i32), "targets": sds((B, S), i32)}
            if cfg.encoder_layers:
                specs["audio_embeds"] = sds((B, cfg.encoder_seq,
                                             cfg.d_model), dtype)
            elif cfg.frontend == "vision":
                specs["image_embeds"] = sds((B, cfg.num_patch_tokens,
                                             cfg.d_model), dtype)
            return specs
        if shape.kind == "prefill":
            specs = {"tokens": sds((B, S), i32),
                     "caches": cache_pytree(B, _cache_len(cfg, shape, window),
                                            as_struct=True)}
            if cfg.encoder_layers:
                specs["audio_embeds"] = sds((B, cfg.encoder_seq,
                                             cfg.d_model), dtype)
            elif cfg.frontend == "vision":
                specs["image_embeds"] = sds((B, cfg.num_patch_tokens,
                                             cfg.d_model), dtype)
            return specs
        # decode
        caches: Dict[str, Any] = {
            "layers": cache_pytree(B, _cache_len(cfg, shape, window),
                                   as_struct=True)}
        if cfg.encoder_layers:
            caches["ctx_tokens"] = sds((B, cfg.encoder_seq, cfg.d_model),
                                       dtype)
        elif cfg.frontend == "vision":
            caches["ctx_tokens"] = sds((B, cfg.num_patch_tokens,
                                        cfg.d_model), dtype)
        return {"token": sds((B, 1), i32), "pos": sds((B, 1), i32),
                "caches": caches}

    return ModelBundle(cfg=cfg, ctx=ctx, init=init, loss_fn=loss_fn,
                       train_step=train_step, prefill_step=prefill_step,
                       decode_step=decode_step, input_specs=input_specs,
                       make_cache=make_cache)
