"""Generic decoder(-encoder) transformer over heterogeneous layer patterns.

Every assigned architecture is expressed as:

    prefix layers (unrolled)  +  R repeats of a layer *unit* (lax.scan)

where a unit is the architecture's repeating pattern — 1 layer for dense
models, [dense, moe] for Llama-4, [7×mamba, attn] for Jamba, [4×self,
cross] for the VLM, etc.  Unit params are stacked on a leading "layers"
axis so the whole depth compiles to ONE scanned HLO body (80 dry-run
combos stay compilable), with ``jax.checkpoint`` on the unit for training.

Caches mirror the param structure: a pytree per unit, stacked on the same
leading axis, carried through the scan as per-unit xs/ys.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ArchConfig
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models.common import DTYPES, dense_init, merge, norm_init, \
    stack_inits, trunc_normal
from repro.models.layers import ModelCtx


@dataclass(frozen=True)
class LayerSpec:
    kind: str            # "attn" | "mamba"
    moe: bool = False
    cross: bool = False  # extra cross-attention sublayer


def layer_specs(cfg: ArchConfig) -> List[LayerSpec]:
    specs = []
    for l in range(cfg.num_layers):
        kind = "attn" if cfg._is_attn_layer(l) else "mamba"
        cross = bool(cfg.cross_attn_every) and \
            (l % cfg.cross_attn_every == cfg.cross_attn_every - 1)
        specs.append(LayerSpec(kind, cfg._is_moe_layer(l), cross))
    return specs


def unit_pattern(cfg: ArchConfig) -> Tuple[List[LayerSpec], List[LayerSpec], int]:
    """(prefix_specs, unit_specs, repeats)."""
    specs = layer_specs(cfg)
    prefix = specs[:cfg.first_dense_layers]
    rest = specs[cfg.first_dense_layers:]
    period = 1
    for k in (cfg.attention_every, cfg.moe_every if cfg.num_experts else 1,
              cfg.cross_attn_every or 1):
        period = math.lcm(period, k)
    if len(rest) % period:
        raise ValueError(f"{cfg.name}: {len(rest)} layers not divisible by "
                         f"pattern period {period}")
    unit = rest[:period]
    for r in range(0, len(rest), period):
        if rest[r:r + period] != unit:
            raise ValueError(f"{cfg.name}: layer pattern is not periodic")
    return prefix, unit, len(rest) // period


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ArchConfig, spec: LayerSpec, dtype):
    ks = jax.random.split(key, 6)
    pairs = [("norm1", norm_init(cfg.d_model, dtype,
                                 bias=cfg.norm == "layernorm"))]
    if spec.kind == "attn":
        attn = L.mla_init(ks[0], cfg, dtype) if cfg.use_mla \
            else L.gqa_init(ks[0], cfg, dtype)
        pairs.append(("attn", attn))
    else:
        pairs.append(("mamba", M.mamba_init(ks[0], cfg, dtype)))
    if spec.cross:
        pairs.append(("cross_norm", norm_init(cfg.d_model, dtype,
                                              bias=cfg.norm == "layernorm")))
        pairs.append(("cross", L.gqa_init(ks[1], cfg, dtype)))
    if cfg.d_ff:
        pairs.append(("norm2", norm_init(cfg.d_model, dtype,
                                         bias=cfg.norm == "layernorm")))
        if spec.moe:
            pairs.append(("moe", MOE.moe_init(ks[2], cfg, dtype)))
        else:
            pairs.append(("mlp", L.mlp_init(ks[3], cfg, dtype)))
    return merge(*pairs)


def _unit_init(key, cfg: ArchConfig, unit: List[LayerSpec], dtype):
    ks = jax.random.split(key, len(unit))
    pairs = [(f"l{i}", _layer_init(ks[i], cfg, s, dtype))
             for i, s in enumerate(unit)]
    return merge(*pairs)


def _encoder_layer_init(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 2)
    return merge(
        ("norm1", norm_init(cfg.d_model, dtype, bias=cfg.norm == "layernorm")),
        ("attn", L.gqa_init(ks[0], cfg, dtype)),
        ("norm2", norm_init(cfg.d_model, dtype, bias=cfg.norm == "layernorm")),
        ("mlp", L.mlp_init(ks[1], cfg, dtype)),
    )


def init_model(cfg: ArchConfig, key) -> Tuple[Dict, Dict]:
    """Returns (params, logical_axes) for the full model."""
    dtype = DTYPES[cfg.dtype]
    prefix, unit, repeats = unit_pattern(cfg)
    k_embed, k_pre, k_stack, k_un, k_enc = jax.random.split(key, 5)

    params: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}
    scale = 1.0 / math.sqrt(cfg.d_model)
    params["embed"] = {"w": trunc_normal(k_embed, (cfg.vocab_size,
                                                   cfg.d_model), scale, dtype)}
    axes["embed"] = {"w": "vocab,embed"}

    if prefix:
        pk = jax.random.split(k_pre, len(prefix))
        pre_pairs = [(f"p{i}", _layer_init(pk[i], cfg, s, dtype))
                     for i, s in enumerate(prefix)]
        params["prefix"], axes["prefix"] = merge(*pre_pairs)

    sk = jax.random.split(k_stack, repeats)
    params["stack"], axes["stack"] = stack_inits(
        lambda k: _unit_init(k, cfg, unit, dtype), sk)

    params["final_norm"], axes["final_norm"] = norm_init(
        cfg.d_model, dtype, bias=cfg.norm == "layernorm")

    if not cfg.tie_embeddings:
        params["unembed"], axes["unembed"] = dense_init(
            k_un, cfg.d_model, cfg.vocab_size, "embed,vocab", dtype)

    if cfg.encoder_layers:
        ek = jax.random.split(k_enc, cfg.encoder_layers)
        stack_p, stack_a = stack_inits(
            lambda k: _encoder_layer_init(k, cfg, dtype), ek)
        fn_p, fn_a = norm_init(cfg.d_model, dtype,
                               bias=cfg.norm == "layernorm")
        params["encoder"] = {"stack": stack_p, "final_norm": fn_p}
        axes["encoder"] = {"stack": stack_a, "final_norm": fn_a}
    return params, axes


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _layer_forward(lp, spec: LayerSpec, h, ctx: ModelCtx, positions,
                   aux: Optional[jnp.ndarray], cache, window: int):
    """One layer; returns (h, new_cache)."""
    cfg = ctx.cfg
    new_cache = {}
    if spec.kind == "attn":
        xin = L.norm_apply(lp["norm1"], h, cfg.norm)
        if cfg.use_mla:
            a, c = L.mla_apply(lp["attn"], xin, ctx, positions,
                               cache=None if cache is None
                               else cache.get("attn"))
        else:
            a, c = L.gqa_apply(lp["attn"], xin, ctx, positions,
                               cache=None if cache is None
                               else cache.get("attn"),
                               causal=True, window=window)
        h = h + a
        if c is not None:
            new_cache["attn"] = c
    else:
        xin = L.norm_apply(lp["norm1"], h, cfg.norm)
        a, c = M.mamba_apply(lp["mamba"], xin, ctx,
                             cache=None if cache is None
                             else cache.get("mamba"))
        h = h + a
        if c is not None:
            new_cache["mamba"] = c
    if spec.cross:
        assert aux is not None, "cross-attention layer needs ctx tokens"
        xin = L.norm_apply(lp["cross_norm"], h, cfg.norm)
        a, c = L.gqa_apply(lp["cross"], xin, ctx, positions, kv_x=aux,
                           causal=False,
                           cache=None if cache is None
                           else cache.get("cross"))
        h = h + a
        if c is not None:
            new_cache["cross"] = c
    if "mlp" in lp or "moe" in lp:
        xin = L.norm_apply(lp["norm2"], h, cfg.norm)
        if "moe" in lp:
            h = h + MOE.moe_apply(lp["moe"], xin, ctx)
        else:
            h = h + L.mlp_apply(lp["mlp"], xin, ctx)
    return h, (new_cache or None)


def _unit_forward(unit_p, unit_specs, h, ctx, positions, aux, unit_cache,
                  window):
    new_caches = {}
    for i, spec in enumerate(unit_specs):
        cache_i = None if unit_cache is None else unit_cache[f"l{i}"]
        h, nc = _layer_forward(unit_p[f"l{i}"], spec, h, ctx, positions,
                               aux, cache_i, window)
        new_caches[f"l{i}"] = nc if nc is not None else {}
    return h, new_caches


def forward_hidden(params, cfg: ArchConfig, tokens, ctx: ModelCtx,
                   positions=None, aux=None, caches=None,
                   remat: bool = False, window: int = 0):
    """Token ids -> final hidden states.

    caches: {"prefix": [...], "stack": stacked pytree} or None.
    Returns (hidden (B,S,D), new_caches-or-None).
    """
    prefix, unit, repeats = unit_pattern(cfg)
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
    h = params["embed"]["w"][tokens]
    if cfg.rope_theta == 0.0:             # whisper: sinusoidal abs positions
        h = h + L.sinusoidal_positions(positions, cfg.d_model).astype(h.dtype)
    h = ctx.shard(h, ("batch", "none", "none"))

    new_caches: Dict[str, Any] = {}
    if prefix:
        pc = []
        for i, spec in enumerate(prefix):
            cache_i = None if caches is None else caches["prefix"][i]
            h, nc = _layer_forward(params["prefix"][f"p{i}"], spec, h, ctx,
                                   positions, aux, cache_i, window)
            pc.append(nc if nc is not None else {})
        new_caches["prefix"] = pc

    unit_fn = partial(_unit_forward, unit_specs=tuple(unit), ctx=ctx,
                      window=window)

    def body(h, xs):
        unit_p, unit_c = xs
        fn = lambda h_, up, uc: unit_fn(up, h=h_, positions=positions,
                                        aux=aux, unit_cache=uc)
        if remat:
            fn = jax.checkpoint(fn)
        h, nc = fn(h, unit_p, unit_c)
        return h, nc

    stack_caches = None if caches is None else caches["stack"]
    if stack_caches is None:
        # dummy per-unit cache pytree of empty dicts
        stack_caches = jax.tree_util.tree_map(lambda _: 0, ())
        h, stack_nc = lax.scan(
            lambda hh, up: body(hh, (up, None)), h, params["stack"])
    else:
        h, stack_nc = lax.scan(body, h, (params["stack"], stack_caches))
    new_caches["stack"] = stack_nc

    h = L.norm_apply(params["final_norm"], h, cfg.norm)
    return h, (new_caches if caches is not None else None)


def encode(params, cfg: ArchConfig, embeds, ctx: ModelCtx,
           remat: bool = True):
    """Whisper encoder over precomputed frame embeddings (B,T,D)."""
    B, T, D = embeds.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    h = embeds + L.sinusoidal_positions(positions, D).astype(embeds.dtype)

    def layer(h, lp):
        xin = L.norm_apply(lp["norm1"], h, cfg.norm)
        a, _ = L.gqa_apply(lp["attn"], xin, ctx, positions, causal=False)
        h = h + a
        xin = L.norm_apply(lp["norm2"], h, cfg.norm)
        h = h + L.mlp_apply(lp["mlp"], xin, ctx)
        return h

    def body(h, lp):
        fn = jax.checkpoint(layer) if remat else layer
        return fn(h, lp), None

    h, _ = lax.scan(body, h, params["encoder"]["stack"])
    return L.norm_apply(params["encoder"]["final_norm"], h, cfg.norm)


def logits_from_hidden(params, cfg: ArchConfig, h):
    w = params["embed"]["w"].T if cfg.tie_embeddings \
        else params["unembed"]["w"]
    return h @ w


def chunked_ce_loss(params, cfg: ArchConfig, h, targets, ctx: ModelCtx,
                    chunk: int = 512):
    """Cross-entropy over the vocab, scanned over sequence chunks so the
    (B,S,V) logits tensor never materialises (DESIGN.md §5)."""
    B, S, D = h.shape
    w = params["embed"]["w"].T if cfg.tie_embeddings \
        else params["unembed"]["w"]
    if S % chunk:
        chunk = S
    nc = S // chunk
    hc = h.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, nc, chunk).transpose(1, 0, 2)

    def body(acc, xs):
        hi, ti = xs
        logits = (hi @ w).astype(jnp.float32)
        logits = ctx.shard(logits, ("batch", "none", "vocab_act"))
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ti[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = lax.scan(body, jnp.zeros((), jnp.float32), (hc, tc))
    return total / (B * S)
