"""Mamba2 / SSD block — chunked matmul form (state-space duality).

TPU adaptation of arXiv:2405.21060: the sequence is split into chunks of
``ssm_chunk``; within a chunk the SSD quadratic (matmul) form runs on the
MXU, and a short ``lax.scan`` carries the (heads, state, head_dim) SSM
state across chunks.  Decode is the O(1) recurrence.

Layout per block (ngroups = 1, as in the 2.7B config):
    in_x  : (D, d_inner)      main path
    in_z  : (D, d_inner)      gate
    in_B  : (D, N)            input->state projection
    in_C  : (D, N)            state->output projection
    in_dt : (D, nh)           per-head timestep
    conv  : (w, d_inner+2N)   depthwise causal conv over [x, B, C]
    A_log : (nh,)             state decay  (A = -exp(A_log))
    D_res : (nh,)             skip
    out   : (d_inner, D)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ArchConfig
from repro.models.common import dense_init, merge, trunc_normal
from repro.models.layers import ModelCtx


def mamba_init(key, cfg: ArchConfig, dtype):
    D, DI, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    NH, W = cfg.num_ssm_heads, cfg.ssm_conv_width
    ks = jax.random.split(key, 7)
    params, axes = merge(
        ("in_x", dense_init(ks[0], D, DI, "embed,inner", dtype)),
        ("in_z", dense_init(ks[1], D, DI, "embed,inner", dtype)),
        ("in_B", dense_init(ks[2], D, N, "embed,state", dtype)),
        ("in_C", dense_init(ks[3], D, N, "embed,state", dtype)),
        ("in_dt", dense_init(ks[4], D, NH, "embed,none", dtype, bias=True)),
        ("out", dense_init(ks[5], DI, D, "inner,embed", dtype)),
    )
    params["conv"] = trunc_normal(ks[6], (W, DI + 2 * N), 0.3, dtype)
    axes["conv"] = "conv,inner"
    params["A_log"] = jnp.zeros((NH,), jnp.float32)
    axes["A_log"] = "none"
    params["D_res"] = jnp.ones((NH,), jnp.float32)
    axes["D_res"] = "none"
    return params, axes


def _depthwise_causal_conv(x, w):
    """x (B,S,C), w (W,C): causal depthwise conv via shift-and-add
    (W is 4 — unrolled adds beat a conv op at this width)."""
    W = w.shape[0]
    y = x * w[-1]
    for i in range(1, W):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i or None, :]
        shifted = shifted[:, :x.shape[1], :]
        y = y + shifted * w[W - 1 - i]
    return y


def _ssd_chunked(xh, dt, A, B_, C_, chunk, ctx,
                 h0: Optional[jnp.ndarray] = None,
                 head_block: int = 8):
    """SSD in chunked matmul form, processed in sequential head blocks.

    xh (B,S,NH,P) head-split inputs; dt (B,S,NH) post-softplus;
    A (NH,) negative decay; B_, C_ (B,S,N).
    Returns (y (B,S,NH,P), h_final (B,NH,N,P)).

    The intra-chunk decay tensor is (B, nc, Q, Q, NH) fp32 — at jamba
    scale that is hundreds of GB if materialised for all heads at once.
    ``lax.map`` over blocks of ``head_block`` heads keeps the live set
    to one block's worth (the blocks are independent by construction).
    """
    B, S, NH, P = xh.shape
    hb = head_block
    while hb > 1 and NH % hb:
        hb -= 1
    if hb < NH:
        nb = NH // hb
        r = lambda a, ax: jnp.moveaxis(
            a.reshape(a.shape[:ax] + (nb, hb) + a.shape[ax + 1:]), ax, 0)
        xs = (r(xh, 2), r(dt, 2), r(A, 0),
              None if h0 is None else r(h0, 1))

        def blk(args):
            xh_b, dt_b, A_b, h0_b = args
            return _ssd_heads(xh_b, dt_b, A_b, B_, C_, chunk, ctx, h0_b)

        if h0 is None:
            y_b, h_b = lax.map(lambda a: blk(a + (None,)), xs[:3])
        else:
            y_b, h_b = lax.map(blk, xs)
        y = jnp.moveaxis(y_b, 0, 2).reshape(B, S, NH, P)
        h = jnp.moveaxis(h_b, 0, 1).reshape(B, NH, *h_b.shape[3:])
        return y, h
    return _ssd_heads(xh, dt, A, B_, C_, chunk, ctx, h0)


def _ssd_heads(xh, dt, A, B_, C_, chunk, ctx,
               h0: Optional[jnp.ndarray] = None):
    """SSD core for one head block (see _ssd_chunked)."""
    B, S, NH, P = xh.shape
    N = B_.shape[-1]
    nc = S // chunk
    r = lambda a: a.reshape(B, nc, chunk, *a.shape[2:])
    xc, dtc, Bc, Cc = r(xh), r(dt), r(B_), r(C_)

    dA = dtc * A[None, None, None, :]                      # (B,nc,Q,NH) <= 0
    cs = jnp.cumsum(dA, axis=2)                            # within-chunk cumsum

    # ---- intra-chunk (quadratic, MXU-friendly) ----
    G = jnp.einsum("bcqn,bckn->bcqk", Cc.astype(jnp.float32),
                   Bc.astype(jnp.float32))                 # (B,nc,Q,Q)
    decay = jnp.exp(cs[:, :, :, None, :] - cs[:, :, None, :, :])  # (B,nc,Q,K,NH)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    M = jnp.where(mask[None, None, :, :, None],
                  G[..., None] * decay * dtc[:, :, None, :, :], 0.0)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", M, xc.astype(jnp.float32))

    # ---- chunk states ----
    seg = jnp.exp(cs[:, :, -1:, :] - cs)                   # decay to chunk end
    state_c = jnp.einsum("bckn,bckh,bckhp->bchnp",
                         Bc.astype(jnp.float32), seg * dtc,
                         xc.astype(jnp.float32))           # (B,nc,NH,N,P)

    # ---- inter-chunk scan ----
    total = jnp.exp(cs[:, :, -1, :])                       # (B,nc,NH)
    h_init = (jnp.zeros((B, NH, N, P), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))

    def body(h, inputs):
        st, tot = inputs                                   # (B,NH,N,P),(B,NH)
        h_out = h                                          # state BEFORE chunk
        h = h * tot[:, :, None, None] + st
        return h, h_out

    (h_final, h_prev) = lax.scan(
        body, h_init, (state_c.transpose(1, 0, 2, 3, 4),
                       total.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)               # (B,nc,NH,N,P)

    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                         Cc.astype(jnp.float32), jnp.exp(cs), h_prev)
    y = (y_intra + y_inter).reshape(B, S, NH, P)
    return y, h_final


def mamba_cache_init(cfg: ArchConfig, batch: int, dtype):
    NH, N, P = cfg.num_ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    W, DI = cfg.ssm_conv_width, cfg.d_inner
    return {
        "h": jnp.zeros((batch, NH, N, P), jnp.float32),
        "conv": jnp.zeros((batch, W - 1, DI + 2 * N), dtype),
    }


def mamba_apply(p, x, ctx: ModelCtx, *, cache=None):
    """x (B,S,D) -> (B,S,D).  cache => single-step decode recurrence."""
    cfg = ctx.cfg
    B, S, D = x.shape
    DI, N, NH, P = cfg.d_inner, cfg.ssm_state, cfg.num_ssm_heads, cfg.ssm_head_dim

    xz = x @ p["in_x"]["w"]                                # (B,S,DI)
    z = x @ p["in_z"]["w"]
    Bp = x @ p["in_B"]["w"]                                # (B,S,N)
    Cp = x @ p["in_C"]["w"]
    dt = x @ p["in_dt"]["w"] + p["in_dt"]["b"]             # (B,S,NH)
    dt = jax.nn.softplus(dt.astype(jnp.float32))
    A = -jnp.exp(p["A_log"])                               # (NH,)

    conv_in = jnp.concatenate([xz, Bp, Cp], axis=-1)       # (B,S,DI+2N)

    if cache is None or S > 1:
        # full-sequence path (training, or prefill when a cache is given)
        conv_out = _depthwise_causal_conv(conv_in, p["conv"])
        conv_out = jax.nn.silu(conv_out)
        xz_c, Bp_c, Cp_c = jnp.split(conv_out, [DI, DI + N], axis=-1)
        xh = xz_c.reshape(B, S, NH, P)
        xh = ctx.shard(xh, ("batch", "none", "heads_act", "none"))
        chunk = min(cfg.ssm_chunk, S)
        if S % chunk:
            chunk = S            # smoke shapes: single chunk
        h0 = None if cache is None else cache["h"]
        y, h_final = _ssd_chunked(xh, dt, A, Bp_c, Cp_c, chunk, ctx, h0=h0)
        if cache is None:
            new_cache = None
        else:
            W = cfg.ssm_conv_width
            tail = conv_in[:, -(W - 1):, :]
            new_cache = {"h": h_final, "conv": tail}
    else:
        # decode: roll the conv window, O(1) state update
        window = jnp.concatenate([cache["conv"], conv_in], axis=1)  # (B,W,·)
        conv_out = jnp.einsum("bwc,wc->bc", window, p["conv"])[:, None, :]
        conv_out = jax.nn.silu(conv_out)
        xz_c, Bp_c, Cp_c = jnp.split(conv_out, [DI, DI + N], axis=-1)
        xh = xz_c.reshape(B, 1, NH, P)
        dA = jnp.exp(dt[:, 0] * A[None, :])                # (B,NH)
        h = cache["h"] * dA[:, :, None, None] + jnp.einsum(
            "bn,bh,bhp->bhnp", Bp_c[:, 0].astype(jnp.float32),
            dt[:, 0], xh[:, 0].astype(jnp.float32))
        y = jnp.einsum("bn,bhnp->bhp", Cp_c[:, 0].astype(jnp.float32),
                       h)[:, None]                         # (B,1,NH,P)
        new_cache = {"h": h, "conv": window[:, 1:, :]}

    y = y + xh.astype(jnp.float32) * p["D_res"][None, None, :, None]
    y = y.reshape(B, S, DI).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out"]["w"], new_cache
