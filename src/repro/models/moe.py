"""Mixture-of-Experts block with capacity-bucketed sort routing.

TPU-native dispatch (DESIGN.md §5): instead of a (tokens × experts ×
capacity) one-hot einsum — whose dispatch mask alone would be terabytes at
32k context × 160 experts — we

  1. route each token to its top-k experts,
  2. build an (experts, capacity) *gather table* of token ids via an
     argsort over expert assignments (position-within-expert comes from a
     searchsorted rank trick, all O(Tk log Tk) and jit-friendly),
  3. gather tokens into (E, C, D) expert buckets, run the expert FFNs as
     one batched einsum on the MXU, and
  4. scatter-add results back with the router gate weights.

Compute is therefore ≈ active-expert FLOPs × capacity_factor, and the
expert axis shards over the mesh "model" axis (expert parallelism); the
bucket gather/scatter across token-sharded ↔ expert-sharded layouts is
where XLA inserts the all-to-all — visible in the §Roofline collective
term.  Tokens overflowing an expert's capacity are dropped (their residual
path still carries them), matching standard dropped-token MoE semantics.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models import layers
from repro.models.common import dense_init, merge, trunc_normal
from repro.models.layers import ModelCtx, mlp_apply, mlp_init


def moe_init(key, cfg: ArchConfig, dtype):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    kr, ke, ks = jax.random.split(key, 3)
    scale = 1.0 / (D ** 0.5)
    params = {
        "router": {"w": trunc_normal(kr, (D, E), scale, jnp.float32)},
        "wi": trunc_normal(jax.random.fold_in(ke, 0), (E, D, F), scale, dtype),
        "wg": trunc_normal(jax.random.fold_in(ke, 1), (E, D, F), scale, dtype),
        "wo": trunc_normal(jax.random.fold_in(ke, 2), (E, F, D),
                           1.0 / (F ** 0.5), dtype),
    }
    axes = {
        "router": {"w": "embed,none"},
        "wi": "experts,embed,mlp",
        "wg": "experts,embed,mlp",
        "wo": "experts,mlp,embed",
    }
    if cfg.num_shared_experts:
        p, a = mlp_init(ks, cfg, dtype,
                        d_ff=cfg.d_ff * cfg.num_shared_experts)
        params["shared"], axes["shared"] = p, a
    return params, axes


def _capacity(cfg: ArchConfig, num_tokens: int) -> int:
    c = int(cfg.moe_capacity_factor * num_tokens * cfg.experts_per_token
            / cfg.num_experts)
    c = max(c, 1)
    return ((c + 7) // 8) * 8        # 8-aligned buckets for tiling


def moe_apply(p, x, ctx: ModelCtx):
    """x (B,S,D) -> (B,S,D).

    With ``ctx.moe_groups == G > 1`` tokens are routed in G independent
    groups laid out on the mesh "data" axis: every group's top-k, sort,
    bucket-build, gather and combine are batched over a G axis that is
    *sharded over data*, so the dispatch gather reads only device-local
    rows (no replication of the full token buffer — §Perf H1 iter 4).
    Per-group capacity keeps the total bucket count identical; dropping
    becomes group-local, the standard grouped-MoE semantics.
    """
    cfg = ctx.cfg
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    G = ctx.moe_groups
    while G > 1 and T % G:
        G -= 1
    if G > 1:
        y = _moe_grouped(p, x.reshape(T, D), ctx, G)
        y = y.reshape(B, S, D)
        if "shared" in p:
            y = y + mlp_apply(p["shared"], x, ctx)
        return y
    C = _capacity(cfg, T)
    xf = x.reshape(T, D)

    # --- route ---
    logits = (xf.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (T,E)
    gate, eidx = jax.lax.top_k(probs, K)                       # (T,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # --- position-within-expert via stable argsort + rank trick ---
    flat_e = eidx.reshape(-1)                                  # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)     # token ids
    flat_g = gate.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first_of_e = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos = jnp.arange(T * K, dtype=jnp.int32) - first_of_e      # rank in expert
    keep = pos < C

    # --- (E, C) gather/combine tables; dropped tokens land in a scratch
    # column.  The gate table lets the combine be a single segment-sum
    # from the expert buckets back to tokens (no (T·k, D) re-gather —
    # EXPERIMENTS.md §Perf H1 iter 2).
    col = jnp.where(keep, pos, C)
    table = jnp.full((E, C + 1), T, dtype=jnp.int32)           # T = pad row id
    table = table.at[sorted_e, col].set(jnp.where(keep, flat_t[order], T))
    gate_tab = jnp.zeros((E, C + 1), jnp.float32)
    gate_tab = gate_tab.at[sorted_e, col].set(
        jnp.where(keep, flat_g[order], 0.0))
    table, gate_tab = table[:, :C], gate_tab[:, :C]

    # --- expert compute on (E, C, D) buckets ---
    xpad = jnp.concatenate([xf, jnp.zeros((1, D), xf.dtype)], axis=0)
    if ctx.moe_dshard:
        # gather with D sharded over 'model': each device gathers its own
        # D-slice locally; the (E/model)-layout needed by the expert
        # matmul is restored by an all-to-all instead of replicating the
        # full token buffer (§Perf H1 iter 3, dispatch side)
        xpad = ctx.shard(xpad, ("none", "mlp_act"))
        xe = xpad[table]                                       # (E,C,D)
        xe = ctx.shard(xe, ("none", "capacity", "mlp_act"))
        xe = ctx.shard(xe, ("expert", "capacity", "none"))
    else:
        xe = xpad[table]                                       # (E,C,D)
        xe = ctx.shard(xe, ("expert", "capacity", "none"))
    act = jax.nn.silu
    h = act(jnp.einsum("ecd,edf->ecf", xe, p["wi"])) * \
        jnp.einsum("ecd,edf->ecf", xe, p["wg"])
    h = ctx.shard(h, ("expert", "capacity", "none"))  # expert owns 'model'
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"])                # (E,C,D)

    # --- combine: weight buckets by their gates and scatter-add straight
    # back to token order (one segment-sum over the E·C bucket rows) ---
    yw = ye * gate_tab[..., None].astype(ye.dtype)
    if ctx.moe_dshard:
        # reshard expert outputs (E/model, C, D) -> (E, C, D/model) first:
        # the scatter-add then produces D-sharded partials with NO full-D
        # all-reduce over the model axis (§Perf H1 iter 3) — the expert ->
        # token return trip becomes an all-to-all instead of a 21 GB AR
        yw = ctx.shard(yw, ("none", "capacity", "mlp_act"))
    yf = jax.ops.segment_sum(yw.reshape(E * C, D).astype(jnp.float32),
                             table.reshape(E * C), num_segments=T + 1)[:T]
    if ctx.moe_dshard:
        yf = ctx.shard(yf, ("none", "mlp_act"))
    y = yf.reshape(B, S, D).astype(x.dtype)

    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, ctx)
    return y


def _moe_grouped(p, xf, ctx: ModelCtx, G: int):
    """Grouped (per-data-shard) routing.  xf (T, D) -> (T, D).

    Every routing step carries a leading G axis sharded over "data"; the
    expert axis shards over "model".  The dispatch gather is batched over
    G (operand and indices share the G sharding), so XLA partitions it
    with zero cross-device traffic; the only activation collective left
    is the combine's partial-sum reduction over the model axis.
    """
    cfg = ctx.cfg
    T, D = xf.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    Tg = T // G
    Cg = _capacity(cfg, Tg)
    xg = ctx.shard(xf.reshape(G, Tg, D), ("group", "none", "none"))

    logits = (xg.astype(jnp.float32) @ p["router"]["w"])       # (G,Tg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)                       # (G,Tg,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    Ng = Tg * K
    flat_e = eidx.reshape(G, Ng)
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Tg, dtype=jnp.int32), K)[None], (G, Ng))
    flat_g = gate.reshape(G, Ng)
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    first_of = jax.vmap(
        lambda s: jnp.searchsorted(s, s, side="left"))(sorted_e)
    pos = jnp.arange(Ng, dtype=jnp.int32)[None] - first_of
    keep = pos < Cg
    col = jnp.where(keep, pos, Cg)
    tok_sorted = jnp.take_along_axis(flat_t, order, axis=-1)
    gat_sorted = jnp.take_along_axis(flat_g, order, axis=-1)

    def build_tables(se, co, ts, gs, kp):
        tab = jnp.full((E, Cg + 1), Tg, jnp.int32)
        tab = tab.at[se, co].set(jnp.where(kp, ts, Tg))
        gtab = jnp.zeros((E, Cg + 1), jnp.float32)
        gtab = gtab.at[se, co].set(jnp.where(kp, gs, 0.0))
        return tab[:, :Cg], gtab[:, :Cg]

    table, gate_tab = jax.vmap(build_tables)(sorted_e, col, tok_sorted,
                                             gat_sorted, keep)
    table = ctx.shard(table, ("group", "expert", "none"))

    xpad = jnp.concatenate(
        [xg, jnp.zeros((G, 1, D), xg.dtype)], axis=1)          # (G,Tg+1,D)
    xe = jax.vmap(lambda xp, tb: xp[tb])(xpad, table)          # (G,E,Cg,D)
    xe = ctx.shard(xe, ("group", "expert", "none", "none"))

    act = jax.nn.silu
    h = act(jnp.einsum("gecd,edf->gecf", xe, p["wi"])) * \
        jnp.einsum("gecd,edf->gecf", xe, p["wg"])
    h = ctx.shard(h, ("group", "expert", "none", "none"))
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"])              # (G,E,Cg,D)

    yw = ye * gate_tab[..., None].astype(ye.dtype)

    def combine(yg, tb):
        return jax.ops.segment_sum(
            yg.reshape(E * Cg, D).astype(jnp.float32),
            tb.reshape(E * Cg), num_segments=Tg + 1)[:Tg]

    yf = jax.vmap(combine)(yw, table)                          # (G,Tg,D)
    yf = ctx.shard(yf, ("group", "none", "none"))
    return yf.reshape(T, D).astype(xf.dtype)


def aux_load_balance_loss(p, x, cfg: ArchConfig) -> jnp.ndarray:
    """Switch-style load-balance auxiliary loss (beyond-paper extra)."""
    B, S, D = x.shape
    logits = x.reshape(-1, D).astype(jnp.float32) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    _, eidx = jax.lax.top_k(probs, cfg.experts_per_token)
    onehot = jax.nn.one_hot(eidx[..., 0], cfg.num_experts)
    frac_tokens = onehot.mean(0)
    frac_probs = probs.mean(0)
    return cfg.num_experts * jnp.sum(frac_tokens * frac_probs)
