"""The paper's model family: an L-layer MLP over binary medication features.

Params are a tuple of per-layer dicts ``{"w": (fan_in, fan_out), "b": (fan_out,)}``
— the exact structure the SCBF channel algebra (repro.core.channels) is
defined over.  Forward is ReLU-activated with a single logit output.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


def init_mlp(features: Sequence[int], key: jax.Array) -> Tuple[dict, ...]:
    """He-init an MLP with the given feature sizes (incl. input and output)."""
    params = []
    keys = jax.random.split(key, len(features) - 1)
    for k, fin, fout in zip(keys, features[:-1], features[1:]):
        w = jax.random.normal(k, (fin, fout), jnp.float32) * jnp.sqrt(2.0 / fin)
        b = jnp.zeros((fout,), jnp.float32)
        params.append({"w": w, "b": b})
    return tuple(params)


def mlp_forward(params: Sequence[dict], x: jnp.ndarray) -> jnp.ndarray:
    """Returns logits of shape (batch,) for a single-output head, else
    (batch, fan_out)."""
    h = x
    for i, layer in enumerate(params):
        h = h @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h[..., 0] if h.shape[-1] == 1 else h


def mlp_activations(params: Sequence[dict], x: jnp.ndarray):
    """Post-ReLU activations per hidden layer (for APoZ pruning)."""
    acts = []
    h = x
    for i, layer in enumerate(params):
        h = h @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
            acts.append(h)
    return acts
