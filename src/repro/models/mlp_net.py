"""The paper's model family: an L-layer MLP over binary medication features.

Params are a tuple of per-layer dicts ``{"w": (fan_in, fan_out), "b": (fan_out,)}``
— the exact structure the SCBF channel algebra (repro.core.channels) is
defined over.  Forward is ReLU-activated with a single logit output.

``neuron_masks`` (mask-mode SCBFwP, repro.core.pruning) is an optional
tuple of per-hidden-layer ``(H_l,)`` float keep-masks (1.0 kept /
0.0 pruned).  Masking the post-ReLU activation realises structural
pruning without changing any array shape: a pruned neuron's activation
is exactly zero, so it contributes nothing forward, its incoming-weight
and bias gradients vanish through the mask, and its outgoing-weight
gradients vanish through the zero activation — the masked network
computes the same function as the physically-compacted one while every
jitted program stays shape-stable.  ``None`` traces the exact original
(unmasked) computation.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


def init_mlp(features: Sequence[int], key: jax.Array) -> Tuple[dict, ...]:
    """He-init an MLP with the given feature sizes (incl. input and output)."""
    params = []
    keys = jax.random.split(key, len(features) - 1)
    for k, fin, fout in zip(keys, features[:-1], features[1:]):
        w = jax.random.normal(k, (fin, fout), jnp.float32) * jnp.sqrt(2.0 / fin)
        b = jnp.zeros((fout,), jnp.float32)
        params.append({"w": w, "b": b})
    return tuple(params)


def mlp_forward(params: Sequence[dict], x: jnp.ndarray,
                neuron_masks: Optional[Sequence[jnp.ndarray]] = None
                ) -> jnp.ndarray:
    """Returns logits of shape (batch,) for a single-output head, else
    (batch, fan_out)."""
    h = x
    for i, layer in enumerate(params):
        h = h @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
            if neuron_masks is not None:
                h = h * neuron_masks[i]
    return h[..., 0] if h.shape[-1] == 1 else h


def mlp_activations(params: Sequence[dict], x: jnp.ndarray,
                    neuron_masks: Optional[Sequence[jnp.ndarray]] = None):
    """Post-ReLU (mask-applied) activations per hidden layer (for APoZ
    pruning).  Under a keep-mask, pruned neurons read exactly zero —
    APoZ 1.0 — and the pruning planner excludes them explicitly."""
    acts = []
    h = x
    for i, layer in enumerate(params):
        h = h @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
            if neuron_masks is not None:
                h = h * neuron_masks[i]
            acts.append(h)
    return acts
