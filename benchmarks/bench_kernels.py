"""Kernel µbenches: fused Pallas pass vs the unfused jnp reference.

On this CPU container the Pallas kernels run in interpret mode, so wall
times are NOT TPU-representative; the meaningful derived metric is the
modelled HBM traffic (the fused kernels halve gradient-matrix reads).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.kernels import ops, ref

# module-level jitted references: the per-shape lambdas used to rebuild
# the wrapper (and its compilation cache) on every loop iteration
# (tracelint TL001) — repeated shapes retraced instead of reusing
_channel_norms_ref = jax.jit(ref.channel_norms_ref)
_select_mask_ref = jax.jit(ref.select_mask_ref)
_select_compact_ref = jax.jit(ref.select_compact_ref,
                              static_argnames=("capacity",))
_apoz_counts_ref = jax.jit(ref.apoz_counts_ref)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shapes", default="256x256,1024x512,2917x256")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for spec in args.shapes.split(","):
        m, n = map(int, spec.split("x"))
        g = jax.random.normal(jax.random.PRNGKey(0), (m, n), jnp.float32)
        t_ref = time_call(_channel_norms_ref, g)
        emit(f"channel_norms_ref_{spec}", t_ref,
             f"traffic={2*m*n*4}B (two passes)")
        t_k = time_call(lambda g: ops.channel_norms(g), g)
        emit(f"channel_norms_pallas_{spec}", t_k,
             f"traffic={m*n*4}B (fused, interpret-mode timing)")

        row, col = ref.channel_norms_ref(g)
        thr = jnp.median(row[:, None] + col[None, :])
        emit(f"select_mask_ref_{spec}",
             time_call(_select_mask_ref, g, row, col, thr),
             f"traffic={3*m*n*4}B (mask materialised)")
        emit(f"select_mask_pallas_{spec}",
             time_call(lambda: ops.select_mask(g, row, col, thr)),
             f"traffic={2*m*n*4}B (fused)")

        # fused select-and-compact: emits the COO upload buffers directly
        # (what repro.comm.wire ships), so the exchange never touches a
        # dense masked tensor
        from repro.comm import wire
        _, _, cnt = ref.select_compact_ref(g, row, col, thr)
        nnz = int(cnt)
        # bounded buffer sized to the kept count — with the m*n default
        # the per-step output revisits dominate and the timing is
        # meaningless; this kernel always runs interpreted (sequential
        # grid), so its rows are NOT comparable to compiled-kernel rows
        cap = max(8, nnz)
        emit(f"select_compact_ref_{spec}",
             time_call(_select_compact_ref, g, row, col, thr, capacity=cap),
             f"encoded={wire.coo_bytes(nnz, m*n)}B coo ({nnz} kept)")
        emit(f"select_compact_pallas_{spec}",
             time_call(lambda: ops.select_compact(g, row, col, thr,
                                                  capacity=cap)),
             f"encoded={wire.cheapest_bytes(nnz, m*n)[1]}B cheapest-codec "
             "(always interpret mode — not comparable to compiled rows)")

        a = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (m, n)))
        emit(f"apoz_ref_{spec}", time_call(_apoz_counts_ref, a), "")
        emit(f"apoz_pallas_{spec}", time_call(lambda: ops.apoz_counts(a)),
             "interpret-mode timing")


if __name__ == "__main__":
    main()
