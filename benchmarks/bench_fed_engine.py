"""Federation-engine scaling: vmapped cohort vs. sequential client loop.

Three sections, all emitted in the repo's ``name,us_per_call,derived``
CSV convention (benchmarks/common.py) and optionally as one JSON blob
(``--json-out``, written by the CI bench-smoke job as
BENCH_fed_engine.json so the perf trajectory accumulates):

1. **K-scaling** — one full SCBF round (local training, channel
   selection, wire encoding) for K ∈ {5, 50, 500} clients under both
   engines, per-round wall clock + batched/sequential speedup.
2. **Compile counts** — a seeded 30-round participation trace with
   ``sample_fraction=0.5`` and nonzero dropout, replayed under the
   ``exact`` (pre-bucketing) and ``pow2`` bucket policies: the exact
   policy compiles ``_scbf_pass`` once per distinct P, pow2 once per
   bucket (the tentpole fix).
3. **Pod scaling** (``--pods N``) — the bucketed round sharded over a
   pod mesh vs. single-device.  ``--pods`` forces the host device
   count, so it must be given on the command line (the flag is applied
   before jax is imported).
4. **Fused round loop** (``--fuse``) — the per-round batched path
   (engine round + host aggregate per round) vs whole ``lax.scan``
   chunks with on-device aggregation at K=500 full participation, plus
   a 30-round varying-P trace asserting the fused path stays <= 2
   compiles (the run-constant (S, B) plan).  Also times the same fused
   trace with the flight recorder on (repro.obs device metrics +
   chunk-boundary offload + event log) — the telemetry overhead gated
   by check_fed_regression.py and documented in docs/OBSERVABILITY.md.
5. **Fused SCBFwP** (``--prune``) — mask-mode pruning on the fused
   path (``prune_impl="mask"``): cold wall clock of fused-SCBFwP vs
   per-round reshape-SCBFwP (which recompiles every program after each
   prune step — the defect the keep-masks remove), the fused compile
   count (<= 2 asserted), and the steady-state (warmed-cache)
   fused-SCBFwP vs fused-SCBF time saving — the paper's claim that
   pruning saves wall time, now measured at fused speed.
6. **Chaos** (``--chaos``) — the resilience tax: a fused run with the
   fault model disarmed vs armed-with-zero-rates (bit-identical results
   and <= 2 compiles asserted, overhead gated by
   check_fed_regression.py), plus a seeded fault storm whose rejection
   counters and no-NaN final params prove the admission gate holds
   (docs/FED_ENGINE.md §Fault model & resilience).

    PYTHONPATH=src python -m benchmarks.bench_fed_engine --quick
    PYTHONPATH=src python -m benchmarks.bench_fed_engine --quick --pods 4
    PYTHONPATH=src python -m benchmarks.bench_fed_engine --quick --fuse
    PYTHONPATH=src python -m benchmarks.bench_fed_engine --quick --prune
    PYTHONPATH=src python -m benchmarks.bench_fed_engine          # larger shards
"""
from __future__ import annotations

import argparse
import json
import os
import time

# --pods shards the cohort over forced host devices; the flag must take
# effect before the FIRST jax import (jax locks the device count), so
# pre-parse it here, ahead of everything that pulls in jax.
_pre = argparse.ArgumentParser(add_help=False)
_pre.add_argument("--pods", type=int, default=1)
_PODS = max(1, _pre.parse_known_args()[0].pods)
if _PODS > 1:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_PODS}")

# ruff: noqa: E402
import jax
import numpy as np

from benchmarks.common import emit
from repro.config import FedConfig, ScbfConfig
from repro.fed.cohort import bucket_size
from repro.fed.engine import (fused_compile_count, make_engine,
                              reset_fused_compile_count,
                              reset_scbf_compile_count, scbf_compile_count)
from repro.fed.scheduler import SyncScheduler
from repro.fed.strategy import RoundContribution, ScbfSum
from repro.models.mlp_net import init_mlp
from repro.obs import EMITTER, metrics as obsm, report as obs_report, \
    trace as obstrace

# Version of the --json-out blob (checked by check_fed_regression.py —
# a mismatched baseline is refused, not mis-compared).  2 = the
# flight-recorder telemetry section (fused.telemetry + top-level
# schema/emitter handshake); 3 = the chaos section (fault-free
# resilience overhead + seeded chaos-run stats).
RESULT_SCHEMA = 3


def _synthetic_clients(K: int, n_per_client: int, d: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(K):
        x = (rng.random((n_per_client, d)) < 0.1).astype(np.float32)
        y = (rng.random(n_per_client) < 0.5).astype(np.float32)
        out.append((x, y))
    return out


def time_round(eng, params, cfg, lr, K, batch_size, iters: int = 3):
    """Median seconds per full SCBF round (train+select+encode)."""
    part = np.arange(K)
    key = jax.random.PRNGKey(0)
    times = []
    payloads = []
    for it in range(iters + 1):                 # first round = compile warmup
        key, kc, ks, kd = jax.random.split(key, 4)
        ckeys = jax.random.split(kc, K)
        skeys = jax.random.split(ks, K)
        dp_keys = jax.random.split(kd, K)
        t0 = time.perf_counter()
        payloads, stats = eng.scbf_round(params, part, lr, ckeys, skeys,
                                         dp_keys, cfg)
        dt = time.perf_counter() - t0
        if it:                                  # drop the warmup round
            times.append(dt)
    times.sort()
    return times[len(times) // 2], payloads


def run(quick: bool = True, cohort_sizes=(5, 50, 500)):
    """Section 1: per-round K-scaling, sequential vs batched."""
    n_per_client = 64 if quick else 512
    d = 128 if quick else 512
    feats = (d, 32, 8, 1) if quick else (d, 128, 32, 1)
    batch_size = 32 if quick else 128
    cfg = ScbfConfig(upload_rate=0.10, num_clients=max(cohort_sizes))
    params = init_mlp(feats, jax.random.PRNGKey(1))
    lr = 0.05

    rows = []
    for K in cohort_sizes:
        clients = _synthetic_clients(K, n_per_client, d)
        seq = make_engine("sequential", clients, batch_size, epochs=1)
        bat = make_engine("batched", clients, batch_size, epochs=1)
        t_seq, p_seq = time_round(seq, params, cfg, lr, K, batch_size)
        t_bat, p_bat = time_round(bat, params, cfg, lr, K, batch_size)
        speedup = t_seq / t_bat
        upload = sum(p.nbytes for p in p_bat)
        assert sum(p.nbytes for p in p_seq) == upload, \
            "engines must ship identical bytes"
        emit(f"fed_round_seq_K{K}", t_seq * 1e6,
             f"clients={K};n_per_client={n_per_client}")
        emit(f"fed_round_batched_K{K}", t_bat * 1e6,
             f"clients={K};speedup_vs_seq={speedup:.1f}x;"
             f"upload_bytes={upload}")
        rows.append({"K": K, "seq_s": t_seq, "batched_s": t_bat,
                     "speedup": speedup, "upload_bytes": upload})
    return rows


def run_compile_counts(quick: bool = True, rounds: int = 30,
                       K: int = 32, seed: int = 0):
    """Section 2: compile-per-bucket vs compile-per-P on a varying-P
    trace — the recompile bug the bucketed engine fixes."""
    n_per_client = 32 if quick else 256
    d = 64 if quick else 256
    feats = (d, 16, 4, 1) if quick else (d, 64, 16, 1)
    batch_size = 16 if quick else 64
    cfg = ScbfConfig(upload_rate=0.10, num_clients=K)
    fed = FedConfig(sample_fraction=0.5, dropout_rate=0.2)
    clients = _synthetic_clients(K, n_per_client, d)
    params = init_mlp(feats, jax.random.PRNGKey(1))

    out = {}
    for policy in ("exact", "pow2"):
        eng = make_engine("batched", clients, batch_size, epochs=1,
                          bucket=policy)
        sched = SyncScheduler(K, fed, seed=seed)   # same trace both policies
        key = jax.random.PRNGKey(seed)
        reset_scbf_compile_count()
        seen_p, seen_buckets, upload = set(), set(), 0
        t0 = time.perf_counter()
        for r in range(rounds):
            plan = sched.plan(r)
            P = plan.num_participants
            if not P:
                continue
            seen_p.add(P)
            seen_buckets.add(bucket_size(P, K, policy))
            key, kc, ks, kd = jax.random.split(key, 4)
            payloads, _ = eng.scbf_round(
                params, plan.participants, 0.05,
                jax.random.split(kc, P), jax.random.split(ks, P),
                jax.random.split(kd, P), cfg)
            upload += sum(p.nbytes for p in payloads)
        wall = time.perf_counter() - t0
        compiles = scbf_compile_count()
        emit(f"fed_compiles_{policy}", wall / rounds * 1e6,
             f"rounds={rounds};distinct_P={len(seen_p)};"
             f"compiles={compiles};upload_bytes={upload}")
        out[policy] = {"rounds": rounds, "distinct_P": len(seen_p),
                       "distinct_buckets": len(seen_buckets),
                       "compiles": compiles, "total_s": wall,
                       "upload_bytes": upload}
    assert out["pow2"]["compiles"] <= out["pow2"]["distinct_buckets"], \
        "bucketed engine must compile at most once per bucket"
    return out


def _round_key_rows(key, participants_sizes):
    """Per-round (ckeys, skeys, dp_keys) rows off one key stream — the
    same derivation order for the per-round and fused drivers, so the
    two paths are comparable AND must ship identical bytes."""
    rows = []
    for p in participants_sizes:
        key, kc, ks, kd = jax.random.split(key, 4)
        if p:
            rows.append(tuple(np.asarray(jax.random.split(k, p))
                              for k in (kc, ks, kd)))
        else:
            empty = np.zeros((0, 2), np.uint32)
            rows.append((empty, empty, empty))
    return key, rows


def run_fused_section(quick: bool = True, rounds: int = 12,
                      fuse: int = 6, trace_rounds: int = 30,
                      events_out=None):
    """Section 4 (``--fuse``): the device-resident fused round loop.

    a) K=500 full participation: ``rounds`` whole SCBF rounds through
       the per-round batched path (engine round + host ScbfSum
       aggregate) vs the fused path (plan → one lax.scan chunk per
       ``fuse`` rounds → boundary wire emit), same key stream, identical
       upload bytes asserted.  The acceptance bar is >= 2x
       round-throughput.
    b) a 30-round varying-P trace (sample_fraction=0.5, dropout=0.2):
       the fused (S, B) plan is padded to a run-constant shape, so the
       whole trace must cost <= 2 fused compiles.
    """
    K = 500
    n_per_client = 64 if quick else 512
    d = 128 if quick else 512
    feats = (d, 32, 8, 1) if quick else (d, 128, 32, 1)
    batch_size = 32 if quick else 128
    cfg = ScbfConfig(upload_rate=0.10, num_clients=K)
    clients = _synthetic_clients(K, n_per_client, d)
    params = init_mlp(feats, jax.random.PRNGKey(1))
    eng = make_engine("batched", clients, batch_size, epochs=1)
    part = np.arange(K)
    lr = 0.05
    strategy = ScbfSum()
    counts = eng.counts[part]

    # ---- per-round batched path: K-round loop, host aggregate ----
    _, warm = _round_key_rows(jax.random.PRNGKey(9), [K])
    state = strategy.init(tuple(params))
    payloads, _ = eng.scbf_round(state.params, part, lr, *warm[0], cfg)
    state = strategy.aggregate(state, RoundContribution(
        num_examples=counts, staleness=np.zeros(K), payloads=payloads))
    _, rows = _round_key_rows(jax.random.PRNGKey(0), [K] * rounds)
    state = strategy.init(tuple(params))
    per_round_bytes = 0
    t0 = time.perf_counter()
    for ck, sk, dk in rows:
        payloads, _ = eng.scbf_round(state.params, part, lr, ck, sk, dk,
                                     cfg)
        per_round_bytes += sum(p.nbytes for p in payloads)
        state = strategy.aggregate(state, RoundContribution(
            num_examples=counts, staleness=np.zeros(K), payloads=payloads))
    per_round_s = (time.perf_counter() - t0) / rounds

    # ---- fused path: same trace, chunks of `fuse` rounds ----
    B = eng.fused_num_slots(K)

    def fused_run(rows, params0, collect=False):
        # fresh device copies: the chunk call donates its params buffers
        # on backends that support donation, and params0 is reused by
        # the caller (warmup run, then the timed run)
        state_p = jax.tree_util.tree_map(lambda a: a + 0, tuple(params0))
        total = 0
        for c0 in range(0, len(rows), fuse):
            chunk = rows[c0:c0 + fuse]
            plan = eng.prepare_fused_plan(
                [part] * len(chunk), [lr] * len(chunk),
                [r[0] for r in chunk], [r[1] for r in chunk],
                [r[2] for r in chunk], horizon=fuse, num_slots=B)
            if collect:
                state_p, masked, masks, met = eng.fused_scbf_chunk(
                    state_p, plan, cfg, collect=True)
            else:
                state_p, masked, masks = eng.fused_scbf_chunk(state_p,
                                                              plan, cfg)
            for pls, _ in eng.emit_fused_payloads(masked, masks, plan):
                total += sum(p.nbytes for p in pls)
            if collect:
                # the driver's pattern: ONE offload per chunk boundary,
                # then host-side round events off the fetched metrics
                for i, dm in enumerate(obsm.offload(met,
                                                    rounds=plan.rounds)):
                    obstrace.event("round", loop=c0 + i,
                                   participants=dm["participants"],
                                   train_loss=dm["train_loss"],
                                   sparse_bytes=dm["sparse_bytes"],
                                   codec_bytes=dm["codec_bytes"])
        return state_p, total

    _, warm_rows = _round_key_rows(jax.random.PRNGKey(9), [K] * fuse)
    fused_run(warm_rows, params)                    # compile warmup
    _, rows = _round_key_rows(jax.random.PRNGKey(0), [K] * rounds)
    t0 = time.perf_counter()
    _, fused_bytes = fused_run(rows, params)
    fused_s = (time.perf_counter() - t0) / rounds
    assert fused_bytes == per_round_bytes, \
        "fused path must ship identical bytes"
    speedup = per_round_s / fused_s
    emit(f"fed_round_fused_K{K}", fused_s * 1e6,
         f"fuse_rounds={fuse};speedup_vs_per_round={speedup:.1f}x;"
         f"upload_bytes={fused_bytes}")

    # ---- telemetry overhead: same fused trace, flight recorder on ----
    # Warm the collect=True program outside any recording (its events
    # no-op), then time ALTERNATING plain/recorded repeats and take the
    # min of each — both sides must sample the same process state, or
    # allocator warm-up between two distant timings swamps the real
    # delta.  The recorded side carries the full telemetry cost: the
    # on-device MetricsCarry arithmetic, the one chunk-boundary
    # offload, and the host event log.  Gated (<= 25%) by
    # check_fed_regression.py; the measured number is committed in
    # docs/OBSERVABILITY.md.
    fused_run(warm_rows, params, collect=True)
    plain_ts, telem_ts = [], []
    rec = obstrace.Recorder()
    for _ in range(3):
        t0 = time.perf_counter()
        fused_run(rows, params)
        plain_ts.append(time.perf_counter() - t0)
        rec = obstrace.Recorder()
        with obstrace.recording(recorder=rec):
            t0 = time.perf_counter()
            _, telem_bytes = fused_run(rows, params, collect=True)
            telem_ts.append(time.perf_counter() - t0)
        assert telem_bytes == per_round_bytes, \
            "telemetry must not change what ships"
    plain_s = min(plain_ts) / rounds
    telem_s = min(telem_ts) / rounds
    overhead = telem_s / plain_s - 1.0
    if events_out:
        rec.write(events_out)
    emit(f"fed_round_fused_telemetry_K{K}", telem_s * 1e6,
         f"overhead_vs_plain={overhead:.1%};"
         f"host_offloads={rec.counters['host_offloads']}")

    # ---- compile-count trace: varying P, one run-constant (S, B) ----
    Kt = 32
    t_clients = _synthetic_clients(Kt, 32 if quick else 256,
                                   64 if quick else 256)
    t_feats = (64, 16, 4, 1) if quick else (256, 64, 16, 1)
    t_params = init_mlp(t_feats, jax.random.PRNGKey(1))
    t_cfg = ScbfConfig(upload_rate=0.10, num_clients=Kt)
    fed = FedConfig(sample_fraction=0.5, dropout_rate=0.2)
    sched = SyncScheduler(Kt, fed, seed=0)
    t_eng = make_engine("batched", t_clients, 16 if quick else 64,
                        epochs=1)
    Bt = t_eng.fused_num_slots(sched.max_participants)
    S = 8
    reset_fused_compile_count()
    key = jax.random.PRNGKey(0)
    seen_p = set()
    t0 = time.perf_counter()
    state_p = tuple(t_params)
    r0 = 0
    while r0 < trace_rounds:
        plans = sched.plan_horizon(r0, min(S, trace_rounds - r0))
        parts = [p.participants for p in plans]
        seen_p.update(p.num_participants for p in plans
                      if p.num_participants)
        key, rows = _round_key_rows(key, [p.size for p in parts])
        plan = t_eng.prepare_fused_plan(
            parts, [0.05] * len(parts), [r[0] for r in rows],
            [r[1] for r in rows], [r[2] for r in rows],
            horizon=S, num_slots=Bt)
        state_p, masked, masks = t_eng.fused_scbf_chunk(state_p, plan,
                                                        t_cfg)
        t_eng.emit_fused_payloads(masked, masks, plan)
        r0 += len(plans)
    trace_wall = time.perf_counter() - t0
    compiles = fused_compile_count()
    assert compiles <= 2, \
        f"fused varying-P trace must stay <= 2 compiles, got {compiles}"
    emit(f"fed_fused_compiles_K{Kt}", trace_wall / trace_rounds * 1e6,
         f"rounds={trace_rounds};distinct_P={len(seen_p)};"
         f"compiles={compiles}")
    return {"K": K, "rounds": rounds, "fuse_rounds": fuse,
            "per_round_s": per_round_s, "fused_s": fused_s,
            "speedup": speedup, "upload_bytes": fused_bytes,
            "telemetry": {"overhead": overhead,
                          "fused_plain_s": plain_s,
                          "fused_telemetry_s": telem_s,
                          "summary": obs_report.summarize(rec.events)},
            "compile_trace": {"rounds": trace_rounds,
                              "distinct_P": len(seen_p),
                              "compiles": compiles,
                              "total_s": trace_wall}}


def run_prune_section(quick: bool = True, loops: int = 16, fuse: int = 4,
                      K: int = 8):
    """Section 5 (``--prune``): SCBFwP on the fused device-resident path.

    a) **cold** wall clock (compiles included, one fresh run each):
       fused mask-mode SCBFwP vs per-round reshape SCBFwP — reshape
       recompiles every jitted program after each prune step while the
       masked fused run stays at <= 2 compiles (asserted), so the ratio
       is the recompile defect the keep-masks remove; gated in CI.
    b) **steady state** (identical warmup run first, so every program
       is cached): fused-SCBFwP vs fused-SCBF — the paper's §3 claim
       that pruning saves wall time, measured as pure execution.
    """
    from repro.core.scbf import run_federated
    from repro.data.medical import generate_cohort

    adm = 4000 if quick else 12000
    med = 128 if quick else 256
    feats = (med, 256, 64, 1) if quick else (med, 512, 128, 1)
    cohort = generate_cohort(num_admissions=adm, num_medicines=med,
                             num_risk_medicines=med // 4,
                             num_interactions=8, seed=0)

    def tcfg(fuse_rounds, impl=None):
        from repro.config import TrainConfig
        return TrainConfig(
            learning_rate=0.05, global_loops=loops, local_batch_size=64,
            local_epochs=1, eval_every=loops,
            scbf=ScbfConfig(upload_rate=0.10, num_clients=K,
                            prune=impl is not None, prune_rate=0.25,
                            prune_total=0.5, prune_impl=impl or "reshape"),
            fed=FedConfig(fuse_rounds=fuse_rounds))

    def timed(cfg):
        t0 = time.perf_counter()
        res = run_federated(cohort, cfg, method="scbf",
                            mlp_features=feats)
        return time.perf_counter() - t0, res

    # ---- cold: fused mask vs per-round reshape, compiles included ----
    reset_fused_compile_count()
    fused_wp_cold, res = timed(tcfg(fuse, "mask"))
    compiles = fused_compile_count()
    assert compiles <= 2, \
        f"fused SCBFwP must stay <= 2 compiles, got {compiles}"
    # records report post-step sizes, so the true starting geometry is
    # the model spec itself, not records[0]
    hidden0 = tuple(feats[1:-1])
    hidden1 = res.records[-1].hidden_sizes
    assert sum(hidden1) <= sum(hidden0) // 2, \
        "prune_total=0.5 must actually halve the hidden neurons"
    per_round_wp_cold, _ = timed(tcfg(1, "reshape"))
    speedup = per_round_wp_cold / fused_wp_cold
    emit(f"fed_fused_scbfwp_K{K}", fused_wp_cold / loops * 1e6,
         f"loops={loops};fuse_rounds={fuse};compiles={compiles};"
         f"speedup_vs_per_round_wp={speedup:.1f}x;"
         f"hidden={hidden0}->{hidden1}")

    # ---- steady state: warmed fused SCBFwP vs warmed fused SCBF ----
    # best-of-2 on both sides: a single warmed repeat can still eat a
    # GC/allocator hiccup from the earlier (large-K) sections
    fused_wp_s = min(timed(tcfg(fuse, "mask"))[0] for _ in range(2))
    timed(tcfg(fuse))                                 # warm no-prune run
    fused_scbf_s = min(timed(tcfg(fuse))[0] for _ in range(2))
    time_saving = 1.0 - fused_wp_s / fused_scbf_s
    emit(f"fed_fused_scbfwp_steady_K{K}", fused_wp_s / loops * 1e6,
         f"fused_scbf_us={fused_scbf_s / loops * 1e6:.0f};"
         f"time_saving={time_saving:.1%}")
    return {"loops": loops, "fuse_rounds": fuse, "K": K,
            "per_round_wp_s": per_round_wp_cold, "fused_wp_s": fused_wp_cold,
            "speedup": speedup, "compiles": compiles,
            "hidden_before": list(hidden0), "hidden_after": list(hidden1),
            "steady": {"fused_wp_s": fused_wp_s,
                       "fused_scbf_s": fused_scbf_s,
                       "time_saving": time_saving}}


def run_chaos_section(quick: bool = True, loops: int = 16, fuse: int = 4,
                      K: int = 8):
    """Section 6 (``--chaos``): the resilience tax and a seeded chaos run.

    a) **fault-free overhead**: the fused medical run with the chaos
       model disarmed vs armed-with-zero-rates (FaultInjector, the
       server admission gate, and the plan-time (S, B) admit masks all
       active, but nothing ever fires).  The two runs must be
       bit-identical (participation, upload bytes, final params) and
       the armed run must stay <= 2 fused compiles; the wall-clock
       ratio is the resilience tax — target < 5%, CI-gated (with a
       noise allowance, like telemetry) by check_fed_regression.py.
    b) **seeded chaos run**: crashes, flaky links, bitflips, NaN and
       norm-inflated poison, duplicates — the rejection counters come
       off the flight recorder and the final params are asserted
       finite (no corrupt update may ever reach ``ServerState``).
    """
    from repro.config import FaultConfig, TrainConfig
    from repro.core.scbf import run_federated
    from repro.data.medical import generate_cohort

    adm = 4000 if quick else 12000
    med = 128 if quick else 256
    feats = (med, 256, 64, 1) if quick else (med, 512, 128, 1)
    cohort = generate_cohort(num_admissions=adm, num_medicines=med,
                             num_risk_medicines=med // 4,
                             num_interactions=8, seed=0)

    def tcfg(faults=None, max_norm=0.0):
        return TrainConfig(
            learning_rate=0.05, global_loops=loops, local_batch_size=64,
            local_epochs=1, eval_every=loops,
            scbf=ScbfConfig(upload_rate=0.10, num_clients=K),
            fed=FedConfig(fuse_rounds=fuse,
                          faults=faults if faults is not None
                          else FaultConfig(),
                          max_update_norm=max_norm))

    def timed(cfg):
        t0 = time.perf_counter()
        res = run_federated(cohort, cfg, method="scbf",
                            mlp_features=feats)
        return time.perf_counter() - t0, res

    # ---- a) fault-free overhead: disarmed vs armed-with-zero-rates ----
    armed = FaultConfig(enabled=True)           # zero rates: never fires
    _, res_plain = timed(tcfg())                # compile warmup, both
    reset_fused_compile_count()
    _, res_armed = timed(tcfg(armed))
    compiles = fused_compile_count()
    assert compiles <= 2, \
        f"armed fused run must stay <= 2 compiles, got {compiles}"
    for rp, ra in zip(res_plain.records, res_armed.records):
        assert rp.num_participants == ra.num_participants \
            and rp.sparse_bytes == ra.sparse_bytes, \
            f"zero-injection run diverged at loop {rp.loop}"
    for lp, la in zip(res_plain.final_params, res_armed.final_params):
        for k in lp:
            assert np.array_equal(np.asarray(lp[k]), np.asarray(la[k])), \
                "zero-injection final params must be bit-identical"
    # alternate repeats, min of each side — same rationale as telemetry
    plain_ts, armed_ts = [], []
    for _ in range(3):
        plain_ts.append(timed(tcfg())[0])
        armed_ts.append(timed(tcfg(armed))[0])
    plain_s = min(plain_ts) / loops
    armed_s = min(armed_ts) / loops
    overhead = armed_s / plain_s - 1.0
    emit(f"fed_chaos_armed_K{K}", armed_s * 1e6,
         f"loops={loops};fuse_rounds={fuse};compiles={compiles};"
         f"overhead_vs_disarmed={overhead:.1%}")

    # ---- b) seeded chaos run: everything fires, nothing lands ----
    chaos = FaultConfig(enabled=True, seed=7, crash_rate=0.1,
                        net_fail_rate=0.1, duplicate_rate=0.1,
                        bitflip_rate=0.1, nan_rate=0.1, poison_rate=0.1)
    rec = obstrace.Recorder()
    with obstrace.recording(recorder=rec):
        chaos_t, res_chaos = timed(tcfg(chaos, max_norm=1e3))
    for layer in res_chaos.final_params:
        for k in layer:
            assert np.isfinite(np.asarray(layer[k])).all(), \
                "corrupt update leaked into the final params"
    rejected = rec.counters.get("payloads_rejected", 0)
    injected = sum(1 for e in rec.events if e["ev"] == "fault_injected")
    assert injected > 0, "seeded chaos trace produced no faults"
    emit(f"fed_chaos_run_K{K}", chaos_t / loops * 1e6,
         f"loops={loops};faults_injected={injected};"
         f"payloads_rejected={rejected}")
    reasons = {k[len("rejected_"):]: v for k, v in rec.counters.items()
               if k.startswith("rejected_")}
    return {"loops": loops, "fuse_rounds": fuse, "K": K,
            "disarmed_s": plain_s, "armed_s": armed_s,
            "overhead": overhead, "compiles": compiles,
            "chaos": {"total_s": chaos_t, "faults_injected": injected,
                      "payloads_rejected": rejected, "reasons": reasons}}


def run_pod_scaling(quick: bool = True, pods: int = 1):
    """Section 3: bucketed round sharded over a pod mesh vs one device."""
    if pods <= 1:
        return None
    K = 64 if quick else 128
    n_per_client = 64 if quick else 256
    d = 128 if quick else 256
    feats = (d, 32, 8, 1)
    batch_size = 32
    cfg = ScbfConfig(upload_rate=0.10, num_clients=K)
    clients = _synthetic_clients(K, n_per_client, d)
    params = init_mlp(feats, jax.random.PRNGKey(1))
    rows = {}
    for p in (1, pods):
        eng = make_engine("batched", clients, batch_size, epochs=1, pods=p)
        t, payloads = time_round(eng, params, cfg, 0.05, K, batch_size)
        emit(f"fed_round_pods{p}_K{K}", t * 1e6,
             f"devices={p};upload_bytes={sum(pl.nbytes for pl in payloads)}")
        rows[p] = t
    emit(f"fed_pod_scaling_K{K}", rows[pods] * 1e6,
         f"speedup_vs_1dev={rows[1] / rows[pods]:.2f}x")
    return {"K": K, "round_s_by_pods": rows,
            "speedup": rows[1] / rows[pods]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized shards/model (the default full run is "
                         "still laptop-scale)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--pods", type=int, default=1,
                    help="shard the bucketed cohort over N forced host "
                         "devices (applied before jax import)")
    ap.add_argument("--fuse", action="store_true",
                    help="also run the fused-round-loop section "
                         "(per-round vs lax.scan chunks at K=500, plus "
                         "the varying-P compile trace)")
    ap.add_argument("--prune", action="store_true",
                    help="also run the fused-SCBFwP section (mask-mode "
                         "pruning: fused vs per-round-reshape, compile "
                         "count, steady-state pruning time saving)")
    ap.add_argument("--chaos", action="store_true",
                    help="also run the chaos section (fault-free "
                         "resilience overhead, zero-injection parity, "
                         "seeded fault-storm rejection stats)")
    ap.add_argument("--json-out", default=None,
                    help="also write the results as JSON (CI writes "
                         "BENCH_fed_engine.json)")
    ap.add_argument("--events-out", default=None,
                    help="write the fused section's flight-recorder "
                         "events.jsonl (render with python -m "
                         "repro.obs.report; needs --fuse)")
    args = ap.parse_args()
    quick = args.quick or not args.full

    rows = run(quick=quick)
    compiles = run_compile_counts(quick=quick)
    fused = run_fused_section(quick=quick, events_out=args.events_out) \
        if args.fuse else None
    prune = run_prune_section(quick=quick) if args.prune else None
    chaos = run_chaos_section(quick=quick) if args.chaos else None
    pod = run_pod_scaling(quick=quick, pods=_PODS)

    print("# K, seq_s/round, batched_s/round, speedup")
    for r in rows:
        print(f"# {r['K']:4d}  {r['seq_s']:8.4f}  {r['batched_s']:8.4f}  "
              f"{r['speedup']:6.1f}x")
    for policy, c in compiles.items():
        print(f"# bucket={policy:5s}  {c['rounds']} rounds, "
              f"{c['distinct_P']} distinct P -> {c['compiles']} compiles "
              f"({c['total_s']:.2f}s)")
    if fused:
        print(f"# fused K={fused['K']} S={fused['fuse_rounds']}: "
              f"{fused['per_round_s']:.4f}s -> {fused['fused_s']:.4f}s "
              f"per round ({fused['speedup']:.1f}x); varying-P trace "
              f"{fused['compile_trace']['rounds']} rounds -> "
              f"{fused['compile_trace']['compiles']} compiles")
        tel = fused["telemetry"]
        print(f"# fused telemetry: {tel['fused_telemetry_s']:.4f}s/round "
              f"with flight recorder on ({tel['overhead']:+.1%} vs plain)")
    if prune:
        st = prune["steady"]
        print(f"# fused SCBFwP K={prune['K']} S={prune['fuse_rounds']}: "
              f"cold {prune['per_round_wp_s']:.2f}s (per-round reshape) "
              f"-> {prune['fused_wp_s']:.2f}s ({prune['speedup']:.1f}x, "
              f"{prune['compiles']} compiles); steady-state pruning "
              f"saves {st['time_saving']:.1%} vs fused-SCBF")
    if chaos:
        ch = chaos["chaos"]
        print(f"# chaos K={chaos['K']} S={chaos['fuse_rounds']}: armed "
              f"zero-rate overhead {chaos['overhead']:+.1%} "
              f"({chaos['compiles']} compiles, bit-identical); storm: "
              f"{ch['faults_injected']} faults -> "
              f"{ch['payloads_rejected']} rejected {ch['reasons']}")
    if pod:
        print(f"# pods={_PODS}: {pod['round_s_by_pods'][1]:.4f}s -> "
              f"{pod['round_s_by_pods'][_PODS]:.4f}s "
              f"({pod['speedup']:.2f}x)")

    if args.json_out:
        blob = {"schema": RESULT_SCHEMA, "emitter": EMITTER,
                "quick": quick, "k_scaling": rows,
                "compile_counts": compiles,
                "fused": fused, "prune": prune, "chaos": chaos,
                "pod_scaling": pod}
        with open(args.json_out, "w") as f:
            json.dump(blob, f, indent=1)
        print(f"# wrote {args.json_out}")


if __name__ == "__main__":
    main()
