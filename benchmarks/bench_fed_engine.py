"""Federation-engine scaling: vmapped cohort vs. sequential client loop.

Measures one full SCBF round — local training, channel selection, wire
encoding — for K ∈ {5, 50, 500} clients under both engines, and
reports the per-round wall clock plus the batched/sequential speedup.

    PYTHONPATH=src python -m benchmarks.bench_fed_engine --quick
    PYTHONPATH=src python -m benchmarks.bench_fed_engine          # larger shards

Output is the repo's ``name,us_per_call,derived`` CSV convention
(benchmarks/common.py).  The sequential engine pays K jit dispatches +
K eager selection passes per round; the batched engine runs the whole
cohort as one XLA program, so the gap widens roughly linearly in K.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.config import ScbfConfig
from repro.fed.engine import make_engine
from repro.models.mlp_net import init_mlp


def _synthetic_clients(K: int, n_per_client: int, d: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(K):
        x = (rng.random((n_per_client, d)) < 0.1).astype(np.float32)
        y = (rng.random(n_per_client) < 0.5).astype(np.float32)
        out.append((x, y))
    return out


def time_round(eng, params, cfg, lr, K, batch_size, iters: int = 3):
    """Median seconds per full SCBF round (train+select+encode)."""
    part = np.arange(K)
    key = jax.random.PRNGKey(0)
    times = []
    for it in range(iters + 1):                 # first round = compile warmup
        key, kc, ks, kd = jax.random.split(key, 4)
        ckeys = jax.random.split(kc, K)
        skeys = jax.random.split(ks, K)
        dp_keys = jax.random.split(kd, K)
        t0 = time.perf_counter()
        payloads, stats = eng.scbf_round(params, part, lr, ckeys, skeys,
                                         dp_keys, cfg)
        dt = time.perf_counter() - t0
        if it:                                  # drop the warmup round
            times.append(dt)
    times.sort()
    return times[len(times) // 2], payloads


def run(quick: bool = True, cohort_sizes=(5, 50, 500)):
    n_per_client = 64 if quick else 512
    d = 128 if quick else 512
    feats = (d, 32, 8, 1) if quick else (d, 128, 32, 1)
    batch_size = 32 if quick else 128
    cfg = ScbfConfig(upload_rate=0.10, num_clients=max(cohort_sizes))
    params = init_mlp(feats, jax.random.PRNGKey(1))
    lr = 0.05

    rows = []
    for K in cohort_sizes:
        clients = _synthetic_clients(K, n_per_client, d)
        seq = make_engine("sequential", clients, batch_size, epochs=1)
        bat = make_engine("batched", clients, batch_size, epochs=1)
        t_seq, p_seq = time_round(seq, params, cfg, lr, K, batch_size)
        t_bat, p_bat = time_round(bat, params, cfg, lr, K, batch_size)
        speedup = t_seq / t_bat
        assert sum(p.nbytes for p in p_seq) == sum(p.nbytes for p in p_bat), \
            "engines must ship identical bytes"
        emit(f"fed_round_seq_K{K}", t_seq * 1e6,
             f"clients={K};n_per_client={n_per_client}")
        emit(f"fed_round_batched_K{K}", t_bat * 1e6,
             f"clients={K};speedup_vs_seq={speedup:.1f}x")
        rows.append((K, t_seq, t_bat, speedup))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized shards/model (the default full run is "
                         "still laptop-scale)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    quick = args.quick or not args.full
    rows = run(quick=quick)
    print("# K, seq_s/round, batched_s/round, speedup")
    for K, ts, tb, sp in rows:
        print(f"# {K:4d}  {ts:8.4f}  {tb:8.4f}  {sp:6.1f}x")


if __name__ == "__main__":
    main()
