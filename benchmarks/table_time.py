"""Paper §3 time-efficiency claims: pruning saves ~57% of SCBF wall time
(~48% for FA) with a ≤0.0047/0.0068 AUC reduction.

Wall time on this CPU container includes jit recompiles after each prune
step, so we report BOTH wall time and the compile-free FLOPs proxy
(params × examples summed over loops) — the proxy is the
hardware-independent statement of the claim.
"""
from __future__ import annotations

import argparse

from benchmarks.fig2_scbf_vs_fa import run as run_fig2


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--loops", type=int, default=None)
    args = ap.parse_args()
    results, _ = run_fig2(quick=not args.full, loops=args.loops, out=None)

    def totals(m):
        res = results[m]
        wall = res.total_time()
        flops = sum(r.flops_proxy for r in res.records)
        return wall, flops

    print("method,wall_s,flops_proxy,auc_roc_best,auc_pr_best")
    for m, res in results.items():
        wall, flops = totals(m)
        print(f"{m},{wall:.2f},{flops:.3e},{res.best('auc_roc'):.4f},"
              f"{res.best('auc_pr'):.4f}")

    for base in ("scbf", "fedavg"):
        wp = base + "wp"
        if base in results and wp in results:
            w0, f0 = totals(base)
            w1, f1 = totals(wp)
            droc = results[base].best("auc_roc") - results[wp].best("auc_roc")
            dpr = results[base].best("auc_pr") - results[wp].best("auc_pr")
            print(f"{wp} vs {base}: wall saved {100*(1-w1/w0):.1f}% "
                  f"flops saved {100*(1-f1/f0):.1f}% "
                  f"d_auc_roc {droc:+.4f} d_auc_pr {dpr:+.4f}")


if __name__ == "__main__":
    main()
