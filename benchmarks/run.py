"""Benchmark runner — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run          # quick (CI-sized)
    PYTHONPATH=src python -m benchmarks.run --full   # paper-scale

Prints ``name,us_per_call,derived`` CSV sections plus the paper-claim
comparisons.  The roofline section reads pre-computed dry-run records if
``experiments/dryrun`` exists (the dry-run itself needs 512 virtual
devices and runs as its own process: ``python -m repro.launch.dryrun``).
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args, _ = ap.parse_known_args()

    print("# === fig2: SCBF vs FedAvg (AUC, ±pruning) ===", flush=True)
    from benchmarks.fig2_scbf_vs_fa import run as fig2
    t0 = time.time()
    results, summary = fig2(quick=not args.full,
                            out="experiments/fig2_summary.json")
    for m, s in summary.items():
        print(f"{m},{1e6*(time.time()-t0)/max(len(s['curve_auc_roc']),1):.0f},"
              f"best_roc={s['best_auc_roc']:.4f};best_pr={s['best_auc_pr']:.4f};"
              f"upload_mb={s['total_upload_mb']:.1f}")

    print("# === paper-claim checks ===")
    scbf, fa = summary.get("scbf"), summary.get("fedavg")
    if scbf and fa:
        print(f"claim_scbf_beats_fa,0,"
              f"scbf_roc={scbf['best_auc_roc']:.4f};"
              f"fa_roc={fa['best_auc_roc']:.4f};"
              f"holds={scbf['best_auc_roc'] > fa['best_auc_roc']}")
    wp = summary.get("scbfwp")
    if scbf and wp:
        droc = scbf["best_auc_roc"] - wp["best_auc_roc"]
        print(f"claim_pruning_cheap,0,d_auc_roc={droc:.4f};"
              f"paper_reports=0.0047")
        tsave = 1 - wp["total_time_s"] / max(scbf["total_time_s"], 1e-9)
        print(f"claim_pruning_saves_time,0,wall_saving={tsave:.2%};"
              f"paper_reports=57%")
    if wp and fa:
        csave = 1 - wp["total_upload_mb"] / max(fa["total_upload_mb"], 1e-9)
        print(f"claim_scbfwp_saves_comm,0,saving={csave:.2%};"
              f"paper_reports=85%")

    print("# === communication table ===")
    from benchmarks.table_communication import run as comm
    for name, rate, frac, enc, dense, _codecs in comm(quick=not args.full):
        print(f"{name}_a{rate},0,param_fraction={frac:.4f};"
              f"encoded_bytes={enc};dense_bytes={dense}")

    print("# === kernel ubenches ===")
    sys.argv = ["bench_kernels"]
    from benchmarks.bench_kernels import main as bk
    bk()

    print("# === roofline (from dry-run records, if present) ===")
    if os.path.isdir("experiments/dryrun"):
        from benchmarks.roofline_report import load
        recs = load("experiments/dryrun")
        ok = sum(1 for r in recs if r["ok"])
        print(f"dryrun_records,0,ok={ok}/{len(recs)}")
        for r in recs:
            if r["ok"]:
                t = r["terms"]
                print(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},0,"
                      f"dom={t['dominant']};compute={t['compute_s']:.4f};"
                      f"mem={t['memory_s']:.4f};coll={t['collective_s']:.4f}")
    else:
        print("dryrun_records,0,missing (run python -m repro.launch.dryrun --all)")


if __name__ == "__main__":
    main()
