"""Paper Fig. 2: SCBF vs Federated Averaging, with and without pruning.

Runs the four methods on the synthetic cohort and reports per-loop
AUC-ROC / AUC-PR plus the paper's §3 headline numbers.  ``--quick`` uses
a reduced cohort (CI-sized); the full paper-scale run is
``python -m benchmarks.fig2_scbf_vs_fa --loops 30``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

from repro.config import ScbfConfig, TrainConfig
from repro.core.scbf import run_federated
from repro.data.medical import generate_cohort


def run(quick: bool = True, loops: int = None, out: str = None,
        methods=("scbf", "fedavg", "scbfwp", "fedavgwp"), seed: int = 0,
        lr: float = 0.05, upload_rate: float = 0.10, num_clients: int = 5,
        engine: str = None):
    if quick:
        cohort = generate_cohort(num_admissions=6000, num_medicines=400,
                                 seed=seed)
        feats = (400, 64, 16, 1)
        loops = loops or 5
    else:
        cohort = generate_cohort(seed=seed)
        feats = (2917, 256, 64, 1)
        loops = loops or 30

    results = {}
    for method in methods:
        base = method.replace("wp", "")
        # the paper's server update SUMS the K masked client deltas
        # (Algorithm 1) while FedAvg averages; scaling SCBF's local lr by
        # 1/K gives both methods the same effective server step — without
        # it the sum-update diverges at FA's stable lr (EXPERIMENTS.md
        # §Paper-validation, note 2)
        m_lr = lr / num_clients if base == "scbf" else lr
        cfg = TrainConfig(
            learning_rate=m_lr, global_loops=loops, local_epochs=2,
            local_batch_size=256, seed=seed,
            scbf=ScbfConfig(upload_rate=upload_rate,
                            num_clients=num_clients,
                            prune=method.endswith("wp")))
        results[method] = run_federated(cohort, cfg, method=base,
                                        mlp_features=feats, verbose=True,
                                        engine=engine)

    summary = {}
    for m, res in results.items():
        summary[m] = {
            "best_auc_roc": res.best("auc_roc"),
            "best_auc_pr": res.best("auc_pr"),
            "final_auc_roc": res.final.auc_roc,
            "final_auc_pr": res.final.auc_pr,
            "total_time_s": res.total_time(),
            "total_upload_mb": res.total_upload_bytes() / 1e6,
            "curve_auc_roc": [r.auc_roc for r in res.records],
            "curve_auc_pr": [r.auc_pr for r in res.records],
        }
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(summary, f, indent=1)
    return results, summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--loops", type=int, default=None)
    ap.add_argument("--clients", type=int, default=5)
    ap.add_argument("--engine", default=None,
                    choices=(None, "batched", "sequential"))
    ap.add_argument("--out", default="experiments/fig2_summary.json")
    args = ap.parse_args()
    _, summary = run(quick=not args.full, loops=args.loops, out=args.out,
                     num_clients=args.clients, engine=args.engine)
    for m, s in summary.items():
        print(f"{m:10s} best ROC {s['best_auc_roc']:.4f} "
              f"PR {s['best_auc_pr']:.4f} time {s['total_time_s']:.1f}s "
              f"upload {s['total_upload_mb']:.1f}MB")


if __name__ == "__main__":
    main()
