"""Fail CI when the fed-engine bench regresses against the committed
baseline (benchmarks/baselines/fed_engine.json).

Only metrics stable enough to gate on are guarded, so a slower CI
runner cannot fail the gate spuriously:

  * **fused round-throughput ratio** — fused-vs-per-round speedup: a
    ratio of two device-bound timings from the SAME process, so
    absolute runner speed cancels; a drop below 75% of the baseline
    ratio (>25% regression) fails.  The batched-vs-sequential k_scaling
    speedups are NOT ratio-guarded: the sequential side is
    dispatch-bound and its per-round time swings by >25% between runs
    of identical code (the repo's own measurements of the K=500 row
    range 8-13x), so gating it would flake — the rows must still be
    *present*, they are just informational.
  * **fused-SCBFwP throughput ratio** — fused mask-mode SCBFwP vs
    per-round reshape SCBFwP, same-process cold runs: a drop below 75%
    of the baseline ratio fails.  Its steady-state pruning time saving
    must additionally stay positive (the paper's wall-time claim) —
    gated as a sign, not a magnitude, so runner jitter cannot flake it.
  * **compile counts** — fully deterministic; ANY growth fails (a
    retracing regression is exactly the bug class PR 3/4 fixed, and
    the fused-SCBFwP count is the PR 5 acceptance bar: <= 2).
  * **telemetry overhead** — the fused section's flight-recorder run
    (repro.obs) must stay within ``TELEMETRY_OVERHEAD_MAX`` of the
    plain fused run.  The acceptance target is < 5% (the measured
    number lives in docs/OBSERVABILITY.md); the CI gate is looser
    because the overhead is a ratio of two *short* wall-clock timings
    and absolute jitter does not fully cancel.
  * **chaos overhead** — the armed-with-zero-rates fused run must stay
    within ``CHAOS_OVERHEAD_MAX`` of the disarmed run (the resilience
    tax: plan-time fault draws, payload sealing, and the host-side
    admission gate).  Target < 5% at real scale; the CI bound is
    looser for the same short-timing-jitter reason as telemetry.
    The armed run's compile count is gated monotone (<= baseline),
    and the seeded fault storm must have rejected at least one payload
    per reason the baseline rejected — a storm that stops rejecting a
    fault class means the gate went inert, not that chaos got lucky.

Both JSON blobs carry a ``schema`` version (bench RESULT_SCHEMA); a
mismatch on either side is refused outright with a refresh
instruction — never compared field-by-field against guessed meanings.

Refresh the baseline after an intentional perf change with EXACTLY the
command CI runs (ci.yml bench-smoke), then commit the result with a
note on what changed:

    PYTHONPATH=src python -m benchmarks.bench_fed_engine --quick --fuse \
        --prune --chaos --pods 2 \
        --json-out benchmarks/baselines/fed_engine.json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

RATIO_TOLERANCE = 0.75      # fresh fused ratio must be >= 75% of baseline
SCHEMA = 3                  # bench_fed_engine.RESULT_SCHEMA this reader groks
TELEMETRY_OVERHEAD_MAX = 0.25   # CI bound; the target (<5%) is in the docs
CHAOS_OVERHEAD_MAX = 0.25       # CI bound on the fault-free resilience tax


def compare(fresh: dict, baseline: dict) -> List[str]:
    """Regression messages (empty = pass).

    Every section the baseline guards must also exist in the fresh
    results — a bench refactor that silently drops a section must fail
    the gate, not vacuously pass it.
    """
    # schema handshake first: comparing blobs of different formats
    # produces confidently-wrong verdicts, so refuse with the fix
    for label, blob in (("fresh", fresh), ("baseline", baseline)):
        if blob.get("schema") != SCHEMA:
            return [
                f"{label} results carry schema {blob.get('schema')!r}, "
                f"this checker reads schema {SCHEMA} — regenerate the "
                f"{label} JSON with the current bench (refresh command "
                "in this module's docstring) instead of comparing "
                "mismatched formats"]

    failures = []

    # k_scaling rows are informational (their seq-vs-batched ratio is
    # too jittery to gate — see module docstring) but must stay present
    fresh_k = {r["K"] for r in fresh.get("k_scaling", [])}
    for row in baseline.get("k_scaling", []):
        if row["K"] not in fresh_k:
            failures.append(f"k_scaling K={row['K']} row missing from "
                            "fresh results (baseline records it)")

    for policy, base in baseline.get("compile_counts", {}).items():
        c = fresh.get("compile_counts", {}).get(policy)
        if c is None:
            failures.append(f"compile_counts[{policy}] missing from "
                            "fresh results (baseline guards it)")
        elif c["compiles"] > base["compiles"]:
            failures.append(
                f"compile_counts[{policy}]: {c['compiles']} compiles > "
                f"baseline {base['compiles']} (retracing regression)")

    f, b = fresh.get("fused"), baseline.get("fused")
    if f and b:
        floor = b["speedup"] * RATIO_TOLERANCE
        if f["speedup"] < floor:
            failures.append(
                f"fused: speedup {f['speedup']:.2f}x < {floor:.2f}x "
                f"(75% of baseline {b['speedup']:.2f}x)")
        fc = f["compile_trace"]["compiles"]
        bc = b["compile_trace"]["compiles"]
        if fc > bc:
            failures.append(f"fused compile trace: {fc} compiles > "
                            f"baseline {bc}")
        tel = f.get("telemetry")
        if tel is None:
            failures.append("fused.telemetry missing from fresh results "
                            "(schema 2 always records it)")
        elif tel["overhead"] > TELEMETRY_OVERHEAD_MAX:
            failures.append(
                f"telemetry overhead {tel['overhead']:.1%} > "
                f"{TELEMETRY_OVERHEAD_MAX:.0%} bound (flight recorder "
                "must stay off the hot path — check for in-chunk "
                "offloads or extra compiles)")
    elif b and not f:
        failures.append("fused section missing from fresh results "
                        "(baseline has one — run the bench with --fuse)")

    p, bp = fresh.get("prune"), baseline.get("prune")
    if p and bp:
        floor = bp["speedup"] * RATIO_TOLERANCE
        if p["speedup"] < floor:
            failures.append(
                f"prune: fused-SCBFwP speedup {p['speedup']:.2f}x < "
                f"{floor:.2f}x (75% of baseline {bp['speedup']:.2f}x)")
        if p["compiles"] > bp["compiles"]:
            failures.append(
                f"prune: {p['compiles']} fused compiles > baseline "
                f"{bp['compiles']} (the <= 2 acceptance bar)")
        if p["steady"]["time_saving"] <= 0:
            failures.append(
                "prune: steady-state pruning time saving "
                f"{p['steady']['time_saving']:.1%} is not positive "
                "(pruned runs must be faster than unpruned)")
    elif bp and not p:
        failures.append("prune section missing from fresh results "
                        "(baseline has one — run the bench with --prune)")

    c, bc = fresh.get("chaos"), baseline.get("chaos")
    if c and bc:
        if c["overhead"] > CHAOS_OVERHEAD_MAX:
            failures.append(
                f"chaos: fault-free resilience overhead {c['overhead']:.1%}"
                f" > {CHAOS_OVERHEAD_MAX:.0%} bound (the armed-but-idle "
                "fault model must stay off the hot path — check for "
                "extra compiles or per-round host sync)")
        if c["compiles"] > bc["compiles"]:
            failures.append(
                f"chaos: {c['compiles']} armed fused compiles > baseline "
                f"{bc['compiles']} (the <= 2 acceptance bar)")
        for reason in bc["chaos"].get("reasons", {}):
            if not c["chaos"].get("reasons", {}).get(reason):
                failures.append(
                    f"chaos: the seeded fault storm no longer rejects "
                    f"any '{reason}' payloads (baseline does) — the "
                    "admission gate for that fault class went inert")
    elif bc and not c:
        failures.append("chaos section missing from fresh results "
                        "(baseline has one — run the bench with --chaos)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", help="freshly-written BENCH_fed_engine.json")
    ap.add_argument("baseline",
                    help="committed benchmarks/baselines/fed_engine.json")
    args = ap.parse_args()
    with open(args.fresh) as fh:
        fresh = json.load(fh)
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    failures = compare(fresh, baseline)
    if failures:
        print("fed-engine bench regression vs committed baseline:")
        for msg in failures:
            print(f"  FAIL {msg}")
        print("(refresh instructions: see benchmarks/check_fed_regression"
              ".py docstring — only do so for an intentional change)")
        return 1
    print("fed-engine bench within baseline "
          f"(ratio tolerance {RATIO_TOLERANCE:.0%}, compile counts "
          "monotone)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
