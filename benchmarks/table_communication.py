"""Paper §3 communication claims, measured on the real wire formats.

  * SCBF positive selection at α=10% uploads ~45% of parameters
    (the channel-union effect);
  * SCBFwP saves ~85% of information exchange vs Federated Averaging
    (selection saving × pruning shrinkage, accumulated over loops);
  * dense vs encoded upload bytes — the bytes reported here are the
    actual ``repro.comm.wire`` payload sizes (cheapest of coo / bitmap
    / dense per layer), not a mask-count model, so "sparse" can never
    exceed dense.
"""
from __future__ import annotations

import argparse
from collections import Counter

import jax

from repro.comm import wire
from repro.core import selection
from repro.models.mlp_net import init_mlp


def measure_upload(rate: float, feats=(2917, 256, 64, 1),
                   selection_mode: str = "positive", seed: int = 0):
    """One client's upload at rate α: (param_fraction, encoded_bytes,
    dense_bytes, per-codec layer counts)."""
    key = jax.random.PRNGKey(seed)
    params = init_mlp(feats, key)
    grads = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.fold_in(key, p.size),
                                    p.shape) * 0.01, params)
    masked, masks, _ = selection.select_gradients(list(grads), rate,
                                                  selection_mode,
                                                  key=jax.random.PRNGKey(1))
    st = selection.UploadStats.from_masks(masks)
    payload = wire.encode(tuple(masked))
    codecs = Counter(lp.codec for lp in payload.layers)
    return st.upload_fraction, payload.nbytes, payload.dense_nbytes, codecs


def run(quick: bool = True):
    feats = (400, 64, 16, 1) if quick else (2917, 256, 64, 1)
    rows = []
    for rate in (0.05, 0.10, 0.25, 0.50, 0.90):
        frac, enc, dense, codecs = measure_upload(rate, feats)
        rows.append(("positive", rate, frac, enc, dense, codecs))
    frac, enc, dense, codecs = measure_upload(0.10, feats, "negative")
    rows.append(("negative", 0.10, frac, enc, dense, codecs))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    rows = run(quick=not args.full)
    print("selection,rate,param_fraction_uploaded,encoded_bytes,"
          "dense_bytes,saving,codecs")
    for mode, rate, frac, enc, dense, codecs in rows:
        saving = 1.0 - enc / max(dense, 1)
        cd = "+".join(f"{v}x{k}" for k, v in sorted(codecs.items()))
        print(f"{mode},{rate},{frac:.4f},{enc},{dense},{saving:.2%},{cd}")
    print("\nencoded bytes are measured repro.comm.wire payloads "
          "(cheapest codec per layer; never exceeds dense)")
    print("paper claim: positive selection at alpha=0.10 uploads ~45% "
          "of parameters")


if __name__ == "__main__":
    main()
