"""Paper §3 communication claims.

  * SCBF positive selection at α=10% uploads ~45% of parameters
    (the channel-union effect);
  * SCBFwP saves ~85% of information exchange vs Federated Averaging
    (selection saving × pruning shrinkage, accumulated over loops);
  * dense vs sparse-encoded upload bytes.

Derived from the same orchestrator runs as fig2 (records carry the byte
accounting), plus a direct single-loop measurement here.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import selection
from repro.models.mlp_net import init_mlp


def upload_fraction_for_rate(rate: float, feats=(2917, 256, 64, 1),
                             selection_mode: str = "positive",
                             seed: int = 0) -> float:
    """Fraction of parameters revealed by channel selection at rate α."""
    key = jax.random.PRNGKey(seed)
    params = init_mlp(feats, key)
    grads = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.fold_in(key, p.size),
                                    p.shape) * 0.01, params)
    _, masks, _ = selection.select_gradients(list(grads), rate,
                                             selection_mode,
                                             key=jax.random.PRNGKey(1))
    st = selection.UploadStats.from_masks(
        [{k: m[k] for k in ("w", "b")} for m in masks])
    return st.upload_fraction


def run(quick: bool = True):
    rows = []
    feats = (400, 64, 16, 1) if quick else (2917, 256, 64, 1)
    for rate in (0.05, 0.10, 0.25, 0.50):
        frac = upload_fraction_for_rate(rate, feats)
        rows.append(("upload_frac_pos", rate, frac))
    frac_neg = upload_fraction_for_rate(0.10, feats, "negative")
    rows.append(("upload_frac_neg", 0.10, frac_neg))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    rows = run(quick=not args.full)
    print("selection,rate,param_fraction_uploaded")
    for name, rate, frac in rows:
        print(f"{name},{rate},{frac:.4f}")
    print("\npaper claim: positive selection at alpha=0.10 uploads ~45% "
          "of parameters")


if __name__ == "__main__":
    main()
