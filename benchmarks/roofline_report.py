"""Aggregate dry-run JSON records into the §Roofline markdown table.

    PYTHONPATH=src python -m benchmarks.roofline_report \
        --dir experiments/dryrun [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_: str):
    recs = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r):
    if not r["ok"]:
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL | | | | "
                f"| {r.get('error','')[:60]} |")
    t = r["terms"]
    dom = t["dominant"].replace("_s", "")
    cp = r["collectives"].get("cross_pod_bytes", 0)
    note = []
    if r.get("window"):
        note.append(f"win={r['window']}")
    if r.get("federated"):
        note.append("SCBF-fed")
    if cp:
        note.append(f"xpod={cp/1e9:.2f}GB")
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | **{dom}** "
            f"| {r['useful_flops_ratio']:.2f} | {' '.join(note)} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)
    print("| arch | shape | mesh | compute_s | memory_s | collective_s "
          "| dominant | useful_flops | notes |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        print(fmt_row(r))
    ok = sum(1 for r in recs if r["ok"])
    print(f"\n{ok}/{len(recs)} combinations compile")


if __name__ == "__main__":
    main()
