"""Batched serving of reduced assigned architectures (prefill + decode
through the ring-buffer KV/SSM caches — the same code path the decode
dry-run shapes lower on the production mesh).

    PYTHONPATH=src python examples/serve_batched.py \
        --archs qwen2-0.5b,mamba2-2.7b,chatglm3-6b --gen 16
"""
import argparse
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default="qwen2-0.5b,mamba2-2.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    for arch in args.archs.split(","):
        print(f"=== {arch} ===", flush=True)
        subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--arch", arch,
             "--batch", str(args.batch),
             "--prompt-len", str(args.prompt_len),
             "--gen", str(args.gen)],
            check=True)


if __name__ == "__main__":
    main()
