"""End-to-end driver: the paper's full experiment.

    PYTHONPATH=src python examples/federated_medical.py [--loops 30]

Reproduces Fig. 2 + the §3 claims at full scale: 30,760 admissions ×
2,917 medicines, MLP (2917-256-64-1), 5 clients, 30 global loops, four
methods (SCBF / FA / SCBFwP / FAwP with APoZ pruning 10%/loop to 47%).
Writes per-loop CSVs + a JSON summary under experiments/medical/.
"""
import argparse
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--loops", type=int, default=30)
    ap.add_argument("--methods", default="scbf,fedavg,scbfwp,fedavgwp")
    ap.add_argument("--out", default="experiments/medical")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--clients", type=int, default=5)
    # cross-device scenarios (docs/FED_ENGINE.md)
    ap.add_argument("--engine", default="batched",
                    choices=["batched", "sequential"])
    ap.add_argument("--sample-fraction", type=float, default=1.0)
    ap.add_argument("--dropout-rate", type=float, default=0.0)
    ap.add_argument("--partition", default="iid",
                    choices=["iid", "dirichlet"])
    ap.add_argument("--dirichlet-alpha", type=float, default=0.5)
    ap.add_argument("--dp-noise", type=float, default=0.0)
    args = ap.parse_args()

    from repro.launch.train import run_medical

    class A:
        methods = args.methods
        loops = args.loops
        clients = args.clients
        lr = args.lr
        local_epochs = 2
        batch_size = 256
        upload_rate = 0.10
        selection = "positive"
        prune_rate = 0.10
        prune_total = 0.47
        seed = args.seed
        out = args.out
        engine = args.engine
        sample_fraction = args.sample_fraction
        dropout_rate = args.dropout_rate
        partition = args.partition
        dirichlet_alpha = args.dirichlet_alpha
        dp_noise = args.dp_noise

    run_medical(A)


if __name__ == "__main__":
    main()
