"""SCBF as a first-class LLM training feature: federated fine-tuning of a
~100M-parameter transformer with channel-masked gradient exchange — the
exact ``make_federated_train_step`` the multi-pod dry-run lowers, running
for real on CPU.

    PYTHONPATH=src python examples/scbf_llm_federated.py \
        --steps 300 --d-model 512 --layers 8

Four simulated hospitals each hold a private synthetic token stream; per
step every client computes gradients locally, channel-masks them to the
top-α output channels, and only the masked sum crosses the client
boundary.  Loss is logged to show learning under 10% channel upload.
"""
import argparse
import dataclasses
import functools
import time


@functools.lru_cache(maxsize=None)
def _fed_step(bundle, scbf, lr: float):
    """One jitted federated step per (bundle, scbf cfg, lr) — built in
    ``main`` the wrapper (and its compile cache) died with every call
    (tracelint TL001)."""
    import jax
    from repro.core.distributed import make_federated_train_step
    return jax.jit(make_federated_train_step(
        lambda p, b: bundle.loss_fn(p, b), scbf, lr=lr))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=32768)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=2, help="per client")
    ap.add_argument("--clients", type=int, default=4)
    # masked updates touch only the top-α channels per step, so the
    # stable-and-moving lr is ~10× a dense run's (probed in EXPERIMENTS)
    ap.add_argument("--upload-rate", type=float, default=0.25)
    ap.add_argument("--lr", type=float, default=0.08)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.config import ScbfConfig
    from repro.data.tokens import SyntheticTokenStream
    from repro.models import model_zoo

    cfg = dataclasses.replace(
        configs.get("qwen2-0.5b"),
        name="qwen2-100m-fed",
        num_layers=args.layers, d_model=args.d_model,
        num_heads=8, num_kv_heads=4, head_dim=args.d_model // 8,
        d_ff=args.d_model * 4, vocab_size=args.vocab)
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model: {cfg.num_layers}L d={cfg.d_model} vocab={cfg.vocab_size} "
          f"-> {n_params/1e6:.1f}M params, {args.clients} clients, "
          f"upload rate {args.upload_rate:.0%}")

    scbf = ScbfConfig(upload_rate=args.upload_rate,
                      num_clients=args.clients)
    step = _fed_step(bundle, scbf, args.lr)

    K, B, S = args.clients, args.batch, args.seq
    stream = SyntheticTokenStream(K * B, S, cfg.vocab_size, seed=1)
    t0 = time.time()
    for i, nb in zip(range(args.steps), stream):
        batch = {k: jnp.asarray(v).reshape(K, B, S) for k, v in nb.items()}
        loss, params = step(params, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            toks = K * B * S * (i + 1)
            dt = time.time() - t0
            print(f"step {i:4d} loss {float(loss):.4f} "
                  f"{toks/dt:,.0f} tok/s  ({dt:.0f}s)", flush=True)


if __name__ == "__main__":
    main()
