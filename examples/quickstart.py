"""Quickstart: SCBF vs FedAvg in ~60 seconds on CPU.

    PYTHONPATH=src python examples/quickstart.py

Generates a small synthetic medical cohort (the paper's dataset shape,
scaled down), runs 4 federated loops of SCBF (upload 10% of channels) and
FedAvg (upload everything), and prints the AUC + communication table.
"""
from repro.config import ScbfConfig, TrainConfig
from repro.core.scbf import run_federated
from repro.data.medical import generate_cohort


def main():
    cohort = generate_cohort(num_admissions=6000, num_medicines=400, seed=0)
    cfg = TrainConfig(learning_rate=0.05, global_loops=4, local_epochs=2,
                      local_batch_size=256,
                      scbf=ScbfConfig(upload_rate=0.10, num_clients=5))

    print("== SCBF (upload 10% of channels) ==")
    scbf = run_federated(cohort, cfg, method="scbf",
                         mlp_features=(400, 64, 16, 1), verbose=True)
    print("== Federated Averaging (upload 100%) ==")
    fa = run_federated(cohort, cfg, method="fedavg",
                       mlp_features=(400, 64, 16, 1), verbose=True)

    print("\nmethod   best-AUCROC  best-AUCPR  params revealed/loop")
    for res in (scbf, fa):
        frac = sum(r.upload_fraction for r in res.records) / len(res.records)
        print(f"{res.method:8s} {res.best('auc_roc'):10.4f} "
              f"{res.best('auc_pr'):10.4f}  {frac:18.0%}")
    frac = sum(r.upload_fraction for r in scbf.records) / len(scbf.records)
    print(f"\nSCBF reveals only {frac:.0%} of the model parameters to the "
          f"server per loop (FedAvg: 100%)\nwhile matching or beating its "
          f"accuracy at this loop count. Tune --upload-rate for more privacy.")


if __name__ == "__main__":
    main()
