"""The federation engine: vmapped cohorts, scheduling, aggregation, DP."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import wire
from repro.config import FedConfig, ScbfConfig, TrainConfig
from repro.core.scbf import run_federated
from repro.data.medical import dirichlet_split, generate_cohort
from repro.fed.cohort import bucket_size, pad_clients
from repro.fed.engine import (make_engine, reset_scbf_compile_count,
                              scbf_compile_count)
from repro.fed.scheduler import FedBuffScheduler, SyncScheduler, make_scheduler
from repro.fed.strategy import (FedBuff, RoundContribution, ScbfSum,
                                make_strategy)
from repro.models.mlp_net import init_mlp


@pytest.fixture(scope="module")
def cohort():
    return generate_cohort(num_admissions=800, num_medicines=40,
                           num_risk_medicines=15, num_interactions=4, seed=0)


FEATS = (40, 16, 4, 1)


def _tcfg(**scbf_kw):
    return TrainConfig(learning_rate=0.05, global_loops=2,
                       local_batch_size=64, local_epochs=1,
                       scbf=ScbfConfig(upload_rate=0.1, num_clients=5,
                                       **scbf_kw))


# ---------------------------------------------------------------------------
# engine parity: the tentpole acceptance criterion
# ---------------------------------------------------------------------------

def test_batched_matches_sequential_full_participation(cohort):
    """K=5, full participation: the vmapped engine reproduces the
    sequential loop — same AUC trajectory and identical wire bytes."""
    tcfg = _tcfg()
    seq = run_federated(cohort, tcfg, method="scbf", mlp_features=FEATS,
                        engine="sequential")
    bat = run_federated(cohort, tcfg, method="scbf", mlp_features=FEATS,
                        engine="batched")
    for a, b in zip(seq.records, bat.records):
        np.testing.assert_allclose(a.auc_roc, b.auc_roc, atol=1e-6)
        np.testing.assert_allclose(a.auc_pr, b.auc_pr, atol=1e-6)
        assert a.sparse_bytes == b.sparse_bytes
        assert a.dense_bytes == b.dense_bytes
        assert a.upload_fraction == b.upload_fraction


def test_batched_matches_sequential_fedavg(cohort):
    tcfg = _tcfg()
    seq = run_federated(cohort, tcfg, method="fedavg", mlp_features=FEATS,
                        engine="sequential")
    bat = run_federated(cohort, tcfg, method="fedavg", mlp_features=FEATS,
                        engine="batched")
    for a, b in zip(seq.records, bat.records):
        np.testing.assert_allclose(a.auc_roc, b.auc_roc, atol=1e-6)


# ---------------------------------------------------------------------------
# padded cohorts
# ---------------------------------------------------------------------------

def test_pad_clients_shapes_and_masks():
    rng = np.random.default_rng(0)
    clients = [(rng.random((n, 7)).astype(np.float32),
                rng.integers(0, 2, n).astype(np.float32))
               for n in (10, 4, 7)]
    pc = pad_clients(clients)
    assert pc.x.shape == (3, 10, 7) and pc.w.shape == (3, 10)
    assert list(pc.counts) == [10, 4, 7]
    assert not pc.uniform
    np.testing.assert_array_equal(np.asarray(pc.w).sum(axis=1), [10, 4, 7])
    # padded rows are zero
    assert float(jnp.abs(pc.x[1, 4:]).sum()) == 0.0
    # equal shards -> no padding -> uniform fast path
    assert pad_clients([c for c in clients if c[0].shape[0] == 10]
                       + [(clients[0][0].copy(), clients[0][1].copy())]
                       ).uniform


# ---------------------------------------------------------------------------
# Dirichlet non-IID partitioning
# ---------------------------------------------------------------------------

def test_dirichlet_split_conserves_examples(cohort):
    parts = dirichlet_split(cohort.x_train, cohort.y_train, 6,
                            alpha=0.3, seed=0)
    assert sum(p[0].shape[0] for p in parts) == cohort.x_train.shape[0]
    assert all(p[0].shape[0] >= 1 for p in parts)
    # every original example appears exactly once (row multisets match)
    total_pos = sum(float(p[1].sum()) for p in parts)
    assert total_pos == float(cohort.y_train.sum())


def test_dirichlet_split_hits_requested_heterogeneity(cohort):
    def mean_max_label_share(alpha):
        parts = dirichlet_split(cohort.x_train, cohort.y_train, 6,
                                alpha=alpha, seed=0)
        shares = []
        for _, y in parts:
            p1 = float(y.mean())
            shares.append(max(p1, 1.0 - p1))
        return np.mean(shares)

    skewed, iid_like = mean_max_label_share(0.05), mean_max_label_share(100.0)
    assert skewed > iid_like + 0.05    # low alpha => label-dominated silos


# ---------------------------------------------------------------------------
# schedulers
# ---------------------------------------------------------------------------

def test_sync_sampling_determinism():
    cfg = FedConfig(sample_fraction=0.4, dropout_rate=0.2,
                    straggler_rate=0.2)
    a = SyncScheduler(20, cfg, seed=7)
    b = SyncScheduler(20, cfg, seed=7)
    c = SyncScheduler(20, cfg, seed=8)
    plans_a = [a.plan(i) for i in range(10)]
    plans_b = [b.plan(i) for i in range(10)]
    plans_c = [c.plan(i) for i in range(10)]
    for pa, pb in zip(plans_a, plans_b):
        np.testing.assert_array_equal(pa.participants, pb.participants)
        np.testing.assert_array_equal(pa.sampled, pb.sampled)
        np.testing.assert_array_equal(pa.dropped, pb.dropped)
    assert any(not np.array_equal(pa.sampled, pc.sampled)
               for pa, pc in zip(plans_a, plans_c))
    # sampling honours the fraction; participants never exceed the sample
    for p in plans_a:
        assert p.sampled.size == 8
        assert p.participants.size <= p.sampled.size
        assert np.all(np.isin(p.participants, p.sampled))
        assert np.all(p.staleness == 0)


def test_fedbuff_scheduler_determinism_and_staleness():
    cfg = FedConfig(mode="fedbuff", concurrency=6, straggler_rate=0.5)
    a = make_scheduler(cfg, 12, seed=3)
    b = make_scheduler(cfg, 12, seed=3)
    assert isinstance(a, FedBuffScheduler)
    saw_stale = False
    for i in range(12):
        pa, pb = a.plan(i, i), b.plan(i, i)
        np.testing.assert_array_equal(pa.participants, pb.participants)
        np.testing.assert_array_equal(pa.staleness, pb.staleness)
        saw_stale |= bool(np.any(pa.staleness > 0))
        # never more in flight than concurrency allows
        assert len(a.in_flight) <= cfg.concurrency
    assert saw_stale                    # stragglers actually produce lag


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

def _payload_of(tree):
    return wire.encode(tree)


def test_fedbuff_staleness_weighting():
    params = init_mlp((4, 3, 1), jax.random.PRNGKey(0))
    d0 = jax.tree_util.tree_map(jnp.ones_like, params)
    d1 = jax.tree_util.tree_map(lambda x: 2.0 * jnp.ones_like(x), params)
    strat = FedBuff(buffer_size=2, staleness_exponent=0.5, server_lr=1.0)
    state = strat.init(params)
    contrib = RoundContribution(
        num_examples=np.array([10, 10]),
        staleness=np.array([0, 3]),
        payloads=[_payload_of(d0), _payload_of(d1)])
    new = strat.aggregate(state, contrib)
    assert new.version == 1 and new.buffer_count == 0
    # expected step: (1*d0 + (1+3)^-0.5 * d1) / 2 = (1 + 0.5*2)/2 = 1.0
    expect = jax.tree_util.tree_map(lambda p: p + 1.0, params)
    for got, exp in zip(jax.tree_util.tree_leaves(new.params),
                        jax.tree_util.tree_leaves(expect)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                                   rtol=1e-6)


def test_fedbuff_buffers_until_full():
    params = init_mlp((4, 3, 1), jax.random.PRNGKey(0))
    d = jax.tree_util.tree_map(jnp.ones_like, params)
    strat = FedBuff(buffer_size=3)
    state = strat.init(params)
    one = RoundContribution(num_examples=np.array([5]),
                            staleness=np.array([0]),
                            payloads=[_payload_of(d)])
    state = strat.aggregate(state, one)
    assert state.version == 0 and state.buffer_count == 1
    for leaf0, leaf in zip(jax.tree_util.tree_leaves(params),
                           jax.tree_util.tree_leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(leaf0), np.asarray(leaf))


def test_fedbuff_flushes_per_upload_not_per_round():
    """One oversized round must flush at the buffer_size-th upload and
    keep buffering the trailing uploads against the advanced version."""
    params = init_mlp((4, 3, 1), jax.random.PRNGKey(0))
    d = jax.tree_util.tree_map(jnp.ones_like, params)
    strat = FedBuff(buffer_size=2)
    contrib = RoundContribution(
        num_examples=np.array([5, 5, 5]),
        staleness=np.array([0, 0, 0]),
        payloads=[_payload_of(d)] * 3)
    state = strat.aggregate(strat.init(params), contrib)
    assert state.version == 1            # exactly one flush (not 0, not 17-style)
    assert state.buffer_count == 1       # third upload carried over
    for p0, p1 in zip(jax.tree_util.tree_leaves(params),
                      jax.tree_util.tree_leaves(state.params)):
        np.testing.assert_allclose(np.asarray(p1), np.asarray(p0) + 1.0,
                                   rtol=1e-6)


def test_dp_refuses_fedavg(cohort):
    tcfg = _tcfg(dp_noise_multiplier=1.0)
    with pytest.raises(ValueError):
        run_federated(cohort, tcfg, method="fedavg", mlp_features=FEATS)


def test_scbf_sum_strategy_matches_wire_apply():
    params = init_mlp((4, 3, 1), jax.random.PRNGKey(0))
    d = jax.tree_util.tree_map(jnp.ones_like, params)
    strat = make_strategy("scbf", ScbfConfig(), FedConfig())
    assert isinstance(strat, ScbfSum)
    state = strat.aggregate(strat.init(params), RoundContribution(
        num_examples=np.array([5]), staleness=np.array([0]),
        payloads=[_payload_of(d)]))
    for p0, p1 in zip(jax.tree_util.tree_leaves(params),
                      jax.tree_util.tree_leaves(state.params)):
        np.testing.assert_allclose(np.asarray(p1), np.asarray(p0) + 1.0,
                                   rtol=1e-6)


# ---------------------------------------------------------------------------
# scenario runs through the driver
# ---------------------------------------------------------------------------

def test_sampling_and_dropout_run_deterministically(cohort):
    fed = FedConfig(sample_fraction=0.5, dropout_rate=0.25)
    tcfg = dataclasses.replace(
        _tcfg(), fed=fed,
        scbf=ScbfConfig(upload_rate=0.1, num_clients=8))
    a = run_federated(cohort, tcfg, method="scbf", mlp_features=FEATS)
    b = run_federated(cohort, tcfg, method="scbf", mlp_features=FEATS)
    assert [r.num_participants for r in a.records] == \
        [r.num_participants for r in b.records]
    assert [r.auc_roc for r in a.records] == [r.auc_roc for r in b.records]
    assert all(r.num_participants <= 4 for r in a.records)


def test_dp_noise_reports_epsilon(cohort):
    tcfg = _tcfg(dp_noise_multiplier=1.0, dp_clip_norm=1.0)
    res = run_federated(cohort, tcfg, method="scbf", mlp_features=FEATS)
    eps = [r.epsilon for r in res.records]
    assert all(e is not None and np.isfinite(e) for e in eps)
    assert eps[1] > eps[0]              # composition accumulates
    assert res.final_epsilon == eps[-1]
    assert res.dp_delta == tcfg.scbf.dp_delta
    # DP off -> no epsilon reported
    res0 = run_federated(cohort, _tcfg(), method="scbf", mlp_features=FEATS)
    assert res0.final_epsilon is None and res0.dp_delta is None


def test_dp_noises_every_revealed_coordinate():
    """A revealed entry whose gradient is exactly zero must still ship
    noised — otherwise it leaks its exact value and the reported (ε, δ)
    is unsound."""
    from repro.core.privacy import gaussian_mechanism
    tree = ({"w": jnp.array([[0.0, 0.5], [0.0, 0.25]]),
             "b": jnp.array([0.0, 0.1])},)
    masks = ({"w": jnp.array([[True, True], [False, True]]),
              "b": jnp.array([True, True])},)
    out = gaussian_mechanism(tree, jax.random.PRNGKey(0), 1.0, 1.0,
                             masks=masks)
    w, b = np.asarray(out[0]["w"]), np.asarray(out[0]["b"])
    assert w[0, 0] != 0.0 and b[0] != 0.0   # revealed zeros are noised
    assert w[1, 0] == 0.0                    # unrevealed entries stay zero


def test_fedbuff_end_to_end_smoke(cohort):
    fed = FedConfig(mode="fedbuff", buffer_size=4, concurrency=6,
                    straggler_rate=0.3)
    tcfg = dataclasses.replace(
        TrainConfig(learning_rate=0.05, global_loops=3,
                    local_batch_size=64, local_epochs=1,
                    scbf=ScbfConfig(upload_rate=0.1, num_clients=8)),
        fed=fed)
    res = run_federated(cohort, tcfg, method="scbf", mlp_features=FEATS)
    assert len(res.records) == 3
    assert all(0.0 <= r.auc_roc <= 1.0 for r in res.records)
    with pytest.raises(ValueError):
        run_federated(cohort, tcfg, method="fedavg", mlp_features=FEATS)


def test_dirichlet_cohort_trains_batched(cohort):
    fed = FedConfig(partition="dirichlet", dirichlet_alpha=0.3)
    tcfg = dataclasses.replace(_tcfg(), fed=fed)
    res = run_federated(cohort, tcfg, method="scbf", mlp_features=FEATS,
                        engine="batched")
    assert len(res.records) == 2
    assert all(0.0 < r.upload_fraction < 1.0 for r in res.records)
    assert all(r.sparse_bytes < r.dense_bytes for r in res.records)


# ---------------------------------------------------------------------------
# bucketed-P padding: the recompile-per-participant-count fix
# ---------------------------------------------------------------------------

def test_bucket_size_policy():
    assert [bucket_size(p, 16) for p in (1, 2, 3, 5, 8, 9, 16)] == \
        [1, 2, 4, 8, 8, 16, 16]
    # cap at the client count: P=5 of K=5 stays exact (no padded slots
    # at full participation)
    assert bucket_size(5, 5) == 5
    assert bucket_size(3, 5) == 4
    # exact reproduces the pre-bucketing behaviour
    assert [bucket_size(p, 16, "exact") for p in (1, 3, 7)] == [1, 3, 7]
    # pod divisibility: buckets round up to the device count
    assert bucket_size(1, 16, "pow2", multiple=4) == 4
    assert bucket_size(5, 16, "pow2", multiple=4) == 8
    assert bucket_size(3, 16, "exact", multiple=4) == 4
    assert bucket_size(0, 16) == 0
    with pytest.raises(ValueError):
        bucket_size(3, 16, "fib")
    with pytest.raises(ValueError):
        bucket_size(17, 16)


def _round_keys(key, n):
    kc, ks, kd = jax.random.split(key, 3)
    return tuple(jax.random.split(k, n) for k in (kc, ks, kd))


def _clients(K, n, d, seed=0):
    rng = np.random.default_rng(seed)
    return [(rng.random((n, d)).astype(np.float32),
             (rng.random(n) < 0.5).astype(np.float32)) for _ in range(K)]


def _assert_payloads_identical(pa, pb):
    assert [p.nbytes for p in pa] == [p.nbytes for p in pb]
    for a, b in zip(pa, pb):
        for la, lb in zip(wire.decode(a), wire.decode(b)):
            assert la.keys() == lb.keys()
            for k in la:
                np.testing.assert_array_equal(np.asarray(la[k]),
                                              np.asarray(lb[k]))


def test_bucket_padding_matches_unbucketed_across_boundary():
    """P=3 of K=5 lands in bucket 4: the padded slot must leave the
    three real participants bit-identical to the unbucketed (exact)
    pass, and statistically identical to the sequential loop (vmap vs.
    per-client dispatch reorders float accumulation at some shapes, so
    the sequential comparison is allclose, not bitwise — the bitwise
    sequential guarantee lives at full participation)."""
    clients = _clients(5, 24, 12)
    params = init_mlp((12, 8, 1), jax.random.PRNGKey(1))
    cfg = ScbfConfig(upload_rate=0.25, num_clients=5)
    part = np.array([0, 2, 4])
    ck, sk, dk = _round_keys(jax.random.PRNGKey(0), 3)
    seq = make_engine("sequential", clients, 8, 1)
    bat = make_engine("batched", clients, 8, 1, bucket="pow2")
    exact = make_engine("batched", clients, 8, 1, bucket="exact")
    assert bucket_size(3, 5) == 4            # the boundary actually pads
    ps, ss = seq.scbf_round(params, part, 0.1, ck, sk, dk, cfg)
    pb, sb = bat.scbf_round(params, part, 0.1, ck, sk, dk, cfg)
    pe, se = exact.scbf_round(params, part, 0.1, ck, sk, dk, cfg)
    _assert_payloads_identical(pe, pb)       # padding changes nothing
    assert [s.upload_fraction for s in ss] == \
        [s.upload_fraction for s in sb]
    for a, b in zip(ps, pb):                 # engines agree numerically
        for la, lb in zip(wire.decode(a), wire.decode(b)):
            for k in la:
                np.testing.assert_allclose(np.asarray(la[k]),
                                           np.asarray(lb[k]), atol=1e-6)


def test_bucketed_matches_exact_on_dirichlet_across_buckets(cohort):
    """Non-uniform Dirichlet shards, sampling + dropout: bucket padding
    must not perturb the trajectory — pow2 and exact (compile-per-P)
    produce identical records while P crosses bucket boundaries."""
    def fed(bucket):
        return FedConfig(partition="dirichlet", dirichlet_alpha=0.3,
                         sample_fraction=0.5, dropout_rate=0.25,
                         bucket=bucket)
    def tcfg(bucket):
        return dataclasses.replace(
            TrainConfig(learning_rate=0.05, global_loops=6,
                        local_batch_size=64, local_epochs=1,
                        scbf=ScbfConfig(upload_rate=0.1, num_clients=8)),
            fed=fed(bucket))
    a = run_federated(cohort, tcfg("pow2"), method="scbf",
                      mlp_features=FEATS, engine="batched")
    b = run_federated(cohort, tcfg("exact"), method="scbf",
                      mlp_features=FEATS, engine="batched")
    ps = [r.num_participants for r in a.records]
    assert ps == [r.num_participants for r in b.records]
    assert len(set(p for p in ps if p)) > 1   # P actually varies
    for ra, rb in zip(a.records, b.records):
        assert ra.auc_roc == rb.auc_roc and ra.auc_pr == rb.auc_pr
        assert ra.sparse_bytes == rb.sparse_bytes
        assert ra.upload_fraction == rb.upload_fraction


def test_scbf_pass_compiles_once_per_bucket(cohort):
    """The tentpole acceptance criterion: a seeded 30-round run with
    sample_fraction=0.5 and nonzero dropout compiles ``_scbf_pass`` at
    most once per bucket, not once per distinct P."""
    fed = FedConfig(sample_fraction=0.5, dropout_rate=0.25, bucket="pow2")
    tcfg = dataclasses.replace(
        TrainConfig(learning_rate=0.05, global_loops=30,
                    local_batch_size=64, local_epochs=1,
                    scbf=ScbfConfig(upload_rate=0.1, num_clients=16)),
        fed=fed)
    reset_scbf_compile_count()
    res = run_federated(cohort, tcfg, method="scbf", mlp_features=FEATS)
    ps = sorted({r.num_participants for r in res.records
                 if r.num_participants})
    buckets = sorted({bucket_size(p, 16) for p in ps})
    assert len(ps) > len(buckets)             # the bug would bite here
    assert scbf_compile_count() <= len(buckets)


def test_empty_rounds_skip_cleanly(cohort):
    """All sampled clients dropping out must not dispatch a P=0 vmap."""
    clients = _clients(4, 16, 12)
    params = init_mlp((12, 8, 1), jax.random.PRNGKey(1))
    cfg = ScbfConfig(upload_rate=0.25, num_clients=4)
    none = np.array([], dtype=np.int64)
    ck, sk, dk = _round_keys(jax.random.PRNGKey(0), 0)
    for kind in ("batched", "sequential"):
        eng = make_engine(kind, clients, 8, 1)
        assert eng.scbf_round(params, none, 0.1, ck, sk, dk, cfg) == ([], [])
        outs, counts = eng.fedavg_round(params, none, 0.1, ck)
        assert outs == [] and len(counts) == 0
    # seeded end-to-end: every round empty, driver still records cleanly
    fed = FedConfig(sample_fraction=0.5, dropout_rate=1.0)
    tcfg = dataclasses.replace(_tcfg(), fed=fed)
    res = run_federated(cohort, tcfg, method="scbf", mlp_features=FEATS)
    assert [r.num_participants for r in res.records] == [0, 0]
    assert all(np.isfinite(r.auc_roc) for r in res.records)
    assert all(r.sparse_bytes == 0 for r in res.records)


# ---------------------------------------------------------------------------
# pod-axis device sharding
# ---------------------------------------------------------------------------

_POD_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")
import numpy as np
import jax
from repro.comm import wire
from repro.config import ScbfConfig
from repro.fed.engine import make_engine
from repro.models.mlp_net import init_mlp

assert len(jax.devices()) == 4
rng = np.random.default_rng(0)
clients = [(rng.random((16, 8)).astype(np.float32),
            (rng.random(16) < .5).astype(np.float32)) for _ in range(4)]
params = init_mlp((8, 6, 1), jax.random.PRNGKey(1))
cfg = ScbfConfig(upload_rate=0.25, num_clients=4)
kc, ks, kd = jax.random.split(jax.random.PRNGKey(0), 3)
one = make_engine("batched", clients, 8, 1, pods=1)
four = make_engine("batched", clients, 8, 1, pods=4)
for P in (1, 3, 4):
    part = np.arange(P)
    ck, sk, dk = (jax.random.split(k, P) for k in (kc, ks, kd))
    p1, _ = one.scbf_round(params, part, 0.1, ck, sk, dk, cfg)
    p4, _ = four.scbf_round(params, part, 0.1, ck, sk, dk, cfg)
    assert [p.nbytes for p in p1] == [p.nbytes for p in p4]
    for a, b in zip(p1, p4):
        for la, lb in zip(wire.decode(a), wire.decode(b)):
            for k in la:
                np.testing.assert_array_equal(np.asarray(la[k]),
                                              np.asarray(lb[k]))
print("POD_PARITY_OK")
"""


@pytest.mark.slow
def test_pod_sharded_round_matches_single_device():
    """The bucketed cohort sharded over a 4-device pod mesh produces
    bit-identical uploads (fresh process: the device count is locked at
    first jax import)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _POD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "POD_PARITY_OK" in out.stdout


def test_sequential_engine_refuses_pods():
    with pytest.raises(ValueError):
        make_engine("sequential", _clients(2, 8, 4), 8, 1, pods=2)


# ---------------------------------------------------------------------------
# strategy / accountant regressions
# ---------------------------------------------------------------------------

def test_make_strategy_rejects_fedbuff_fedavg():
    """fedbuff + fedavg used to return the payload-only FedBuff strategy,
    whose aggregate() silently no-ops on client_params rounds."""
    with pytest.raises(ValueError):
        make_strategy("fedavg", ScbfConfig(), FedConfig(mode="fedbuff"))
    assert isinstance(
        make_strategy("scbf", ScbfConfig(), FedConfig(mode="fedbuff")),
        FedBuff)


def test_rdp_accountant_default_and_classic_domain():
    from repro.core import privacy
    # rdp: finite, monotone in loops, tighter than linear classic
    # composition where classic is valid (sigma=5 -> per-release eps<1)
    e1 = privacy.epsilon_for(5.0, 1e-5, loops=1)
    e30 = privacy.epsilon_for(5.0, 1e-5, loops=30)
    c30 = privacy.epsilon_for(5.0, 1e-5, loops=30, accountant="classic")
    assert 0 < e1 < e30 < c30
    # classic is refused outside its eps <= 1 validity domain (it used
    # to fabricate a number there)
    with pytest.raises(ValueError):
        privacy.epsilon_for(1.0, 1e-5, loops=1, accountant="classic")
    with pytest.raises(ValueError):
        privacy.sigma_for(2.0, 1e-5, loops=1, accountant="classic")
    # sigma_for inverts epsilon_for under composition
    sigma = privacy.sigma_for(2.0, 1e-5, loops=10)
    assert np.isclose(privacy.epsilon_for(sigma, 1e-5, loops=10), 2.0,
                      rtol=1e-6)
    # dp-off sentinel unchanged
    assert privacy.epsilon_for(0.0) == np.inf


def test_subsampled_rdp_amplification_bounds():
    """Subsampled-Gaussian RDP: q=1 reduces exactly to the unamplified
    curve, q<1 amplifies (smaller ε), the curve is monotone in q and in
    rounds, and the integer-order domain is enforced."""
    from repro.core import privacy
    sigma, delta, rounds = 2.0, 1e-5, 30
    full = privacy.epsilon_for(sigma, delta, loops=rounds)
    amp_small = privacy.amplified_epsilon_for(sigma, 0.1, delta, rounds)
    amp_mid = privacy.amplified_epsilon_for(sigma, 0.5, delta, rounds)
    amp_q1 = privacy.amplified_epsilon_for(sigma, 1.0, delta, rounds)
    assert 0 < amp_small < amp_mid < full
    assert amp_q1 == full                       # exact reduction at q=1
    # composition accumulates
    assert privacy.amplified_epsilon_for(sigma, 0.1, delta, 1) < amp_small
    # per-order reduction at q=1 matches the Gaussian RDP curve exactly
    assert privacy.subsampled_gaussian_rdp(sigma, 1.0, 4) == \
        privacy.gaussian_rdp(sigma, 4.0)
    assert privacy.subsampled_gaussian_rdp(sigma, 0.0, 4) == 0.0
    with pytest.raises(ValueError):
        privacy.subsampled_gaussian_rdp(sigma, 0.1, 1)      # order >= 2
    with pytest.raises(ValueError):
        privacy.subsampled_gaussian_rdp(sigma, 0.1, 2.5)    # integer only
    with pytest.raises(ValueError):
        privacy.subsampled_gaussian_rdp(sigma, 1.5, 4)      # q in [0, 1]
    # dp-off / no-rounds sentinels mirror epsilon_for
    assert privacy.amplified_epsilon_for(0.0, 0.1) == np.inf
    assert privacy.amplified_epsilon_for(sigma, 0.1, delta, 0) == 0.0


def test_driver_reports_amplified_and_unamplified_epsilon(cohort):
    """One seeded sampled run with dp_amplification on: every record
    carries both the operative (amplified) ε and the unamplified one,
    with the amplified strictly tighter; the unamplified ledger matches
    a run with amplification off bit-for-bit."""
    def tcfg(amplify):
        # batch 32: K=8 shards hold 60 rows, so batch 64 would train
        # zero batches and the run would be a no-op
        return TrainConfig(
            learning_rate=0.05, global_loops=2, local_batch_size=32,
            local_epochs=1,
            scbf=ScbfConfig(upload_rate=0.1, num_clients=8,
                            dp_noise_multiplier=2.0, dp_clip_norm=1.0,
                            dp_amplification=amplify),
            fed=FedConfig(sample_fraction=0.25))
    res = run_federated(cohort, tcfg(True), method="scbf",
                        mlp_features=FEATS)
    assert sum(r.sparse_bytes for r in res.records) > 0
    for r in res.records:
        assert r.epsilon is not None and r.epsilon_unamplified is not None
        assert 0 < r.epsilon < r.epsilon_unamplified
    plain = run_federated(cohort, tcfg(False), method="scbf",
                          mlp_features=FEATS)
    assert [r.epsilon_unamplified for r in res.records] == \
        [r.epsilon for r in plain.records]
    assert all(r.epsilon_unamplified is None for r in plain.records)


def test_amplification_refused_where_unsound(cohort):
    """Amplification must refuse fedbuff participation (not an i.i.d.
    per-round sample) and the classic accountant (it is an RDP
    analysis) instead of reporting a silently-wrong ε."""
    fedbuff = dataclasses.replace(
        TrainConfig(learning_rate=0.05, global_loops=2,
                    local_batch_size=64, local_epochs=1,
                    scbf=ScbfConfig(upload_rate=0.1, num_clients=8,
                                    dp_noise_multiplier=2.0,
                                    dp_amplification=True)),
        fed=FedConfig(mode="fedbuff"))
    with pytest.raises(ValueError, match="fedbuff"):
        run_federated(cohort, fedbuff, method="scbf", mlp_features=FEATS)
    classic = _tcfg(dp_noise_multiplier=5.0, dp_amplification=True,
                    dp_accountant="classic")
    with pytest.raises(ValueError, match="rdp"):
        run_federated(cohort, classic, method="scbf", mlp_features=FEATS)


def test_driver_rejects_bad_accountant_before_training(cohort):
    """A bad accountant config must fail at run start, not after a full
    training loop when the first LoopRecord is assembled."""
    for kw in (dict(dp_accountant="classic"),   # nm=1 -> eps>1, off-domain
               dict(dp_accountant="nope")):
        tcfg = _tcfg(dp_noise_multiplier=1.0, **kw)
        with pytest.raises(ValueError):
            run_federated(cohort, tcfg, method="scbf", mlp_features=FEATS)
