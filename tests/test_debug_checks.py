"""``TrainConfig.debug_checks``: host-side finite/validity assertions
at chunk boundaries (repro.obs.checks) — the dynamic counterpart of the
shapelint static gate (docs/STATIC_ANALYSIS.md §Shape lint).

Contract under test: the checks run on values the loop has already
offloaded, so the traced program is byte-identical with the flag on or
off (bitwise record parity); a poisoned tree fails loudly with the
offending leaf path; and the unified sequential-path loss accounting
(satellite 6) is bit-identical to the sliced form it replaced.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.fed.engine as engine_mod
from repro.config import FedConfig, ScbfConfig, TrainConfig
from repro.core.scbf import run_federated
from repro.data.medical import generate_cohort
from repro.fed.cohort import bucket_size
from repro.fed.engine import make_engine
from repro.models.mlp_net import init_mlp
from repro.obs import checks


@pytest.fixture(scope="module")
def cohort():
    return generate_cohort(num_admissions=120, num_medicines=10,
                           num_risk_medicines=4, num_interactions=2, seed=0)


FEATS = (10, 6, 1)


def _tcfg(**kw):
    return TrainConfig(learning_rate=0.05, global_loops=2,
                       local_batch_size=32, local_epochs=1,
                       scbf=ScbfConfig(upload_rate=0.25, num_clients=3),
                       **kw)


# ---------------------------------------------------------------------------
# unit contracts: repro.obs.checks
# ---------------------------------------------------------------------------

def test_check_finite_passes_and_names_the_bad_leaf():
    good = ({"w": jnp.ones((3, 2)), "b": jnp.zeros((2,))},)
    checks.check_finite(good, where="unit")        # no raise

    bad = ({"w": jnp.ones((3, 2)).at[1, 0].set(jnp.nan),
            "b": jnp.zeros((2,))},)
    with pytest.raises(checks.DebugCheckError) as exc:
        checks.check_finite(bad, where="loop 3")
    msg = str(exc.value)
    assert "loop 3" in msg and "'0/w'" in msg and "1 nan" in msg

    inf = ({"w": jnp.full((2,), jnp.inf)},)
    with pytest.raises(checks.DebugCheckError, match="2 inf"):
        checks.check_finite(inf, where="unit")

    # integer leaves are exempt (finiteness is a float property)
    checks.check_finite((jnp.arange(4),), where="unit")
    checks.check_finite(None, where="unit")        # vacuous


def test_check_participants_detects_mask_skew():
    checks.check_participants(jnp.asarray(3), 3, where="unit")
    checks.check_participants(None, 3, where="unit")     # unknown: skip
    checks.check_participants(jnp.asarray(3), None, where="unit")
    with pytest.raises(checks.DebugCheckError, match="skew"):
        checks.check_participants(jnp.asarray(4), 3, where="chunk@loop 0")


def test_verify_records_rejects_nonfinite_fields():
    @dataclasses.dataclass
    class Rec:
        loss: float
        auc_roc: float

    checks.verify_records([Rec(0.5, 0.9)], where="unit")
    with pytest.raises(checks.DebugCheckError, match="auc_roc"):
        checks.verify_records([Rec(0.5, float("nan"))], where="unit")


# ---------------------------------------------------------------------------
# the parity contract: debug_checks must not perturb the run
# ---------------------------------------------------------------------------

def test_debug_checks_bitwise_parity_per_round(cohort):
    base = run_federated(cohort, _tcfg(), method="scbf",
                         mlp_features=FEATS)
    checked = run_federated(cohort, _tcfg(debug_checks=True),
                            method="scbf", mlp_features=FEATS)
    assert len(base.records) == len(checked.records)
    for a, b in zip(base.records, checked.records):
        assert a.auc_roc == b.auc_roc        # bitwise: same trace either way
        assert a.auc_pr == b.auc_pr
        assert a.sparse_bytes == b.sparse_bytes


def test_debug_checks_bitwise_parity_fused(cohort):
    fed = FedConfig(fuse_rounds=2)
    base = run_federated(cohort, _tcfg(fed=fed), method="scbf",
                         mlp_features=FEATS)
    checked = run_federated(cohort, _tcfg(fed=fed, debug_checks=True),
                            method="scbf", mlp_features=FEATS)
    for a, b in zip(base.records, checked.records):
        assert a.auc_roc == b.auc_roc
        assert a.auc_pr == b.auc_pr


# ---------------------------------------------------------------------------
# satellite 6: unified loss accounting, bit parity with the sliced form
# ---------------------------------------------------------------------------

def test_fedavg_masked_loss_sum_bit_matches_sliced(monkeypatch):
    """fedavg_round now computes ``Σ where(valid, losses, 0)`` like the
    fused round_body; on a padded bucket (P=3 → bucket 4) this must be
    bit-identical to the ``Σ losses[:p_count]`` form it replaced — the
    dead slot is excluded by mask or by slice either way, and adding
    its masked zero cannot move an f32 sum of finite positives."""
    rng = np.random.default_rng(0)
    clients = [(rng.random((24, 12)).astype(np.float32),
                (rng.random(24) < 0.5).astype(np.float32))
               for _ in range(5)]
    eng = make_engine("batched", clients, 8, 1, bucket="pow2")
    params = init_mlp((12, 8, 1), jax.random.PRNGKey(1))
    part = np.array([0, 2, 4])
    assert bucket_size(3, 5) == 4            # the bucket actually pads
    ck = jax.random.split(jax.random.PRNGKey(0), 3)

    captured = {}
    orig = engine_mod._fedavg_pass

    def spy(*args, **kw):
        out = orig(*args, **kw)
        captured["losses"] = out[1]
        return out

    monkeypatch.setattr(engine_mod, "_fedavg_pass", spy)
    _, _, dm = eng.fedavg_round(params, part, 0.1, ck, collect=True)

    losses = captured["losses"]
    assert losses.shape == (4,)              # padded to the bucket
    sliced = float(jnp.sum(losses[:3]).astype(jnp.float32))
    assert dm["train_loss"] == sliced / 3    # bitwise, not allclose
    # the padded slot carries a REAL (nonzero, distinct-key) loss the
    # accounting must exclude — if the mask ever widened, the sums
    # above could not match
    pad_loss = float(losses[3])
    assert np.isfinite(pad_loss) and pad_loss != 0.0
    assert pad_loss not in {float(losses[i]) for i in range(3)}
