"""The fused round loop: whole SCBF rounds as one device program.

Covers the PR-4 acceptance bars: fused-vs-per-round bit-parity at full
participation and under varying bucketed P, the prune/fedbuff fallback
boundary, a transfer-guard proof that the fused hot loop never crosses
the host, the <= 2-compiles property on a varying-P trace, and the
eval_every / evaluated-flag record semantics.
"""
import jax
import numpy as np
import pytest

from _trace_guards import assert_compiles, assert_no_transfers
from repro.config import FedConfig, ScbfConfig, TrainConfig
from repro.core.scbf import run_federated
from repro.data.medical import generate_cohort
from repro.fed.engine import make_engine
from repro.fed.scheduler import make_scheduler
from repro.models.mlp_net import init_mlp


@pytest.fixture(scope="module")
def cohort():
    return generate_cohort(num_admissions=800, num_medicines=40,
                           num_risk_medicines=15, num_interactions=4, seed=0)


FEATS = (40, 16, 4, 1)


def _tcfg(fuse: int, loops: int = 4, K: int = 5, eval_every: int = 1,
          batch: int = 64, scbf_kw=None, **fed_kw):
    # K=8 splits the 480 train rows into 60-row shards, so those tests
    # must pass batch=32 — at batch 64 every client trains ZERO batches
    # and the whole run is a (legitimate, but vacuous) no-op
    return TrainConfig(
        learning_rate=0.05, global_loops=loops, local_batch_size=batch,
        local_epochs=1, eval_every=eval_every,
        scbf=ScbfConfig(upload_rate=0.1, num_clients=K, **(scbf_kw or {})),
        fed=FedConfig(fuse_rounds=fuse, **fed_kw))


def _params_bitwise_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


def _assert_trajectories_match(per_round, fused, bitwise_params=True):
    """Everything the fused path owes the per-round path: identical
    participation, byte accounting, ε spend, and final model."""
    assert len(per_round.records) == len(fused.records)
    for ra, rb in zip(per_round.records, fused.records):
        assert ra.loop == rb.loop
        assert ra.num_participants == rb.num_participants
        assert ra.sparse_bytes == rb.sparse_bytes
        assert ra.dense_bytes == rb.dense_bytes
        assert ra.upload_fraction == rb.upload_fraction
        assert ra.epsilon == rb.epsilon
    if bitwise_params:
        assert _params_bitwise_equal(per_round.final_params,
                                     fused.final_params)


# ---------------------------------------------------------------------------
# parity: the tentpole acceptance criterion
# ---------------------------------------------------------------------------

def test_fused_matches_per_round_full_participation(cohort):
    """fuse_rounds=S is bit-identical to fuse_rounds=1 at K=5 full
    participation: params, masks (via byte accounting), upload bytes,
    and ε all agree; the final evaluated AUC agrees exactly because the
    models are the same bits."""
    a = run_federated(cohort, _tcfg(1, loops=5), method="scbf",
                      mlp_features=FEATS)
    b = run_federated(cohort, _tcfg(3, loops=5), method="scbf",
                      mlp_features=FEATS)
    _assert_trajectories_match(a, b)
    assert b.records[-1].evaluated
    assert a.final.auc_roc == b.final.auc_roc
    assert a.final.auc_pr == b.final.auc_pr


def test_fused_matches_per_round_with_dp(cohort):
    """DP noise runs inside the fused scan; the ε ledger and the noised
    trajectory must both match the per-round path bit-for-bit."""
    kw = dict(scbf_kw=dict(dp_noise_multiplier=1.0, dp_clip_norm=1.0))
    a = run_federated(cohort, _tcfg(1, **kw), method="scbf",
                      mlp_features=FEATS)
    b = run_federated(cohort, _tcfg(4, **kw), method="scbf",
                      mlp_features=FEATS)
    _assert_trajectories_match(a, b)
    assert all(r.epsilon is not None for r in b.records)


def test_fused_matches_per_round_varying_bucketed_p(cohort):
    """Sampling + dropout make P vary across bucket boundaries; the
    fused plan pads every round to one run-constant slot count, and the
    real slots must stay bit-identical to the per-round bucketed
    engine."""
    kw = dict(loops=7, K=8, batch=32, sample_fraction=0.5,
              dropout_rate=0.25)
    a = run_federated(cohort, _tcfg(1, **kw), method="scbf",
                      mlp_features=FEATS)
    b = run_federated(cohort, _tcfg(3, **kw), method="scbf",
                      mlp_features=FEATS)
    ps = [r.num_participants for r in a.records]
    assert len({p for p in ps if p}) > 1      # P actually varies
    # guard against a vacuous pass: real training, real uploads
    assert sum(r.sparse_bytes for r in a.records) > 0
    _assert_trajectories_match(a, b)


def test_fused_fedavg_matches_per_round(cohort):
    """Fused FedAvg aggregates on device too.  XLA contracts the
    weight-multiply-accumulate inside the fused program (FMA), so
    parity here is allclose-tight rather than bitwise — the scbf path
    (pure adds, nothing to contract) is the bitwise one."""
    a = run_federated(cohort, _tcfg(1, loops=5), method="fedavg",
                      mlp_features=FEATS)
    b = run_federated(cohort, _tcfg(3, loops=5), method="fedavg",
                      mlp_features=FEATS)
    for la, lb in zip(jax.tree_util.tree_leaves(a.final_params),
                      jax.tree_util.tree_leaves(b.final_params)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=1e-6, rtol=1e-5)
    assert a.final.auc_roc == pytest.approx(b.final.auc_roc, abs=1e-6)


# ---------------------------------------------------------------------------
# fallback boundary: prune / fedbuff / sequential run per-round
# ---------------------------------------------------------------------------

def test_fused_prune_falls_back_to_per_round(cohort):
    """Pruning reshapes the model mid-run, which a fixed-shape scan
    cannot express: fuse_rounds>1 + prune must take the per-round path
    — every loop evaluated (no chunk coarsening) and the trajectory
    identical to an explicit fuse_rounds=1 run."""
    kw = dict(loops=4, scbf_kw=dict(prune=True, prune_rate=0.2,
                                    prune_total=0.4))
    a = run_federated(cohort, _tcfg(1, **kw), method="scbf",
                      mlp_features=FEATS)
    b = run_federated(cohort, _tcfg(4, **kw), method="scbf",
                      mlp_features=FEATS)
    assert all(r.evaluated for r in b.records)      # per-round cadence
    assert [r.hidden_sizes for r in a.records] == \
        [r.hidden_sizes for r in b.records]
    assert [r.auc_roc for r in a.records] == [r.auc_roc for r in b.records]
    _assert_trajectories_match(a, b)


def test_fused_fedbuff_falls_back_to_per_round(cohort):
    """FedBuff needs per-round server-version feedback (staleness), so
    fuse_rounds>1 falls back rather than fabricating a horizon."""
    kw = dict(loops=3, K=8, batch=32, mode="fedbuff", buffer_size=4,
              concurrency=6, straggler_rate=0.3)
    a = run_federated(cohort, _tcfg(1, **kw), method="scbf",
                      mlp_features=FEATS)
    b = run_federated(cohort, _tcfg(4, **kw), method="scbf",
                      mlp_features=FEATS)
    assert all(r.evaluated for r in b.records)
    _assert_trajectories_match(a, b)


def test_fused_sequential_engine_falls_back(cohort):
    """There is no sequential program to fuse: the reference engine
    keeps its per-client loop under fuse_rounds>1."""
    a = run_federated(cohort, _tcfg(1, loops=3), method="scbf",
                      mlp_features=FEATS, engine="sequential")
    b = run_federated(cohort, _tcfg(3, loops=3), method="scbf",
                      mlp_features=FEATS, engine="sequential")
    assert all(r.evaluated for r in b.records)
    _assert_trajectories_match(a, b)


def test_fuse_rounds_validation(cohort):
    with pytest.raises(ValueError):
        run_federated(cohort, _tcfg(0), method="scbf", mlp_features=FEATS)


# ---------------------------------------------------------------------------
# the hot loop is host-transfer-free, and compiles once
# ---------------------------------------------------------------------------

def _engine_fixture(K=5, n=24, d=12, seed=0):
    rng = np.random.default_rng(seed)
    clients = [(rng.random((n, d)).astype(np.float32),
                (rng.random(n) < 0.5).astype(np.float32))
               for _ in range(K)]
    params = init_mlp((d, 8, 1), jax.random.PRNGKey(1))
    return make_engine("batched", clients, 8, 1), params


def _round_key_rows(parts, seed=0):
    key = jax.random.PRNGKey(seed)
    cks, sks, dks = [], [], []
    for part in parts:
        p = int(np.asarray(part).size)
        key, kc, ks, kd = jax.random.split(key, 4)
        if p:
            cks.append(np.asarray(jax.random.split(kc, p)))
            sks.append(np.asarray(jax.random.split(ks, p)))
            dks.append(np.asarray(jax.random.split(kd, p)))
        else:
            empty = np.zeros((0, 2), np.uint32)
            cks.append(empty)
            sks.append(empty)
            dks.append(empty)
    return cks, sks, dks


def test_fused_chunk_runs_under_transfer_guard():
    """The scan body performs zero host transfers: after the one-time
    compile, a whole chunk dispatches and returns device arrays under
    ``jax.transfer_guard("disallow")`` — the proof that planning
    (prepare_fused_plan) really hoisted every transfer out of the hot
    loop.  Emission then runs outside the guard, as designed."""
    eng, params = _engine_fixture()
    cfg = ScbfConfig(upload_rate=0.25, num_clients=5)
    parts = [np.arange(5), np.array([0, 2, 4]),
             np.array([], dtype=np.int64)]
    cks, sks, dks = _round_key_rows(parts)
    plan = eng.prepare_fused_plan(parts, [0.1, 0.1, 0.1], cks, sks, dks,
                                  horizon=4,
                                  num_slots=eng.fused_num_slots(5))
    # every chunk call gets its own copy: the call donates its params
    # buffers on backends where donation is real, so `params` itself
    # must never be handed to a chunk and then reused
    warm = jax.tree_util.tree_map(lambda a: a + 0, tuple(params))
    eng.fused_scbf_chunk(warm, plan, cfg)          # compile outside guard
    fresh = jax.tree_util.tree_map(lambda a: a + 0, tuple(params))
    with assert_no_transfers():
        new_p, masked, masks = eng.fused_scbf_chunk(fresh, plan, cfg)
    emitted = eng.emit_fused_payloads(masked, masks, plan)
    assert [len(p) for p, _ in emitted] == [5, 3, 0]
    assert all(np.asarray(leaf).dtype == np.float32
               for leaf in jax.tree_util.tree_leaves(new_p))


def test_fused_compiles_once_across_varying_p(cohort):
    """The (S, B) plan is padded to a run-constant shape — short tail
    chunks and every distinct P included — so a whole varying-P run
    costs at most 2 fused compiles (expected: exactly 1)."""
    kw = dict(loops=10, K=8, batch=32, sample_fraction=0.5,
              dropout_rate=0.25)
    with assert_compiles(2):
        res = run_federated(cohort, _tcfg(4, **kw), method="scbf",
                            mlp_features=FEATS)
    ps = {r.num_participants for r in res.records if r.num_participants}
    assert len(ps) > 1
    assert sum(r.sparse_bytes for r in res.records) > 0


# ---------------------------------------------------------------------------
# eval_every / evaluated-flag record semantics
# ---------------------------------------------------------------------------

def test_eval_every_per_round_records(cohort):
    res = run_federated(cohort, _tcfg(1, loops=5, eval_every=2),
                        method="scbf", mlp_features=FEATS)
    assert [r.evaluated for r in res.records] == \
        [False, True, False, True, True]
    # non-evaluated loops carry the last-known metrics
    assert res.records[2].auc_roc == res.records[1].auc_roc
    assert res.records[2].auc_pr == res.records[1].auc_pr
    # loop 0 predates any evaluation: it carries the initial model's
    # metrics, still finite and well-defined
    assert np.isfinite(res.records[0].auc_roc)
    ref = run_federated(cohort, _tcfg(1, loops=5), method="scbf",
                        mlp_features=FEATS)
    assert res.final.auc_roc == ref.final.auc_roc   # training unchanged


def test_fused_evaluates_at_chunk_boundaries(cohort):
    """Fused execution coarsens evaluation to chunk boundaries; the
    final loop is always evaluated."""
    res = run_federated(cohort, _tcfg(3, loops=6), method="scbf",
                        mlp_features=FEATS)
    assert [r.evaluated for r in res.records] == \
        [False, False, True, False, False, True]
    for i in (0, 1):                      # pre-first-eval: initial model
        assert res.records[i].auc_roc == res.records[0].auc_roc
    for i in (3, 4):                      # carried from the loop-2 eval
        assert res.records[i].auc_roc == res.records[2].auc_roc
    assert res.final.evaluated


# ---------------------------------------------------------------------------
# horizon planning
# ---------------------------------------------------------------------------

def test_sync_plan_horizon_matches_per_round_plans():
    cfg = FedConfig(sample_fraction=0.5, dropout_rate=0.2)
    a = make_scheduler(cfg, 16, seed=3)
    b = make_scheduler(cfg, 16, seed=3)
    horizon = a.plan_horizon(0, 6)
    singles = [b.plan(i) for i in range(6)]
    for pa, pb in zip(horizon, singles):
        np.testing.assert_array_equal(pa.participants, pb.participants)
        np.testing.assert_array_equal(pa.sampled, pb.sampled)
        np.testing.assert_array_equal(pa.dropped, pb.dropped)
    assert a.max_participants == 8
    with pytest.raises(ValueError):
        a.plan_horizon(0, 0)


def test_fedbuff_plan_horizon_refuses_multi_round():
    sched = make_scheduler(FedConfig(mode="fedbuff"), 8, seed=0)
    with pytest.raises(ValueError):
        sched.plan_horizon(0, 2)
    assert len(sched.plan_horizon(0, 1)) == 1


# ---------------------------------------------------------------------------
# pod-axis sharding composes with fused chunks
# ---------------------------------------------------------------------------

_FUSED_POD_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")
import numpy as np
import jax
from repro.comm import wire
from repro.config import ScbfConfig
from repro.fed.engine import make_engine
from repro.models.mlp_net import init_mlp

assert len(jax.devices()) == 4
rng = np.random.default_rng(0)
clients = [(rng.random((16, 8)).astype(np.float32),
            (rng.random(16) < .5).astype(np.float32)) for _ in range(4)]
params = init_mlp((8, 6, 1), jax.random.PRNGKey(1))
cfg = ScbfConfig(upload_rate=0.25, num_clients=4)
parts = [np.arange(4), np.array([0, 2]), np.array([], dtype=np.int64)]

def rows(seed):
    key = jax.random.PRNGKey(seed)
    cks, sks, dks = [], [], []
    for p in parts:
        key, kc, ks, kd = jax.random.split(key, 4)
        n = p.size
        if n:
            cks.append(np.asarray(jax.random.split(kc, n)))
            sks.append(np.asarray(jax.random.split(ks, n)))
            dks.append(np.asarray(jax.random.split(kd, n)))
        else:
            e = np.zeros((0, 2), np.uint32)
            cks.append(e); sks.append(e); dks.append(e)
    return cks, sks, dks

out = {}
for pods in (1, 4):
    eng = make_engine("batched", clients, 8, 1, pods=pods)
    cks, sks, dks = rows(0)
    plan = eng.prepare_fused_plan(parts, [0.1] * 3, cks, sks, dks,
                                  horizon=4,
                                  num_slots=eng.fused_num_slots(4))
    # fresh copy per engine: the chunk call donates its params buffers
    # where the backend supports donation
    p = jax.tree_util.tree_map(lambda a: a + 0, tuple(params))
    _, m, k = eng.fused_scbf_chunk(p, plan, cfg)
    out[pods] = eng.emit_fused_payloads(m, k, plan)
for (p1, _), (p4, _) in zip(out[1], out[4]):
    assert [a.nbytes for a in p1] == [a.nbytes for a in p4]
    for a, b in zip(p1, p4):
        for la, lb in zip(wire.decode(a), wire.decode(b)):
            for kk in la:
                np.testing.assert_array_equal(np.asarray(la[kk]),
                                              np.asarray(lb[kk]))
print("FUSED_POD_PARITY_OK")
"""


@pytest.mark.slow
def test_fused_chunk_pod_sharded_matches_single_device():
    """A fused chunk sharded over a 4-device pod mesh (slot axis on
    ``pod``, scan carry replicated) ships bit-identical uploads to the
    single-device chunk — including a bucket-padded round and an empty
    round.  Fresh process: the device count locks at first jax import."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _FUSED_POD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "FUSED_POD_PARITY_OK" in out.stdout


# ---------------------------------------------------------------------------
# CI bench regression guard
# ---------------------------------------------------------------------------

def _load_checker():
    import importlib.util
    import pathlib
    path = (pathlib.Path(__file__).resolve().parents[1] / "benchmarks"
            / "check_fed_regression.py")
    spec = importlib.util.spec_from_file_location("check_fed_regression",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_bench_regression_checker_logic():
    """The CI gate: a >25% fused-throughput-ratio drop and ANY
    compile-count growth fail; k_scaling speedup jitter passes (those
    rows are informational — only their presence is required)."""
    chk = _load_checker()
    baseline = {
        "schema": 3,
        "k_scaling": [{"K": 5, "speedup": 8.0}, {"K": 500, "speedup": 10.0}],
        "compile_counts": {"pow2": {"compiles": 1},
                           "exact": {"compiles": 7}},
        "fused": {"speedup": 4.0, "compile_trace": {"compiles": 1},
                  "telemetry": {"overhead": 0.03}},
        "prune": {"speedup": 2.0, "compiles": 2,
                  "steady": {"time_saving": 0.4}},
        "chaos": {"overhead": 0.08, "compiles": 1,
                  "chaos": {"reasons": {"checksum": 8, "nonfinite": 12}}},
    }
    same = {
        "schema": 3,
        "k_scaling": [{"K": 5, "speedup": 2.0},    # jitter: not gated
                      {"K": 500, "speedup": 5.0}],  # jitter: not gated
        "compile_counts": {"pow2": {"compiles": 1},
                           "exact": {"compiles": 7}},
        "fused": {"speedup": 3.5, "compile_trace": {"compiles": 1},
                  "telemetry": {"overhead": 0.10}},  # jitter: <= 25% passes
        "prune": {"speedup": 1.8, "compiles": 2,
                  "steady": {"time_saving": 0.1}},   # jitter: sign-gated
        "chaos": {"overhead": 0.15, "compiles": 1,   # jitter: <= 25% passes
                  "chaos": {"reasons": {"checksum": 3, "nonfinite": 5}}},
    }
    assert chk.compare(same, baseline) == []
    # schema handshake: a mismatched blob on EITHER side is refused
    # outright with a regenerate instruction, never field-compared
    old_fresh = {k: v for k, v in same.items() if k != "schema"}
    msgs = chk.compare(old_fresh, baseline)
    assert len(msgs) == 1 and "schema" in msgs[0] and "fresh" in msgs[0]
    old_base = {**baseline, "schema": 1}
    msgs = chk.compare(same, old_base)
    assert len(msgs) == 1 and "schema" in msgs[0] and "baseline" in msgs[0]
    retrace = {**same, "compile_counts": {"pow2": {"compiles": 3},
                                          "exact": {"compiles": 7}}}
    assert any("compile_counts" in m for m in chk.compare(retrace, baseline))
    fused_slow = {**same, "fused": {**same["fused"], "speedup": 2.0}}
    assert any("fused" in m for m in chk.compare(fused_slow, baseline))
    fused_retrace = {**same, "fused": {**same["fused"], "speedup": 4.0,
                                       "compile_trace": {"compiles": 2}}}
    assert any("compile trace" in m
               for m in chk.compare(fused_retrace, baseline))
    # flight-recorder cost: > 25% overhead fails, a dropped telemetry
    # section fails (schema >= 2 always records one)
    slow_telem = {**same, "fused": {**same["fused"],
                                    "telemetry": {"overhead": 0.40}}}
    assert any("telemetry overhead" in m
               for m in chk.compare(slow_telem, baseline))
    no_telem = {**same, "fused": {k: v for k, v in same["fused"].items()
                                  if k != "telemetry"}}
    assert any("telemetry" in m and "missing" in m
               for m in chk.compare(no_telem, baseline))
    missing = {k: v for k, v in same.items() if k != "fused"}
    assert any("missing" in m for m in chk.compare(missing, baseline))
    # the fused-SCBFwP section: ratio drop, compile growth, a negative
    # pruning time saving, and a silently-dropped section all fail
    prune_slow = {**same, "prune": {"speedup": 1.0, "compiles": 2,
                                    "steady": {"time_saving": 0.1}}}
    assert any("prune" in m and "speedup" in m
               for m in chk.compare(prune_slow, baseline))
    prune_retrace = {**same, "prune": {"speedup": 1.8, "compiles": 3,
                                       "steady": {"time_saving": 0.1}}}
    assert any("prune" in m and "compiles" in m
               for m in chk.compare(prune_retrace, baseline))
    prune_slower_than_unpruned = {
        **same, "prune": {"speedup": 1.8, "compiles": 2,
                          "steady": {"time_saving": -0.05}}}
    assert any("time saving" in m
               for m in chk.compare(prune_slower_than_unpruned, baseline))
    no_prune = {k: v for k, v in same.items() if k != "prune"}
    assert any("prune" in m and "missing" in m
               for m in chk.compare(no_prune, baseline))
    # the chaos section: fault-free resilience tax, armed compile
    # growth, an admission gate gone inert, and a dropped section all fail
    chaos_slow = {**same, "chaos": {**same["chaos"], "overhead": 0.40}}
    assert any("chaos" in m and "overhead" in m
               for m in chk.compare(chaos_slow, baseline))
    chaos_retrace = {**same, "chaos": {**same["chaos"], "compiles": 2}}
    assert any("chaos" in m and "compiles" in m
               for m in chk.compare(chaos_retrace, baseline))
    chaos_inert = {**same, "chaos": {**same["chaos"],
                                     "chaos": {"reasons": {"checksum": 3}}}}
    assert any("inert" in m and "nonfinite" in m
               for m in chk.compare(chaos_inert, baseline))
    no_chaos = {k: v for k, v in same.items() if k != "chaos"}
    assert any("chaos" in m and "missing" in m
               for m in chk.compare(no_chaos, baseline))
    # dropping a guarded section must fail, never vacuously pass
    no_counts = {k: v for k, v in same.items() if k != "compile_counts"}
    assert any("compile_counts" in m and "missing" in m
               for m in chk.compare(no_counts, baseline))
    no_k500 = {**same, "k_scaling": [{"K": 5, "speedup": 2.0}]}
    assert any("k_scaling" in m and "missing" in m
               for m in chk.compare(no_k500, baseline))
    # the committed baseline itself stays parseable and self-consistent
    import json
    import pathlib
    bl_path = (pathlib.Path(__file__).resolve().parents[1] / "benchmarks"
               / "baselines" / "fed_engine.json")
    committed = json.loads(bl_path.read_text())
    assert chk.compare(committed, committed) == []
    assert committed["schema"] == 3
    assert committed["fused"]["speedup"] >= 2.0   # the acceptance bar
    assert committed["fused"]["compile_trace"]["compiles"] <= 2
    # the flight recorder stays cheap (the <5% target lives in
    # docs/OBSERVABILITY.md; the committed number must meet the CI bound)
    assert committed["fused"]["telemetry"]["overhead"] <= 0.25
    assert committed["prune"]["compiles"] <= 2    # the PR 5 bar
    assert committed["prune"]["steady"]["time_saving"] > 0
    # the armed-but-idle fault model stays off the hot path, and the
    # committed storm exercises every admission-gate rejection reason
    assert committed["chaos"]["overhead"] <= 0.25
    assert committed["chaos"]["compiles"] <= 2
    assert set(committed["chaos"]["chaos"]["reasons"]) == {
        "malformed", "checksum", "duplicate", "nonfinite", "norm"}
