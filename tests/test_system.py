"""End-to-end behaviour of the paper's system (small cohort, few loops)."""
import dataclasses

import numpy as np
import pytest

from repro.config import ScbfConfig, TrainConfig
from repro.core.scbf import run_federated
from repro.data.medical import generate_cohort, federated_split


@pytest.fixture(scope="module")
def cohort():
    return generate_cohort(num_admissions=3000, num_medicines=200, seed=0)


@pytest.fixture(scope="module")
def tcfg():
    return TrainConfig(learning_rate=0.05, global_loops=3,
                       local_batch_size=128, local_epochs=2,
                       scbf=ScbfConfig(upload_rate=0.1, num_clients=5))


@pytest.mark.slow
def test_scbf_run_structure(cohort, tcfg):
    res = run_federated(cohort, tcfg, method="scbf",
                        mlp_features=(200, 32, 8, 1))
    assert len(res.records) == 3
    for r in res.records:
        assert 0.0 <= r.auc_roc <= 1.0
        assert 0.0 < r.upload_fraction < 1.0      # partial upload
        assert r.sparse_bytes < r.dense_bytes      # comm saving vs dense
    # learning happens
    assert res.records[-1].auc_roc > 0.5


@pytest.mark.slow
def test_fedavg_uploads_everything(cohort, tcfg):
    res = run_federated(cohort, tcfg, method="fedavg",
                        mlp_features=(200, 32, 8, 1))
    assert all(r.upload_fraction == 1.0 for r in res.records)
    # FA's mean update is ~5x smaller per loop than SCBF's sum, so 3 loops
    # only establishes an improving trend, not >0.5 AUC
    assert res.records[-1].auc_roc > res.records[0].auc_roc


@pytest.mark.slow
def test_scbfwp_prunes(cohort, tcfg):
    cfg = dataclasses.replace(
        tcfg, scbf=dataclasses.replace(tcfg.scbf, prune=True,
                                       prune_rate=0.2, prune_total=0.5))
    res = run_federated(cohort, cfg, method="scbf",
                        mlp_features=(200, 32, 8, 1))
    h_last = res.records[-1].hidden_sizes
    assert sum(h_last) < 40                       # pruned below original
    assert sum(h_last) >= int(0.5 * 40) - 1       # respects total budget
    assert res.records[-1].flops_proxy < res.records[0].flops_proxy


def test_federated_split_properties(cohort):
    parts = federated_split(cohort.x_train, cohort.y_train, 5, seed=0)
    sizes = [p[0].shape[0] for p in parts]
    assert len(set(sizes)) == 1                   # equal split (paper §2.2)
    total = np.concatenate([p[0] for p in parts])
    assert total.shape[0] <= cohort.x_train.shape[0]
