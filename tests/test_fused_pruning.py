"""Fused-path SCBFwP: device-resident pruning via static keep-masks.

The PR-5 acceptance bars: ``fuse_rounds > 1`` with ``prune=True`` and
``prune_impl='mask'`` runs the FUSED path (no silent per-round
fallback) at <= 2 compiles per run, with a keep-mask trajectory, byte
accounting and AUC identical to the per-round SCBFwP path; the masked
fused chunk body still never touches the host (transfer_guard); the
mask and reshape implementations remove the same neurons; and the
refusal matrix (fedavg+mask, fedbuff+reshape) fails fast.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _trace_guards import assert_compiles, assert_no_transfers
from repro.config import FedConfig, ScbfConfig, TrainConfig
from repro.core.scbf import run_federated
from repro.data.medical import generate_cohort
from repro.fed.engine import make_engine
from repro.models.mlp_net import init_mlp


@pytest.fixture(scope="module")
def cohort():
    return generate_cohort(num_admissions=800, num_medicines=40,
                           num_risk_medicines=15, num_interactions=4, seed=0)


FEATS = (40, 16, 4, 1)


def _tcfg(fuse: int, loops: int = 8, K: int = 5, batch: int = 64,
          impl: str = "mask", compact: bool = True, prune_rate: float = 0.2,
          prune_total: float = 0.5, eval_every: int = 1, **fed_kw):
    return TrainConfig(
        learning_rate=0.05, global_loops=loops, local_batch_size=batch,
        local_epochs=1, eval_every=eval_every,
        scbf=ScbfConfig(upload_rate=0.1, num_clients=K, prune=True,
                        prune_rate=prune_rate, prune_total=prune_total,
                        prune_impl=impl, prune_compact=compact),
        fed=FedConfig(fuse_rounds=fuse, **fed_kw))


def _params_bitwise_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# parity: the tentpole acceptance criterion
# ---------------------------------------------------------------------------

def test_fused_scbfwp_matches_per_round_mask_mode(cohort):
    """fuse_rounds=S with mask pruning is bit-identical to the
    per-round mask run at K=5 full participation: same keep-mask
    trajectory (hidden_sizes per loop), same upload bytes, same ε, and
    the same final params/AUC — and it really ran fused (post-pruning
    loops coarsen evaluation to chunk boundaries)."""
    a = run_federated(cohort, _tcfg(1), method="scbf", mlp_features=FEATS)
    b = run_federated(cohort, _tcfg(4), method="scbf", mlp_features=FEATS)
    assert a.method == b.method == "scbfwp"
    assert len(a.records) == len(b.records)
    for ra, rb in zip(a.records, b.records):
        assert ra.hidden_sizes == rb.hidden_sizes
        assert ra.sparse_bytes == rb.sparse_bytes
        assert ra.dense_bytes == rb.dense_bytes
        assert ra.upload_fraction == rb.upload_fraction
        assert ra.num_participants == rb.num_participants
        assert ra.flops_proxy == rb.flops_proxy
        assert ra.epsilon == rb.epsilon
    # pruning actually happened, and bytes shrank with it
    assert a.records[0].hidden_sizes != a.records[-1].hidden_sizes
    assert a.records[-1].sparse_bytes < a.records[0].sparse_bytes
    assert _params_bitwise_equal(a.final_params, b.final_params)
    assert a.final.auc_roc == b.final.auc_roc
    assert a.final.auc_pr == b.final.auc_pr
    # no silent fallback: once pruning finished, fused chunks coarsen
    # evaluation, so at least one non-boundary loop is un-evaluated
    assert not all(r.evaluated for r in b.records)
    assert all(r.evaluated for r in a.records)


def test_fused_scbfwp_matches_per_round_with_dp(cohort):
    """DP noise lands only on revealed (kept-geometry) coordinates;
    the noised masked trajectories must still match bit-for-bit."""
    def cfgs(fuse):
        t = _tcfg(fuse, loops=6)
        return TrainConfig(
            learning_rate=t.learning_rate, global_loops=t.global_loops,
            local_batch_size=t.local_batch_size, local_epochs=1,
            scbf=ScbfConfig(upload_rate=0.1, num_clients=5, prune=True,
                            prune_rate=0.2, prune_total=0.5,
                            prune_impl="mask", dp_noise_multiplier=1.0,
                            dp_clip_norm=1.0),
            fed=FedConfig(fuse_rounds=fuse))
    a = run_federated(cohort, cfgs(1), method="scbf", mlp_features=FEATS)
    b = run_federated(cohort, cfgs(3), method="scbf", mlp_features=FEATS)
    for ra, rb in zip(a.records, b.records):
        assert ra.hidden_sizes == rb.hidden_sizes
        assert ra.sparse_bytes == rb.sparse_bytes
        assert ra.epsilon == rb.epsilon
    assert all(r.epsilon is not None for r in b.records)
    assert _params_bitwise_equal(a.final_params, b.final_params)


def test_fused_scbfwp_varying_bucketed_p(cohort):
    """Mask pruning composes with sampling/dropout bucketing: the
    run-constant (S, B) plan plus run-constant geometry keep the fused
    trajectory identical to per-round across varying P."""
    kw = dict(loops=8, K=8, batch=32, sample_fraction=0.75,
              dropout_rate=0.2)
    a = run_federated(cohort, _tcfg(1, **kw), method="scbf",
                      mlp_features=FEATS)
    b = run_federated(cohort, _tcfg(3, **kw), method="scbf",
                      mlp_features=FEATS)
    ps = [r.num_participants for r in a.records]
    assert len({p for p in ps if p}) > 1      # P actually varies
    assert sum(r.sparse_bytes for r in a.records) > 0
    for ra, rb in zip(a.records, b.records):
        assert ra.hidden_sizes == rb.hidden_sizes
        assert ra.sparse_bytes == rb.sparse_bytes
        assert ra.num_participants == rb.num_participants
    assert _params_bitwise_equal(a.final_params, b.final_params)


def test_mask_and_reshape_remove_the_same_neurons(cohort):
    """The two prune implementations are one algorithm: same per-loop
    hidden sizes, same effective byte accounting, same AUC up to the
    (reduction-order) float tolerance of masked-vs-compacted matmuls.
    On this CPU backend they agree exactly."""
    a = run_federated(cohort, _tcfg(1, impl="reshape"), method="scbf",
                      mlp_features=FEATS)
    m = run_federated(cohort, _tcfg(1, impl="mask"), method="scbf",
                      mlp_features=FEATS)
    assert [r.hidden_sizes for r in a.records] == \
        [r.hidden_sizes for r in m.records]
    assert [r.flops_proxy for r in a.records] == \
        [r.flops_proxy for r in m.records]
    assert m.final.auc_roc == pytest.approx(a.final.auc_roc, abs=1e-5)


def test_mask_mode_without_compaction_keeps_geometry(cohort):
    """prune_compact=False: the model stays at full geometry (masks
    forever) — records still report effective sizes and effective
    bytes, and the final params keep the original shapes."""
    res = run_federated(cohort, _tcfg(3, compact=False), method="scbf",
                        mlp_features=FEATS)
    assert res.records[-1].hidden_sizes != (16, 4)    # effective sizes
    assert res.records[-1].sparse_bytes < res.records[0].sparse_bytes
    shapes = [tuple(l["w"].shape) for l in res.final_params]
    assert shapes == [(40, 16), (16, 4), (4, 1)]      # uncompacted
    cmp = run_federated(cohort, _tcfg(3, compact=True), method="scbf",
                        mlp_features=FEATS)
    cshapes = [tuple(l["w"].shape) for l in cmp.final_params]
    h = cmp.records[-1].hidden_sizes
    assert cshapes == [(40, h[0]), (h[0], h[1]), (h[1], 1)]
    # same effective accounting either way
    assert [r.hidden_sizes for r in res.records] == \
        [r.hidden_sizes for r in cmp.records]
    assert [r.sparse_bytes for r in res.records] == \
        [r.sparse_bytes for r in cmp.records]


# ---------------------------------------------------------------------------
# compiles and the transfer guard
# ---------------------------------------------------------------------------

def test_fused_scbfwp_at_most_two_compiles(cohort):
    """The whole SCBFwP run costs at most 2 fused compiles: the
    horizon-1 masked program the prune phase runs on, and the
    horizon-S program for everything after (compacted geometry when
    prune_compact, masked full geometry otherwise)."""
    with assert_compiles(2):
        res = run_federated(cohort, _tcfg(4, loops=10), method="scbf",
                            mlp_features=FEATS)
    assert res.records[0].hidden_sizes != res.records[-1].hidden_sizes
    with assert_compiles(2):
        run_federated(cohort, _tcfg(4, loops=10, compact=False),
                      method="scbf", mlp_features=FEATS)


def _engine_fixture(K=5, n=24, d=12, seed=0, hidden=(8, 4)):
    rng = np.random.default_rng(seed)
    clients = [(rng.random((n, d)).astype(np.float32),
                (rng.random(n) < 0.5).astype(np.float32))
               for _ in range(K)]
    params = init_mlp((d,) + hidden + (1,), jax.random.PRNGKey(1))
    return make_engine("batched", clients, 8, 1), params


def _round_key_rows(parts, seed=0):
    key = jax.random.PRNGKey(seed)
    cks, sks, dks = [], [], []
    for part in parts:
        p = int(np.asarray(part).size)
        key, kc, ks, kd = jax.random.split(key, 4)
        if p:
            cks.append(np.asarray(jax.random.split(kc, p)))
            sks.append(np.asarray(jax.random.split(ks, p)))
            dks.append(np.asarray(jax.random.split(kd, p)))
        else:
            empty = np.zeros((0, 2), np.uint32)
            cks.append(empty)
            sks.append(empty)
            dks.append(empty)
    return cks, sks, dks


def test_masked_fused_chunk_runs_under_transfer_guard():
    """The masked chunk body performs zero host transfers: keep-masks
    ride in as device inputs placed at plan time, so a whole pruned
    chunk dispatches and returns under transfer_guard('disallow') —
    emission (host wire encoding) then happens outside the guard."""
    eng, params = _engine_fixture()
    cfg = ScbfConfig(upload_rate=0.25, num_clients=5, prune=True,
                     prune_impl="mask")
    nmasks = (jnp.asarray(np.array([1, 1, 0, 1, 0, 1, 1, 0], np.float32)),
              jnp.asarray(np.array([1, 0, 1, 1], np.float32)))
    keep = [np.array([0, 1, 3, 5, 6]), np.array([0, 2, 3])]
    parts = [np.arange(5), np.array([0, 2, 4]),
             np.array([], dtype=np.int64)]
    cks, sks, dks = _round_key_rows(parts)
    plan = eng.prepare_fused_plan(parts, [0.1, 0.1, 0.1], cks, sks, dks,
                                  horizon=4,
                                  num_slots=eng.fused_num_slots(5))
    warm = jax.tree_util.tree_map(lambda a: a + 0, tuple(params))
    eng.fused_scbf_chunk(warm, plan, cfg, nmasks=nmasks)  # compile
    fresh = jax.tree_util.tree_map(lambda a: a + 0, tuple(params))
    with assert_no_transfers():
        new_p, masked, masks = eng.fused_scbf_chunk(fresh, plan, cfg,
                                                    nmasks=nmasks)
    emitted = eng.emit_fused_payloads(masked, masks, plan, keep=keep)
    assert [len(p) for p, _ in emitted] == [5, 3, 0]
    # emitted payloads are effective-geometry: 5 kept x 3 kept hidden
    shapes = [lp.shape for lp in emitted[0][0][0].layers]
    assert (5, 3) in shapes and (12, 5) in shapes and (3, 1) in shapes
    # pruned server coordinates are bit-frozen through the whole chunk
    for l, km in enumerate(nmasks):
        dead = np.where(np.asarray(km) == 0)[0]
        np.testing.assert_array_equal(
            np.asarray(new_p[l]["w"])[:, dead],
            np.asarray(params[l]["w"])[:, dead])
        np.testing.assert_array_equal(
            np.asarray(new_p[l + 1]["w"])[dead, :],
            np.asarray(params[l + 1]["w"])[dead, :])


# ---------------------------------------------------------------------------
# refusal matrix / fallback boundary
# ---------------------------------------------------------------------------

def test_reshape_prune_still_falls_back_per_round(cohort):
    """prune_impl='reshape' genuinely changes shapes, so fuse_rounds>1
    keeps taking the per-round path (every loop evaluated)."""
    res = run_federated(cohort, _tcfg(4, impl="reshape", loops=4),
                        method="scbf", mlp_features=FEATS)
    assert all(r.evaluated for r in res.records)


def test_mask_prune_refuses_fedavg(cohort):
    with pytest.raises(ValueError, match="mask"):
        run_federated(cohort, _tcfg(1, impl="mask"), method="fedavg",
                      mlp_features=FEATS)


def test_unknown_prune_impl_refused(cohort):
    with pytest.raises(ValueError, match="prune_impl"):
        run_federated(cohort, _tcfg(1, impl="banana"), method="scbf",
                      mlp_features=FEATS)


def test_fedbuff_mask_prune_now_runs(cohort):
    """The fedbuff+prune refusal is lifted where sound: mask pruning
    keeps geometry run-constant, so stale in-flight params stack fine;
    reshape pruning stays refused."""
    kw = dict(loops=6, K=8, batch=32, mode="fedbuff", buffer_size=4,
              concurrency=6)
    res = run_federated(cohort, _tcfg(1, impl="mask", **kw),
                        method="scbf", mlp_features=FEATS)
    assert res.records[-1].hidden_sizes != (16, 4)    # really pruned
    # compaction is forced off under fedbuff: geometry stays full
    shapes = [tuple(l["w"].shape) for l in res.final_params]
    assert shapes == [(40, 16), (16, 4), (4, 1)]
    with pytest.raises(ValueError, match="reshape"):
        run_federated(cohort, _tcfg(1, impl="reshape", **kw),
                      method="scbf", mlp_features=FEATS)


def test_sequential_engine_mask_prune_matches_batched(cohort):
    """Mask mode is engine-agnostic: the sequential reference loop
    prunes the same neurons and ships the same effective bytes as the
    batched engine at K=5 full participation."""
    a = run_federated(cohort, _tcfg(1, loops=5), method="scbf",
                      mlp_features=FEATS)
    s = run_federated(cohort, _tcfg(1, loops=5), method="scbf",
                      mlp_features=FEATS, engine="sequential")
    assert [r.hidden_sizes for r in a.records] == \
        [r.hidden_sizes for r in s.records]
    assert [r.sparse_bytes for r in a.records] == \
        [r.sparse_bytes for r in s.records]
