"""Hypothesis property tests for the wire formats (skipped cleanly when
hypothesis is not installed — see requirements-dev.txt)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.comm import wire


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 40), st.integers(1, 40),
       st.floats(0.0, 1.0), st.integers(0, 10_000))
def test_roundtrip_exact_any_shape(m, n, density, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, n)).astype(np.float32)
    a = np.where(rng.random((m, n)) < density, a, 0).astype(np.float32)
    lp = wire.encode_leaf(jnp.asarray(a))
    np.testing.assert_array_equal(a, np.asarray(wire.decode_leaf(lp)))
    assert lp.nbytes <= wire.dense_bytes(a.size, 4)


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 100_000), st.data())
def test_cheapest_never_beats_itself(size, data):
    nnz = data.draw(st.integers(0, size))
    codec, b = wire.cheapest_bytes(nnz, size, 4)
    for c in wire.CODECS:
        assert b <= wire.codec_bytes(c, nnz, size, 4)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 12), st.integers(1, 12), st.integers(0, 10_000))
def test_apply_payloads_matches_dense_sum(m, n, seed):
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=(m, n)), jnp.float32)}
    deltas = []
    for c in range(3):
        d = rng.normal(size=(m, n)).astype(np.float32)
        d = np.where(rng.random((m, n)) < 0.4, d, 0).astype(np.float32)
        deltas.append({"w": jnp.asarray(d)})
    want = params["w"] + sum(d["w"] for d in deltas)
    got = wire.apply_payloads(params, [wire.encode(d) for d in deltas])
    np.testing.assert_allclose(np.asarray(want), np.asarray(got["w"]),
                               rtol=1e-5, atol=1e-6)
