"""Sharding rules: logical-axis mapping, divisibility fallbacks."""
import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import ShardingRules, activation_rules, \
    activation_spec, batch_spec
from repro.launch.mesh import make_host_mesh


class FakeMesh:
    """Only .shape is consulted by spec_for."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


RULES = ShardingRules()
MESH = FakeMesh({"data": 16, "model": 16})


def spec(axes, shape):
    return RULES.spec_for(axes, shape, MESH)


def test_dense_weight():
    # (embed, mlp): mlp -> model, embed -> data (FSDP)
    assert spec("embed,mlp", (5120, 27648)) == P("data", "model")


def test_expert_priority():
    # experts win the model axis; embed gets data
    assert spec("experts,embed,mlp", (160, 5120, 1536)) == \
        P("model", "data", None)


def test_vocab_not_divisible_falls_through():
    # mamba2 vocab 50280 is not 16-divisible -> it stays unsharded and the
    # embed dim picks up the FSDP (data) axis instead
    s = spec("vocab,embed", (50280, 2560))
    assert s == P(None, "data")
    # divisible vocab does take the model axis
    assert spec("vocab,embed", (65536, 2560)) == P("model", "data")


def test_qkv_fused_heads():
    assert spec("embed,heads", (896, 896)) == P("data", "model")


def test_small_dim_replicates():
    # nothing divisible -> fully replicated
    assert spec("none,none", (7, 9)) == P(None, None)


def test_layers_never_sharded():
    s = spec("layers,embed,mlp", (24, 1024, 2816))
    assert s[0] is None


def test_batch_spec_fallbacks():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert batch_spec(mesh, 256) == P(("pod", "data"))
    assert batch_spec(mesh, 16) == P("data")
    assert batch_spec(mesh, 1) == P(None)


def test_activation_spec():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = activation_rules(mesh)
    s = activation_spec(("batch", "none", "kv_seq"), rules)
    assert s == P(("data",), None, ("model",))


def test_host_mesh_constraint_runs():
    """ctx.shard path executes on a 1x1 host mesh (CPU)."""
    import jax.numpy as jnp
    from repro.sharding.rules import make_shard_fn
    mesh = make_host_mesh()
    shard = make_shard_fn(mesh)
    x = jnp.ones((4, 8))

    def f(x):
        return shard(x, ("batch", "none")) * 2

    y = jax.jit(f)(x)
    np.testing.assert_allclose(np.asarray(y), 2.0)
