"""privlint: the interprocedural taint gate for the client→server
privacy boundary (docs/STATIC_ANALYSIS.md §Privacy lint).

Covers the PR 8 acceptance bars: every golden bad fixture (including
the verbatim ``pad_rows`` key-padding reduction — the worst real
finding this PR fixed in fed/engine.py) is detected with the right
rule code and nothing extra; the known-good sanctioned-chain and
mask-geometry fixtures produce ZERO findings; taint propagation is
interprocedural (a leak routed through a helper in another module is
caught *inside the helper*); suppression comments, baseline keys, and
the committed privacy baseline all gate correctly; the CLI goes red on
an injected PL001 (the CI lint job's contract); and the core/privacy.py
hardening this PR shipped (σ ≤ 0, δ ∉ (0, 1)) refuses loudly.
"""
import json
import os
import pathlib
import shutil
import subprocess
import sys
import textwrap
from collections import Counter

import pytest

from repro.analysis.privlint import run_paths
from repro.analysis.privrules import PRIV_RULES, run_privacy_rules
from repro.analysis.report import Baseline

REPO = pathlib.Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "privlint"

# filename -> exactly which rules fire, and how often (no extras!)
BAD_EXPECT = {
    "pl001_dense_delta.py": {"PL001": 1},
    "pl002_noise_after_encode.py": {"PL002": 1},
    "pl003_key_reuse.py": {"PL003": 2},     # loop-invariant + double use
    "pl003_padded_keys.py": {"PL003": 1},   # the engine.py bug, verbatim
    "pl004_unaccounted.py": {"PL004": 2},   # unaccounted + double-count
    "pl005_mask_widen.py": {"PL005": 2},    # widen + compacted-geometry
    "pl006_loss_event.py": {"PL006": 1},
    "pl001_interproc.py": {},               # finding lands in the helper
    "leak_helper.py": {"PL001": 1},         # ...which is here
}


def _scan_bad():
    findings, _ = run_paths([str(FIXTURES / "bad")],
                            source_roots=[str(FIXTURES)])
    return findings


# ---------------------------------------------------------------------------
# golden fixtures
# ---------------------------------------------------------------------------

def test_bad_fixtures_detected_with_exact_rules():
    by_file = {name: Counter() for name in BAD_EXPECT}
    for f in _scan_bad():
        by_file[pathlib.Path(f.path).name][f.rule] += 1
    for name, got in by_file.items():
        assert got == Counter(BAD_EXPECT[name]), (name, dict(got))


def test_bad_fixture_coverage_is_all_rules():
    covered = {r for expect in BAD_EXPECT.values() for r in expect}
    assert covered == set(PRIV_RULES)


def test_good_fixtures_zero_false_positives():
    findings, files = run_paths([str(FIXTURES / "good")],
                                source_roots=[str(FIXTURES)])
    assert files == 3
    assert findings == [], [f.render() for f in findings]


def test_taint_is_interprocedural_across_modules():
    """The helper that encodes its argument is clean in isolation; add
    the caller module that feeds it a dense delta and the PL001 appears
    INSIDE the helper — proof the taint crossed the module boundary."""
    alone, _ = run_paths([str(FIXTURES / "bad" / "leak_helper.py")],
                         source_roots=[str(FIXTURES)])
    assert alone == [], [f.render() for f in alone]

    pair, _ = run_paths([str(FIXTURES / "bad" / "leak_helper.py"),
                         str(FIXTURES / "bad" / "pl001_interproc.py")],
                        source_roots=[str(FIXTURES)])
    assert [(pathlib.Path(f.path).name, f.rule, f.symbol)
            for f in pair] == [("leak_helper.py", "PL001", "ship_update")]


# ---------------------------------------------------------------------------
# suppressions, baseline, key stability
# ---------------------------------------------------------------------------

_PL001_SNIPPET = textwrap.dedent("""
    from repro.comm import wire
    from repro.fed.engine import client_delta

    def leak(params, new_p):
        delta = client_delta(tuple(params), new_p)
        return wire.encode(tuple(delta)){suffix}
""")


def test_suppression_comment_silences(tmp_path):
    noisy = tmp_path / "noisy.py"
    noisy.write_text(_PL001_SNIPPET.format(suffix=""))
    assert len(run_paths([str(noisy)])[0]) == 1

    quiet = tmp_path / "quiet.py"
    quiet.write_text(_PL001_SNIPPET.format(
        suffix="  # privlint: disable=PL001"))
    assert run_paths([str(quiet)])[0] == []

    # the wrong code does NOT silence it
    wrong = tmp_path / "wrong.py"
    wrong.write_text(_PL001_SNIPPET.format(
        suffix="  # privlint: disable=PL004"))
    assert len(run_paths([str(wrong)])[0]) == 1


def test_finding_keys_survive_line_shifts(tmp_path):
    f = tmp_path / "m.py"
    f.write_text(_PL001_SNIPPET.format(suffix=""))
    before = run_paths([str(f)])[0]
    f.write_text("# a new header comment\n# another\n\n"
                 + _PL001_SNIPPET.format(suffix=""))
    after = run_paths([str(f)])[0]
    assert [x.key for x in after] == [x.key for x in before]
    assert after[0].line == before[0].line + 3   # line moved; key did not


def test_unknown_rule_codes_refused():
    from repro.analysis import astgraph
    graph = astgraph.build_graph([str(FIXTURES / "good")])
    with pytest.raises(ValueError, match="PL999"):
        run_privacy_rules(graph, rules=["PL999"])


def test_committed_privacy_baseline_matches_repo(monkeypatch):
    """The shipped gate: <= 3 entries, every one justified, and the
    repo lints clean against it."""
    bl = Baseline.load(str(REPO / "analysis" / "privacy_baseline.json"))
    assert len(bl.entries) <= 3
    for key, rec in bl.entries.items():
        just = rec.get("justification", "")
        assert just and "TODO" not in just, f"unjustified baseline: {key}"
    monkeypatch.chdir(REPO)   # relative paths, as the CI lint job runs
    findings, files = run_paths(["src", "benchmarks", "examples"])
    assert files > 50
    keys = {x.key for x in findings}
    assert keys == set(bl.entries), \
        f"repo drifted from analysis/privacy_baseline.json: {sorted(keys)}"


# ---------------------------------------------------------------------------
# the CLI — the CI lint job's exact contract
# ---------------------------------------------------------------------------

def _run_cli(module, args, cwd):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", module, *args],
        env=env, cwd=cwd, capture_output=True, text=True, timeout=120)


def test_cli_gate_fails_on_injected_pl001(tmp_path):
    tree = tmp_path / "pkg"
    tree.mkdir()
    shutil.copy(FIXTURES / "good" / "good_sanctioned_chain.py", tree)
    out = _run_cli("repro.analysis.privlint",
                   [str(tree), "--baseline", ""], cwd=tmp_path)
    assert out.returncode == 0, out.stdout + out.stderr

    # inject the PL001 regression: the gate must go red
    (tree / "regress.py").write_text(_PL001_SNIPPET.format(suffix=""))
    out = _run_cli("repro.analysis.privlint",
                   [str(tree), "--baseline", ""], cwd=tmp_path)
    assert out.returncode == 1
    assert "PL001" in out.stdout and "regress.py" in out.stdout

    # accepting into a baseline brings it back to green...
    bl = tmp_path / "baseline.json"
    out = _run_cli("repro.analysis.privlint",
                   [str(tree), "--baseline", str(bl), "--write-baseline"],
                   cwd=tmp_path)
    assert out.returncode == 0
    out = _run_cli("repro.analysis.privlint",
                   [str(tree), "--baseline", str(bl)], cwd=tmp_path)
    assert out.returncode == 0
    # ...and a SECOND regression still fails against that baseline
    (tree / "regress2.py").write_text(_PL001_SNIPPET.format(suffix=""))
    out = _run_cli("repro.analysis.privlint",
                   [str(tree), "--baseline", str(bl)], cwd=tmp_path)
    assert out.returncode == 1 and "regress2.py" in out.stdout


def test_merged_runner_reports_both_tools(tmp_path):
    """``python -m repro.analysis`` runs every linter with one merged
    report/exit code; --privacy scopes it to the PL rules."""
    tree = tmp_path / "pkg"
    tree.mkdir()
    (tree / "regress.py").write_text(_PL001_SNIPPET.format(suffix=""))
    out = _run_cli("repro.analysis",
                   [str(tree), "--trace-baseline", "",
                    "--privacy-baseline", "", "--shape-baseline", "",
                    "--json-out", "-"],
                   cwd=tmp_path)
    assert out.returncode == 1
    head, _, tail = out.stdout.partition("\n}\n")
    data = json.loads(head + "\n}")
    assert set(data["tools"]) == {"tracelint", "privlint", "shapelint"}
    assert [f["rule"] for f in data["tools"]["privlint"]["new"]] == \
        ["PL001"]
    assert data["tools"]["tracelint"]["new"] == []
    assert "tracelint:" in tail and "privlint:" in tail

    # --privacy runs privlint only, and still gates
    out = _run_cli("repro.analysis",
                   [str(tree), "--privacy", "--privacy-baseline", ""],
                   cwd=tmp_path)
    assert out.returncode == 1
    assert "privlint:" in out.stdout and "tracelint:" not in out.stdout


# ---------------------------------------------------------------------------
# core/privacy.py hardening (satellite): refuse vacuous DP parameters
# ---------------------------------------------------------------------------

def test_gaussian_mechanism_refuses_zero_noise():
    import jax
    import jax.numpy as jnp
    from repro.core import privacy

    tree = (jnp.ones((3,)),)
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="noise_multiplier"):
        privacy.gaussian_mechanism(tree, key, 0.0, 1.0)
    with pytest.raises(ValueError, match="noise_multiplier"):
        privacy.gaussian_mechanism(tree, key, -0.5, 1.0)
    with pytest.raises(ValueError, match="max_norm"):
        privacy.gaussian_mechanism(tree, key, 1.0, 0.0)
    # the valid case still noises
    out = privacy.gaussian_mechanism(tree, key, 1.0, 1.0)
    assert out[0].shape == (3,)


def test_accountants_refuse_vacuous_delta():
    import numpy as np
    from repro.core import privacy

    for bad_delta in (0.0, 1.0, 1.5, -0.1):
        with pytest.raises(ValueError, match="delta"):
            privacy.epsilon_for(1.0, bad_delta)
        with pytest.raises(ValueError, match="delta"):
            privacy.amplified_epsilon_for(1.0, 0.1, bad_delta)
        with pytest.raises(ValueError, match="delta"):
            privacy.sigma_for(1.0, bad_delta)
        with pytest.raises(ValueError, match="delta"):
            privacy.rdp_to_dp([1.0], [2.0], bad_delta)
    # σ <= 0 reports ε = ∞ honestly (the engine gate is σ > 0)
    assert privacy.epsilon_for(0.0) == np.inf
    assert privacy.amplified_epsilon_for(0.0, 0.1) == np.inf


def test_driver_refuses_negative_noise_multiplier():
    from repro.config import ScbfConfig, TrainConfig
    from repro.core.scbf import run_federated
    from repro.data.medical import generate_cohort

    cohort = generate_cohort(num_admissions=60, num_medicines=8,
                             num_risk_medicines=3, num_interactions=2,
                             seed=0)
    tcfg = TrainConfig(global_loops=1, local_batch_size=16,
                       scbf=ScbfConfig(upload_rate=0.5, num_clients=2,
                                       dp_noise_multiplier=-1.0))
    with pytest.raises(ValueError, match="dp_noise_multiplier"):
        run_federated(cohort, tcfg, method="scbf",
                      mlp_features=(8, 4, 1))
