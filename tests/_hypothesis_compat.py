"""Degrade gracefully when hypothesis is absent (see requirements-dev.txt).

``from tests._hypothesis_compat import given, settings, st`` behaves
exactly like the real hypothesis imports when the package is installed.
Without it, ``@given``-decorated tests collect as zero-arg tests that
skip with a clear reason instead of killing the whole module with a
collection-time ImportError.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    class _StrategyStub:
        """Strategy constructors are only evaluated at decoration time;
        any placeholder value works because the stub ``given`` never
        draws from them."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()
