"""Chaos-hardened federation: faults, admission control, quorum.

The PR-9 acceptance bars, asserted directly:

* zero-injection runs (fault model armed, every rate zero) are
  bit-identical to the resilience machinery being absent — records,
  ε spend, and final params;
* no nonfinite update ever reaches ``ServerState``, even under a 100%
  NaN storm;
* corrupted (bitflip/NaN/poison) and duplicated payloads are rejected
  at the admission gate and counted by reason;
* quorum-missing rounds retry with backoff and, when exhausted, skip
  aggregation without bumping the server version;
* the fused path stays <= 2 compiles with the fault model armed and
  matches the per-round path bitwise under the same fault trace;
* the whole fault trace replays deterministically from its seed.
"""
import dataclasses

import jax
import numpy as np
import pytest

from _trace_guards import assert_compiles
from repro.comm import wire
from repro.config import (ClockConfig, FaultConfig, FedConfig, ScbfConfig,
                          TrainConfig)
from repro.core.scbf import run_federated
from repro.data.medical import generate_cohort
from repro.fed.clock import SimClock
from repro.fed.faults import (CORRUPT_BITFLIP, CORRUPT_NAN, CORRUPT_POISON,
                              FaultInjector, parse_fault_trace)
from repro.fed.strategy import (AdmissionPolicy, FedBuff, RoundContribution,
                                ScbfSum, admit_payloads)
from repro.obs import Recorder, recording


@pytest.fixture(scope="module")
def cohort():
    return generate_cohort(num_admissions=800, num_medicines=40,
                           num_risk_medicines=15, num_interactions=4, seed=0)


FEATS = (40, 16, 4, 1)

# every fault class at once — the storm used by the chaos CI job
STORM = FaultConfig(enabled=True, seed=7, crash_rate=0.15,
                    net_fail_rate=0.15, duplicate_rate=0.2,
                    bitflip_rate=0.15, nan_rate=0.15, poison_rate=0.15)


def _tcfg(fuse: int = 1, loops: int = 4, faults=None, clock=None,
          max_norm: float = 0.0, **fed_kw):
    return TrainConfig(
        learning_rate=0.05, global_loops=loops, local_batch_size=64,
        local_epochs=1, eval_every=loops,
        scbf=ScbfConfig(upload_rate=0.1, num_clients=5),
        fed=FedConfig(fuse_rounds=fuse,
                      faults=faults if faults is not None else FaultConfig(),
                      clock=clock if clock is not None else ClockConfig(),
                      max_update_norm=max_norm, **fed_kw))


def _params_equal(a, b):
    la, lb = (jax.tree_util.tree_leaves(t) for t in (a, b))
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


def _assert_same_run(a, b, bitwise_params=True):
    assert len(a.records) == len(b.records)
    for ra, rb in zip(a.records, b.records):
        assert ra.loop == rb.loop
        assert ra.num_participants == rb.num_participants
        assert ra.sparse_bytes == rb.sparse_bytes
        assert ra.dense_bytes == rb.dense_bytes
        assert ra.epsilon == rb.epsilon
    if bitwise_params:
        assert _params_equal(a.final_params, b.final_params)


def _finite_params(params):
    return all(np.isfinite(np.asarray(leaf)).all()
               for leaf in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# zero-injection parity: the fault model must cost nothing when idle
# ---------------------------------------------------------------------------

def test_zero_injection_bit_parity_per_round(cohort):
    """Armed-with-zero-rates == disarmed, bitwise, on the per-round
    path: the injector draws, seals, and gates every payload, but the
    outcome must be the exact run that would have happened anyway."""
    plain = run_federated(cohort, _tcfg(), method="scbf",
                          mlp_features=FEATS)
    armed = run_federated(cohort, _tcfg(faults=FaultConfig(enabled=True)),
                          method="scbf", mlp_features=FEATS)
    _assert_same_run(plain, armed)


def test_zero_injection_bit_parity_fused(cohort):
    """Same parity on the fused path, with the run-constant admit mask
    active — and still <= 2 compiles (the PR-9 acceptance bar)."""
    plain = run_federated(cohort, _tcfg(fuse=2), method="scbf",
                          mlp_features=FEATS)
    with assert_compiles(2):
        armed = run_federated(cohort,
                              _tcfg(fuse=2, faults=FaultConfig(enabled=True)),
                              method="scbf", mlp_features=FEATS)
    _assert_same_run(plain, armed)


def test_zero_injection_parity_with_dp(cohort):
    """ε accounting is part of the parity contract: the armed run must
    spend exactly the same privacy budget."""
    def cfg(faults):
        c = _tcfg(faults=faults)
        return dataclasses.replace(
            c, scbf=dataclasses.replace(c.scbf, dp_noise_multiplier=0.8))
    a = run_federated(cohort, cfg(FaultConfig()), method="scbf",
                      mlp_features=FEATS)
    b = run_federated(cohort, cfg(FaultConfig(enabled=True)),
                      method="scbf", mlp_features=FEATS)
    assert a.records[-1].epsilon == b.records[-1].epsilon
    _assert_same_run(a, b)


# ---------------------------------------------------------------------------
# the admission gate: nothing corrupt may reach ServerState
# ---------------------------------------------------------------------------

def test_nan_storm_never_reaches_server(cohort):
    """100% NaN corruption: every payload is rejected, the model never
    moves, and the final params carry no nonfinite values."""
    faults = FaultConfig(enabled=True, seed=3, nan_rate=1.0)
    rec = Recorder()
    with recording(recorder=rec):
        res = run_federated(cohort, _tcfg(faults=faults), method="scbf",
                            mlp_features=FEATS)
    assert _finite_params(res.final_params)
    rejected = rec.counters.get("rejected_nonfinite", 0)
    assert rejected == 4 * 5      # every slot of every round
    # nothing admitted → the server never stepped
    init = run_federated(cohort, _tcfg(loops=0), method="scbf",
                         mlp_features=FEATS)
    assert _params_equal(res.final_params, init.final_params)


def test_bitflip_rejected_by_checksum(cohort):
    """Bit-flipped wire payloads fail CRC verification (or, rarely,
    structural validation when the flip lands in a length header)."""
    faults = FaultConfig(enabled=True, seed=5, bitflip_rate=1.0)
    rec = Recorder()
    with recording(recorder=rec):
        res = run_federated(cohort, _tcfg(faults=faults), method="scbf",
                            mlp_features=FEATS)
    assert _finite_params(res.final_params)
    n = rec.counters.get("rejected_checksum", 0) \
        + rec.counters.get("rejected_malformed", 0)
    assert n == 4 * 5
    assert rec.counters.get("payloads_rejected") == n


def test_duplicates_rejected_and_counted(cohort):
    """Replayed payloads are dropped by the (client, round) nonce and
    the originals still land: participation and bytes-shipped move,
    but each update is applied exactly once."""
    faults = FaultConfig(enabled=True, seed=11, duplicate_rate=1.0)
    plain = run_federated(cohort, _tcfg(), method="scbf",
                          mlp_features=FEATS)
    rec = Recorder()
    with recording(recorder=rec):
        res = run_federated(cohort, _tcfg(faults=faults), method="scbf",
                            mlp_features=FEATS)
    assert rec.counters.get("rejected_duplicate") == 4 * 5
    # dedup means the MODEL is the fault-free one, while the byte
    # accounting honestly reports the replayed traffic
    assert _params_equal(plain.final_params, res.final_params)
    for rp, rr in zip(plain.records, res.records):
        assert rr.sparse_bytes == 2 * rp.sparse_bytes


def test_poison_rejected_by_norm_gate(cohort):
    """Norm-inflated updates exceed max_update_norm and are rejected;
    without the gate they would be admitted (the refusal matrix makes
    the gate mandatory for poison on the fused path)."""
    faults = FaultConfig(enabled=True, seed=13, poison_rate=1.0,
                         poison_scale=64.0)
    rec = Recorder()
    with recording(recorder=rec):
        res = run_federated(cohort, _tcfg(faults=faults, max_norm=10.0),
                            method="scbf", mlp_features=FEATS)
    assert rec.counters.get("rejected_norm") == 4 * 5
    assert _finite_params(res.final_params)


def test_norm_clip_scales_instead_of_rejecting(cohort):
    """norm_action='clip' admits over-norm updates scaled down to the
    bound (per-round path only — the fused path refuses clip+faults)."""
    rec = Recorder()
    with recording(recorder=rec):
        run_federated(cohort, _tcfg(max_norm=1e-3, norm_action="clip"),
                      method="scbf", mlp_features=FEATS)
    assert rec.counters.get("payloads_clipped", 0) > 0
    assert rec.counters.get("rejected_norm", 0) == 0


def test_full_storm_finite_and_counted(cohort):
    """Every fault class at once: the run completes, the params stay
    finite, and every injected-and-delivered corruption is rejected."""
    rec = Recorder()
    with recording(recorder=rec):
        res = run_federated(cohort, _tcfg(loops=6, faults=STORM,
                                          max_norm=100.0),
                            method="scbf", mlp_features=FEATS)
    assert _finite_params(res.final_params)
    injected = sum(1 for e in rec.events if e["ev"] == "fault_injected")
    assert injected > 0
    assert rec.counters.get("payloads_rejected", 0) > 0


# ---------------------------------------------------------------------------
# fused path under faults
# ---------------------------------------------------------------------------

def test_fused_matches_per_round_under_storm(cohort):
    """The same seeded fault trace produces the same run on both paths:
    faults are drawn per (seed, round, attempt, client) so fuse_rounds
    cannot shift them, plan-time exclusion contributes exact zeros, and
    the post-chunk gate re-check guarantees planned == actual."""
    a = run_federated(cohort, _tcfg(loops=6, faults=STORM, max_norm=100.0),
                      method="scbf", mlp_features=FEATS)
    with assert_compiles(2):
        b = run_federated(cohort, _tcfg(fuse=3, loops=6, faults=STORM,
                                        max_norm=100.0),
                          method="scbf", mlp_features=FEATS)
    _assert_same_run(a, b)


def test_fused_refuses_unarmed_norm_gate(cohort):
    """max_update_norm without the fault model is silently inert on the
    fused path (aggregation happens on device) — refused loudly."""
    with pytest.raises(ValueError, match="norm gate"):
        run_federated(cohort, _tcfg(fuse=2, max_norm=1.0),
                      method="scbf", mlp_features=FEATS)


def test_fused_refuses_clip_under_faults(cohort):
    """Clipping cannot be applied to on-device deltas at plan time."""
    faults = FaultConfig(enabled=True, poison_rate=0.5)
    with pytest.raises(ValueError, match="clip"):
        run_federated(cohort, _tcfg(fuse=2, faults=faults, max_norm=1.0,
                                    norm_action="clip"),
                      method="scbf", mlp_features=FEATS)


def test_fused_refuses_poison_without_gate(cohort):
    """Poisoned updates are only excludable at plan time when the
    reject-mode norm gate is armed."""
    faults = FaultConfig(enabled=True, poison_rate=0.5)
    with pytest.raises(ValueError, match="poison"):
        run_federated(cohort, _tcfg(fuse=2, faults=faults),
                      method="scbf", mlp_features=FEATS)


# ---------------------------------------------------------------------------
# quorum and retry
# ---------------------------------------------------------------------------

def test_quorum_retry_and_miss(cohort):
    """crash_rate=1 can never satisfy a quorum: each round retries
    round_retries times with backoff, then records a quorum miss and
    skips aggregation — the model must not move, but the run completes."""
    faults = FaultConfig(enabled=True, seed=2, crash_rate=1.0)
    rec = Recorder()
    with recording(recorder=rec):
        res = run_federated(cohort, _tcfg(loops=3, faults=faults,
                                          min_valid_participants=2,
                                          round_retries=2),
                            method="scbf", mlp_features=FEATS)
    assert rec.counters.get("rounds_retried") == 3 * 2
    assert rec.counters.get("quorum_misses") == 3
    retries = [e for e in rec.events if e["ev"] == "round_retried"]
    assert all(e["backoff_s"] > 0 for e in retries)
    init = run_federated(cohort, _tcfg(loops=0), method="scbf",
                         mlp_features=FEATS)
    assert _params_equal(res.final_params, init.final_params)


def test_quorum_satisfied_after_retry(cohort):
    """A quorum that fails on attempt 0 but passes on a retry steps the
    server exactly once for that round, and the aborted first-attempt
    cohort still shows up in the ε accounting (their uploads happened)."""
    # nan_rate high enough that some rounds miss quorum=4 of 5 on the
    # first draw but clear it on a retry (seeded, so deterministic)
    faults = FaultConfig(enabled=True, seed=17, nan_rate=0.35)
    rec = Recorder()
    with recording(recorder=rec):
        res = run_federated(cohort, _tcfg(loops=6, faults=faults,
                                          min_valid_participants=4,
                                          round_retries=3),
                            method="scbf", mlp_features=FEATS)
    retried = rec.counters.get("rounds_retried", 0)
    assert retried > 0, "seed must produce at least one retry"
    assert rec.counters.get("quorum_misses", 0) == 0
    assert _finite_params(res.final_params)


def test_quorum_fused_matches_per_round(cohort):
    """Quorum retries replan with a bumped attempt counter on both
    paths, so the fused run sees the identical final cohorts."""
    faults = FaultConfig(enabled=True, seed=17, nan_rate=0.35)

    def run(fuse):
        return run_federated(cohort, _tcfg(fuse=fuse, loops=6,
                                           faults=faults,
                                           min_valid_participants=4,
                                           round_retries=3),
                             method="scbf", mlp_features=FEATS)
    _assert_same_run(run(1), run(3))


# ---------------------------------------------------------------------------
# deterministic replay
# ---------------------------------------------------------------------------

def test_fault_trace_replays_from_seed(cohort):
    """Two runs of the same seeded chaos config are the same run —
    events, counters, bytes, and final bits."""
    def run():
        rec = Recorder()
        with recording(recorder=rec):
            res = run_federated(cohort, _tcfg(loops=5, faults=STORM,
                                              max_norm=100.0),
                                method="scbf", mlp_features=FEATS)
        return res, rec
    ra, reca = run()
    rb, recb = run()
    _assert_same_run(ra, rb)
    ka = [(e["ev"], e.get("fault"), e.get("client"), e.get("loop"))
          for e in reca.events if e["ev"] == "fault_injected"]
    kb = [(e["ev"], e.get("fault"), e.get("client"), e.get("loop"))
          for e in recb.events if e["ev"] == "fault_injected"]
    assert ka == kb


def test_injector_outcomes_do_not_depend_on_cohort(cohort):
    """A client's fate for (round, attempt) is a pure function of the
    seed and its id — not of who else was sampled."""
    inj = FaultInjector(8, FaultConfig(enabled=True, seed=9, crash_rate=0.4,
                                       nan_rate=0.3, duplicate_rate=0.3))
    full = inj.round_faults(2, np.arange(8))
    sub = inj.round_faults(2, np.array([1, 5, 6]))
    for k, j in [(1, 0), (5, 1), (6, 2)]:
        assert full.crashed[k] == sub.crashed[j]
        assert full.corrupt[k] == sub.corrupt[j]
        assert full.duplicated[k] == sub.duplicated[j]


# ---------------------------------------------------------------------------
# simulated clock: deadline cuts and spill
# ---------------------------------------------------------------------------

def _clock_cfg(action="drop", quantile=0.6):
    return ClockConfig(enabled=True, deadline_quantile=quantile,
                       deadline_action=action, hetero_sigma=1.0,
                       compute_sigma=0.5)


def test_deadline_drop_cuts_cohort(cohort):
    """A sub-1.0 latency quantile must cut somebody, and the telemetry
    carries the deadline/latency fields for every round."""
    rec = Recorder()
    with recording(recorder=rec):
        res = run_federated(cohort, _tcfg(loops=4,
                                          clock=_clock_cfg("drop")),
                            method="scbf", mlp_features=FEATS)
    rounds = [e for e in rec.events if e["ev"] == "round"]
    assert all("deadline_s" in e and e["deadline_s"] > 0 for e in rounds)
    assert any(r.num_participants < 5 for r in res.records)
    assert _finite_params(res.final_params)


def test_deadline_spill_delivers_late_updates(cohort):
    """Spill mode turns deadline misses into staleness-weighted late
    arrivals instead of losing them (per-round path only)."""
    rec = Recorder()
    with recording(recorder=rec):
        res = run_federated(cohort, _tcfg(loops=6,
                                          clock=_clock_cfg("spill")),
                            method="scbf", mlp_features=FEATS)
    rounds = [e for e in rec.events if e["ev"] == "round"]
    assert any(e.get("staleness_mean", 0) > 0 for e in rounds), \
        "at least one spilled update must arrive late"
    assert _finite_params(res.final_params)


def test_clock_run_is_deterministic(cohort):
    a = run_federated(cohort, _tcfg(loops=4, clock=_clock_cfg()),
                      method="scbf", mlp_features=FEATS)
    b = run_federated(cohort, _tcfg(loops=4, clock=_clock_cfg()),
                      method="scbf", mlp_features=FEATS)
    _assert_same_run(a, b)


def test_clock_refuses_legacy_coinflips():
    from repro.fed.scheduler import SyncScheduler
    clock = SimClock(5, _clock_cfg(), seed=0)
    with pytest.raises(ValueError, match="clock"):
        SyncScheduler(5, FedConfig(dropout_rate=0.2), seed=0, clock=clock)


# ---------------------------------------------------------------------------
# unit gates: wire integrity (S1) and the admission helper
# ---------------------------------------------------------------------------

def _tiny_payload():
    tree = [{"w": np.array([[0.5, 0.0], [0.0, -0.25]], np.float32),
             "b": np.array([0.1, 0.0], np.float32)}]
    return wire.encode(tree)


def test_seal_and_verify_roundtrip():
    p = wire.seal(_tiny_payload(), client_id=3, round_index=7)
    assert p.meta.client_id == 3 and p.meta.round_index == 7
    assert p.meta.nonce == (3, 7)
    assert wire.verify_checksum(p)
    # unsealed payloads (the fault-free path) verify trivially
    assert wire.verify_checksum(_tiny_payload())


def _tamper_value(p, delta=1.0):
    lp = p.layers[0]
    vals = np.array(lp.values, np.float32).copy()
    vals[0] += delta
    return dataclasses.replace(
        p, layers=(dataclasses.replace(lp, values=vals),) + p.layers[1:])


def test_checksum_detects_tampering():
    p = wire.seal(_tiny_payload(), client_id=0, round_index=0)
    assert not wire.verify_checksum(_tamper_value(p))


def test_validate_rejects_malformed():
    p = _tiny_payload()
    lp = p.layers[0]
    bad = dataclasses.replace(
        p, layers=(dataclasses.replace(lp, nnz=lp.size + 1),)
        + p.layers[1:])
    with pytest.raises(wire.PayloadError):
        wire.validate_payload(bad)


def test_admit_payloads_reasons():
    """One call, every verdict: ok, checksum, duplicate, nonfinite,
    over-norm — kept indices and reasons must line up exactly."""
    ok = wire.seal(_tiny_payload(), 0, 0)
    flip = _tamper_value(wire.seal(_tiny_payload(), 1, 0))
    dup = wire.seal(_tiny_payload(), 0, 0)          # same nonce as ok
    tree = [{"w": np.array([[np.nan, 0.0], [0.0, 0.0]], np.float32),
             "b": np.zeros(2, np.float32)}]
    nonf = wire.seal(wire.encode(tree), 2, 0)
    big = [{"w": np.full((2, 2), 100.0, np.float32),
            "b": np.zeros(2, np.float32)}]
    over = wire.seal(wire.encode(big), 3, 0)

    from repro.fed.strategy import ServerState
    state = ServerState(params=())
    rec = Recorder()
    with recording(recorder=rec):
        contrib = RoundContribution(
            num_examples=np.ones(5), staleness=np.zeros(5),
            payloads=[ok, flip, dup, nonf, over])
        kept, kept_idx = admit_payloads(
            state, contrib, AdmissionPolicy(max_update_norm=10.0))
    assert kept_idx == [0]
    assert len(kept) == 1 and kept[0] is ok
    assert rec.counters.get("rejected_checksum") == 1
    assert rec.counters.get("rejected_duplicate") == 1
    assert rec.counters.get("rejected_nonfinite") == 1
    assert rec.counters.get("rejected_norm") == 1


def test_fedbuff_always_guards_nonfinite():
    """S2: FedBuff filters nonfinite uploads even with no admission
    policy configured — a single NaN would otherwise poison the whole
    buffered average."""
    params = [{"w": np.zeros((2, 2), np.float32),
               "b": np.zeros(2, np.float32)}]
    good = wire.encode([{"w": np.full((2, 2), 0.5, np.float32),
                         "b": np.zeros(2, np.float32)}])
    bad = wire.encode([{"w": np.full((2, 2), np.nan, np.float32),
                        "b": np.zeros(2, np.float32)}])
    strat = FedBuff(buffer_size=2, staleness_exponent=0.0)
    state = strat.init(params)
    rec = Recorder()
    with recording(recorder=rec):
        state = strat.aggregate(state, RoundContribution(
            num_examples=np.ones(2), staleness=np.zeros(2),
            payloads=[good, bad]))
        state = strat.aggregate(state, RoundContribution(
            num_examples=np.ones(1), staleness=np.zeros(1),
            payloads=[good]))
    assert rec.counters.get("rejected_nonfinite") == 1
    assert all(np.isfinite(np.asarray(leaf)).all()
               for layer in state.params for leaf in layer.values())
    # buffer flushed on the 2nd good upload: the step landed
    assert float(np.abs(np.asarray(state.params[0]["w"])).max()) > 0


# ---------------------------------------------------------------------------
# the CLI spec parser
# ---------------------------------------------------------------------------

def test_parse_fault_trace():
    cfg = parse_fault_trace("seed=4,crash=0.1,net_fail=0.2,retries=5,"
                            "backoff=2.5,duplicate=0.3,bitflip=0.01,"
                            "nan=0.02,poison=0.03,poison_scale=8")
    assert cfg.enabled
    assert cfg.seed == 4
    assert cfg.crash_rate == 0.1
    assert cfg.net_fail_rate == 0.2
    assert cfg.net_retries == 5
    assert cfg.net_backoff_s == 2.5
    assert cfg.duplicate_rate == 0.3
    assert cfg.bitflip_rate == 0.01
    assert cfg.nan_rate == 0.02
    assert cfg.poison_rate == 0.03
    assert cfg.poison_scale == 8.0


def test_parse_fault_trace_rejects_garbage():
    with pytest.raises(ValueError, match="unknown"):
        parse_fault_trace("crash=0.1,warp=9")
    with pytest.raises(ValueError):
        parse_fault_trace("crash")


def test_corruption_rates_must_fit():
    with pytest.raises(ValueError, match="<= 1"):
        FaultInjector(4, FaultConfig(enabled=True, bitflip_rate=0.5,
                                     nan_rate=0.4, poison_rate=0.4))
    with pytest.raises(ValueError):
        FaultInjector(4, FaultConfig(enabled=True, crash_rate=1.5))
