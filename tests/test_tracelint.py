"""tracelint: the static gate for the retrace/host-sync/recompile bug
class (docs/STATIC_ANALYSIS.md).

Covers the PR 6 acceptance bars: every golden bad-fixture (including
the verbatim PR 1 ``_evaluate`` and PR 5 ``apoz_scores`` reductions) is
detected with the right rule code; the known-good idiom fixtures
produce ZERO findings; suppression comments and the committed baseline
both gate correctly; finding keys survive line shifts; the CLI exits
nonzero on an injected TL001 (the CI lint job's contract); and the
per-call-jit fixes this PR shipped (serve, dryrun, train) actually
cache their wrappers.
"""
import json
import os
import pathlib
import shutil
import subprocess
import sys
import textwrap
from collections import Counter

import pytest

from repro.analysis import astgraph
from repro.analysis.report import Baseline
from repro.analysis.tracelint import run_paths

REPO = pathlib.Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "tracelint"

# filename -> exactly which rules fire, and how often (no extras!)
BAD_EXPECT = {
    "tl001_evaluate_retrace.py": {"TL001": 1},   # the PR 1 bug, verbatim
    "tl001_apoz_jit_lambda.py": {"TL001": 1},    # the PR 5 bug, verbatim
    "tl002_host_sync.py": {"TL002": 3},
    "tl003_tracer_branch.py": {"TL003": 2},
    "tl004_varying_shapes.py": {"TL004": 2},
    "tl005_blockspec.py": {"TL005": 2},
    "tl006_host_loop_transfers.py": {"TL006": 3},
}


# ---------------------------------------------------------------------------
# golden fixtures
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fname", sorted(BAD_EXPECT))
def test_bad_fixture_detected_with_exact_rules(fname):
    findings, _ = run_paths([str(FIXTURES / "bad" / fname)])
    got = Counter(f.rule for f in findings)
    assert got == Counter(BAD_EXPECT[fname]), \
        f"{fname}: {[f.render() for f in findings]}"


def test_bad_fixture_coverage_is_all_rules():
    """The bad fixtures exercise every rule the analyzer ships."""
    from repro.analysis.rules import ALL_RULES
    covered = {r for expect in BAD_EXPECT.values() for r in expect}
    assert covered == set(ALL_RULES)


def test_good_fixtures_zero_false_positives():
    findings, files = run_paths([str(FIXTURES / "good")])
    assert files == 4
    assert findings == [], [f.render() for f in findings]


# ---------------------------------------------------------------------------
# the call graph
# ---------------------------------------------------------------------------

def test_in_trace_marking_transitive(tmp_path):
    f = tmp_path / "m.py"
    f.write_text(textwrap.dedent("""
        import jax
        from jax import lax

        def traced_root(p, x):
            return helper(p, x)

        def helper(p, x):
            def nested(q):
                return inner(q)
            return nested(p) + x

        def inner(p):
            return p

        def scan_body(carry, x):
            return carry, x

        def host_only(p):
            return float(p)

        step = jax.jit(traced_root)

        def driver(p, xs):
            return lax.scan(scan_body, p, xs)
    """))
    graph = astgraph.build_graph([str(f)])
    mod = next(iter(graph.modules.values()))
    in_trace = {q for q, fn in mod.functions.items() if fn.in_trace}
    assert "traced_root" in in_trace          # jit-wrapped at module level
    assert "helper" in in_trace               # called from a traced fn
    assert "helper.nested" in in_trace        # nested defs trace along
    assert "inner" in in_trace                # transitively reached
    assert "scan_body" in in_trace            # lax.scan traced callable
    assert "host_only" not in in_trace
    assert "driver" not in in_trace           # calls scan, isn't traced
    assert "step" in mod.jitted_symbols


def test_static_argnames_are_not_tracers(tmp_path):
    f = tmp_path / "m.py"
    f.write_text(textwrap.dedent("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("mode",))
        def select(x, mode):
            if mode:
                return x * 2.0
            return x
    """))
    findings, _ = run_paths([str(f)])
    assert findings == [], [x.render() for x in findings]


# ---------------------------------------------------------------------------
# suppressions, baseline, key stability
# ---------------------------------------------------------------------------

_PER_CALL_JIT = textwrap.dedent("""
    import jax

    def main(p, x):
        step = jax.jit(lambda p, x: p + x){suffix}
        return step(p, x)
""")


def test_suppression_comment_silences(tmp_path):
    noisy = tmp_path / "noisy.py"
    noisy.write_text(_PER_CALL_JIT.format(suffix=""))
    assert len(run_paths([str(noisy)])[0]) == 1

    quiet = tmp_path / "quiet.py"
    quiet.write_text(_PER_CALL_JIT.format(
        suffix="  # tracelint: disable=TL001"))
    assert run_paths([str(quiet)])[0] == []

    # the wrong code does NOT silence it
    wrong = tmp_path / "wrong.py"
    wrong.write_text(_PER_CALL_JIT.format(
        suffix="  # tracelint: disable=TL004"))
    assert len(run_paths([str(wrong)])[0]) == 1


# a TL001 anchored to a *decorator* line of a nested def: the disable
# comment must work anywhere in the decorated-def header (any decorator
# line through the `def` line) or on the line above it — regression for
# the comment previously having to sit on the exact decorator line.
_DECORATED_JIT = textwrap.dedent("""
    from functools import partial

    import jax

    def make_step(lr):{above}
        @partial(jax.jit,{dec_suffix}
                 static_argnums=(0,)){arg_suffix}
        def step(n, p, x):{def_suffix}
            return p - lr * x
        return step
""")


def test_suppression_covers_decorated_def_header(tmp_path):
    blank = {"above": "", "dec_suffix": "", "arg_suffix": "",
             "def_suffix": ""}

    noisy = tmp_path / "noisy.py"
    noisy.write_text(_DECORATED_JIT.format(**blank))
    findings = run_paths([str(noisy)])[0]
    assert [f.rule for f in findings] == ["TL001"]

    # the comment may sit on ANY header line, not just the finding's
    for slot in ("dec_suffix", "arg_suffix", "def_suffix"):
        quiet = tmp_path / f"quiet_{slot}.py"
        quiet.write_text(_DECORATED_JIT.format(
            **{**blank, slot: "  # tracelint: disable=TL001"}))
        assert run_paths([str(quiet)])[0] == [], slot

    # ...or on the line directly above the first decorator
    above = tmp_path / "above.py"
    above.write_text(_DECORATED_JIT.format(
        **{**blank, "above": "\n        # tracelint: disable=TL001"}))
    assert run_paths([str(above)])[0] == []

    # the wrong rule code in the header does NOT silence it
    wrong = tmp_path / "wrong.py"
    wrong.write_text(_DECORATED_JIT.format(
        **{**blank, "def_suffix": "  # tracelint: disable=TL004"}))
    assert [f.rule for f in run_paths([str(wrong)])[0]] == ["TL001"]


def test_finding_keys_survive_line_shifts(tmp_path):
    f = tmp_path / "m.py"
    f.write_text(_PER_CALL_JIT.format(suffix=""))
    before = run_paths([str(f)])[0]
    f.write_text("# a new header comment\n# another\n\n"
                 + _PER_CALL_JIT.format(suffix=""))
    after = run_paths([str(f)])[0]
    assert [x.key for x in after] == [x.key for x in before]
    assert after[0].line == before[0].line + 3   # line moved; key did not


def test_baseline_roundtrip_and_stale_detection(tmp_path):
    f = tmp_path / "m.py"
    f.write_text(_PER_CALL_JIT.format(suffix=""))
    findings, _ = run_paths([str(f)])
    bl_path = tmp_path / "baseline.json"
    Baseline().write(str(bl_path), findings)

    bl = Baseline.load(str(bl_path))
    new, accepted, stale = bl.split(findings)
    assert (len(new), len(accepted), stale) == (0, 1, [])

    # justifications survive a rewrite
    data = json.loads(bl_path.read_text())
    data["findings"][0]["justification"] = "intentional: bench-only"
    bl_path.write_text(json.dumps(data))
    Baseline.load(str(bl_path)).write(str(bl_path), findings)
    assert json.loads(bl_path.read_text())["findings"][0][
        "justification"] == "intentional: bench-only"

    # a fixed finding shows up as stale, never silently lingers
    new, accepted, stale = Baseline.load(str(bl_path)).split([])
    assert (new, accepted) == ([], []) and len(stale) == 1

    # unknown versions refuse to load rather than mis-gating
    bl_path.write_text(json.dumps({"version": 99, "findings": []}))
    with pytest.raises(ValueError):
        Baseline.load(str(bl_path))


def test_committed_baseline_matches_repo(monkeypatch):
    """The shipped gate: the committed baseline is near-empty, every
    entry is justified, and the repo lints clean against it."""
    bl = Baseline.load(str(REPO / "analysis" / "baseline.json"))
    assert len(bl.entries) <= 4
    for key, rec in bl.entries.items():
        just = rec.get("justification", "")
        assert just and "TODO" not in just, f"unjustified baseline: {key}"
    monkeypatch.chdir(REPO)   # relative paths, as the CI lint job runs
    findings, files = run_paths(["src", "benchmarks", "examples"])
    assert files > 50
    keys = {x.key for x in findings}
    assert keys == set(bl.entries), \
        f"repo drifted from analysis/baseline.json: {sorted(keys)}"


# ---------------------------------------------------------------------------
# the CLI — the CI lint job's exact contract
# ---------------------------------------------------------------------------

def _run_cli(args, cwd):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.tracelint", *args],
        env=env, cwd=cwd, capture_output=True, text=True, timeout=120)


def test_cli_gate_fails_on_injected_tl001(tmp_path):
    tree = tmp_path / "pkg"
    tree.mkdir()
    shutil.copy(FIXTURES / "good" / "jit_caching_idioms.py", tree)
    out = _run_cli([str(tree), "--baseline", ""], cwd=tmp_path)
    assert out.returncode == 0, out.stdout + out.stderr

    # inject the TL001 regression: the gate must go red
    (tree / "regress.py").write_text(_PER_CALL_JIT.format(suffix=""))
    out = _run_cli([str(tree), "--baseline", ""], cwd=tmp_path)
    assert out.returncode == 1
    assert "TL001" in out.stdout and "regress.py" in out.stdout

    # accepting into a baseline brings it back to green...
    bl = tmp_path / "baseline.json"
    out = _run_cli([str(tree), "--baseline", str(bl), "--write-baseline"],
                   cwd=tmp_path)
    assert out.returncode == 0
    out = _run_cli([str(tree), "--baseline", str(bl)], cwd=tmp_path)
    assert out.returncode == 0
    # ...and a SECOND regression still fails against that baseline
    (tree / "regress2.py").write_text(_PER_CALL_JIT.format(suffix=""))
    out = _run_cli([str(tree), "--baseline", str(bl)], cwd=tmp_path)
    assert out.returncode == 1 and "regress2.py" in out.stdout


def test_cli_json_out_and_rule_subset(tmp_path):
    tree = tmp_path / "pkg"
    tree.mkdir()
    (tree / "regress.py").write_text(_PER_CALL_JIT.format(suffix=""))
    report = tmp_path / "report.json"
    out = _run_cli([str(tree), "--baseline", "", "--json-out", str(report)],
                   cwd=tmp_path)
    assert out.returncode == 1
    data = json.loads(report.read_text())
    assert [f["rule"] for f in data["new"]] == ["TL001"]
    assert data["baselined"] == [] and data["files_scanned"] == 1
    # rule subsetting: TL004-only run ignores the TL001
    out = _run_cli([str(tree), "--baseline", "", "--rules", "TL004"],
                   cwd=tmp_path)
    assert out.returncode == 0
    # unknown rules are a usage error, not a silent pass
    out = _run_cli([str(tree), "--baseline", "", "--rules", "TL999"],
                   cwd=tmp_path)
    assert out.returncode == 2


# ---------------------------------------------------------------------------
# the per-call-jit fixes this PR shipped: wrappers are really cached
# ---------------------------------------------------------------------------

class _Bundle:
    """Identity-hashed stand-in for ModelBundle (which is eq=False so it
    can key per-bundle jit caches)."""

    def __init__(self, **kw):
        self.__dict__.update(kw)


def test_serve_jitted_steps_cached_per_bundle():
    import jax.numpy as jnp
    from repro.launch import serve

    traces = Counter()

    def prefill_step(params, batch):
        traces["prefill"] += 1
        return params + batch, batch

    def decode_step(params, batch):
        traces["decode"] += 1
        return params * batch, batch

    bundle = _Bundle(prefill_step=prefill_step,
                             decode_step=decode_step)
    p1, d1 = serve._jitted_steps(bundle)
    p2, d2 = serve._jitted_steps(bundle)
    assert p1 is p2 and d1 is d2          # one wrapper pair per bundle
    x = jnp.ones((2, 2))
    p1(x, x), p2(x, x), d1(x, x), d2(x, x)
    assert traces == {"prefill": 1, "decode": 1}   # one trace each

    other = _Bundle(prefill_step=prefill_step,
                            decode_step=decode_step)
    assert serve._jitted_steps(other)[0] is not p1  # distinct bundle


def test_dryrun_step_cache_reuses_wrapper():
    import jax.numpy as jnp

    # importing dryrun appends the 512-virtual-device XLA flag; jax is
    # already initialized in this process so it cannot take effect, but
    # restore the env so subprocess-spawning tests stay deterministic
    before = os.environ.get("XLA_FLAGS")
    try:
        from repro.launch import dryrun
    finally:
        if before is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = before

    dryrun._STEP_CACHE.clear()
    traces = Counter()

    def step(p, b):
        traces["step"] += 1
        return p + b

    try:
        j1 = dryrun._jitted_step(("qwen", "train_4k", "single"), step,
                                 None, None)
        j2 = dryrun._jitted_step(("qwen", "train_4k", "single"),
                                 lambda p, b: p, None, None)
        assert j1 is j2                   # same combo: cached wrapper wins
        x = jnp.ones((2,))
        j1(x, x), j2(x, x)
        assert traces["step"] == 1        # one trace for the combo
        j3 = dryrun._jitted_step(("qwen", "decode_4k", "single"), step,
                                 None, None)
        assert j3 is not j1
    finally:
        dryrun._STEP_CACHE.clear()


def test_train_fed_lm_step_cached():
    from repro.config import ScbfConfig
    from repro.launch import train

    bundle = _Bundle(loss_fn=lambda p, b: (p * b).sum())
    scbf = ScbfConfig(upload_rate=0.1, num_clients=2)
    s1 = train._fed_lm_step(bundle, scbf, 0.05)
    assert train._fed_lm_step(bundle, scbf, 0.05) is s1
    assert train._fed_lm_step(bundle, scbf, 0.06) is not s1
