"""Loop-aware HLO analyzer: exactness on known programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze, parse_module, _crosses_pod


def compile_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_flops_exact():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    st = analyze(compile_text(f, x, x))
    assert st.flops == pytest.approx(2 * 128 ** 3 * 10)


def test_unrolled_matches_scan():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def scan_f(x, w):
        y, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=7)
        return y

    def unrolled_f(x, w):
        for _ in range(7):
            x = x @ w
        return x
    s1 = analyze(compile_text(scan_f, x, x))
    s2 = analyze(compile_text(unrolled_f, x, x))
    assert s1.flops == pytest.approx(s2.flops)


def test_nested_scan():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    st = analyze(compile_text(f, x, x))
    assert st.flops == pytest.approx(2 * 32 ** 3 * 15)


def test_batched_dot_flops():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    a = jax.ShapeDtypeStruct((4, 16, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 8), jnp.float32)
    st = analyze(compile_text(f, a, b))
    assert st.flops == pytest.approx(2 * 4 * 16 * 32 * 8)


def test_traffic_counts_dot_operands():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    st = analyze(compile_text(f, a, a))
    assert st.traffic_bytes >= 3 * 128 * 128 * 4


def test_cross_pod_classification():
    line_explicit = "replica_groups={{0,256},{1,257}}"
    assert _crosses_pod(line_explicit, 256)
    line_local = "replica_groups={{0,1},{2,3}}"
    assert not _crosses_pod(line_local, 256)
    # iota: groups are contiguous 16-blocks -> pod-local
    assert not _crosses_pod("replica_groups=[32,16]<=[512]", 256)
    # iota with transpose: stride-256 partners -> crosses pods
    assert _crosses_pod("replica_groups=[256,2]<=[2,256]T(1,0)", 256)
