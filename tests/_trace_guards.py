"""Shared trace-hygiene assertions for the fused-path tests.

The fused engine defends two properties in CI (docs/FED_ENGINE.md):

* a dispatched chunk performs **zero** implicit host transfers, and
* a whole run costs a **bounded number of fused compiles** no matter
  how the participant count varies.

Both used to be asserted ad hoc (a raw ``jax.transfer_guard`` block
here, a ``reset_fused_compile_count`` / ``fused_compile_count`` pair
there).  These context managers are the single spelling; new tests and
new engines should use them instead of re-deriving the idiom — the same
properties tracelint's TL006/TL004 rules lint for statically.
"""
from __future__ import annotations

import contextlib

import jax

from repro.fed.engine import fused_compile_count, reset_fused_compile_count


@contextlib.contextmanager
def assert_no_transfers():
    """The block must never cross the host boundary.

    Any implicit device→host or host→device transfer inside the block
    raises immediately (``jax.transfer_guard("disallow")``).  Compile
    first, guard second: tracing itself is allowed to transfer, so the
    caller warms the program up outside the block.
    """
    with jax.transfer_guard("disallow"):
        yield


@contextlib.contextmanager
def assert_compiles(at_most: int):
    """The block may trigger at most ``at_most`` fused-program compiles.

    Resets the engine's compile counter on entry and asserts on exit,
    so the bound covers exactly the guarded block.
    """
    reset_fused_compile_count()
    yield
    count = fused_compile_count()
    assert count <= at_most, (
        f"fused path compiled {count}x inside the guarded block "
        f"(allowed {at_most}) — a retrace/recompile regression")
