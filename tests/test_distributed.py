"""Scaled SCBF: the vmap-over-clients federated step used by the
multi-pod dry-run, on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ScbfConfig
from repro.core.distributed import make_federated_train_step
from repro.core import channels


def quad_loss(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def make_data(k=2, n=32, d=8, out=4, seed=0):
    rng = np.random.default_rng(seed)
    return {"x": jnp.asarray(rng.normal(size=(k, n, d)), jnp.float32),
            "y": jnp.asarray(rng.normal(size=(k, n, out)), jnp.float32)}


def make_params(d=8, out=4, seed=1):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(d, out)), jnp.float32),
            "b": jnp.zeros((out,), jnp.float32)}


def test_full_upload_equals_plain_sum():
    """upload_rate ≈ 1 → masked exchange == plain summed-gradient step."""
    params = make_params()
    batch = make_data()
    step = make_federated_train_step(quad_loss,
                                     ScbfConfig(upload_rate=1.0),
                                     lr=0.1)
    loss, new = jax.jit(step)(params, batch)
    # manual: sum of per-client grads
    g0 = jax.grad(quad_loss)(params, {k: v[0] for k, v in batch.items()})
    g1 = jax.grad(quad_loss)(params, {k: v[1] for k, v in batch.items()})
    want_w = params["w"] - 0.1 * (g0["w"] + g1["w"])
    np.testing.assert_allclose(np.asarray(new["w"]), np.asarray(want_w),
                               rtol=1e-5)


def test_partial_upload_masks_channels():
    params = make_params(d=16, out=32)
    batch = make_data(d=16, out=32)
    step = make_federated_train_step(quad_loss,
                                     ScbfConfig(upload_rate=0.25), lr=0.1)
    loss, new = jax.jit(step)(params, batch)
    delta = np.asarray(new["w"] - params["w"])
    # most output channels untouched (masked out)
    untouched = np.mean(np.all(delta == 0, axis=0))
    assert untouched > 0.4
    assert np.isfinite(float(loss))


def test_compressed_exchange_matches_dense_mask():
    """Gather/scatter compressed exchange selects the same channels as the
    dense mask (modulo quantile-vs-topk boundary ties)."""
    rng = np.random.default_rng(3)
    grads = {"w": jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)}
    from repro.core.distributed import _compressed_masked
    dense, _ = channels.apply_factored_mask(grads, 0.25)
    comp = _compressed_masked(grads, 0.25)
    dz = np.asarray(dense["w"]) != 0
    cz = np.asarray(comp["w"]) != 0
    # same number of selected channels (exactly k = rate*n)
    assert abs(dz.any(0).sum() - cz.any(0).sum()) <= 1
    # overlap near-total
    overlap = (dz & cz).sum() / max(cz.sum(), 1)
    assert overlap > 0.9


def test_federated_step_learns():
    params = make_params(d=8, out=4)
    rng = np.random.default_rng(5)
    w_true = rng.normal(size=(8, 4)).astype(np.float32)
    x = rng.normal(size=(2, 64, 8)).astype(np.float32)
    y = x @ w_true
    batch = {"x": jnp.asarray(x), "y": jnp.asarray(y)}
    step = jax.jit(make_federated_train_step(
        quad_loss, ScbfConfig(upload_rate=0.5), lr=0.05))
    losses = []
    for _ in range(60):
        loss, params = step(params, batch)
        losses.append(float(loss))
    assert losses[-1] < 0.3 * losses[0]


def test_dp_gaussian_mechanism():
    """DP extension (paper §4 future work): clipping bounds sensitivity,
    noise lands only on revealed entries, accounting is sane."""
    import math
    from repro.core.privacy import (clip_tree, epsilon_for,
                                    gaussian_mechanism, sigma_for)
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.normal(size=(32, 16)) * 10, jnp.float32)}
    tree["w"] = tree["w"].at[:, :8].set(0.0)          # masked-out channels
    clipped, norm = clip_tree(tree, 1.0)
    total = float(jnp.sqrt(sum(jnp.sum(x ** 2) for x in
                               jax.tree_util.tree_leaves(clipped))))
    assert total <= 1.0 + 1e-5
    noised = gaussian_mechanism(tree, jax.random.PRNGKey(0),
                                noise_multiplier=1.0, max_norm=1.0)
    # masked entries remain exactly zero (nothing new is revealed)
    assert float(jnp.max(jnp.abs(noised["w"][:, :8]))) == 0.0
    assert float(jnp.std(noised["w"][:, 8:])) > 0.1   # noise present
    eps = epsilon_for(1.0, delta=1e-5, loops=10)
    assert 0 < eps < 200
    assert math.isclose(epsilon_for(sigma_for(1.0), loops=1), 1.0,
                        rel_tol=1e-6)
