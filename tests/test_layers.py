"""Layer-level correctness: SSD vs naive recurrence, MoE routing,
attention masks, RoPE."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models.layers import ModelCtx


def ssm_cfg(chunk=8):
    cfg = configs.smoke_variant(configs.get("mamba2-2.7b"))
    return dataclasses.replace(cfg, dtype="float32", ssm_chunk=chunk)


def naive_ssd(xh, dt, A, B_, C_):
    """Reference O(S·N·P) recurrence: h += dt*(B ⊗ x); y = C·h."""
    B, S, NH, P = xh.shape
    N = B_.shape[-1]
    h = np.zeros((B, NH, N, P), np.float32)
    ys = []
    for t in range(S):
        dA = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None, :])
        h = h * dA[:, :, None, None] + np.einsum(
            "bn,bh,bhp->bhnp", np.asarray(B_[:, t]),
            np.asarray(dt[:, t]), np.asarray(xh[:, t]))
        ys.append(np.einsum("bn,bhnp->bhp", np.asarray(C_[:, t]), h))
    return np.stack(ys, axis=1), h


def test_ssd_chunked_matches_naive_recurrence():
    rng = np.random.default_rng(0)
    B, S, NH, P, N = 2, 32, 3, 4, 5
    xh = jnp.asarray(rng.normal(size=(B, S, NH, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, size=(B, S, NH)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 1.5, size=(NH,)), jnp.float32)
    B_ = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    C_ = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    ctx = ModelCtx(cfg=ssm_cfg(), dtype=jnp.float32)
    for chunk in (8, 16, 32):
        y, h = M._ssd_chunked(xh, dt, A, B_, C_, chunk, ctx)
        y_ref, h_ref = naive_ssd(xh, dt, A, B_, C_)
        np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4,
                                   atol=2e-4)
        np.testing.assert_allclose(np.asarray(h), h_ref, rtol=2e-4,
                                   atol=2e-4)


def test_ssd_chunked_state_carry():
    """Running two half-sequences with carried state == one full pass."""
    rng = np.random.default_rng(1)
    B, S, NH, P, N = 1, 32, 2, 4, 3
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    xh, B_, C_ = mk(B, S, NH, P), mk(B, S, N), mk(B, S, N)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, size=(B, S, NH)), jnp.float32)
    A = jnp.asarray([-1.0, -0.5], jnp.float32)
    ctx = ModelCtx(cfg=ssm_cfg(), dtype=jnp.float32)
    y_full, h_full = M._ssd_chunked(xh, dt, A, B_, C_, 8, ctx)
    y1, h1 = M._ssd_chunked(xh[:, :16], dt[:, :16], A, B_[:, :16],
                            C_[:, :16], 8, ctx)
    y2, h2 = M._ssd_chunked(xh[:, 16:], dt[:, 16:], A, B_[:, 16:],
                            C_[:, 16:], 8, ctx, h0=h1)
    np.testing.assert_allclose(np.asarray(y_full[:, 16:]), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2),
                               rtol=2e-4, atol=2e-4)


def test_attention_causality():
    """Future tokens must not influence logits at position t."""
    cfg = dataclasses.replace(configs.smoke_variant(
        configs.get("qwen2-0.5b")), dtype="float32")
    ctx = ModelCtx(cfg=cfg, dtype=jnp.float32)
    p, _ = L.gqa_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    pos = jnp.arange(8)[None]
    y1, _ = L.gqa_apply(p, x, ctx, pos)
    x2 = x.at[:, 5:].set(0.0)
    y2, _ = L.gqa_apply(p, x2, ctx, pos)
    np.testing.assert_allclose(np.asarray(y1[:, :5]),
                               np.asarray(y2[:, :5]), rtol=1e-5, atol=1e-6)


def test_attention_chunked_equals_unchunked():
    cfg = dataclasses.replace(configs.smoke_variant(
        configs.get("qwen2-0.5b")), dtype="float32")
    p, _ = L.gqa_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    y1, _ = L.gqa_apply(p, x, ModelCtx(cfg=cfg, dtype=jnp.float32,
                                       q_chunk=4), pos)
    y2, _ = L.gqa_apply(p, x, ModelCtx(cfg=cfg, dtype=jnp.float32,
                                       q_chunk=64), pos)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)


def test_sliding_window_masks_old_tokens():
    cfg = dataclasses.replace(configs.smoke_variant(
        configs.get("qwen2-0.5b")), dtype="float32")
    ctx = ModelCtx(cfg=cfg, dtype=jnp.float32)
    p, _ = L.gqa_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, cfg.d_model))
    pos = jnp.arange(12)[None]
    yw, _ = L.gqa_apply(p, x, ctx, pos, window=4)
    # perturbing token 0 must not affect output at t >= 4
    x2 = x.at[:, 0].set(7.0)
    yw2, _ = L.gqa_apply(p, x2, ctx, pos, window=4)
    np.testing.assert_allclose(np.asarray(yw[:, 4:]),
                               np.asarray(yw2[:, 4:]), rtol=1e-5, atol=1e-6)


def test_rope_relative_property():
    """RoPE dot products depend only on relative positions."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 64))
    def score(dq, dk):
        qr = L.rope(q, jnp.array([[dq]]), 10000.0)
        kr = L.rope(k, jnp.array([[dk]]), 10000.0)
        return float(jnp.sum(qr * kr))
    assert score(5, 3) == pytest.approx(score(12, 10), rel=1e-4)
    assert score(5, 3) != pytest.approx(score(5, 0), rel=1e-3)


def test_rope_partial_fraction_leaves_tail():
    x = jnp.ones((1, 2, 1, 8))
    y = L.rope(x, jnp.array([[1, 2]]), 10000.0, fraction=0.5)
    np.testing.assert_allclose(np.asarray(y[..., 4:]), 1.0)
    assert not np.allclose(np.asarray(y[..., :4]), 1.0)


def test_moe_routes_to_topk_and_balances():
    cfg = dataclasses.replace(configs.smoke_variant(
        configs.get("llama4-maverick-400b-a17b")), dtype="float32",
        moe_capacity_factor=4.0)
    ctx = ModelCtx(cfg=cfg, dtype=jnp.float32)
    p, _ = MOE.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.1
    y = MOE.moe_apply(p, x, ctx)
    assert y.shape == x.shape
    assert not bool(jnp.isnan(y).any())
    # zero input -> shared expert path only, routed contribution ~0-ish
    y0 = MOE.moe_apply(p, jnp.zeros_like(x), ctx)
    assert float(jnp.max(jnp.abs(y0))) < 1.0


def test_moe_no_drop_matches_dense_computation():
    """With top-k == E and huge capacity, MoE == gate-weighted sum of all
    expert MLPs computed densely."""
    cfg = configs.smoke_variant(configs.get("llama4-maverick-400b-a17b"))
    cfg = dataclasses.replace(cfg, dtype="float32", num_experts=2,
                              experts_per_token=2, moe_capacity_factor=4.0,
                              num_shared_experts=0)
    ctx = ModelCtx(cfg=cfg, dtype=jnp.float32)
    p, _ = MOE.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model)) * 0.3
    y = MOE.moe_apply(p, x, ctx)
    # dense reference
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    ys = []
    for e in range(2):
        h = jax.nn.silu(xf @ p["wi"][e]) * (xf @ p["wg"][e])
        ys.append((h @ p["wo"][e]) * probs[:, e:e + 1])
    want = (ys[0] + ys[1]).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=5e-4, atol=5e-4)
