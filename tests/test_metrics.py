"""AUC-ROC / AUC-PR correctness: brute force + property tests."""
import jax.numpy as jnp
import numpy as np
import pytest

# degrades to per-test skips when hypothesis is missing, instead of a
# module-level collection error
from _hypothesis_compat import given, settings, st

from repro.metrics.auc import auc_pr, auc_roc, binary_cross_entropy


def brute_force_auc_roc(scores, labels):
    """Pairwise P(score_pos > score_neg) + 0.5 ties."""
    pos = scores[labels == 1]
    neg = scores[labels == 0]
    if len(pos) == 0 or len(neg) == 0:
        return None
    wins = (pos[:, None] > neg[None, :]).sum()
    ties = (pos[:, None] == neg[None, :]).sum()
    return (wins + 0.5 * ties) / (len(pos) * len(neg))


def test_auc_roc_perfect():
    s = jnp.array([0.9, 0.8, 0.2, 0.1])
    y = jnp.array([1.0, 1.0, 0.0, 0.0])
    assert float(auc_roc(s, y)) == pytest.approx(1.0)
    assert float(auc_pr(s, y)) == pytest.approx(1.0)


def test_auc_roc_random_vs_bruteforce():
    rng = np.random.default_rng(0)
    for _ in range(10):
        n = rng.integers(10, 200)
        scores = rng.normal(size=n).astype(np.float32)
        # inject ties
        scores = np.round(scores, 1)
        labels = rng.integers(0, 2, size=n).astype(np.float32)
        if labels.sum() in (0, n):
            labels[0] = 1 - labels[0]
        got = float(auc_roc(jnp.asarray(scores), jnp.asarray(labels)))
        want = brute_force_auc_roc(scores, labels)
        assert got == pytest.approx(float(want), abs=1e-5)


def test_auc_pr_matches_sklearn_formula():
    # hand-checked example (sklearn.average_precision_score == 0.8333...)
    s = jnp.array([0.9, 0.8, 0.7, 0.6])
    y = jnp.array([1.0, 0.0, 1.0, 0.0])
    assert float(auc_pr(s, y)) == pytest.approx(1 / 2 + 2 / 3 / 2, abs=1e-6)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(-500, 500), min_size=4, max_size=64),
       st.data())
def test_auc_roc_property_monotone_invariance(scores, data):
    labels = data.draw(st.lists(st.integers(0, 1), min_size=len(scores),
                                max_size=len(scores)))
    labels = np.asarray(labels, np.float32)
    if labels.sum() in (0, len(labels)):
        return
    # grid-valued scores: the affine transform below is exact in fp32,
    # so tie structure is preserved exactly
    s = np.asarray(scores, np.float32) / 8.0
    a1 = float(auc_roc(jnp.asarray(s), jnp.asarray(labels)))
    # strictly monotone transform preserves ROC-AUC
    a2 = float(auc_roc(jnp.asarray(2.0 * s + 1.0), jnp.asarray(labels)))
    assert a1 == pytest.approx(a2, abs=1e-5)
    # label flip + score negation preserves it too
    a3 = float(auc_roc(jnp.asarray(-s), jnp.asarray(1 - labels)))
    assert a1 == pytest.approx(a3, abs=1e-5)


def test_bce_matches_manual():
    logits = jnp.array([0.0, 2.0, -2.0])
    labels = jnp.array([1.0, 1.0, 0.0])
    p = 1 / (1 + np.exp(-np.asarray(logits)))
    want = -np.mean(np.asarray(labels) * np.log(p) +
                    (1 - np.asarray(labels)) * np.log(1 - p))
    assert float(binary_cross_entropy(logits, labels)) == \
        pytest.approx(float(want), abs=1e-6)
