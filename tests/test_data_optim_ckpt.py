"""Substrate tests: data pipeline, optimizers, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
from repro.data.medical import batch_iterator, federated_split, \
    generate_cohort
from repro.data.tokens import SyntheticTokenStream, synthetic_lm_batch
from repro.optim import adam, adamw, sgd
from repro.optim.sgd import apply_updates
from repro.optim.schedules import cosine_decay, linear_warmup_cosine


def test_cohort_shapes_and_stats():
    co = generate_cohort(num_admissions=2000, num_medicines=150, seed=1)
    assert co.x_train.shape == (1200, 150)
    assert co.x_val.shape[0] == 200
    assert co.x_test.shape[0] == 600
    assert set(np.unique(co.x_train)) <= {0.0, 1.0}
    prev = co.y_train.mean()
    assert 0.2 < prev < 0.8
    meds = co.x_train.sum(1).mean()
    assert 2.0 < meds < 20.0          # ~7 medicines per admission


def test_cohort_deterministic():
    a = generate_cohort(num_admissions=500, num_medicines=50, seed=7)
    b = generate_cohort(num_admissions=500, num_medicines=50, seed=7)
    np.testing.assert_array_equal(a.x_train, b.x_train)
    np.testing.assert_array_equal(a.y_test, b.y_test)


def test_batch_iterator_partitions():
    x = np.arange(100, dtype=np.float32)[:, None]
    y = np.zeros(100, np.float32)
    seen = [xb for xb, _ in batch_iterator(x, y, 32, seed=0)]
    assert len(seen) == 3
    assert all(b.shape == (32, 1) for b in seen)


def test_token_stream_learnable_structure():
    b = synthetic_lm_batch(8, 64, 100, seed=0)
    toks, tgt = b["tokens"], b["targets"]
    assert toks.shape == (8, 64)
    np.testing.assert_array_equal(toks[:, 1:], tgt[:, :-1])
    det = (toks * 31 + 17) % 100
    frac = (det[:, :-1] == toks[:, 1:]).mean()
    assert frac > 0.6                 # sticky Markov structure present


def quad(p):
    return jnp.sum((p["w"] - 3.0) ** 2)


@pytest.mark.parametrize("opt", [sgd(0.1), sgd(0.1, momentum=0.9),
                                 adam(0.1), adamw(0.1, weight_decay=0.001)])
def test_optimizers_converge(opt):
    params = {"w": jnp.zeros((4,))}
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(quad)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(quad(params)) < 1e-2


def test_schedules():
    lr = cosine_decay(1.0, 100)
    assert float(lr(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)
    lw = linear_warmup_cosine(1.0, 10, 110)
    assert float(lw(jnp.asarray(5))) == pytest.approx(0.5)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "d": [jnp.zeros((2,), jnp.int32), jnp.ones((1,))]}
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, tree, step=17)
    restored, step = load_checkpoint(path, tree)
    assert step == 17
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
