"""shapelint: the abstract shape/dtype/padding interpreter for the
bucketed-padding discipline (docs/STATIC_ANALYSIS.md §Shape lint).

Covers the PR 10 acceptance bars: every golden bad fixture (including
the verbatim PR 3 slot-padding and PR 9 admit-mask reductions) is
detected with the right rule code and nothing extra; the known-good
masked-reduction and host-accounting fixtures produce ZERO findings;
padding provenance is interprocedural (a padded array reduced by a
helper in another module is caught *inside the helper*); suppression
comments, baseline keys, and the committed shape baseline all gate
correctly; the CLI goes red on an injected SL001 (the CI lint job's
contract); and the merged ``python -m repro.analysis`` runner reports
all three linters under one exit code.
"""
import json
import os
import pathlib
import shutil
import subprocess
import sys
import textwrap
from collections import Counter

import pytest

from repro.analysis.report import Baseline
from repro.analysis.shapelint import run_paths
from repro.analysis.shaperules import SHAPE_RULES, run_shape_rules

REPO = pathlib.Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "shapelint"

# filename -> exactly which rules fire, and how often (no extras!)
BAD_EXPECT = {
    "sl001_padded_sum.py": {"SL001": 1},    # the PR 3 padder, verbatim
    "sl002_mean_over_bucket.py": {"SL002": 2},  # mean + Σvalid denom
    "sl003_f64_drift.py": {"SL003": 2},     # f64 creation + astype(float)
    "sl004_bool_arith.py": {"SL004": 2},    # sum(valid) + per_slot*valid
    "sl005_broadcast.py": {"SL005": 1},     # padded rank-2 × clean rank-1
    "sl006_unguarded_div.py": {"SL006": 2},  # unguarded Σvalid + log
    "sl001_interproc.py": {},               # finding lands in the helper
    "reduce_helper.py": {"SL001": 1},       # ...which is here
}


def _scan_bad():
    findings, _ = run_paths([str(FIXTURES / "bad")],
                            source_roots=[str(FIXTURES)])
    return findings


# ---------------------------------------------------------------------------
# golden fixtures
# ---------------------------------------------------------------------------

def test_bad_fixtures_detected_with_exact_rules():
    by_file = {name: Counter() for name in BAD_EXPECT}
    for f in _scan_bad():
        by_file[pathlib.Path(f.path).name][f.rule] += 1
    for name, got in by_file.items():
        assert got == Counter(BAD_EXPECT[name]), (name, dict(got))


def test_bad_fixture_coverage_is_all_rules():
    covered = {r for expect in BAD_EXPECT.values() for r in expect}
    assert covered == set(SHAPE_RULES)


def test_good_fixtures_zero_false_positives():
    findings, files = run_paths([str(FIXTURES / "good")],
                                source_roots=[str(FIXTURES)])
    assert files == 2
    assert findings == [], [f.render() for f in findings]


def test_provenance_is_interprocedural_across_modules():
    """The helper that sums its argument is clean in isolation; add the
    caller module that feeds it a padded buffer and the SL001 appears
    INSIDE the helper — proof padding provenance crossed the module
    boundary via the caller-arg → callee-param fixpoint."""
    alone, _ = run_paths([str(FIXTURES / "bad" / "reduce_helper.py")],
                         source_roots=[str(FIXTURES)])
    assert alone == [], [f.render() for f in alone]

    pair, _ = run_paths([str(FIXTURES / "bad" / "reduce_helper.py"),
                         str(FIXTURES / "bad" / "sl001_interproc.py")],
                        source_roots=[str(FIXTURES)])
    assert [(pathlib.Path(f.path).name, f.rule, f.symbol)
            for f in pair] == [("reduce_helper.py", "SL001", "total")]


# ---------------------------------------------------------------------------
# suppressions, baseline, key stability
# ---------------------------------------------------------------------------

_SL001_SNIPPET = textwrap.dedent("""
    import jax.numpy as jnp


    def _pad_slots(x, b):
        return x


    def tally(losses, b):
        padded = _pad_slots(losses, b)
        return jnp.sum(padded){suffix}
""")


def test_suppression_comment_silences(tmp_path):
    noisy = tmp_path / "noisy.py"
    noisy.write_text(_SL001_SNIPPET.format(suffix=""))
    assert len(run_paths([str(noisy)])[0]) == 1

    quiet = tmp_path / "quiet.py"
    quiet.write_text(_SL001_SNIPPET.format(
        suffix="  # shapelint: disable=SL001"))
    assert run_paths([str(quiet)])[0] == []

    # the wrong code does NOT silence it
    wrong = tmp_path / "wrong.py"
    wrong.write_text(_SL001_SNIPPET.format(
        suffix="  # shapelint: disable=SL004"))
    assert len(run_paths([str(wrong)])[0]) == 1


def test_finding_keys_survive_line_shifts(tmp_path):
    f = tmp_path / "m.py"
    f.write_text(_SL001_SNIPPET.format(suffix=""))
    before = run_paths([str(f)])[0]
    f.write_text("# a new header comment\n# another\n\n"
                 + _SL001_SNIPPET.format(suffix=""))
    after = run_paths([str(f)])[0]
    assert [x.key for x in after] == [x.key for x in before]
    assert after[0].line == before[0].line + 3   # line moved; key did not


def test_unknown_rule_codes_refused():
    from repro.analysis import astgraph
    graph = astgraph.build_graph([str(FIXTURES / "good")])
    with pytest.raises(ValueError, match="SL999"):
        run_shape_rules(graph, rules=["SL999"])


def test_committed_shape_baseline_matches_repo(monkeypatch):
    """The shipped gate: the repo is fully clean — the committed shape
    baseline is EMPTY and the full source tree lints to zero findings
    (every historical SL00x was fixed, not baselined)."""
    bl = Baseline.load(str(REPO / "analysis" / "shape_baseline.json"))
    assert bl.entries == {}, sorted(bl.entries)
    monkeypatch.chdir(REPO)   # relative paths, as the CI lint job runs
    findings, files = run_paths(["src", "benchmarks", "examples"])
    assert files > 50
    assert findings == [], [f.render() for f in findings]


# ---------------------------------------------------------------------------
# the CLI — the CI lint job's exact contract
# ---------------------------------------------------------------------------

def _run_cli(module, args, cwd):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", module, *args],
        env=env, cwd=cwd, capture_output=True, text=True, timeout=120)


def test_cli_gate_fails_on_injected_sl001(tmp_path):
    tree = tmp_path / "pkg"
    tree.mkdir()
    shutil.copy(FIXTURES / "good" / "masked_reduction.py", tree)
    out = _run_cli("repro.analysis.shapelint",
                   [str(tree), "--baseline", ""], cwd=tmp_path)
    assert out.returncode == 0, out.stdout + out.stderr

    # inject the SL001 regression: the gate must go red
    (tree / "regress.py").write_text(_SL001_SNIPPET.format(suffix=""))
    out = _run_cli("repro.analysis.shapelint",
                   [str(tree), "--baseline", ""], cwd=tmp_path)
    assert out.returncode == 1
    assert "SL001" in out.stdout and "regress.py" in out.stdout

    # accepting into a baseline brings it back to green...
    bl = tmp_path / "baseline.json"
    out = _run_cli("repro.analysis.shapelint",
                   [str(tree), "--baseline", str(bl), "--write-baseline"],
                   cwd=tmp_path)
    assert out.returncode == 0
    out = _run_cli("repro.analysis.shapelint",
                   [str(tree), "--baseline", str(bl)], cwd=tmp_path)
    assert out.returncode == 0
    # ...and a SECOND regression still fails against that baseline
    (tree / "regress2.py").write_text(_SL001_SNIPPET.format(suffix=""))
    out = _run_cli("repro.analysis.shapelint",
                   [str(tree), "--baseline", str(bl)], cwd=tmp_path)
    assert out.returncode == 1 and "regress2.py" in out.stdout


def test_stale_baseline_entries_reported(tmp_path):
    """A baseline key that no longer matches any finding is flagged, so
    fixed findings cannot silently linger in the accepted set."""
    tree = tmp_path / "pkg"
    tree.mkdir()
    (tree / "regress.py").write_text(_SL001_SNIPPET.format(suffix=""))
    bl = tmp_path / "baseline.json"
    out = _run_cli("repro.analysis.shapelint",
                   [str(tree), "--baseline", str(bl), "--write-baseline"],
                   cwd=tmp_path)
    assert out.returncode == 0

    # fix the finding (slice back to the live prefix): its baseline
    # entry is now stale and reported
    (tree / "regress.py").write_text(_SL001_SNIPPET.format(suffix="")
        .replace("def tally(losses, b):", "def tally(losses, b, p_count):")
        .replace("jnp.sum(padded)", "jnp.sum(padded[:p_count])"))
    out = _run_cli("repro.analysis.shapelint",
                   [str(tree), "--baseline", str(bl)], cwd=tmp_path)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "1 stale baseline" in out.stdout


def test_merged_runner_includes_shapelint(tmp_path):
    """``python -m repro.analysis`` runs shapelint alongside tracelint
    and privlint; --shape scopes the run to the SL rules only."""
    tree = tmp_path / "pkg"
    tree.mkdir()
    (tree / "regress.py").write_text(_SL001_SNIPPET.format(suffix=""))
    out = _run_cli("repro.analysis",
                   [str(tree), "--trace-baseline", "",
                    "--privacy-baseline", "", "--shape-baseline", "",
                    "--json-out", "-"],
                   cwd=tmp_path)
    assert out.returncode == 1
    head, _, tail = out.stdout.partition("\n}\n")
    data = json.loads(head + "\n}")
    assert set(data["tools"]) == {"tracelint", "privlint", "shapelint"}
    assert [f["rule"] for f in data["tools"]["shapelint"]["new"]] == \
        ["SL001"]
    assert data["tools"]["tracelint"]["new"] == []
    assert data["tools"]["privlint"]["new"] == []
    assert "shapelint:" in tail

    # --shape runs shapelint only, and still gates
    out = _run_cli("repro.analysis",
                   [str(tree), "--shape", "--shape-baseline", ""],
                   cwd=tmp_path)
    assert out.returncode == 1
    assert "shapelint:" in out.stdout
    assert "tracelint:" not in out.stdout
    assert "privlint:" not in out.stdout
