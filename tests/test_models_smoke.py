"""Per-architecture smoke tests (deliverable f): reduced same-family
variants, one train + prefill + decode step on CPU, shape + NaN checks,
plus decode-vs-full-forward consistency where MoE dropping permits."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model_zoo
from repro.models import transformer as T

ARCHS = configs.ASSIGNED


def make_batch(cfg, b=2, s=16):
    batch = {"tokens": jnp.ones((b, s), jnp.int32),
             "targets": jnp.ones((b, s), jnp.int32)}
    if cfg.encoder_layers:
        batch["audio_embeds"] = jnp.full(
            (b, cfg.encoder_seq, cfg.d_model), 0.01, jnp.bfloat16)
    elif cfg.frontend == "vision":
        batch["image_embeds"] = jnp.full(
            (b, cfg.num_patch_tokens, cfg.d_model), 0.01, jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = configs.smoke_variant(configs.get(arch))
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    assert not cfg.num_experts or cfg.num_experts <= 4
    bundle = model_zoo.build(cfg)
    params, axes = bundle.init(jax.random.PRNGKey(0))
    # axes tree mirrors params tree
    assert (jax.tree_util.tree_structure(params) ==
            jax.tree_util.tree_structure(axes))
    batch = make_batch(cfg)
    loss, new_params = jax.jit(bundle.train_step)(params, batch)
    assert np.isfinite(float(loss))
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert not bool(jnp.isnan(leaf.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = configs.smoke_variant(configs.get(arch))
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, s=8)
    batch.pop("targets")
    batch["caches"] = bundle.make_cache(2, 16)
    logits, caches = jax.jit(bundle.prefill_step)(params, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    db = {"token": jnp.ones((2, 1), jnp.int32),
          "pos": jnp.full((2, 1), 8, jnp.int32), "caches": caches}
    logits2, _ = jax.jit(bundle.decode_step)(params, db)
    assert logits2.shape == (2, cfg.vocab_size)
    assert not bool(jnp.isnan(logits2.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-2.7b",
                                  "deepseek-v2-236b", "chatglm3-6b",
                                  "whisper-medium",
                                  "llama-3.2-vision-11b"])
def test_decode_matches_full_forward(arch):
    """Prefill+decode through the ring cache must reproduce the full
    forward logits (fp32, no-drop MoE)."""
    cfg = configs.smoke_variant(configs.get(arch))
    cfg = dataclasses.replace(cfg, dtype="float32",
                              moe_capacity_factor=8.0)
    bundle = model_zoo.build(cfg, remat=False)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    batch = make_batch(cfg, s=12)
    aux = {k: v for k, v in batch.items()
           if k in ("audio_embeds", "image_embeds")}
    aux = {k: v.astype(jnp.float32) for k, v in aux.items()}
    full_aux = None
    if cfg.encoder_layers:
        full_aux = T.encode(params, cfg, aux["audio_embeds"], bundle.ctx)
    elif cfg.frontend == "vision":
        full_aux = aux["image_embeds"]
    h, _ = T.forward_hidden(params, cfg, toks, bundle.ctx, aux=full_aux)
    full_logits = T.logits_from_hidden(params, cfg, h)

    pb = {"tokens": toks[:, :8], "caches": bundle.make_cache(2, 16), **aux}
    lg, caches = bundle.prefill_step(params, pb)
    errs = [float(jnp.max(jnp.abs(lg - full_logits[:, 7])))]
    for t in range(8, 12):
        db = {"token": toks[:, t:t + 1],
              "pos": jnp.full((2, 1), t, jnp.int32), "caches": caches}
        lg, caches = bundle.decode_step(params, db)
        errs.append(float(jnp.max(jnp.abs(lg - full_logits[:, t]))))
    assert max(errs) < 2e-4, errs


def test_sliding_window_ring_buffer():
    """Decode with a window smaller than the sequence: ring buffer wraps
    and old positions are masked out."""
    cfg = configs.smoke_variant(configs.get("qwen2-0.5b"))
    cfg = dataclasses.replace(cfg, dtype="float32")
    bundle = model_zoo.build(cfg, remat=False)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0,
                              cfg.vocab_size)
    window = 4
    # reference: full forward with window mask
    h, _ = T.forward_hidden(params, cfg, toks, bundle.ctx, window=window)
    full_logits = T.logits_from_hidden(params, cfg, h)
    # decode token-by-token with cache length == window
    caches = {"layers": bundle.make_cache(1, window)}
    errs = []
    for t in range(12):
        db = {"token": toks[:, t:t + 1],
              "pos": jnp.full((1, 1), t, jnp.int32), "caches": caches}
        lg, new_layers = jax.jit(
            lambda p, b: bundle.decode_step(p, b, window=window)
        )(params, db)
        caches = {"layers": new_layers["layers"]}
        errs.append(float(jnp.max(jnp.abs(lg - full_logits[:, t]))))
    assert max(errs) < 2e-4, errs


def test_param_counts_match_analytic():
    """Analytic param_count agrees with actual initialised trees."""
    for arch in ["qwen2-0.5b", "qwen1.5-0.5b", "chatglm3-6b"]:
        cfg = configs.smoke_variant(configs.get(arch))
        bundle = model_zoo.build(cfg)
        params, _ = bundle.init(jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
        want = cfg.param_count()
        assert actual == pytest.approx(want, rel=0.02), (arch, actual, want)


def test_int8_kv_cache_close_to_fp():
    """Quantized KV cache decode stays within ~1% of the fp cache path
    (beyond-paper §Perf optimization)."""
    cfg = configs.smoke_variant(configs.get("qwen2-0.5b"))
    cfg = dataclasses.replace(cfg, dtype="float32")
    b_fp = model_zoo.build(cfg, remat=False)
    b_q = model_zoo.build(cfg, remat=False, kv_quant=True)
    params, _ = b_fp.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                              cfg.vocab_size)

    def run(bundle):
        caches = {"layers": bundle.make_cache(2, 16)}
        outs = []
        for t in range(10):
            db = {"token": toks[:, t:t + 1],
                  "pos": jnp.full((2, 1), t, jnp.int32), "caches": caches}
            lg, new_layers = bundle.decode_step(params, db)
            caches = {"layers": new_layers["layers"]}
            outs.append(lg)
        return jnp.stack(outs, 1)

    lf = run(b_fp)
    lq = run(b_q)
    # logits track closely and the argmax token rarely flips
    rel = float(jnp.max(jnp.abs(lf - lq)) / (jnp.max(jnp.abs(lf)) + 1e-9))
    assert rel < 0.05, rel
    agree = float(jnp.mean(jnp.argmax(lf, -1) == jnp.argmax(lq, -1)))
    assert agree > 0.8, agree
