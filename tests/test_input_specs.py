"""input_specs: every (arch × shape) pair yields well-formed
ShapeDtypeStructs — the contract the dry-run lowers against.
Pure metadata, no allocation."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.config import INPUT_SHAPES
from repro.models import model_zoo

PAIRS = [(a, s) for a in configs.ASSIGNED for s in INPUT_SHAPES]


@pytest.mark.parametrize("arch,shape", PAIRS)
def test_input_specs_shapes(arch, shape):
    cfg = configs.get(arch)
    bundle = model_zoo.build(cfg)
    sc = INPUT_SHAPES[shape]
    window = 8192 if (sc.name == "long_500k"
                      and not cfg.supports_long_decode_natively) else 0
    specs = bundle.input_specs(sc, window=window)
    leaves = jax.tree_util.tree_leaves(specs)
    assert all(isinstance(x, jax.ShapeDtypeStruct) for x in leaves)

    if sc.kind == "train":
        assert specs["tokens"].shape == (sc.global_batch, sc.seq_len)
        assert specs["targets"].dtype == jnp.int32
    elif sc.kind == "prefill":
        assert specs["tokens"].shape == (sc.global_batch, sc.seq_len)
        assert "caches" in specs
    else:
        assert specs["token"].shape == (sc.global_batch, 1)
        assert specs["pos"].shape == (sc.global_batch, 1)
        # cache length: full seq, or the sliding window for dense archs
        kpos = [x for p, x in
                jax.tree_util.tree_flatten_with_path(specs["caches"])[0]
                if "kpos" in str(p[-1])]
        if kpos:
            expect = window or sc.seq_len
            assert kpos[0].shape[-1] == expect

    # modality stubs present exactly for audio/vlm
    has_audio = any("audio" in str(p)
                    for p, _ in jax.tree_util.tree_flatten_with_path(specs)[0])
    assert has_audio == (cfg.encoder_layers > 0 and sc.kind == "train"
                         or cfg.encoder_layers > 0 and sc.kind == "prefill") \
        or cfg.encoder_layers == 0 or sc.kind == "decode"


@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_long500k_policy(arch):
    """Sub-quadratic archs decode 500k natively; dense archs need the
    sliding-window variant (DESIGN.md §4)."""
    cfg = configs.get(arch)
    if cfg.family in ("ssm", "hybrid"):
        assert cfg.supports_long_decode_natively
    else:
        assert not cfg.supports_long_decode_natively


def test_assigned_configs_match_brief():
    """Spot-check the pinned numbers from the assignment table."""
    c = configs.get("deepseek-v2-236b")
    assert (c.num_layers, c.d_model, c.num_heads, c.vocab_size) == \
        (60, 5120, 128, 102400)
    assert (c.num_experts, c.experts_per_token, c.num_shared_experts,
            c.kv_lora_rank) == (160, 6, 2, 512)
    c = configs.get("qwen2.5-32b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (64, 5120, 40, 8, 27648, 152064)
    assert c.qkv_bias
    c = configs.get("jamba-1.5-large-398b")
    assert (c.attention_every, c.num_experts, c.experts_per_token) == \
        (16 // 2, 16, 2)
    c = configs.get("mamba2-2.7b")
    assert (c.num_layers, c.d_model, c.ssm_state, c.vocab_size) == \
        (64, 2560, 128, 50280)
    c = configs.get("llama-3.2-vision-11b")
    assert (c.cross_attn_every, c.num_kv_heads, c.d_ff) == (5, 8, 14336)
    c = configs.get("chatglm3-6b")
    assert c.rope_fraction == 0.5 and c.num_kv_heads == 2
    c = configs.get("whisper-medium")
    assert c.encoder_layers == 24 and c.vocab_size == 51865
    c = configs.get("llama4-maverick-400b-a17b")
    assert c.num_experts == 128 and c.experts_per_token == 1
    c = configs.get("qwen2-0.5b")
    assert c.d_model == 896 and c.num_kv_heads == 2
    c = configs.get("qwen1.5-0.5b")
    assert c.d_model == 1024 and c.num_kv_heads == 16


def test_param_counts_plausible():
    """Analytic totals land near the models' nameplate sizes."""
    expect = {
        "deepseek-v2-236b": (200e9, 280e9),
        "qwen2.5-32b": (28e9, 36e9),
        "jamba-1.5-large-398b": (300e9, 450e9),
        "llama4-maverick-400b-a17b": (330e9, 480e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "chatglm3-6b": (5.5e9, 7.5e9),
        "llama-3.2-vision-11b": (8e9, 12e9),
        "qwen2-0.5b": (0.3e9, 0.7e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.1f}B not in [{lo/1e9}-{hi/1e9}]"
