"""Purity properties of the simulated clock and fault injector (S3).

The chaos machinery must be a *pure function of seed + config*: same
inputs → bit-identical traces, no dependence on call order, cohort
composition, or how many rounds are fused per chunk.  That is what
makes a chaos run replayable from its CLI spec and what lets the fused
driver plan faults for a whole chunk up front.

Hypothesis drives the seed/config space when installed; the suite
degrades to clean skips without it (tests/_hypothesis_compat), and the
deterministic spot checks below always run.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.config import ClockConfig, FaultConfig, FedConfig
from repro.fed.clock import SimClock
from repro.fed.faults import Resilience, FaultInjector
from repro.fed.scheduler import make_scheduler

CLOCK = ClockConfig(enabled=True, deadline_quantile=0.8, hetero_sigma=0.8,
                    diurnal_amplitude=0.3, availability_mean=0.9)


# ---------------------------------------------------------------------------
# always-run determinism spot checks
# ---------------------------------------------------------------------------

def test_clock_trace_identical_across_instances():
    a = SimClock(16, CLOCK, seed=5)
    b = SimClock(16, CLOCK, seed=5)
    for r in range(6):
        np.testing.assert_array_equal(a.latencies(r), b.latencies(r))
        np.testing.assert_array_equal(a.available(r), b.available(r))
        a.advance(100.0)
        b.advance(100.0)
    assert a.now == b.now


def test_clock_latencies_are_call_order_free():
    """latencies(r, attempt) is keyed by (seed, round, attempt) — not
    by how many draws happened before it."""
    a = SimClock(8, CLOCK, seed=1)
    b = SimClock(8, CLOCK, seed=1)
    fwd = [a.latencies(r) for r in range(5)]
    rev = [b.latencies(r) for r in reversed(range(5))]
    for r in range(5):
        np.testing.assert_array_equal(fwd[r], rev[4 - r])


def test_injector_trace_is_call_order_free():
    cfg = FaultConfig(enabled=True, seed=3, crash_rate=0.2,
                      net_fail_rate=0.2, duplicate_rate=0.2,
                      bitflip_rate=0.2, nan_rate=0.2, poison_rate=0.2)
    part = np.arange(12)
    a = FaultInjector(12, cfg)
    b = FaultInjector(12, cfg)
    fwd = [a.round_faults(r, part) for r in range(5)]
    rev = [b.round_faults(r, part) for r in reversed(range(5))]
    for r in range(5):
        f, g = fwd[r], rev[4 - r]
        np.testing.assert_array_equal(f.crashed, g.crashed)
        np.testing.assert_array_equal(f.net_lost, g.net_lost)
        np.testing.assert_array_equal(f.net_tries, g.net_tries)
        np.testing.assert_array_equal(f.corrupt, g.corrupt)
        np.testing.assert_array_equal(f.duplicated, g.duplicated)


def test_resilience_plan_sequence_replays():
    """The full plan_round sequence — cohorts, fault verdicts, retry
    counts — is identical between two independent stacks, which is
    exactly why the fused driver may plan a whole chunk ahead."""
    fed = FedConfig(sample_fraction=0.8,
                    faults=FaultConfig(enabled=True, seed=9, crash_rate=0.2,
                                       nan_rate=0.3),
                    clock=CLOCK, min_valid_participants=2, round_retries=2,
                    max_update_norm=10.0)

    def stack():
        clock = SimClock(10, CLOCK, seed=4)
        sched = make_scheduler(fed, 10, seed=4, clock=clock)
        inj = FaultInjector(10, fed.faults)
        return Resilience(sched, clock, inj, fed)

    ra, rb = stack(), stack()
    for loop in range(8):
        aa = ra.plan_round(loop, loop)
        ab = rb.plan_round(loop, loop)
        np.testing.assert_array_equal(aa.plan.participants,
                                      ab.plan.participants)
        np.testing.assert_array_equal(aa.corrupt, ab.corrupt)
        np.testing.assert_array_equal(aa.will_reject, ab.will_reject)
        assert aa.quorum_ok == ab.quorum_ok
        assert aa.attempts == ab.attempts


# ---------------------------------------------------------------------------
# hypothesis: the same properties over the seed/config space
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 32), st.integers(0, 50),
       st.integers(0, 3))
def test_clock_latency_pure_function_of_seed(seed, K, round_index, attempt):
    a = SimClock(K, CLOCK, seed=seed).latencies(round_index, attempt)
    b = SimClock(K, CLOCK, seed=seed).latencies(round_index, attempt)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (K,) and (a >= 0).all() and np.isfinite(a).all()


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 32), st.integers(0, 50))
def test_clock_availability_pure_and_bounded(seed, K, round_index):
    a = SimClock(K, CLOCK, seed=seed)
    b = SimClock(K, CLOCK, seed=seed)
    a.advance(123.0)
    b.advance(123.0)
    av_a, av_b = a.available(round_index), b.available(round_index)
    np.testing.assert_array_equal(av_a, av_b)
    assert av_a.dtype == bool and av_a.shape == (K,)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 24), st.integers(0, 50),
       st.floats(0.0, 0.3), st.floats(0.0, 0.3), st.floats(0.0, 0.3),
       st.data())
def test_injector_subset_consistent(seed, K, round_index, crash, nan, dup,
                                    data):
    """A client's fate is indexed by its id: any sampled sub-cohort
    observes exactly the slice of the full-cohort trace."""
    cfg = FaultConfig(enabled=True, seed=seed, crash_rate=crash,
                      net_fail_rate=0.2, nan_rate=nan, duplicate_rate=dup)
    inj = FaultInjector(K, cfg)
    full = inj.round_faults(round_index, np.arange(K))
    ids = sorted(data.draw(st.sets(st.integers(0, K - 1), min_size=1)))
    sub = inj.round_faults(round_index, np.array(ids))
    for j, k in enumerate(ids):
        assert sub.crashed[j] == full.crashed[k]
        assert sub.net_lost[j] == full.net_lost[k]
        assert sub.net_tries[j] == full.net_tries[k]
        assert sub.net_delay_s[j] == full.net_delay_s[k]
        assert sub.corrupt[j] == full.corrupt[k]
        assert sub.duplicated[j] == full.duplicated[k]


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.integers(0, 50), st.integers(0, 2))
def test_injector_attempts_draw_fresh_faults(seed, round_index, attempt):
    """Retry attempts re-draw the fault trace (attempt is part of the
    rng key) — otherwise a deterministic crash set could never clear a
    quorum retry — while the same attempt always replays identically."""
    cfg = FaultConfig(enabled=True, seed=seed, crash_rate=0.5)
    inj = FaultInjector(16, cfg)
    part = np.arange(16)
    a = inj.round_faults(round_index, part, attempt)
    b = inj.round_faults(round_index, part, attempt)
    np.testing.assert_array_equal(a.crashed, b.crashed)
