"""APoZ pruning: scores, budgets, structural surgery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pruning
from repro.kernels.apoz import (apoz_scorer_compile_count,
                                reset_apoz_scorer_compile_count)
from repro.models.mlp_net import init_mlp, mlp_forward, mlp_activations


def test_apoz_scores_manual():
    params = init_mlp((8, 4, 1), jax.random.PRNGKey(0))
    x = np.random.default_rng(0).random((100, 8)).astype(np.float32)
    scores = pruning.apoz_scores(params, x, batch_size=32)
    acts = mlp_activations(params, jnp.asarray(x))
    want = np.mean(np.asarray(acts[0]) == 0, axis=0)
    np.testing.assert_allclose(scores[0], want, atol=1e-6)


def test_apoz_scorer_compiles_once_across_calls():
    """The scorer used to rebuild ``jax.jit(lambda ...)`` per call, so
    every pruning step retraced the activation pass (the PR 1
    ``_evaluate`` defect class).  It is now one module-level jit:
    repeated calls at the same geometry must not grow the cache."""
    params = init_mlp((8, 6, 3, 1), jax.random.PRNGKey(0))
    x = np.random.default_rng(0).random((96, 8)).astype(np.float32)
    reset_apoz_scorer_compile_count()
    first = pruning.apoz_scores(params, x, batch_size=32)
    after_one = apoz_scorer_compile_count()
    for _ in range(4):
        again = pruning.apoz_scores(params, x, batch_size=32)
    assert apoz_scorer_compile_count() == after_one
    for a, b in zip(first, again):
        np.testing.assert_array_equal(a, b)
    # a genuinely new geometry is allowed its own (single) compile
    pruning.apoz_scores(init_mlp((8, 5, 1), jax.random.PRNGKey(1)), x,
                        batch_size=32)
    grown = apoz_scorer_compile_count()
    pruning.apoz_scores(init_mlp((8, 5, 1), jax.random.PRNGKey(2)), x,
                        batch_size=32)
    assert apoz_scorer_compile_count() == grown


def test_apoz_scores_empty_validation_set_raises():
    """``x_val`` with zero rows used to crash with a ``TypeError`` on
    the unbound accumulator; it must be a clear ValueError instead."""
    params = init_mlp((8, 4, 1), jax.random.PRNGKey(0))
    empty = np.zeros((0, 8), np.float32)
    with pytest.raises(ValueError, match="non-empty validation"):
        pruning.apoz_scores(params, empty)


def test_apoz_scores_smaller_than_one_batch():
    """A validation set smaller than one batch (and an uneven tail)
    must weight into the mean by true example counts."""
    params = init_mlp((8, 4, 1), jax.random.PRNGKey(0))
    x = np.random.default_rng(1).random((7, 8)).astype(np.float32)
    scores = pruning.apoz_scores(params, x, batch_size=32)
    want = np.mean(np.asarray(mlp_activations(params, jnp.asarray(x))[0])
                   == 0, axis=0)
    np.testing.assert_allclose(scores[0], want, atol=1e-6)
    # uneven tail: 10 = 4 + 4 + 2
    x10 = np.random.default_rng(2).random((10, 8)).astype(np.float32)
    scores10 = pruning.apoz_scores(params, x10, batch_size=4)
    want10 = np.mean(np.asarray(mlp_activations(params,
                                                jnp.asarray(x10))[0]) == 0,
                     axis=0)
    np.testing.assert_allclose(scores10[0], want10, atol=1e-6)


def test_plan_prune_budget_and_floor():
    apoz = [np.array([0.9, 0.8, 0.1, 0.0]), np.array([0.95, 0.2])]
    keep = pruning.plan_prune(apoz, prune_rate=0.5, already_pruned=0,
                              original_hidden=6, prune_total=1.0)
    kept_total = sum(len(k) for k in keep)
    assert kept_total == 6 - 3                   # budget = 0.5*6 = 3
    assert all(len(k) >= 1 for k in keep)        # never empties a layer
    # highest-APoZ neurons went first
    assert 0 not in keep[0] or 1 not in keep[0]


def test_plan_prune_respects_total():
    apoz = [np.linspace(1, 0, 10)]
    keep = pruning.plan_prune(apoz, prune_rate=0.5, already_pruned=4,
                              original_hidden=10, prune_total=0.5)
    # only 1 more allowed (total 5, already 4)
    assert len(keep[0]) == 9


def test_apply_structure_shapes_and_forward():
    params = init_mlp((8, 6, 4, 1), jax.random.PRNGKey(0))
    keep = [np.array([0, 2, 5]), np.array([1, 3])]
    new = pruning.apply_structure(params, keep)
    assert new[0]["w"].shape == (8, 3)
    assert new[1]["w"].shape == (3, 2)
    assert new[2]["w"].shape == (2, 1)
    x = jnp.ones((5, 8))
    y = mlp_forward(new, x)
    assert y.shape == (5,)
    assert not bool(jnp.isnan(y).any())


def test_plan_prune_budget_is_theta_of_remaining():
    """The per-step budget is θ of the REMAINING neurons (paper §2.1
    and the module docstring) — it used to be θ of the original count.
    Pin the full cumulative trajectory: geometric decay of the step
    size, the prune_total cap, and the stable tie rule."""
    rng = np.random.default_rng(0)
    apoz = [rng.random(64)]
    removed_per_step, already = [], 0
    for _ in range(6):
        # plan_prune plans one step from a fresh (compacted) view;
        # emulate the between-loop compaction by shrinking the scores
        keep = pruning.plan_prune(apoz, prune_rate=0.25,
                                  already_pruned=already,
                                  original_hidden=64, prune_total=0.5)
        removed_per_step.append(apoz[0].shape[0] - keep[0].size)
        apoz = [apoz[0][keep[0]]]
        already = 64 - apoz[0].shape[0]
    # θ=0.25 of remaining (16, 12, then the prune_total cap bites: only
    # 32 may ever go) — the old θ-of-original rule would have removed
    # [16, 16, 0, ...] instead
    assert removed_per_step == [16, 12, 4, 0, 0, 0]
    assert already == 32                      # exactly prune_total * 64


def test_plan_prune_tie_behavior_is_deterministic():
    """Equal APoZ scores break ties stably: earliest layer, lowest
    index first — and the never-empty-a-layer rule skips a layer that
    is down to one neuron, spending the budget elsewhere."""
    apoz = [np.full(3, 0.5), np.full(4, 0.5)]
    keep = pruning.plan_prune(apoz, prune_rate=1.0, already_pruned=0,
                              original_hidden=7, prune_total=1.0)
    # budget 7, but each layer keeps one: layer 0 keeps its LAST
    # neuron (0, 1 removed first by the stable order), likewise layer 1
    assert keep[0].tolist() == [2]
    assert keep[1].tolist() == [3]
    # deterministic across calls
    keep2 = pruning.plan_prune([a.copy() for a in apoz], 1.0, 0, 7, 1.0)
    assert [k.tolist() for k in keep2] == [k.tolist() for k in keep]


def test_update_keep_masks_matches_plan_prune_trajectory():
    """Mask mode and reshape mode share the greedy core: for the same
    APoZ values the masked removal trajectory equals the compacted one
    (masked scores at pruned positions must NOT win again even though
    their activations read APoZ 1.0)."""
    rng = np.random.default_rng(3)
    full = [rng.random(12), rng.random(6)]
    # reshape-style: compact after each step
    comp = [a.copy() for a in full]
    keep_ids = [np.arange(12), np.arange(6)]
    already = 0
    for _ in range(3):
        kl = pruning.plan_prune(comp, 0.2, already, 18, 0.6)
        keep_ids = [g[k] for g, k in zip(keep_ids, kl)]
        comp = [a[k] for a, k in zip(comp, kl)]
        already = 18 - sum(a.shape[0] for a in comp)
    # mask-style: full geometry, APoZ of pruned forced to 1.0 (as the
    # masked activations would report) — the keep guard must ignore it
    masks = [np.ones(12, bool), np.ones(6, bool)]
    for _ in range(3):
        apoz_masked = [np.where(m, a, 1.0) for a, m in zip(full, masks)]
        masks = pruning.update_keep_masks(apoz_masked, masks, 0.2, 0.6)
    assert [np.where(m)[0].tolist() for m in masks] == \
        [k.tolist() for k in keep_ids]


def test_expand_payloads_roundtrip():
    """Effective-geometry payloads decode back to the full geometry
    with values on the original coordinates (the server-side inverse
    of mask-mode emission)."""
    from repro.comm import wire
    params = init_mlp((5, 6, 4, 1), jax.random.PRNGKey(0))
    keep = [np.array([0, 2, 5]), np.array([1, 3])]
    rng = np.random.default_rng(0)
    full = tuple({"w": rng.random(p["w"].shape).astype(np.float32),
                  "b": rng.random(p["b"].shape).astype(np.float32)}
                 for p in params)
    # zero the pruned coordinates (as masked training guarantees)
    masked = pruning.apply_structure(full, keep)
    eff_payload = wire.encode(masked)
    (exp,) = pruning.expand_payloads([eff_payload], keep, params)
    # wire bytes are the shipped (effective) ones
    assert exp.nbytes == eff_payload.nbytes
    dec = wire.decode(exp)
    # decoded full-geometry delta compacts back to exactly the original
    back = pruning.apply_structure(dec, keep)
    for a, b in zip(back, masked):
        np.testing.assert_array_equal(np.asarray(a["w"]),
                                      np.asarray(b["w"]))
        np.testing.assert_array_equal(np.asarray(a["b"]),
                                      np.asarray(b["b"]))
    # and everything off the kept coordinates decodes to exact zeros
    dead = np.asarray(dec[0]["w"])[:, [1, 3, 4]]
    assert not dead.any()


def test_pruner_deactivates_when_no_progress_possible():
    """``Pruner.active`` must go False as soon as a step can no longer
    remove anything — zero-truncated budget or the never-empty-a-layer
    stall — otherwise the fused driver would loop single-round chunks
    (and APoZ sweeps) forever and compaction would never fire."""
    x_val = np.random.default_rng(0).random((16, 4)).astype(np.float32)
    # budget truncates to zero: int(0.1 * 8) == 0, limit 4 never reached
    p = pruning.Pruner(init_mlp((4, 8, 1), jax.random.PRNGKey(0)), x_val,
                       prune_rate=0.1, prune_total=0.5, impl="mask")
    assert not p.active
    assert not p.should_compact               # nothing was ever pruned
    # never-empty-a-layer stall: (2, 2) hidden, limit 3, but only one
    # neuron per layer may ever go — the second step removes nothing
    params = init_mlp((4, 2, 2, 1), jax.random.PRNGKey(1))
    p2 = pruning.Pruner(params, x_val, prune_rate=1.0, prune_total=0.9,
                        impl="mask")
    assert p2.active
    p2.step(params)
    assert p2.pruned_so_far == 2              # one per layer
    assert p2.active                          # budget 1 still open
    p2.step(params)
    assert p2.pruned_so_far == 2              # stalled below the limit
    assert not p2.active
    assert p2.should_compact                  # pruning is finished
    # reshape mode stalls identically, without an identity re-slice
    p3 = pruning.Pruner(params, x_val, prune_rate=1.0, prune_total=0.9,
                        impl="reshape")
    out = p3.step(params)
    out2 = p3.step(out)
    assert out2 is out                        # no-op step returns as-is
    assert not p3.active


def test_pruning_dead_neurons_preserves_function():
    """Pruning neurons whose outgoing weights are zero must not change
    the network function."""
    params = list(init_mlp((8, 6, 4, 1), jax.random.PRNGKey(0)))
    dead = [1, 4]
    w1 = params[1]["w"].at[dead, :].set(0.0)
    params[1] = {"w": w1, "b": params[1]["b"]}
    params = tuple(params)
    keep = [np.array([i for i in range(6) if i not in dead]),
            np.arange(4)]
    pruned = pruning.apply_structure(params, keep)
    x = jnp.asarray(np.random.default_rng(0).random((20, 8)), jnp.float32)
    np.testing.assert_allclose(np.asarray(mlp_forward(params, x)),
                               np.asarray(mlp_forward(pruned, x)),
                               rtol=1e-5, atol=1e-6)
