"""APoZ pruning: scores, budgets, structural surgery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pruning
from repro.models.mlp_net import init_mlp, mlp_forward, mlp_activations


def test_apoz_scores_manual():
    params = init_mlp((8, 4, 1), jax.random.PRNGKey(0))
    x = np.random.default_rng(0).random((100, 8)).astype(np.float32)
    scores = pruning.apoz_scores(params, x, batch_size=32)
    acts = mlp_activations(params, jnp.asarray(x))
    want = np.mean(np.asarray(acts[0]) == 0, axis=0)
    np.testing.assert_allclose(scores[0], want, atol=1e-6)


def test_plan_prune_budget_and_floor():
    apoz = [np.array([0.9, 0.8, 0.1, 0.0]), np.array([0.95, 0.2])]
    keep = pruning.plan_prune(apoz, prune_rate=0.5, already_pruned=0,
                              original_hidden=6, prune_total=1.0)
    kept_total = sum(len(k) for k in keep)
    assert kept_total == 6 - 3                   # budget = 0.5*6 = 3
    assert all(len(k) >= 1 for k in keep)        # never empties a layer
    # highest-APoZ neurons went first
    assert 0 not in keep[0] or 1 not in keep[0]


def test_plan_prune_respects_total():
    apoz = [np.linspace(1, 0, 10)]
    keep = pruning.plan_prune(apoz, prune_rate=0.5, already_pruned=4,
                              original_hidden=10, prune_total=0.5)
    # only 1 more allowed (total 5, already 4)
    assert len(keep[0]) == 9


def test_apply_structure_shapes_and_forward():
    params = init_mlp((8, 6, 4, 1), jax.random.PRNGKey(0))
    keep = [np.array([0, 2, 5]), np.array([1, 3])]
    new = pruning.apply_structure(params, keep)
    assert new[0]["w"].shape == (8, 3)
    assert new[1]["w"].shape == (3, 2)
    assert new[2]["w"].shape == (2, 1)
    x = jnp.ones((5, 8))
    y = mlp_forward(new, x)
    assert y.shape == (5,)
    assert not bool(jnp.isnan(y).any())


def test_pruning_dead_neurons_preserves_function():
    """Pruning neurons whose outgoing weights are zero must not change
    the network function."""
    params = list(init_mlp((8, 6, 4, 1), jax.random.PRNGKey(0)))
    dead = [1, 4]
    w1 = params[1]["w"].at[dead, :].set(0.0)
    params[1] = {"w": w1, "b": params[1]["b"]}
    params = tuple(params)
    keep = [np.array([i for i in range(6) if i not in dead]),
            np.arange(4)]
    pruned = pruning.apply_structure(params, keep)
    x = jnp.asarray(np.random.default_rng(0).random((20, 8)), jnp.float32)
    np.testing.assert_allclose(np.asarray(mlp_forward(params, x)),
                               np.asarray(mlp_forward(pruned, x)),
                               rtol=1e-5, atol=1e-6)
