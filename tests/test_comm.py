"""Wire-format subsystem: lossless round-trips, the bytes-never-exceed-
dense invariant, and sparse-apply == dense-apply equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import wire
from repro.core import selection
from repro.core.server import scbf_update
from repro.models.mlp_net import init_mlp

RATES = [0.05, 0.25, 0.5, 0.9]
SHAPES = [(4,), (1, 1), (8, 8), (100, 3), (33, 257), (3, 4, 5), (64,)]


def _masked_array(shape, density, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=shape).astype(dtype)
    keep = rng.random(shape) < density
    return jnp.asarray(np.where(keep, a, 0).astype(dtype))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("density", [0.0, 0.05, 0.5, 1.0])
def test_leaf_roundtrip_exact(shape, density):
    a = _masked_array(shape, density)
    lp = wire.encode_leaf(a)
    back = wire.decode_leaf(lp)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(back))
    assert lp.nbytes <= wire.dense_bytes(a.size, 4)


@pytest.mark.parametrize("codec", ["coo", "bitmap", "dense"])
def test_every_codec_roundtrips(codec):
    a = _masked_array((17, 23), 0.3, seed=len(codec))
    lp = wire.encode_leaf(a, codec=codec)
    assert lp.codec == codec
    np.testing.assert_array_equal(np.asarray(a),
                                  np.asarray(wire.decode_leaf(lp)))


def test_cheapest_bytes_is_min_and_never_above_dense():
    for size in [1, 7, 64, 10_000]:
        for nnz in {0, 1, size // 2, size - 1, size} - {-1}:
            nnz = max(0, nnz)
            codec, b = wire.cheapest_bytes(nnz, size, 4)
            assert b == min(wire.codec_bytes(c, nnz, size, 4)
                            for c in wire.CODECS)
            assert b <= wire.dense_bytes(size, 4)


@pytest.mark.parametrize("rate", RATES)
def test_mlp_payload_roundtrip_and_byte_invariant(rate):
    """Paper pipeline end to end: channel-select an MLP delta, encode,
    decode losslessly, and never pay more than the dense exchange."""
    key = jax.random.PRNGKey(0)
    params = init_mlp((40, 16, 8, 1), key)
    grads = [
        {"w": jax.random.normal(jax.random.fold_in(key, 2 * i), l["w"].shape),
         "b": jax.random.normal(jax.random.fold_in(key, 2 * i + 1),
                                l["b"].shape)}
        for i, l in enumerate(params)]
    masked, masks, _ = selection.select_gradients(grads, rate,
                                                  key=jax.random.PRNGKey(1))
    payload = wire.encode(tuple(masked))
    back = wire.decode(payload)
    for a, b in zip(jax.tree_util.tree_leaves(tuple(masked)),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert payload.nbytes <= payload.dense_nbytes
    # mask-based accounting agrees with the invariant too
    st = selection.UploadStats.from_masks(masks)
    assert st.sparse_bytes <= st.dense_bytes


@pytest.mark.parametrize("rate", RATES)
def test_sparse_apply_equals_dense_apply(rate):
    """scbf_update(payloads=...) == scbf_update(masked_deltas) on random
    MLP deltas — the scatter-add path reproduces the dense tree-sum."""
    key = jax.random.PRNGKey(3)
    params = init_mlp((30, 12, 4, 1), key)
    deltas = []
    for c in range(4):
        g = [{"w": jax.random.normal(jax.random.fold_in(key, 10 * c + i),
                                     l["w"].shape),
              "b": jax.random.normal(jax.random.fold_in(key, 10 * c + 5 + i),
                                     l["b"].shape)}
             for i, l in enumerate(params)]
        masked, _, _ = selection.select_gradients(
            g, rate, key=jax.random.fold_in(key, 100 + c))
        deltas.append(tuple(masked))
    dense_new = scbf_update(params, deltas)
    sparse_new = scbf_update(params, payloads=[wire.encode(d)
                                               for d in deltas])
    for a, b in zip(jax.tree_util.tree_leaves(dense_new),
                    jax.tree_util.tree_leaves(sparse_new)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_scbf_update_rejects_ambiguous_args():
    params = init_mlp((6, 3, 1), jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        scbf_update(params)
    with pytest.raises(ValueError):
        scbf_update(params, [params], payloads=[wire.encode(params)])


def test_apply_payloads_shape_mismatch_raises():
    params = {"w": jnp.zeros((4, 4))}
    bad = wire.encode({"w": jnp.ones((3, 3))})
    with pytest.raises(ValueError):
        wire.apply_payloads(params, [bad])


def test_kernel_compact_buffers_match_wire_coo():
    """The fused select-and-compact kernel emits exactly the (idx, value)
    buffers the COO codec ships for the same mask."""
    from repro.kernels import ops, ref
    g = jax.random.normal(jax.random.PRNGKey(5), (24, 17))
    row, col = ref.channel_norms_ref(g)
    thr = jnp.quantile(row[:, None] + col[None, :], 0.8)
    idx, vals, cnt = ops.select_compact(g, row, col, thr)
    n = int(cnt)
    masked = ref.select_mask_ref(g, row, col, thr)
    lp = wire.encode_leaf(masked, codec="coo")
    np.testing.assert_array_equal(np.asarray(idx[:n]), lp.idx)
    np.testing.assert_allclose(np.asarray(vals[:n]),
                               lp.values.astype(np.float32), rtol=1e-6)
